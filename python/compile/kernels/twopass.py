"""L1 Pallas kernels for the two-pass separable convolution.

Hardware adaptation (DESIGN.md section 3): the paper parallelises the outer
row loop with ``#pragma omp parallel for`` and vectorises the inner column
loop with ``#pragma simd`` on the Xeon Phi's 512-bit VPU. On a TPU-shaped
target the same structure becomes:

* the **grid** plays the role of the parallel outer loop -- one program
  instance per row band (horizontal pass) / column band (vertical pass);
* the unrolled 5-term expression over **whole-row slices** plays the role
  of the SIMD inner loop: the column dimension is vectorised across the
  VPU lanes by construction, no pragma needed;
* ``BlockSpec`` plays the role of the threadblock/L2-tile mapping: it
  names the HBM->VMEM slab each instance owns.

The crucial trick is choosing the grid axis *orthogonal to the convolution
axis* of each pass: the horizontal pass grids over row bands and the
vertical pass grids over column bands, so every BlockSpec tile is disjoint
and no halo exchange is needed at all. (The single-pass kernel cannot do
this -- it convolves both axes -- which is why it needs an ANY-memory-space
input and explicit halo loads; see ``singlepass.py``.)

All kernels compute the *valid* region only; the border-band semantics of
the paper (border pixels pass through) are stitched in L2 (``model.py``)
so the kernels stay pure vector arithmetic, exactly like the paper's inner
loops which also never touch the border.

Kernels are built per (shape, width) at AOT time, run with
``interpret=True`` (the CPU PJRT client cannot execute Mosaic
custom-calls), and lowered into the surrounding jax graph's HLO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default band sizes. 16 rows x C f32: for the paper's largest image
# (C=8748) that is a 16*8752*4 B ~ 560 KB input slab -- comfortably inside
# a TPU core's ~16 MB VMEM with double buffering (DESIGN.md section 9).
DEFAULT_BLOCK_ROWS = 16
DEFAULT_BLOCK_COLS = 128


def _pad_to_multiple(a: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    """Zero-pad ``axis`` of ``a`` up to the next multiple of ``multiple``."""
    n = a.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


# ---------------------------------------------------------------------------
# horizontal pass: grid over disjoint row bands
# ---------------------------------------------------------------------------


def _horiz_kernel(a_ref, k_ref, o_ref, *, width: int, cols: int):
    """One row band: o[(br, C-2h)] = sum_v a[:, v:...] * k[v], unrolled."""
    x = a_ref[...]
    valid = cols - (width - 1)
    # Unrolled: python-level sum of `width` shifted whole-row slices. This
    # is the Pallas analogue of the paper's hand-unrolled 5-term expression
    # (Opt-1) *and* its #pragma simd (Opt-2) at once: each term is a full
    # vector operation over the lanes of the column dimension.
    acc = x[:, 0:valid] * k_ref[0]
    for v in range(1, width):
        acc = acc + x[:, v : valid + v] * k_ref[v]
    o_ref[...] = acc


def horiz_pass_valid(
    a: jnp.ndarray,
    k: jnp.ndarray,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jnp.ndarray:
    """Horizontal 1-D convolution, valid columns: (R, C) -> (R, C-2h).

    Grids over row bands of ``block_rows``; R is padded up to a multiple
    and the pad rows cropped from the result (they are garbage, never
    read by the caller).
    """
    r, c = a.shape
    width = int(k.shape[0])
    ap = _pad_to_multiple(a, 0, block_rows)
    rp = ap.shape[0]
    out = pl.pallas_call(
        functools.partial(_horiz_kernel, width=width, cols=c),
        grid=(rp // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((width,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, c - (width - 1)), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, c - (width - 1)), a.dtype),
        interpret=interpret,
    )(ap, k)
    return out[:r, :]


# ---------------------------------------------------------------------------
# vertical pass: grid over disjoint column bands
# ---------------------------------------------------------------------------


def _vert_kernel(a_ref, k_ref, o_ref, *, width: int, rows: int):
    """One column band: o[(R-2h, bc)] = sum_u a[u:..., :] * k[u], unrolled."""
    x = a_ref[...]
    valid = rows - (width - 1)
    acc = x[0:valid, :] * k_ref[0]
    for u in range(1, width):
        acc = acc + x[u : valid + u, :] * k_ref[u]
    o_ref[...] = acc


def vert_pass_valid(
    a: jnp.ndarray,
    k: jnp.ndarray,
    *,
    block_cols: int = DEFAULT_BLOCK_COLS,
    interpret: bool = True,
) -> jnp.ndarray:
    """Vertical 1-D convolution, valid rows: (R, C) -> (R-2h, C)."""
    r, c = a.shape
    width = int(k.shape[0])
    ap = _pad_to_multiple(a, 1, block_cols)
    cp = ap.shape[1]
    out = pl.pallas_call(
        functools.partial(_vert_kernel, width=width, rows=r),
        grid=(cp // block_cols,),
        in_specs=[
            pl.BlockSpec((r, block_cols), lambda i: (0, i)),
            pl.BlockSpec((width,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((r - (width - 1), block_cols), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((r - (width - 1), cp), a.dtype),
        interpret=interpret,
    )(ap, k)
    return out[:, :c]


# ---------------------------------------------------------------------------
# fused whole-array variant (perf-ablation subject; no grid)
# ---------------------------------------------------------------------------


def _fused_kernel(a_ref, k_ref, o_ref, *, width: int, rows: int, cols: int):
    """Both passes in one kernel instance over the whole plane.

    Computes the final interior directly, reproducing the paper's border
    semantics internally: the vertical pass reads the horizontally
    *unfiltered* source in the border rows (DESIGN.md section 4).
    """
    h = width // 2
    x = a_ref[...]
    vc = cols - (width - 1)
    # horizontal valid over ALL rows
    hz = x[:, 0:vc] * k_ref[0]
    for v in range(1, width):
        hz = hz + x[:, v : vc + v] * k_ref[v]
    # b = source with interior rows replaced by the horizontal result
    b = jnp.concatenate([x[:h, h : cols - h], hz[h : rows - h, :], x[rows - h :, h : cols - h]], axis=0)
    # vertical valid over the interior columns
    vr = rows - (width - 1)
    vt = b[0:vr, :] * k_ref[0]
    for u in range(1, width):
        vt = vt + b[u : vr + u, :] * k_ref[u]
    o_ref[...] = vt


def twopass_valid_fused(
    a: jnp.ndarray, k: jnp.ndarray, *, interpret: bool = True
) -> jnp.ndarray:
    """Fused two-pass interior: (R, C) -> (R-2h, C-2h), single grid step."""
    r, c = a.shape
    width = int(k.shape[0])
    return pl.pallas_call(
        functools.partial(_fused_kernel, width=width, rows=r, cols=c),
        out_shape=jax.ShapeDtypeStruct((r - (width - 1), c - (width - 1)), a.dtype),
        interpret=interpret,
    )(a, k)


# ---------------------------------------------------------------------------
# naive (non-unrolled) variant -- the ladder's Opt-3-without-unroll analogue
# ---------------------------------------------------------------------------


def _horiz_kernel_naive(a_ref, k_ref, o_ref, *, width: int, cols: int):
    """fori_loop over kernel taps: the structural analogue of the paper's
    *non*-unrolled loop, kept for the optimisation-ladder ablation."""
    x = a_ref[...]
    valid = cols - (width - 1)

    def body(v, acc):
        return acc + jax.lax.dynamic_slice_in_dim(x, v, valid, axis=1) * k_ref[v]

    o_ref[...] = jax.lax.fori_loop(
        0, width, body, jnp.zeros((x.shape[0], valid), x.dtype)
    )


def horiz_pass_valid_naive(
    a: jnp.ndarray,
    k: jnp.ndarray,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jnp.ndarray:
    """Naive-loop horizontal pass (same numerics, looped taps)."""
    r, c = a.shape
    width = int(k.shape[0])
    ap = _pad_to_multiple(a, 0, block_rows)
    rp = ap.shape[0]
    out = pl.pallas_call(
        functools.partial(_horiz_kernel_naive, width=width, cols=c),
        grid=(rp // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((width,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, c - (width - 1)), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, c - (width - 1)), a.dtype),
        interpret=interpret,
    )(ap, k)
    return out[:r, :]
