"""Pure-jnp correctness oracles for the convolution kernels.

These implement, in the simplest possible slicing form, the exact semantics
fixed in DESIGN.md section 4 (the paper's algorithms from Listings 1 & 2):

* ``single-pass``  -- direct WxW convolution: every interior pixel is the
  25-tap (for W=5) weighted sum of its neighbourhood; border pixels pass
  through unchanged.
* ``two-pass``     -- separable convolution: a horizontal 1-D pass writes
  the interior of an auxiliary array B (B equals the source elsewhere),
  then a vertical 1-D pass over B writes the interior of the output.

Every Pallas kernel variant and every native Rust engine is tested against
these oracles; the oracles themselves are validated against a brute-force
python-loop implementation in the test-suite.

All functions operate on a single plane ``a`` of shape (R, C), f32, with a
separable kernel vector ``k`` of odd width W (paper: W=5, Gaussian).
``h = W // 2`` is the halo.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def gaussian_kernel(width: int = 5, sigma: float = 1.0) -> jnp.ndarray:
    """Normalised 1-D Gaussian convolution vector of odd ``width``.

    The paper uses a separable Gaussian 5x5 kernel; K[i][j] = k[i]*k[j].
    """
    if width % 2 != 1:
        raise ValueError(f"kernel width must be odd, got {width}")
    h = width // 2
    x = np.arange(-h, h + 1, dtype=np.float64)
    k = np.exp(-(x**2) / (2.0 * sigma**2))
    k /= k.sum()
    return jnp.asarray(k, dtype=jnp.float32)


def outer_kernel(k: jnp.ndarray) -> jnp.ndarray:
    """K[i][j] = k[i] * k[j] -- the 2-D kernel of a separable vector."""
    return k[:, None] * k[None, :]


# ---------------------------------------------------------------------------
# "valid" building blocks: convolution restricted to fully-covered outputs
# ---------------------------------------------------------------------------


def horiz_valid(a: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Horizontal 1-D convolution, valid columns only: (R, C) -> (R, C-2h)."""
    w = k.shape[0]
    c = a.shape[1]
    return sum(a[:, v : c - (w - 1) + v] * k[v] for v in range(w))


def vert_valid(a: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Vertical 1-D convolution, valid rows only: (R, C) -> (R-2h, C)."""
    w = k.shape[0]
    r = a.shape[0]
    return sum(a[u : r - (w - 1) + u, :] * k[u] for u in range(w))


def singlepass_valid(a: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Direct WxW convolution, valid region only: (R, C) -> (R-2h, C-2h)."""
    w = k.shape[0]
    r, c = a.shape
    kk = outer_kernel(k)
    return sum(
        a[u : r - (w - 1) + u, v : c - (w - 1) + v] * kk[u, v]
        for u in range(w)
        for v in range(w)
    )


# ---------------------------------------------------------------------------
# full-plane oracles with the paper's border semantics
# ---------------------------------------------------------------------------


def singlepass_ref(a: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Single-pass convolution of one plane; border rows/cols = source.

    This is the no-copy-back output B of the paper's section 7. The
    copy-back variant produces the same pixels (B is copied over A), so the
    oracle is shared; copy-back only matters for *timing*.
    """
    h = k.shape[0] // 2
    return a.at[h:-h, h:-h].set(singlepass_valid(a, k))


def twopass_ref(a: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Two-pass separable convolution of one plane (paper Listing 1).

    Pass 1 (horizontal) writes only the interior of B; B equals A on the
    border band, exactly as the paper's loops ``for i in 2..rows-2``.
    Pass 2 (vertical) reads B -- including the horizontally-unfiltered
    border rows -- and writes the interior of the output.
    """
    h = k.shape[0] // 2
    b = a.at[h:-h, h:-h].set(horiz_valid(a, k)[h:-h, :])
    return a.at[h:-h, h:-h].set(vert_valid(b, k)[:, h:-h])


# ---------------------------------------------------------------------------
# multi-plane / layout helpers (mirror rust/src/image + models/agglomerate)
# ---------------------------------------------------------------------------


def per_plane(fn, img: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Apply a single-plane oracle to every plane of ``img`` (P, R, C)."""
    return jnp.stack([fn(img[p], k) for p in range(img.shape[0])], axis=0)


def agglomerate(img: jnp.ndarray) -> jnp.ndarray:
    """(P, R, C) -> (R, P*C): the paper's 3RxC task-agglomeration layout.

    "images with the width of 3 times the width of the original images,
    meaning that each row includes information for all 3 colour planes."
    """
    return jnp.concatenate([img[p] for p in range(img.shape[0])], axis=1)


def deagglomerate(wide: jnp.ndarray, planes: int) -> jnp.ndarray:
    """(R, P*C) -> (P, R, C): inverse of :func:`agglomerate`."""
    c = wide.shape[1] // planes
    return jnp.stack([wide[:, p * c : (p + 1) * c] for p in range(planes)], 0)


def deep_interior(a: jnp.ndarray, k_width: int = 5) -> jnp.ndarray:
    """Region where single-pass and two-pass agree exactly.

    Two-pass reads horizontally-unfiltered rows within ``h`` of the border
    band, so equality only holds 2h pixels in (DESIGN.md section 4).
    """
    d = 2 * (k_width // 2)
    return a[..., d:-d, d:-d]
