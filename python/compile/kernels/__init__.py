"""L1 Pallas kernels + pure-jnp oracles for the phi-conv reproduction."""

from . import ref, singlepass, twopass  # noqa: F401
