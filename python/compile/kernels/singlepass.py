"""L1 Pallas kernels for the single-pass (direct WxW) convolution.

The single-pass algorithm convolves both axes at once, so -- unlike the
two-pass kernels in ``twopass.py`` -- no grid axis is orthogonal to the
convolution: every row band needs a 2h-row halo from its neighbours.
Pallas ``BlockSpec`` index maps address ``index * block_shape`` offsets and
cannot express overlapping tiles, so the gridded variant keeps the input in
``pl.ANY`` memory space (no automatic HBM->VMEM copy) and each program
instance explicitly loads its haloed slab with a dynamic row slice. This is
the TPU analogue of the paper's threads reading their neighbours' boundary
rows through the shared L2/GDDR5.

Variants (all tested against ``ref.singlepass_valid``):

* ``singlepass_valid_gridded``  -- grid over output row bands, ANY-space
  input + explicit halo load; the production variant.
* ``singlepass_valid_whole``    -- single grid step over the whole plane;
  perf-ablation subject and fallback for tiny planes.
* ``singlepass_valid_naive``    -- 25-tap ``fori_loop`` accumulation,
  mirroring the paper's non-unrolled naive code (Opt-0 rung).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 16


def _unrolled_taps(slab, k_ref, width: int, out_rows: int, out_cols: int):
    """Fully-unrolled W*W-tap weighted sum (the paper's Eq. 3 / Opt-1)."""
    acc = None
    for u in range(width):
        for v in range(width):
            term = slab[u : u + out_rows, v : v + out_cols] * (k_ref[u] * k_ref[v])
            acc = term if acc is None else acc + term
    return acc


# ---------------------------------------------------------------------------
# gridded variant: ANY-space input, explicit halo loads
# ---------------------------------------------------------------------------


def _gridded_kernel(a_ref, k_ref, o_ref, *, width: int, block_rows: int, cols: int):
    i = pl.program_id(0)
    # Haloed slab: block_rows output rows need block_rows + 2h input rows.
    slab = a_ref[pl.ds(i * block_rows, block_rows + width - 1), :]
    o_ref[...] = _unrolled_taps(slab, k_ref, width, block_rows, cols - (width - 1))


def singlepass_valid_gridded(
    a: jnp.ndarray,
    k: jnp.ndarray,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jnp.ndarray:
    """Direct convolution, valid region: (R, C) -> (R-2h, C-2h)."""
    r, c = a.shape
    width = int(k.shape[0])
    out_rows = r - (width - 1)
    # Pad so the output row count divides the band size; pad rows of the
    # *input* feed only garbage output rows which are cropped below.
    pad = (-out_rows) % block_rows
    ap = jnp.pad(a, ((0, pad), (0, 0))) if pad else a
    out = pl.pallas_call(
        functools.partial(
            _gridded_kernel, width=width, block_rows=block_rows, cols=c
        ),
        grid=((out_rows + pad) // block_rows,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((width,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, c - (width - 1)), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((out_rows + pad, c - (width - 1)), a.dtype),
        interpret=interpret,
    )(ap, k)
    return out[:out_rows, :]


# ---------------------------------------------------------------------------
# whole-array variant (single grid step)
# ---------------------------------------------------------------------------


def _whole_kernel(a_ref, k_ref, o_ref, *, width: int, rows: int, cols: int):
    x = a_ref[...]
    o_ref[...] = _unrolled_taps(
        x, k_ref, width, rows - (width - 1), cols - (width - 1)
    )


def singlepass_valid_whole(
    a: jnp.ndarray, k: jnp.ndarray, *, interpret: bool = True
) -> jnp.ndarray:
    """Direct convolution in one grid step: (R, C) -> (R-2h, C-2h)."""
    r, c = a.shape
    width = int(k.shape[0])
    return pl.pallas_call(
        functools.partial(_whole_kernel, width=width, rows=r, cols=c),
        out_shape=jax.ShapeDtypeStruct((r - (width - 1), c - (width - 1)), a.dtype),
        interpret=interpret,
    )(a, k)


# ---------------------------------------------------------------------------
# naive variant: looped taps (the ladder's Opt-0 structural analogue)
# ---------------------------------------------------------------------------


def _naive_kernel(a_ref, k_ref, o_ref, *, width: int, rows: int, cols: int):
    """W*W fori_loop of dynamic slices -- deliberately un-unrolled.

    Structurally mirrors the paper's naive 4-nested-loop code compiled with
    ``-no-vec``: the tap loop is a real (lowered) loop, not W*W fused
    vector statements.
    """
    x = a_ref[...]
    out_rows = rows - (width - 1)
    out_cols = cols - (width - 1)

    def body(t, acc):
        u, v = t // width, t % width
        sl = jax.lax.dynamic_slice(x, (u, v), (out_rows, out_cols))
        return acc + sl * (k_ref[u] * k_ref[v])

    o_ref[...] = jax.lax.fori_loop(
        0, width * width, body, jnp.zeros((out_rows, out_cols), x.dtype)
    )


def singlepass_valid_naive(
    a: jnp.ndarray, k: jnp.ndarray, *, interpret: bool = True
) -> jnp.ndarray:
    """Naive looped direct convolution: (R, C) -> (R-2h, C-2h)."""
    r, c = a.shape
    width = int(k.shape[0])
    return pl.pallas_call(
        functools.partial(_naive_kernel, width=width, rows=r, cols=c),
        out_shape=jax.ShapeDtypeStruct((r - (width - 1), c - (width - 1)), a.dtype),
        interpret=interpret,
    )(a, k)
