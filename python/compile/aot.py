"""AOT compiler: lower every L2 graph to HLO text + a JSON manifest.

This is the only bridge between the Python build path and the Rust runtime
(DESIGN.md section 2). Each entry point in ``model.py`` is jitted, lowered
to StableHLO, converted to an XlaComputation and dumped as HLO **text**:
the image's xla_extension 0.5.1 rejects serialized HloModuleProto from
jax>=0.5 (64-bit instruction ids), while the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

``artifacts/manifest.json`` records, for every artifact, the input/output
shapes and dtypes plus a role tag so the Rust artifact registry
(rust/src/runtime/manifest.rs) can load and validate them without
hard-coding shapes.

Usage:
    python -m compile.aot --out-dir ../artifacts [--sizes 288,576,1152]
                          [--tile-rows 64] [--planes 3] [--width 5]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


@dataclass
class Entry:
    """One AOT artifact: a jax callable and its example input specs."""

    name: str
    fn: Callable
    in_specs: list[jax.ShapeDtypeStruct]
    role: str  # "full" | "agg" | "tile" | "pyramid" | "ablation"
    algorithm: str  # "twopass" | "singlepass"
    variant: str
    meta: dict = field(default_factory=dict)

    def lower(self):
        return jax.jit(self.fn).lower(*self.in_specs)


def f32(*shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_entries(
    sizes: list[int], planes: int, width: int, tile_rows: int, ablation_size: int
) -> list[Entry]:
    """The full artifact set for one configuration."""
    h = width // 2
    k = f32(width)
    entries: list[Entry] = []

    for n in sizes:
        entries += [
            # Full-image two-pass ships the *gridded* lowering (disjoint-axis
            # BlockSpecs). §Perf iteration 2 (EXPERIMENTS.md) compared the
            # fused whole-plane kernel through CPU PJRT: the difference was
            # within run-to-run noise (<5 %), so the gridded shape — the one
            # that scales past VMEM on a real TPU — stays the default; the
            # fused kernel remains an ablation artifact.
            Entry(
                f"twopass_p{planes}_{n}",
                lambda img, kk: model.conv_image_twopass(img, kk),
                [f32(planes, n, n), k],
                "full",
                "twopass",
                "gridded",
                {"rows": n, "cols": n, "planes": planes},
            ),
            Entry(
                f"singlepass_p{planes}_{n}",
                lambda img, kk: model.conv_image_singlepass(img, kk),
                [f32(planes, n, n), k],
                "full",
                "singlepass",
                "gridded",
                {"rows": n, "cols": n, "planes": planes},
            ),
            Entry(
                f"twopass_agg_{n}",
                lambda img, kk: model.conv_image_twopass_agglomerated(img, kk),
                [f32(planes, n, n), k],
                "agg",
                "twopass",
                "gridded",
                {"rows": n, "cols": n, "planes": planes},
            ),
            # Row-band tile kernels: what the execution models dispatch.
            Entry(
                f"horiz_tile_{tile_rows}x{n}",
                model.horiz_tile,
                [f32(tile_rows, n), k],
                "tile",
                "twopass",
                "horiz",
                {"tile_rows": tile_rows, "cols": n, "halo": 0},
            ),
            Entry(
                f"vert_tile_{tile_rows}x{n}",
                model.vert_tile,
                [f32(tile_rows + 2 * h, n), k],
                "tile",
                "twopass",
                "vert",
                {"tile_rows": tile_rows, "cols": n, "halo": h},
            ),
            Entry(
                f"single_tile_{tile_rows}x{n}",
                model.single_tile,
                [f32(tile_rows + 2 * h, n), k],
                "tile",
                "singlepass",
                "whole",
                {"tile_rows": tile_rows, "cols": n, "halo": h},
            ),
        ]

    # Ablation rungs of the optimisation ladder, lowered at one small size
    # so Rust integration tests can cross-validate every variant via PJRT.
    n = ablation_size
    for variant in ("naive", "fused"):
        entries.append(
            Entry(
                f"twopass_{variant}_{n}",
                lambda img, kk, v=variant: model.conv_image_twopass(img, kk, variant=v),
                [f32(planes, n, n), k],
                "ablation",
                "twopass",
                variant,
                {"rows": n, "cols": n, "planes": planes},
            )
        )
    for variant in ("naive", "whole"):
        entries.append(
            Entry(
                f"singlepass_{variant}_{n}",
                lambda img, kk, v=variant: model.conv_image_singlepass(
                    img, kk, variant=v
                ),
                [f32(planes, n, n), k],
                "ablation",
                "singlepass",
                variant,
                {"rows": n, "cols": n, "planes": planes},
            )
        )

    # Stereo front end: Gaussian pyramid at the largest size.
    nmax = max(sizes)
    entries.append(
        Entry(
            f"pyramid_{nmax}",
            lambda img, kk: model.gaussian_pyramid(img, kk, levels=3),
            [f32(planes, nmax, nmax), k],
            "pyramid",
            "twopass",
            "gridded",
            {"rows": nmax, "cols": nmax, "planes": planes, "levels": 3},
        )
    )
    return entries


def _spec_json(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def emit(entries: list[Entry], out_dir: str, width: int) -> dict:
    """Lower every entry, write <name>.hlo.txt, return the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "format": "hlo-text",
        "kernel_width": width,
        "gaussian_sigma": 1.0,
        "artifacts": [],
    }
    for e in entries:
        lowered = e.lower()
        text = to_hlo_text(lowered)
        fname = f"{e.name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(e.fn, *e.in_specs)
        outs = jax.tree_util.tree_leaves(out_shapes)
        manifest["artifacts"].append(
            {
                "name": e.name,
                "file": fname,
                "role": e.role,
                "algorithm": e.algorithm,
                "variant": e.variant,
                "inputs": [_spec_json(s) for s in e.in_specs],
                "outputs": [_spec_json(s) for s in outs],
                "meta": e.meta,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "bytes": len(text),
            }
        )
        print(f"  {e.name:32s} -> {fname} ({len(text)//1024} KiB)")
    # Reference Gaussian kernel values so Rust can verify its own generator.
    manifest["kernel_values"] = [
        float(x) for x in ref.gaussian_kernel(width, 1.0).tolist()
    ]
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--sizes", default="288,576,1152")
    p.add_argument("--tile-rows", type=int, default=64)
    p.add_argument("--planes", type=int, default=3)
    p.add_argument("--width", type=int, default=5)
    p.add_argument("--ablation-size", type=int, default=288)
    args = p.parse_args()
    sizes = [int(s) for s in args.sizes.split(",")]
    entries = build_entries(
        sizes, args.planes, args.width, args.tile_rows, args.ablation_size
    )
    print(f"lowering {len(entries)} artifacts to {args.out_dir}")
    m = emit(entries, args.out_dir, args.width)
    total = sum(a["bytes"] for a in m["artifacts"])
    print(f"wrote {len(m['artifacts'])} artifacts, {total//1024} KiB total")


if __name__ == "__main__":
    main()
