"""L2: jax compute graphs for the phi-conv reproduction.

This layer composes the L1 Pallas kernels (``kernels/``) into the whole
operations the paper times -- full 3-plane image convolutions under both
algorithms, the 3RxC task-agglomerated layout, the row-band tile kernels
the Rust coordinator schedules, and the Gaussian-pyramid graph for the
stereo-matching example that motivates the paper.

Everything here is build-time only: ``aot.py`` lowers these functions to
HLO text artifacts which the Rust runtime loads through PJRT. Python never
runs on the request path.

Border semantics are stitched here (kernels compute valid regions only);
see DESIGN.md section 4 and ``kernels/ref.py``.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import singlepass as sp
from .kernels import twopass as tp

Variant = str  # "gridded" | "whole" | "naive" | "fused"


# ---------------------------------------------------------------------------
# single-plane full convolutions (valid kernels + border stitching)
# ---------------------------------------------------------------------------


def twopass_plane(
    a: jnp.ndarray, k: jnp.ndarray, *, variant: Variant = "gridded"
) -> jnp.ndarray:
    """Two-pass separable convolution of one (R, C) plane, paper semantics.

    variant:
      * ``gridded`` -- horizontal pass grids over row bands, vertical pass
        over column bands (production; disjoint BlockSpecs, no halo).
      * ``fused``   -- both passes in one whole-plane kernel instance.
      * ``naive``   -- looped-tap horizontal pass (ladder ablation rung).
    """
    h = int(k.shape[0]) // 2
    if variant == "fused":
        interior = tp.twopass_valid_fused(a, k)
        return a.at[h:-h, h:-h].set(interior)
    horiz = tp.horiz_pass_valid_naive if variant == "naive" else tp.horiz_pass_valid
    b = a.at[h:-h, h:-h].set(horiz(a, k)[h:-h, :])
    return a.at[h:-h, h:-h].set(tp.vert_pass_valid(b, k)[:, h:-h])


def singlepass_plane(
    a: jnp.ndarray, k: jnp.ndarray, *, variant: Variant = "gridded"
) -> jnp.ndarray:
    """Single-pass direct convolution of one (R, C) plane, paper semantics.

    Produces the no-copy-back output (section 7 of the paper); the
    copy-back variant has identical pixels and is a timing-only distinction
    modelled in L3.
    """
    h = int(k.shape[0]) // 2
    fn = {
        "gridded": sp.singlepass_valid_gridded,
        "whole": sp.singlepass_valid_whole,
        "naive": sp.singlepass_valid_naive,
    }[variant]
    return a.at[h:-h, h:-h].set(fn(a, k))


# ---------------------------------------------------------------------------
# multi-plane images (P, R, C) -- the paper's 3 colour planes
# ---------------------------------------------------------------------------


def _per_plane(fn: Callable, img: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    # planes is small and static (3): unrolled python loop, one kernel
    # instantiation per plane, exactly like the paper's `conv` wrapper that
    # calls twoPassConv per planeId (Listing 1).
    return jnp.stack([fn(img[p], k) for p in range(img.shape[0])], axis=0)


def conv_image_twopass(
    img: jnp.ndarray, k: jnp.ndarray, *, variant: Variant = "gridded"
) -> jnp.ndarray:
    """(P, R, C) two-pass convolution, plane-sequential (the RxC layout)."""
    return _per_plane(functools.partial(twopass_plane, variant=variant), img, k)


def conv_image_singlepass(
    img: jnp.ndarray, k: jnp.ndarray, *, variant: Variant = "gridded"
) -> jnp.ndarray:
    """(P, R, C) single-pass convolution, plane-sequential."""
    return _per_plane(functools.partial(singlepass_plane, variant=variant), img, k)


def conv_image_twopass_agglomerated(
    img: jnp.ndarray, k: jnp.ndarray, *, variant: Variant = "gridded"
) -> jnp.ndarray:
    """Two-pass in the paper's 3RxC task-agglomeration layout.

    Planes are concatenated along columns ((P,R,C) -> (R, P*C)) so one
    parallel sweep covers all planes; task size triples, per-task overhead
    amortises to a third (paper section 6, Fig. 3). The horizontal pass
    smears 2h columns across plane seams -- the paper accepts the same
    artefact ("what happens at the far edges are ignored"); tests therefore
    compare agglomerated output away from seams only.
    """
    planes = img.shape[0]
    wide = jnp.concatenate([img[p] for p in range(planes)], axis=1)
    out = twopass_plane(wide, k, variant=variant)
    c = img.shape[2]
    return jnp.stack([out[:, p * c : (p + 1) * c] for p in range(planes)], axis=0)


# ---------------------------------------------------------------------------
# row-band tile kernels -- what the Rust execution models schedule via PJRT
# ---------------------------------------------------------------------------


def horiz_tile(slab: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """(T, C) row band -> (T, C-2h) horizontally-convolved band."""
    return tp.horiz_pass_valid(slab, k)


def vert_tile(slab: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """(T+2h, C) haloed band -> (T, C) vertically-convolved band."""
    return tp.vert_pass_valid(slab, k)


def single_tile(slab: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """(T+2h, C) haloed band -> (T, C-2h) directly-convolved band."""
    return sp.singlepass_valid_whole(slab, k)


# ---------------------------------------------------------------------------
# stereo-matching front end: Gaussian pyramid (the paper's motivating app)
# ---------------------------------------------------------------------------


def gaussian_pyramid(
    img: jnp.ndarray, k: jnp.ndarray, *, levels: int = 3
) -> tuple[jnp.ndarray, ...]:
    """Blur + 2x decimate ``levels-1`` times: the conv+scale hot loop of the
    stereo matcher the paper's kernels were taken from.

    Returns ``levels`` images: (P,R,C), (P,R/2,C/2), ...
    """
    out = [img]
    cur = img
    for _ in range(levels - 1):
        blurred = conv_image_twopass(cur, k)
        cur = blurred[:, ::2, ::2]
        out.append(cur)
    return tuple(out)
