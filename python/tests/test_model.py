"""L2 graphs (model.py) vs oracles: plane composition, agglomeration,
tiles, pyramid -- and the executable round-trip of the AOT artifacts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

ATOL = 1e-5


class TestFullImages:
    @pytest.mark.parametrize("variant", ["gridded", "fused", "naive"])
    def test_twopass_variants(self, image, k5, variant):
        got = np.asarray(model.conv_image_twopass(image, k5, variant=variant))
        want = np.asarray(ref.per_plane(ref.twopass_ref, image, k5))
        np.testing.assert_allclose(got, want, atol=ATOL)

    @pytest.mark.parametrize("variant", ["gridded", "whole", "naive"])
    def test_singlepass_variants(self, image, k5, variant):
        got = np.asarray(model.conv_image_singlepass(image, k5, variant=variant))
        want = np.asarray(ref.per_plane(ref.singlepass_ref, image, k5))
        np.testing.assert_allclose(got, want, atol=ATOL)

    def test_jit_matches_eager(self, image, k5):
        eager = model.conv_image_twopass(image, k5)
        jitted = jax.jit(lambda i, k: model.conv_image_twopass(i, k))(image, k5)
        np.testing.assert_allclose(np.asarray(eager), np.asarray(jitted), atol=1e-6)

    def test_output_shape_preserved(self, image, k5):
        assert model.conv_image_twopass(image, k5).shape == image.shape
        assert model.conv_image_singlepass(image, k5).shape == image.shape


class TestAgglomeration:
    def test_matches_per_plane_away_from_seams(self, image, k5):
        """3RxC output == RxC output except the 2h-column seam bands (the
        paper accepts the seam artefact; DESIGN.md section 4)."""
        agg = np.asarray(model.conv_image_twopass_agglomerated(image, k5))
        per = np.asarray(model.conv_image_twopass(image, k5))
        np.testing.assert_allclose(agg[:, :, 4:-4], per[:, :, 4:-4], atol=ATOL)

    def test_shape_roundtrip(self, image, k5):
        agg = model.conv_image_twopass_agglomerated(image, k5)
        assert agg.shape == image.shape

    def test_interior_plane_seams_differ(self, image, k5):
        """The seam bands must actually differ -- guards against silently
        implementing per-plane under the agglomerated name.

        In the 3RxC layout plane 1's columns 0..2h-1 are *interior* of the
        wide image (convolved, reading plane 0 pixels across the seam),
        whereas per-plane they are border pass-through."""
        agg = np.asarray(model.conv_image_twopass_agglomerated(image, k5))
        per = np.asarray(model.conv_image_twopass(image, k5))
        assert not np.allclose(agg[1, 4:-4, 0:2], per[1, 4:-4, 0:2], atol=1e-6)
        # plane 0's right seam likewise reads plane 1 pixels
        assert not np.allclose(agg[0, 4:-4, -2:], per[0, 4:-4, -2:], atol=1e-6)


class TestTiles:
    """The row-band tile contracts used by the Rust execution models:
    stitching convolved tiles reproduces the full-plane result."""

    def test_horiz_tile_stitching(self, plane, k5):
        r = plane.shape[0]
        t = 8
        bands = [model.horiz_tile(plane[i : i + t, :], k5) for i in range(0, r, t)]
        got = np.asarray(jnp.concatenate(bands, axis=0))
        np.testing.assert_allclose(got, np.asarray(ref.horiz_valid(plane, k5)), atol=ATOL)

    def test_vert_tile_stitching(self, plane, k5):
        """Haloed vertical tiles: band i covers output rows [i*t, i*t+t)."""
        r = plane.shape[0]
        t = 9  # (40-4)/9 = 4 bands
        bands = [
            model.vert_tile(plane[i : i + t + 4, :], k5) for i in range(0, r - 4, t)
        ]
        got = np.asarray(jnp.concatenate(bands, axis=0))
        np.testing.assert_allclose(got, np.asarray(ref.vert_valid(plane, k5)), atol=ATOL)

    def test_single_tile_stitching(self, plane, k5):
        r = plane.shape[0]
        t = 12  # (40-4)/12 = 3 bands
        bands = [
            model.single_tile(plane[i : i + t + 4, :], k5) for i in range(0, r - 4, t)
        ]
        got = np.asarray(jnp.concatenate(bands, axis=0))
        np.testing.assert_allclose(
            got, np.asarray(ref.singlepass_valid(plane, k5)), atol=ATOL
        )


class TestPyramid:
    def test_levels_and_shapes(self, image, k5):
        p = model.gaussian_pyramid(image, k5, levels=3)
        assert len(p) == 3
        assert p[0].shape == (3, 40, 36)
        assert p[1].shape == (3, 20, 18)
        assert p[2].shape == (3, 10, 9)

    def test_level1_is_blur_then_decimate(self, image, k5):
        p = model.gaussian_pyramid(image, k5, levels=2)
        want = model.conv_image_twopass(image, k5)[:, ::2, ::2]
        np.testing.assert_allclose(np.asarray(p[1]), np.asarray(want), atol=1e-6)

    def test_pyramid_preserves_mean_roughly(self, k5):
        """Blur preserves mean; decimation of a smooth field keeps it close."""
        a = jnp.ones((3, 64, 64), jnp.float32) * 7.5
        p = model.gaussian_pyramid(a, k5, levels=3)
        for lvl in p:
            np.testing.assert_allclose(np.asarray(lvl), 7.5, atol=1e-4)
