"""Pallas kernel variants vs the pure-jnp oracles -- the CORE correctness
signal of the L1 layer (system README: kernel-vs-ref allclose)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels import singlepass as sp
from compile.kernels import twopass as tp


ATOL = 1e-5


class TestHorizPass:
    def test_matches_ref(self, plane, k5):
        np.testing.assert_allclose(
            np.asarray(tp.horiz_pass_valid(plane, k5)),
            np.asarray(ref.horiz_valid(plane, k5)),
            atol=ATOL,
        )

    def test_naive_variant_matches(self, plane, k5):
        np.testing.assert_allclose(
            np.asarray(tp.horiz_pass_valid_naive(plane, k5)),
            np.asarray(ref.horiz_valid(plane, k5)),
            atol=ATOL,
        )

    @pytest.mark.parametrize("block_rows", [1, 4, 16, 64])
    def test_block_rows_invariance(self, plane, k5, block_rows):
        """Any row-band size gives identical pixels (padding is cropped)."""
        np.testing.assert_allclose(
            np.asarray(tp.horiz_pass_valid(plane, k5, block_rows=block_rows)),
            np.asarray(ref.horiz_valid(plane, k5)),
            atol=ATOL,
        )

    def test_rows_not_multiple_of_block(self, rng, k5):
        """41 rows with block 16 forces the pad+crop path."""
        a = jnp.asarray(rng.standard_normal((41, 30)), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(tp.horiz_pass_valid(a, k5, block_rows=16)),
            np.asarray(ref.horiz_valid(a, k5)),
            atol=ATOL,
        )


class TestVertPass:
    def test_matches_ref(self, plane, k5):
        np.testing.assert_allclose(
            np.asarray(tp.vert_pass_valid(plane, k5)),
            np.asarray(ref.vert_valid(plane, k5)),
            atol=ATOL,
        )

    @pytest.mark.parametrize("block_cols", [1, 8, 32, 128])
    def test_block_cols_invariance(self, plane, k5, block_cols):
        np.testing.assert_allclose(
            np.asarray(tp.vert_pass_valid(plane, k5, block_cols=block_cols)),
            np.asarray(ref.vert_valid(plane, k5)),
            atol=ATOL,
        )

    def test_cols_not_multiple_of_block(self, rng, k5):
        a = jnp.asarray(rng.standard_normal((30, 41)), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(tp.vert_pass_valid(a, k5, block_cols=16)),
            np.asarray(ref.vert_valid(a, k5)),
            atol=ATOL,
        )


class TestFusedTwoPass:
    def test_matches_composed_ref(self, plane, k5):
        """Fused kernel == the full twopass_ref interior."""
        got = np.asarray(tp.twopass_valid_fused(plane, k5))
        want = np.asarray(ref.twopass_ref(plane, k5))[2:-2, 2:-2]
        np.testing.assert_allclose(got, want, atol=ATOL)


class TestSinglePass:
    def test_gridded_matches_ref(self, plane, k5):
        np.testing.assert_allclose(
            np.asarray(sp.singlepass_valid_gridded(plane, k5)),
            np.asarray(ref.singlepass_valid(plane, k5)),
            atol=ATOL,
        )

    def test_whole_matches_ref(self, plane, k5):
        np.testing.assert_allclose(
            np.asarray(sp.singlepass_valid_whole(plane, k5)),
            np.asarray(ref.singlepass_valid(plane, k5)),
            atol=ATOL,
        )

    def test_naive_matches_ref(self, plane, k5):
        np.testing.assert_allclose(
            np.asarray(sp.singlepass_valid_naive(plane, k5)),
            np.asarray(ref.singlepass_valid(plane, k5)),
            atol=ATOL,
        )

    @pytest.mark.parametrize("block_rows", [1, 4, 9, 16])
    def test_gridded_block_invariance(self, plane, k5, block_rows):
        np.testing.assert_allclose(
            np.asarray(sp.singlepass_valid_gridded(plane, k5, block_rows=block_rows)),
            np.asarray(ref.singlepass_valid(plane, k5)),
            atol=ATOL,
        )

    def test_gridded_odd_rows(self, rng, k5):
        """Output rows (R-4) not divisible by the band -> pad+crop path."""
        a = jnp.asarray(rng.standard_normal((37, 29)), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(sp.singlepass_valid_gridded(a, k5, block_rows=16)),
            np.asarray(ref.singlepass_valid(a, k5)),
            atol=ATOL,
        )

    def test_variants_bitwise_comparable(self, plane, k5):
        """All unrolled variants share the tap order, so they agree far
        tighter than ATOL (same-summation-order determinism)."""
        g = np.asarray(sp.singlepass_valid_gridded(plane, k5))
        w = np.asarray(sp.singlepass_valid_whole(plane, k5))
        np.testing.assert_allclose(g, w, atol=1e-7)


@pytest.mark.parametrize("width", [3, 5, 7])
def test_kernel_width_generality(rng, width):
    """The kernels are width-generic even though the paper fixes W=5."""
    k = ref.gaussian_kernel(width, 1.0)
    a = jnp.asarray(rng.standard_normal((32, 28)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(tp.horiz_pass_valid(a, k)),
        np.asarray(ref.horiz_valid(a, k)),
        atol=ATOL,
    )
    np.testing.assert_allclose(
        np.asarray(sp.singlepass_valid_whole(a, k)),
        np.asarray(ref.singlepass_valid(a, k)),
        atol=ATOL,
    )


def test_minimum_viable_plane(k5):
    """Smallest plane with a non-empty interior: 6x6 (one valid pixel... a
    2x2 valid block for W=5 needs R=C=6)."""
    a = jnp.asarray(np.arange(36, dtype=np.float32).reshape(6, 6))
    got = np.asarray(sp.singlepass_valid_whole(a, k5))
    assert got.shape == (2, 2)
    np.testing.assert_allclose(got, np.asarray(ref.singlepass_valid(a, k5)), atol=ATOL)
