"""The oracles themselves, validated against brute-force python loops.

Everything else in the project (Pallas kernels, HLO artifacts, native Rust
engines) is tested against ``kernels/ref.py``; this file anchors ref.py to
an implementation simple enough to audit by eye.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from .conftest import brute_force_singlepass, brute_force_twopass


def test_gaussian_kernel_normalised(k5):
    assert np.isclose(float(jnp.sum(k5)), 1.0, atol=1e-6)


def test_gaussian_kernel_symmetric(k5):
    k = np.asarray(k5)
    assert np.allclose(k, k[::-1])


def test_gaussian_kernel_peak_centre(k5):
    k = np.asarray(k5)
    assert np.argmax(k) == 2


@pytest.mark.parametrize("width", [3, 5, 7, 9])
def test_gaussian_kernel_widths(width):
    k = ref.gaussian_kernel(width, 1.0)
    assert k.shape == (width,)
    assert np.isclose(float(jnp.sum(k)), 1.0, atol=1e-6)


def test_gaussian_kernel_rejects_even_width():
    with pytest.raises(ValueError):
        ref.gaussian_kernel(4, 1.0)


def test_outer_kernel_separable(k5):
    kk = np.asarray(ref.outer_kernel(k5))
    k = np.asarray(k5)
    for i in range(5):
        for j in range(5):
            assert np.isclose(kk[i, j], k[i] * k[j])


def test_singlepass_ref_vs_brute_force(plane, k5):
    got = np.asarray(ref.singlepass_ref(plane, k5))
    want = brute_force_singlepass(np.asarray(plane), np.asarray(k5))
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_twopass_ref_vs_brute_force(plane, k5):
    got = np.asarray(ref.twopass_ref(plane, k5))
    want = brute_force_twopass(np.asarray(plane), np.asarray(k5))
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_border_passthrough_singlepass(plane, k5):
    out = np.asarray(ref.singlepass_ref(plane, k5))
    a = np.asarray(plane)
    np.testing.assert_array_equal(out[:2, :], a[:2, :])
    np.testing.assert_array_equal(out[-2:, :], a[-2:, :])
    np.testing.assert_array_equal(out[:, :2], a[:, :2])
    np.testing.assert_array_equal(out[:, -2:], a[:, -2:])


def test_border_passthrough_twopass(plane, k5):
    out = np.asarray(ref.twopass_ref(plane, k5))
    a = np.asarray(plane)
    np.testing.assert_array_equal(out[:2, :], a[:2, :])
    np.testing.assert_array_equal(out[-2:, :], a[-2:, :])
    np.testing.assert_array_equal(out[:, :2], a[:, :2])
    np.testing.assert_array_equal(out[:, -2:], a[:, -2:])


def test_deep_interior_agreement(plane, k5):
    """Single-pass and two-pass agree 2h pixels in (DESIGN.md section 4)."""
    sp = ref.singlepass_ref(plane, k5)
    tp = ref.twopass_ref(plane, k5)
    np.testing.assert_allclose(
        np.asarray(ref.deep_interior(sp)),
        np.asarray(ref.deep_interior(tp)),
        atol=1e-4,
    )


def test_near_border_band_differs(plane, k5):
    """Rows 2..4 genuinely differ between the algorithms -- the paper's
    two-pass reads horizontally-unfiltered border rows there. Guards
    against an oracle 'fix' that would silently change the semantics."""
    sp = np.asarray(ref.singlepass_ref(plane, k5))
    tp = np.asarray(ref.twopass_ref(plane, k5))
    assert not np.allclose(sp[2:4, 2:-2], tp[2:4, 2:-2], atol=1e-6)


def test_constant_image_is_fixed_point(k5):
    """A normalised kernel leaves a constant image unchanged."""
    a = jnp.full((24, 24), 3.25, jnp.float32)
    np.testing.assert_allclose(np.asarray(ref.twopass_ref(a, k5)), 3.25, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ref.singlepass_ref(a, k5)), 3.25, atol=1e-5)


def test_smoothing_reduces_variance(plane, k5):
    """Gaussian blur must reduce interior variance of a noise image."""
    out = np.asarray(ref.twopass_ref(plane, k5))
    a = np.asarray(plane)
    assert out[4:-4, 4:-4].var() < a[4:-4, 4:-4].var() * 0.5


def test_agglomerate_roundtrip(image):
    wide = ref.agglomerate(image)
    assert wide.shape == (40, 3 * 36)
    back = ref.deagglomerate(wide, 3)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(image))


def test_per_plane_matches_manual(image, k5):
    out = ref.per_plane(ref.twopass_ref, image, k5)
    for p in range(3):
        np.testing.assert_allclose(
            np.asarray(out[p]), np.asarray(ref.twopass_ref(image[p], k5)), atol=1e-6
        )


def test_linearity(plane, k5):
    """Convolution is linear: conv(a+b) == conv(a)+conv(b)."""
    b = plane[::-1, :]
    lhs = np.asarray(ref.singlepass_valid(plane + b, k5))
    rhs = np.asarray(ref.singlepass_valid(plane, k5)) + np.asarray(
        ref.singlepass_valid(b, k5)
    )
    np.testing.assert_allclose(lhs, rhs, atol=1e-4)


def test_valid_region_separability(plane, k5):
    """On the fully-valid region, horiz(vert(a)) == singlepass(a): the
    separable identity the two-pass algorithm exploits."""
    hv = ref.vert_valid(ref.horiz_valid(plane, k5), k5)
    sp = ref.singlepass_valid(plane, k5)
    np.testing.assert_allclose(np.asarray(hv), np.asarray(sp), atol=1e-4)
