"""Shared fixtures for the phi-conv Python test suite."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref


@pytest.fixture(scope="session")
def rng() -> np.random.Generator:
    return np.random.default_rng(20170710)


@pytest.fixture(scope="session")
def k5() -> jnp.ndarray:
    """The paper's kernel: width-5 Gaussian, sigma=1, normalised."""
    return ref.gaussian_kernel(5, 1.0)


@pytest.fixture()
def plane(rng) -> jnp.ndarray:
    """One 40x36 f32 plane of Gaussian noise (non-square on purpose)."""
    return jnp.asarray(rng.standard_normal((40, 36)), jnp.float32)


@pytest.fixture()
def image(rng) -> jnp.ndarray:
    """A 3-plane 40x36 image."""
    return jnp.asarray(rng.standard_normal((3, 40, 36)), jnp.float32)


def brute_force_singlepass(a: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Triple-checked python-loop oracle for the oracle (O(R*C*W*W))."""
    w = len(k)
    h = w // 2
    r, c = a.shape
    out = a.copy()
    for i in range(h, r - h):
        for j in range(h, c - h):
            s = 0.0
            for u in range(w):
                for v in range(w):
                    s += a[i + u - h, j + v - h] * k[u] * k[v]
            out[i, j] = s
    return out


def brute_force_twopass(a: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Python-loop two-pass with the paper's border semantics."""
    w = len(k)
    h = w // 2
    r, c = a.shape
    b = a.copy()
    for i in range(h, r - h):
        for j in range(h, c - h):
            b[i, j] = sum(a[i, j + v - h] * k[v] for v in range(w))
    out = a.copy()
    for i in range(h, r - h):
        for j in range(h, c - h):
            out[i, j] = sum(b[i + u - h, j] * k[u] for u in range(w))
    return out
