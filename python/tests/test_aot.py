"""AOT layer: entry construction, HLO-text emission, manifest integrity,
and executable round-trip of the lowered graphs at small sizes."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


@pytest.fixture(scope="module")
def small_entries():
    return aot.build_entries(
        sizes=[32], planes=3, width=5, tile_rows=8, ablation_size=16
    )


def test_entry_names_unique(small_entries):
    names = [e.name for e in small_entries]
    assert len(names) == len(set(names))


def test_entry_roles_cover_all_kinds(small_entries):
    roles = {e.role for e in small_entries}
    assert roles == {"full", "agg", "tile", "ablation", "pyramid"}


def test_lower_produces_hlo_text(small_entries):
    e = next(e for e in small_entries if e.role == "tile")
    text = aot.to_hlo_text(e.lower())
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_hlo_text_parameter_count(small_entries):
    """Every artifact takes (image, kernel) -> HLO has two parameters."""
    e = next(e for e in small_entries if e.name == "twopass_p3_32")
    text = aot.to_hlo_text(e.lower())
    # nested computations (fusions, loop bodies) carry their own
    # parameter(0); the ENTRY computation must have exactly two.
    entry = text[text.index("ENTRY") :].split("\n\n")[0]
    assert entry.count("parameter(0)") == 1
    assert entry.count("parameter(1)") == 1


def test_emit_writes_manifest(tmp_path, small_entries):
    m = aot.emit(small_entries[:3], str(tmp_path), width=5)
    assert (tmp_path / "manifest.json").exists()
    loaded = json.loads((tmp_path / "manifest.json").read_text())
    assert loaded["format"] == "hlo-text"
    assert loaded["kernel_width"] == 5
    assert len(loaded["artifacts"]) == 3
    for a in loaded["artifacts"]:
        assert (tmp_path / a["file"]).exists()
        assert a["bytes"] == os.path.getsize(tmp_path / a["file"])
    np.testing.assert_allclose(
        loaded["kernel_values"],
        np.asarray(ref.gaussian_kernel(5, 1.0)),
        atol=1e-7,
    )


def test_manifest_shapes_match_eval_shape(tmp_path, small_entries):
    e = next(e for e in small_entries if e.name == "twopass_agg_32")
    m = aot.emit([e], str(tmp_path), width=5)
    art = m["artifacts"][0]
    assert art["inputs"][0]["shape"] == [3, 32, 32]
    assert art["inputs"][1]["shape"] == [5]
    assert art["outputs"][0]["shape"] == [3, 32, 32]


class TestLoweredExecutableRoundTrip:
    """Compile the lowered StableHLO with jax's own runtime and compare to
    eager -- catches lowering bugs before the Rust PJRT path ever runs."""

    def _roundtrip(self, fn, *args):
        lowered = jax.jit(fn).lower(*(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args))
        compiled = lowered.compile()
        return compiled(*args)

    def test_twopass_full(self, image, k5):
        # image fixture is 40x36; build a matching entry inline
        got = self._roundtrip(lambda i, k: model.conv_image_twopass(i, k), image, k5)
        want = model.conv_image_twopass(image, k5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)

    def test_pyramid_multiout(self, image, k5):
        got = self._roundtrip(
            lambda i, k: model.gaussian_pyramid(i, k, levels=3), image, k5
        )
        want = model.gaussian_pyramid(image, k5, levels=3)
        assert len(got) == 3
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-6)


def test_shipped_manifest_is_consistent():
    """If `make artifacts` has run, the shipped manifest must be coherent."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    m = json.loads(open(path).read())
    names = [a["name"] for a in m["artifacts"]]
    assert len(names) == len(set(names))
    for a in m["artifacts"]:
        f = os.path.join(os.path.dirname(path), a["file"])
        assert os.path.exists(f), f"missing artifact file {a['file']}"
        assert a["role"] in {"full", "agg", "tile", "ablation", "pyramid"}
        assert all(d["dtype"] == "float32" for d in a["inputs"])
