"""Hypothesis sweeps: shapes, dtypes, widths, block sizes against ref.py
(system requirement: hypothesis sweeps the Pallas kernel's shapes/dtypes
and assert_allclose against ref.py)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels import singlepass as sp
from compile.kernels import twopass as tp

# Interpret-mode Pallas is slow-ish; keep example counts modest but real.
COMMON = dict(max_examples=25, deadline=None)


def _plane(rows: int, cols: int, seed: int) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((rows, cols)), jnp.float32)


@st.composite
def plane_and_kernel(draw, min_side=8, max_side=96):
    width = draw(st.sampled_from([3, 5, 7]))
    rows = draw(st.integers(min_side, max_side))
    cols = draw(st.integers(min_side, max_side))
    # interior must be non-empty
    rows = max(rows, width + 1)
    cols = max(cols, width + 1)
    sigma = draw(st.floats(0.5, 3.0))
    seed = draw(st.integers(0, 2**31 - 1))
    return _plane(rows, cols, seed), ref.gaussian_kernel(width, sigma)


@given(pk=plane_and_kernel())
@settings(**COMMON)
def test_horiz_pass_any_shape(pk):
    a, k = pk
    np.testing.assert_allclose(
        np.asarray(tp.horiz_pass_valid(a, k)),
        np.asarray(ref.horiz_valid(a, k)),
        atol=1e-5,
    )


@given(pk=plane_and_kernel())
@settings(**COMMON)
def test_vert_pass_any_shape(pk):
    a, k = pk
    np.testing.assert_allclose(
        np.asarray(tp.vert_pass_valid(a, k)),
        np.asarray(ref.vert_valid(a, k)),
        atol=1e-5,
    )


@given(pk=plane_and_kernel(max_side=64), br=st.sampled_from([1, 3, 8, 16]))
@settings(**COMMON)
def test_singlepass_gridded_any_shape_any_block(pk, br):
    a, k = pk
    np.testing.assert_allclose(
        np.asarray(sp.singlepass_valid_gridded(a, k, block_rows=br)),
        np.asarray(ref.singlepass_valid(a, k)),
        atol=1e-5,
    )


@given(pk=plane_and_kernel(max_side=48))
@settings(**COMMON)
def test_full_plane_semantics_any_shape(pk):
    """twopass_plane / singlepass_plane == ref with border passthrough."""
    a, k = pk
    np.testing.assert_allclose(
        np.asarray(model.twopass_plane(a, k)),
        np.asarray(ref.twopass_ref(a, k)),
        atol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(model.singlepass_plane(a, k, variant="whole")),
        np.asarray(ref.singlepass_ref(a, k)),
        atol=1e-5,
    )


@given(
    seed=st.integers(0, 2**31 - 1),
    rows=st.integers(12, 48),
    cols=st.integers(12, 48),
)
@settings(**COMMON)
def test_deep_interior_agreement_property(seed, rows, cols):
    """For every shape: single-pass == two-pass on the deep interior, and
    the kernels inherit it (the separability invariant end-to-end)."""
    a = _plane(rows, cols, seed)
    k = ref.gaussian_kernel(5, 1.0)
    spo = ref.singlepass_ref(a, k)
    tpo = ref.twopass_ref(a, k)
    np.testing.assert_allclose(
        np.asarray(ref.deep_interior(spo)),
        np.asarray(ref.deep_interior(tpo)),
        atol=1e-4,
    )


@given(seed=st.integers(0, 2**31 - 1), planes=st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_agglomeration_property(seed, planes):
    """Agglomerated == per-plane away from the 2h seam bands, any P."""
    rng = np.random.default_rng(seed)
    img = jnp.asarray(rng.standard_normal((planes, 24, 20)), jnp.float32)
    k = ref.gaussian_kernel(5, 1.0)
    agg = np.asarray(model.conv_image_twopass_agglomerated(img, k))
    per = np.asarray(model.conv_image_twopass(img, k))
    np.testing.assert_allclose(agg[:, :, 4:-4], per[:, :, 4:-4], atol=1e-5)


@given(
    seed=st.integers(0, 2**31 - 1),
    tile=st.sampled_from([4, 6, 9, 12, 18]),
)
@settings(max_examples=15, deadline=None)
def test_tile_stitching_property(seed, tile):
    """Any tile height that divides the valid rows stitches losslessly --
    the invariant the Rust execution models rely on."""
    a = _plane(40, 32, seed)  # 36 valid rows: divisible by all sampled tiles
    k = ref.gaussian_kernel(5, 1.0)
    bands = [
        np.asarray(model.single_tile(a[i : i + tile + 4, :], k))
        for i in range(0, 36, tile)
    ]
    got = np.concatenate(bands, axis=0)
    np.testing.assert_allclose(got, np.asarray(ref.singlepass_valid(a, k)), atol=1e-5)
