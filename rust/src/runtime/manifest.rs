//! The artifact manifest written by `python/compile/aot.py`.
//!
//! `manifest.json` names every lowered HLO artifact together with its
//! input/output tensor specs and a role tag, so the Rust side can load and
//! validate artifacts without hard-coding shapes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};

use crate::util::json::Json;

/// Tensor spec as recorded by the AOT compiler.
#[derive(Debug, Clone, PartialEq)]
pub struct SpecJson {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl SpecJson {
    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .req_arr("shape")?
            .iter()
            .map(|d| d.as_usize().context("non-integer dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { shape, dtype: j.req_str("dtype")?.to_string() })
    }
}

/// One AOT artifact: name, file, role and tensor contracts.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    /// "full" | "agg" | "tile" | "ablation" | "pyramid"
    pub role: String,
    /// "twopass" | "singlepass"
    pub algorithm: String,
    pub variant: String,
    pub inputs: Vec<SpecJson>,
    pub outputs: Vec<SpecJson>,
    pub meta: BTreeMap<String, Json>,
    pub sha256: String,
    pub bytes: u64,
}

impl ArtifactEntry {
    fn from_json(j: &Json) -> Result<Self> {
        let specs = |key: &str| -> Result<Vec<SpecJson>> {
            j.req_arr(key)?.iter().map(SpecJson::from_json).collect()
        };
        Ok(Self {
            name: j.req_str("name")?.to_string(),
            file: j.req_str("file")?.to_string(),
            role: j.req_str("role")?.to_string(),
            algorithm: j.req_str("algorithm")?.to_string(),
            variant: j.req_str("variant")?.to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            meta: j.get("meta").as_obj().cloned().unwrap_or_default(),
            sha256: j.req_str("sha256")?.to_string(),
            bytes: j.req_f64("bytes")? as u64,
        })
    }

    /// Integer metadata field (rows, cols, planes, tile_rows, halo, …).
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.as_usize())
    }
}

/// The whole manifest: artifact index plus the reference kernel.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub kernel_width: usize,
    pub gaussian_sigma: f64,
    pub artifacts: Vec<ArtifactEntry>,
    /// Reference Gaussian kernel values — used to cross-check the Rust
    /// kernel generator against the Python one.
    pub kernel_values: Vec<f32>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("cannot read {}. Run `make artifacts` first.", path.display())
        })?;
        let j = Json::parse(&text).context("manifest.json is not valid JSON")?;
        if j.req_str("format")? != "hlo-text" {
            bail!("unsupported artifact format {:?}", j.get("format"));
        }
        let artifacts = j
            .req_arr("artifacts")?
            .iter()
            .map(ArtifactEntry::from_json)
            .collect::<Result<Vec<_>>>()?;
        let kernel_values = j
            .req_arr("kernel_values")?
            .iter()
            .map(|v| v.as_f64().context("kernel value not a number").map(|f| f as f32))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            kernel_width: j.req_usize("kernel_width")?,
            gaussian_sigma: j.req_f64("gaussian_sigma")?,
            artifacts,
            kernel_values,
            dir,
        })
    }

    /// Find an artifact by exact name.
    pub fn get(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts.iter().find(|a| a.name == name).ok_or_else(|| {
            err!(
                "artifact {name:?} not in manifest ({} entries)",
                self.artifacts.len()
            )
        })
    }

    /// All artifacts with a given role tag.
    pub fn by_role(&self, role: &str) -> Vec<&ArtifactEntry> {
        self.artifacts.iter().filter(|a| a.role == role).collect()
    }

    /// Absolute path of an artifact's HLO text file.
    pub fn path_of(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// Full-image artifact name for (algorithm, planes, size).
    pub fn full_image_name(&self, algorithm: &str, planes: usize, size: usize) -> String {
        format!("{algorithm}_p{planes}_{size}")
    }

    /// The square full-image sizes available in this manifest.
    pub fn full_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .by_role("full")
            .iter()
            .filter_map(|a| a.meta_usize("rows"))
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

/// Locate the artifacts directory: $PHI_CONV_ARTIFACTS or ./artifacts
/// relative to the crate root (works from `cargo test` / `cargo bench`).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("PHI_CONV_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Canonical example manifest JSON — the single source of truth for the
/// schema used by the unit- and integration-test fixtures (full, agg,
/// tile and pyramid roles; full-image sizes 288 and 576). Test-support
/// only; not part of the public API.
#[doc(hidden)]
pub fn example_manifest_json() -> String {
    let spec = |shape: &str| format!(r#"{{"shape": {shape}, "dtype": "float32"}}"#);
    let entry = |name: &str,
                 role: &str,
                 algorithm: &str,
                 variant: &str,
                 inputs: &str,
                 outputs: &str,
                 meta: &str| {
        format!(
            r#"{{"name": "{name}", "file": "{name}.hlo.txt", "role": "{role}",
                "algorithm": "{algorithm}", "variant": "{variant}",
                "inputs": {inputs}, "outputs": {outputs}, "meta": {meta},
                "sha256": "0000", "bytes": 128}}"#
        )
    };
    let img288 = spec("[3, 288, 288]");
    let img576 = spec("[3, 576, 576]");
    let kv = spec("[5]");
    let artifacts = [
        entry(
            "twopass_p3_288",
            "full",
            "twopass",
            "simd",
            &format!("[{img288}, {kv}]"),
            &format!("[{img288}]"),
            r#"{"rows": 288, "cols": 288, "planes": 3}"#,
        ),
        entry(
            "singlepass_p3_288",
            "full",
            "singlepass",
            "simd",
            &format!("[{img288}, {kv}]"),
            &format!("[{img288}]"),
            r#"{"rows": 288, "cols": 288, "planes": 3}"#,
        ),
        entry(
            "twopass_p3_576",
            "full",
            "twopass",
            "simd",
            &format!("[{img576}, {kv}]"),
            &format!("[{img576}]"),
            r#"{"rows": 576, "cols": 576, "planes": 3}"#,
        ),
        entry(
            "twopass_agg_288",
            "agg",
            "twopass",
            "simd",
            &format!("[{}, {kv}]", spec("[288, 864]")),
            &format!("[{}]", spec("[288, 864]")),
            r#"{"rows": 288, "cols": 288, "planes": 3}"#,
        ),
        entry(
            "horiz_tile_64x288",
            "tile",
            "twopass",
            "horiz",
            &format!("[{}, {kv}]", spec("[64, 288]")),
            &format!("[{}]", spec("[64, 284]")),
            r#"{"tile_rows": 60, "cols": 288, "halo": 2}"#,
        ),
        entry(
            "pyramid_288",
            "pyramid",
            "twopass",
            "simd",
            &format!("[{img288}, {kv}]"),
            &format!("[{img288}, {}, {}]", spec("[3, 144, 144]"), spec("[3, 72, 72]")),
            r#"{"rows": 288, "cols": 288, "planes": 3, "levels": 3}"#,
        ),
    ];
    format!(
        r#"{{"format": "hlo-text", "kernel_width": 5, "gaussian_sigma": 1.0,
            "kernel_values": [0.05448868, 0.24420135, 0.40261996, 0.24420135, 0.05448868],
            "artifacts": [{}]}}"#,
        artifacts.join(",\n")
    )
}

/// Write [`example_manifest_json`] plus stub artifact files into `dir`
/// (creating it), so `path_of(..).exists()` holds — the shared fixture
/// writer for the unit and integration suites. Test-support only.
#[doc(hidden)]
pub fn write_example_manifest(dir: &Path) {
    std::fs::create_dir_all(dir).expect("create fixture dir");
    std::fs::write(dir.join("manifest.json"), example_manifest_json())
        .expect("write fixture manifest");
    let m = Manifest::load(dir).expect("fixture manifest parses");
    for a in &m.artifacts {
        std::fs::write(m.path_of(a), "HloModule stub\n").expect("write stub artifact");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shared example manifest + stub artifact files in a unique temp dir.
    fn write_fixture(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("phi_conv_manifest_{}_{tag}", std::process::id()));
        write_example_manifest(&dir);
        dir
    }

    #[test]
    fn loads_fixture_manifest() {
        let m = Manifest::load(write_fixture("load")).unwrap();
        assert_eq!(m.kernel_width, 5);
        assert_eq!(m.artifacts.len(), 6);
        assert_eq!(m.kernel_values.len(), 5);
        let s: f32 = m.kernel_values.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!((m.gaussian_sigma - 1.0).abs() < 1e-12);
    }

    #[test]
    fn roles_and_lookup() {
        let m = Manifest::load(write_fixture("roles")).unwrap();
        assert_eq!(m.by_role("full").len(), 3);
        assert_eq!(m.by_role("tile").len(), 1);
        assert_eq!(m.by_role("pyramid").len(), 1);
        assert_eq!(m.full_sizes(), vec![288, 576]);
        let name = m.full_image_name("twopass", 3, m.full_sizes()[0]);
        let e = m.get(&name).unwrap();
        assert_eq!(e.algorithm, "twopass");
        assert!(m.path_of(e).exists());
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[1].shape, vec![5]);
        assert_eq!(e.outputs[0].shape, vec![3, 288, 288]);
    }

    #[test]
    fn tile_metadata_present() {
        let m = Manifest::load(write_fixture("tile")).unwrap();
        for t in m.by_role("tile") {
            assert_eq!(t.meta_usize("tile_rows"), Some(60), "{}", t.name);
            assert_eq!(t.meta_usize("cols"), Some(288), "{}", t.name);
            assert_eq!(t.meta_usize("halo"), Some(2), "{}", t.name);
            assert_eq!(t.meta_usize("not_there"), None);
        }
    }

    #[test]
    fn missing_artifact_is_an_error() {
        let m = Manifest::load(write_fixture("missing")).unwrap();
        let e = m.get("definitely_not_an_artifact").unwrap_err();
        assert!(e.to_string().contains("not in manifest"));
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let e = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(e.to_string().contains("make artifacts"));
    }

    #[test]
    fn wrong_format_tag_rejected() {
        let dir = std::env::temp_dir()
            .join(format!("phi_conv_manifest_{}_badformat", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format": "hlo-proto", "kernel_width": 5, "gaussian_sigma": 1.0,
                "kernel_values": [], "artifacts": []}"#,
        )
        .unwrap();
        let e = Manifest::load(&dir).unwrap_err();
        assert!(e.to_string().contains("unsupported artifact format"), "{e}");
    }

    #[test]
    fn malformed_json_reports_context() {
        let dir = std::env::temp_dir()
            .join(format!("phi_conv_manifest_{}_badjson", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
        let e = Manifest::load(&dir).unwrap_err();
        assert!(e.to_string().contains("not valid JSON"), "{e}");
    }

    #[test]
    fn shipped_artifacts_parse_if_present() {
        // the artifacts dir only exists after `make artifacts`; when it
        // does, it must satisfy the same contract as the fixture
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts at {}", dir.display());
            return;
        }
        let m = Manifest::load(dir).unwrap();
        assert_eq!(m.kernel_width, 5);
        assert!(!m.by_role("full").is_empty());
    }
}
