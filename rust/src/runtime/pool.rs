//! `EnginePool`: lazy, shared registry of compiled executables.
//!
//! One PJRT client per process; engines compile on first use and are
//! cached behind an `Arc` so the coordinator's worker threads can execute
//! the same artifact concurrently (PJRT executables are thread-safe for
//! execution).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::util::error::Result;

use super::engine::HloEngine;
use super::manifest::Manifest;

pub struct EnginePool {
    client: Arc<xla::PjRtClient>,
    manifest: Manifest,
    engines: Mutex<HashMap<String, Arc<HloEngine>>>,
}

impl EnginePool {
    /// Open an artifacts directory: loads the manifest, creates the PJRT
    /// CPU client, compiles nothing yet.
    pub fn open(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(Self {
            client: super::engine::cpu_client()?,
            manifest: Manifest::load(artifacts_dir)?,
            engines: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Get (compiling on first use) the engine for an artifact name.
    pub fn engine(&self, name: &str) -> Result<Arc<HloEngine>> {
        if let Some(e) = self.engines.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        // Compile outside the lock: compilation can take hundreds of ms
        // and other engines should stay usable meanwhile. A racing second
        // compile of the same name is harmless (last insert wins).
        let engine = Arc::new(HloEngine::load(&self.client, &self.manifest, name)?);
        self.engines
            .lock()
            .unwrap()
            .insert(name.to_string(), engine.clone());
        Ok(engine)
    }

    /// Pre-compile a set of artifacts (the serving warm-up path).
    pub fn warm(&self, names: &[&str]) -> Result<Vec<f64>> {
        names
            .iter()
            .map(|n| Ok(self.engine(n)?.compile_time_ms))
            .collect()
    }

    /// Names currently resident.
    pub fn resident(&self) -> Vec<String> {
        let mut v: Vec<String> = self.engines.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}
