//! PJRT runtime: load AOT HLO-text artifacts and execute them from Rust.
//!
//! This is the only place the compiled Python/Pallas world touches the
//! Rust request path. The flow (from /opt/xla-example/load_hlo):
//!
//! ```text
//! artifacts/<name>.hlo.txt --HloModuleProto::from_text_file-->
//!   XlaComputation --PjRtClient::compile--> PjRtLoadedExecutable
//!   --execute(&[Literal])--> tuple of output Literals
//! ```
//!
//! HLO *text* is the interchange format: xla_extension 0.5.1 rejects
//! serialized protos from jax ≥ 0.5 (64-bit instruction ids); the text
//! parser reassigns ids (DESIGN.md §2).
//!
//! ## The `pjrt` cargo feature
//!
//! The bridge depends on the vendored `xla` crate, which the default
//! offline build does not require: the [`manifest`] module (pure JSON,
//! no PJRT) is always compiled, while [`engine`] / [`pool`] / [`actor`]
//! are gated behind the off-by-default `pjrt` feature. Without the
//! feature, [`stub`] provides the identical public API — every
//! constructor returns an error explaining the gate — so the
//! coordinator, CLI and benches compile and degrade gracefully instead
//! of being littered with `cfg` at call sites.

pub mod manifest;

#[cfg(feature = "pjrt")]
pub mod actor;
#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod pool;

#[cfg(not(feature = "pjrt"))]
pub mod stub;

pub use manifest::{ArtifactEntry, Manifest};

#[cfg(feature = "pjrt")]
pub use actor::PjrtHandle;
#[cfg(feature = "pjrt")]
pub use engine::{HloEngine, TensorSpec};
#[cfg(feature = "pjrt")]
pub use pool::EnginePool;

#[cfg(not(feature = "pjrt"))]
pub use stub::{EnginePool, HloEngine, PjrtHandle, TensorSpec};

/// True when this build carries the real PJRT bridge.
pub const fn pjrt_enabled() -> bool {
    cfg!(feature = "pjrt")
}
