//! PJRT runtime: load AOT HLO-text artifacts and execute them from Rust.
//!
//! This is the only place the compiled Python/Pallas world touches the
//! Rust request path. The flow (from /opt/xla-example/load_hlo):
//!
//! ```text
//! artifacts/<name>.hlo.txt --HloModuleProto::from_text_file-->
//!   XlaComputation --PjRtClient::compile--> PjRtLoadedExecutable
//!   --execute(&[Literal])--> tuple of output Literals
//! ```
//!
//! HLO *text* is the interchange format: xla_extension 0.5.1 rejects
//! serialized protos from jax ≥ 0.5 (64-bit instruction ids); the text
//! parser reassigns ids (DESIGN.md §2).

pub mod actor;
pub mod engine;
pub mod manifest;
pub mod pool;

pub use actor::PjrtHandle;
pub use engine::{HloEngine, TensorSpec};
pub use manifest::{ArtifactEntry, Manifest};
pub use pool::EnginePool;
