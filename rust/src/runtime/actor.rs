//! PJRT actor: confines the (non-`Send`) xla client to one dedicated
//! thread and exposes a channel-based, `Send + Sync + Clone` handle.
//!
//! The `xla` crate's `PjRtClient` holds `Rc` internals, so executables
//! cannot be shared across the coordinator's executor threads. Instead a
//! single actor thread owns the [`EnginePool`] and serves execution
//! requests over a channel — the standard confinement pattern, and a
//! reasonable serving shape regardless: the PJRT CPU client parallelises
//! execution internally, so one submission thread does not serialise the
//! actual compute.

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

use crate::util::error::{Context, Result};

use super::pool::EnginePool;

enum Job {
    Run {
        name: String,
        inputs: Vec<Vec<f32>>,
        reply: Sender<Result<Vec<Vec<f32>>>>,
    },
    Warm {
        names: Vec<String>,
        reply: Sender<Result<Vec<f64>>>,
    },
    Shutdown,
}

/// Cloneable, thread-safe handle to the PJRT actor.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: Arc<Mutex<Sender<Job>>>,
}

impl PjrtHandle {
    /// Spawn the actor; fails fast if the artifacts dir / client are bad.
    pub fn spawn(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let (tx, rx) = channel::<Job>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        std::thread::Builder::new()
            .name("phi-conv-pjrt".into())
            .spawn(move || {
                let pool = match EnginePool::open(&dir) {
                    Ok(p) => {
                        let _ = ready_tx.send(Ok(()));
                        p
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for job in rx {
                    match job {
                        Job::Run { name, inputs, reply } => {
                            let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
                            let result = pool.engine(&name).and_then(|e| e.run(&refs));
                            let _ = reply.send(result);
                        }
                        Job::Warm { names, reply } => {
                            let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
                            let _ = reply.send(pool.warm(&refs));
                        }
                        Job::Shutdown => return,
                    }
                }
            })
            .context("spawning PJRT actor")?;
        ready_rx.recv().context("PJRT actor died during startup")??;
        Ok(Self { tx: Arc::new(Mutex::new(tx)) })
    }

    /// Execute an artifact; blocks until the actor replies.
    pub fn run(&self, name: &str, inputs: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job::Run { name: name.to_string(), inputs, reply })
            .context("PJRT actor gone")?;
        rx.recv().context("PJRT actor dropped reply")?
    }

    /// Single-output convenience.
    pub fn run1(&self, name: &str, inputs: Vec<Vec<f32>>) -> Result<Vec<f32>> {
        let mut outs = self.run(name, inputs)?;
        ensure!(outs.len() == 1, "{name}: expected 1 output, got {}", outs.len());
        Ok(outs.pop().unwrap())
    }

    /// Pre-compile artifacts; returns per-artifact compile ms.
    pub fn warm(&self, names: &[&str]) -> Result<Vec<f64>> {
        let (reply, rx) = channel();
        self.tx
            .lock()
            .unwrap()
            .send(Job::Warm { names: names.iter().map(|s| s.to_string()).collect(), reply })
            .context("PJRT actor gone")?;
        rx.recv().context("PJRT actor dropped reply")?
    }

    /// Ask the actor to exit (also happens when the last handle drops the
    /// channel).
    pub fn shutdown(&self) {
        let _ = self.tx.lock().unwrap().send(Job::Shutdown);
    }
}
