//! No-PJRT stand-ins, compiled when the `pjrt` feature is off.
//!
//! Mirrors the public API of [`super::engine`] / [`super::pool`] /
//! [`super::actor`] exactly, so the coordinator, the CLI and the benches
//! compile unchanged. Every constructor fails with [`GATE_MESSAGE`]-style
//! guidance instead of linking the vendored `xla` closure; request paths
//! that would reach PJRT fall back (the coordinator) or report the gate
//! (the CLI's `validate`).

use std::sync::Arc;

use crate::util::error::Result;

use super::manifest::Manifest;

/// The error every gated entry point returns.
pub const GATE_MESSAGE: &str =
    "built without the `pjrt` feature: rebuild with `cargo build --features pjrt` \
     (requires the vendored xla closure) to enable the PJRT bridge";

fn gated<T>() -> Result<T> {
    Err(err!("{GATE_MESSAGE}"))
}

/// Shape + dtype contract for one tensor (f32 only in this project).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Stand-in for a loaded-and-compiled HLO artifact. Never constructed;
/// methods exist so call sites typecheck.
pub struct HloEngine {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub compile_time_ms: f64,
}

impl HloEngine {
    pub fn run(&self, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        gated()
    }

    pub fn run1(&self, _inputs: &[&[f32]]) -> Result<Vec<f32>> {
        gated()
    }
}

/// Stand-in engine registry: `open` always reports the feature gate.
pub struct EnginePool {
    manifest: Manifest,
}

impl EnginePool {
    pub fn open(_artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        gated()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn engine(&self, _name: &str) -> Result<Arc<HloEngine>> {
        gated()
    }

    pub fn warm(&self, _names: &[&str]) -> Result<Vec<f64>> {
        gated()
    }

    pub fn resident(&self) -> Vec<String> {
        Vec::new()
    }
}

/// Stand-in actor handle: `spawn` always reports the feature gate.
#[derive(Clone)]
pub struct PjrtHandle {
    _private: (),
}

impl PjrtHandle {
    pub fn spawn(_artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        gated()
    }

    pub fn run(&self, _name: &str, _inputs: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        gated()
    }

    pub fn run1(&self, _name: &str, _inputs: Vec<Vec<f32>>) -> Result<Vec<f32>> {
        gated()
    }

    pub fn warm(&self, _names: &[&str]) -> Result<Vec<f64>> {
        gated()
    }

    pub fn shutdown(&self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_the_gate() {
        let e = EnginePool::open("/tmp/nowhere").unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
        let e = PjrtHandle::spawn("/tmp/nowhere").unwrap_err();
        assert!(e.to_string().contains("--features pjrt"), "{e}");
    }

    #[test]
    fn tensor_spec_is_fully_functional() {
        let s = TensorSpec { shape: vec![3, 4, 5] };
        assert_eq!(s.elements(), 60);
    }
}
