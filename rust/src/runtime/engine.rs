//! `HloEngine`: one compiled PJRT executable plus its tensor contracts.

use std::sync::Arc;
use std::time::Instant;

use crate::util::error::Result;

use super::manifest::{ArtifactEntry, Manifest};

/// Shape + dtype contract for one tensor (f32 only in this project).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A loaded-and-compiled HLO artifact, ready to execute.
///
/// Compilation happens once at load time (AOT on the Python side, JIT of
/// the *text* here); `run` is the request-path entry and does no Python,
/// no parsing, no compilation.
pub struct HloEngine {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// wall time spent compiling the artifact, for the perf log
    pub compile_time_ms: f64,
}

impl HloEngine {
    /// Load one artifact from a manifest through a shared PJRT client.
    pub fn load(client: &xla::PjRtClient, manifest: &Manifest, name: &str) -> Result<Self> {
        let entry = manifest.get(name)?;
        let path = manifest.path_of(entry);
        Self::load_entry(client, entry, &path)
    }

    /// Load from an explicit entry + path (used by the pool loader).
    pub fn load_entry(
        client: &xla::PjRtClient,
        entry: &ArtifactEntry,
        path: &std::path::Path,
    ) -> Result<Self> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| err!("non-utf8 artifact path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        let compile_time_ms = t0.elapsed().as_secs_f64() * 1e3;
        Ok(Self {
            name: entry.name.clone(),
            exe,
            inputs: entry
                .inputs
                .iter()
                .map(|s| TensorSpec { shape: s.shape.clone() })
                .collect(),
            outputs: entry
                .outputs
                .iter()
                .map(|s| TensorSpec { shape: s.shape.clone() })
                .collect(),
            compile_time_ms,
        })
    }

    /// Execute with raw f32 buffers; returns one `Vec<f32>` per output.
    ///
    /// Inputs are validated against the manifest contract — a wrong-sized
    /// buffer is a caller bug and fails fast here rather than deep inside
    /// PJRT.
    pub fn run(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, spec) in inputs.iter().zip(&self.inputs) {
            if buf.len() != spec.elements() {
                bail!(
                    "{}: input buffer has {} elements, spec {:?} wants {}",
                    self.name,
                    buf.len(),
                    spec.shape,
                    spec.elements()
                );
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(buf).reshape(&dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple, even 1-ary.
        let parts = result.to_tuple()?;
        if parts.len() != self.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.outputs.len(),
                parts.len()
            );
        }
        parts.into_iter().map(|l| Ok(l.to_vec::<f32>()?)).collect()
    }

    /// Convenience: single-output artifacts.
    pub fn run1(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let mut outs = self.run(inputs)?;
        if outs.len() != 1 {
            bail!("{}: run1 on a {}-output artifact", self.name, outs.len());
        }
        Ok(outs.pop().unwrap())
    }
}

/// Create the process-wide PJRT CPU client.
pub fn cpu_client() -> Result<Arc<xla::PjRtClient>> {
    Ok(Arc::new(xla::PjRtClient::cpu()?))
}
