//! Learned cost model: regression-fit plan selection with persistent
//! tuning artifacts.
//!
//! `autotune` rediscovers the paper's central result — that granularity
//! and fusion choice dominate performance — by brute-force sweeping, and
//! forgets everything at process exit. This module makes that knowledge
//! cheap and durable, following the vm-cost-model approach (linear
//! regression over bench samples with R²-gated validity):
//!
//! * [`Sample`] — one (model, shape, kernel, candidate) timing
//!   observation, self-describing (repeats, warmup, worker count ride
//!   along) so persisted sample sets can be audited and re-fit.
//! * [`CostModel`] — groups samples by (model, class, fused, tiled),
//!   fits one [`fit::LinearModel`] per group (`predicted_ms = c0 +
//!   c1·pixels + c2·width + c3·pixels·width + c4·units`), and answers
//!   [`CostModel::choose`]: the predicted-cheapest class/tile/fusion
//!   candidate for a *never-before-seen* shape, with the separable
//!   untiled baseline always in the comparison set. Because the fits
//!   are per kernel class, the direct-vs-FFT crossover falls out of the
//!   regression: FFT groups are near-flat in kernel width while direct
//!   groups grow with `pixels·width`, so large kernels route to the
//!   transform without ever having been swept. Groups whose fit fails or whose R² is below
//!   `r2_min` are unusable; a shape whose baseline group is unusable
//!   yields `None`, which routes the caller back to empirical sweeping.
//! * JSON persistence ([`CostModel::save`] / [`CostModel::load`])
//!   following the `BENCH_*.json` convention (`BENCH_costmodel.json`):
//!   raw samples and fitted coefficients travel together, and a loaded
//!   model reproduces the in-memory fit's predictions bitwise because
//!   coefficients are restored verbatim, never re-fit.
//!
//! Consumers: `TuningTable::choose` (predictive tier on lookup miss),
//! coordinator admission (`Coordinator::set_tuning`), `phi-conv tune
//! --save/--load/--predict`, and `cargo bench --bench costmodel`.

pub mod fit;

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::error::{Context, Result};
use crate::util::json::Json;

use crate::autotune::{default_candidates, Candidate};
use crate::config::RunConfig;
use crate::image::synth_image;
use crate::metrics::{time_reps, Table};
use crate::models::{
    ExecutionModel, GprmModel, OpenClModel, OpenMpModel, TileGrid, TileSpec,
};
use crate::plan::{ConvPlan, ScratchArena};

pub use fit::{LinearModel, FEATURE_NAMES, NFEATURES};

/// One timing observation from an autotune sweep, self-describing
/// enough to audit (or re-fit) after a save/load cycle.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// execution-model name ("OpenMP" / "OpenCL" / "GPRM")
    pub model: String,
    /// kernel-class label ("separable" / "direct2d" / "fft") — the plan
    /// dimension the crossover policy selects over.
    pub class: String,
    pub planes: usize,
    pub rows: usize,
    pub cols: usize,
    pub kernel_width: usize,
    /// `None` = the untiled row-partition baseline.
    pub tile: Option<TileSpec>,
    pub fused: bool,
    /// GPRM tiles-per-task factor (1 elsewhere).
    pub agglomeration: usize,
    /// Dispatch units the candidate decomposes into (tile count, or
    /// worker count for the untiled row partition).
    pub units: usize,
    /// Worker threads in the model's pool when measured.
    pub workers: usize,
    /// Median total milliseconds.
    pub ms: f64,
    /// Timed repetitions behind the median.
    pub reps: usize,
    /// Warmup repetitions discarded before timing.
    pub warmup: usize,
}

/// Number of dispatch units a candidate decomposition produces: the
/// tile-grid cardinality, or the worker count for the untiled row
/// partition (one band per worker).
pub fn dispatch_units(rows: usize, cols: usize, tile: Option<TileSpec>, workers: usize) -> usize {
    match tile {
        Some(t) => TileGrid::new(rows, cols, t).len(),
        None => workers,
    }
    .max(1)
}

/// The regression feature vector, in [`FEATURE_NAMES`] order.
pub fn features(
    planes: usize,
    rows: usize,
    cols: usize,
    kernel_width: usize,
    units: usize,
) -> [f64; NFEATURES] {
    let pixels = (planes * rows * cols) as f64;
    let width = kernel_width as f64;
    [pixels, width, pixels * width, units as f64]
}

/// One fitted (model, class, fused, tiled) group. `fit: None` is the
/// structured low-rank/degenerate outcome; a present fit can still be
/// unusable if its R² misses the acceptance threshold.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupFit {
    pub model: String,
    /// kernel-class label ("separable" / "direct2d" / "fft")
    pub class: String,
    pub fused: bool,
    pub tiled: bool,
    pub n_samples: usize,
    pub fit: Option<LinearModel>,
}

impl GroupFit {
    pub fn usable(&self, r2_min: f64) -> bool {
        self.fit.as_ref().is_some_and(|f| f.usable(r2_min))
    }
}

/// The predicted-cheapest execution configuration for a shape, plus the
/// predicted baseline it was compared against (mirrors
/// [`crate::autotune::Tuned`] for the measured path).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prediction {
    pub candidate: Candidate,
    /// predicted ms of the chosen candidate
    pub ms: f64,
    /// predicted ms of the untiled row-partition baseline
    pub baseline_ms: f64,
}

/// Fitted cost model over a sample set: per-(model, fused, tiled)
/// linear models with R²-gated validity and JSON persistence.
#[derive(Debug, Clone, PartialEq)]
pub struct CostModel {
    r2_min: f64,
    samples: Vec<Sample>,
    groups: Vec<GroupFit>,
}

impl CostModel {
    /// Fit one linear model per (model, class, fused, tiled) group.
    /// Grouping is a `BTreeMap` so group order — and therefore artifact
    /// bytes — is deterministic.
    pub fn fit(samples: Vec<Sample>, r2_min: f64) -> Self {
        let mut grouped: BTreeMap<(String, String, bool, bool), (Vec<[f64; NFEATURES]>, Vec<f64>)> =
            BTreeMap::new();
        for s in &samples {
            let key = (s.model.clone(), s.class.clone(), s.fused, s.tile.is_some());
            let entry = grouped.entry(key).or_default();
            entry.0.push(features(s.planes, s.rows, s.cols, s.kernel_width, s.units));
            entry.1.push(s.ms);
        }
        let groups = grouped
            .into_iter()
            .map(|((model, class, fused, tiled), (xs, ys))| GroupFit {
                model,
                class,
                fused,
                tiled,
                n_samples: xs.len(),
                fit: fit::fit(&xs, &ys),
            })
            .collect();
        Self { r2_min, samples, groups }
    }

    pub fn r2_min(&self) -> f64 {
        self.r2_min
    }

    /// Override the acceptance threshold (e.g. with the config's
    /// `--r2-min` after loading a persisted artifact).
    pub fn set_r2_min(&mut self, r2_min: f64) {
        self.r2_min = r2_min;
    }

    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }

    pub fn groups(&self) -> &[GroupFit] {
        &self.groups
    }

    /// Number of groups whose fit passes the R² gate.
    pub fn usable_groups(&self) -> usize {
        self.groups.iter().filter(|g| g.usable(self.r2_min)).count()
    }

    fn group(&self, model: &str, class: &str, fused: bool, tiled: bool) -> Option<&GroupFit> {
        self.groups.iter().find(|g| {
            g.model == model && g.class == class && g.fused == fused && g.tiled == tiled
        })
    }

    /// Predicted milliseconds for one concrete *separable* configuration
    /// (the pre-class signature, kept for the dominant call sites), or
    /// `None` when the matching group is missing or fails the R² gate.
    #[allow(clippy::too_many_arguments)]
    pub fn predict_ms(
        &self,
        model: &str,
        fused: bool,
        tile: Option<TileSpec>,
        planes: usize,
        rows: usize,
        cols: usize,
        kernel_width: usize,
        workers: usize,
    ) -> Option<f64> {
        self.predict_class_ms(
            model,
            "separable",
            fused,
            tile,
            planes,
            rows,
            cols,
            kernel_width,
            workers,
        )
    }

    /// Per-class twin of [`CostModel::predict_ms`]: `class` is a
    /// [`crate::plan::KernelClass`] label ("separable" / "direct2d" /
    /// "fft"). For FFT groups `kernel_width` still feeds the feature
    /// vector — the fit learns its near-zero weight from the samples
    /// rather than having it hard-coded away.
    #[allow(clippy::too_many_arguments)]
    pub fn predict_class_ms(
        &self,
        model: &str,
        class: &str,
        fused: bool,
        tile: Option<TileSpec>,
        planes: usize,
        rows: usize,
        cols: usize,
        kernel_width: usize,
        workers: usize,
    ) -> Option<f64> {
        let g = self.group(model, class, fused, tile.is_some())?;
        if !g.usable(self.r2_min) {
            return None;
        }
        let units = dispatch_units(rows, cols, tile, workers);
        Some(g.fit.as_ref()?.predict(&features(planes, rows, cols, kernel_width, units)))
    }

    /// Predicted milliseconds for a streamed k-stage filter chain: the
    /// sum of per-stage fused untiled fits, since a streamed segment
    /// executes each stage as a fused row-ring pass over the same
    /// shape. `None` when any stage's group is missing or fails the R²
    /// gate — a chain prediction is only as trustworthy as its
    /// worst-fitted stage.
    pub fn predict_chain_ms(
        &self,
        model: &str,
        planes: usize,
        rows: usize,
        cols: usize,
        widths: &[usize],
        workers: usize,
    ) -> Option<f64> {
        if widths.is_empty() {
            return None;
        }
        let mut total = 0.0;
        for &w in widths {
            total += self.predict_ms(model, true, None, planes, rows, cols, w, workers)?;
        }
        Some(total)
    }

    /// The predicted-cheapest candidate for a shape, over the same
    /// candidate set the empirical sweep uses (separable untiled
    /// baseline always index 0, kernel-class alternatives included).
    /// `None` — fall back to sweeping — when the untiled baseline group
    /// itself is unpredictable; candidates whose group is unusable are
    /// skipped rather than guessed at. Deterministic: candidates are
    /// scanned in order with a strict `<`, so ties keep the earlier
    /// (coarser/baseline-first) candidate. This is where the measured
    /// crossover policy lives: a never-swept large kernel routes to the
    /// FFT class purely because its fitted group predicts cheaper.
    pub fn choose(
        &self,
        model: &str,
        planes: usize,
        rows: usize,
        cols: usize,
        kernel_width: usize,
        workers: usize,
    ) -> Option<Prediction> {
        let baseline_ms =
            self.predict_ms(model, false, None, planes, rows, cols, kernel_width, workers)?;
        let mut best = (Candidate::untiled(), baseline_ms);
        for cand in default_candidates(rows, model == "GPRM") {
            let Some(ms) = self.predict_class_ms(
                model,
                cand.class.label(),
                cand.fused,
                cand.tile,
                planes,
                rows,
                cols,
                kernel_width,
                workers,
            ) else {
                continue;
            };
            if ms < best.1 {
                best = (cand, ms);
            }
        }
        Some(Prediction { candidate: best.0, ms: best.1, baseline_ms })
    }

    // -- persistence -------------------------------------------------------

    /// Serialize samples + fitted groups following the `BENCH_*.json`
    /// convention. Tile dimensions persist as integers with `0` meaning
    /// full extent (`usize::MAX` does not survive the f64 JSON number
    /// space); `null` tile fields mean untiled. Non-finite R² (and any
    /// non-finite coefficient) serializes as `null`, which
    /// [`CostModel::from_json`] maps back to an *invalid* model — never
    /// to zero.
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("bench".into(), Json::Str("costmodel".into()));
        root.insert("r2_min".into(), Json::Num(self.r2_min));
        root.insert(
            "features".into(),
            Json::Arr(FEATURE_NAMES.iter().map(|n| Json::Str((*n).into())).collect()),
        );
        root.insert(
            "samples".into(),
            Json::Arr(self.samples.iter().map(sample_to_json).collect()),
        );
        root.insert(
            "models".into(),
            Json::Arr(self.groups.iter().map(group_to_json).collect()),
        );
        Json::Obj(root)
    }

    /// Rebuild a model from its JSON form. Coefficients are restored
    /// verbatim — never re-fit — so a saved-then-loaded model predicts
    /// bitwise-identically to the in-memory fit. Groups whose
    /// coefficients are `null` (non-finite at save time, or hand-edited)
    /// come back as `fit: None`; a `null` R² comes back as NaN, which
    /// fails every usability check.
    pub fn from_json(v: &Json) -> Result<CostModel> {
        ensure!(
            v.get("bench").as_str() == Some("costmodel"),
            "not a costmodel artifact (bench field is {:?})",
            v.get("bench")
        );
        let feats = v.req_arr("features")?;
        let names: Vec<&str> = feats.iter().filter_map(|f| f.as_str()).collect();
        ensure!(
            names == FEATURE_NAMES,
            "feature layout mismatch: artifact has {names:?}, this build expects {FEATURE_NAMES:?}"
        );
        let r2_min = v.req_f64("r2_min")?;
        let mut samples = Vec::new();
        for (i, s) in v.req_arr("samples")?.iter().enumerate() {
            samples.push(sample_from_json(s).with_context(|| format!("samples[{i}]"))?);
        }
        let mut groups = Vec::new();
        for (i, g) in v.req_arr("models")?.iter().enumerate() {
            groups.push(group_from_json(g).with_context(|| format!("models[{i}]"))?);
        }
        Ok(CostModel { r2_min, samples, groups })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut text = self.to_json().to_string();
        text.push('\n');
        std::fs::write(path, text).with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<CostModel> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&v).with_context(|| format!("loading cost model {}", path.display()))
    }

    /// Load a persisted model and apply the run's R² validity gate in
    /// one step — the shared entry point for `tune --load`,
    /// `serve --load` and `load --load` (group usability is evaluated
    /// against the *consumer's* gate, not the one the artifact was
    /// fitted under).
    pub fn load_with_gate(path: &Path, r2_min: f64) -> Result<CostModel> {
        let mut cm = Self::load(path)?;
        cm.set_r2_min(r2_min);
        Ok(cm)
    }

    /// Render the fit summary as a harness table.
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Cost model: per-(model, class, fused, tiled) linear fits over {} samples (R² gate {})",
                self.samples.len(),
                self.r2_min
            ),
            &["Model", "Class", "Fused", "Tiled", "Samples", "R²", "Status"],
        );
        for g in &self.groups {
            let (r2, status) = match &g.fit {
                Some(f) if f.usable(self.r2_min) => (format!("{:.4}", f.r2), "ok".to_string()),
                Some(f) if f.r2.is_finite() => {
                    (format!("{:.4}", f.r2), "fallback: R² below gate".to_string())
                }
                Some(_) => ("NaN".to_string(), "fallback: degenerate targets".to_string()),
                None => ("-".to_string(), "fallback: no fit (rank/samples)".to_string()),
            };
            t.row(vec![
                g.model.clone(),
                g.class.clone(),
                g.fused.to_string(),
                g.tiled.to_string(),
                g.n_samples.to_string(),
                r2,
                status,
            ]);
        }
        t
    }
}

fn tile_dim_to_json(d: usize) -> Json {
    // usize::MAX means "full extent" and cannot round-trip through the
    // f64 JSON number space; persist it as 0 (never a valid tile dim).
    Json::Num(if d == usize::MAX { 0.0 } else { d as f64 })
}

fn tile_dim_from_json(v: &Json) -> Result<usize> {
    let d = v.as_usize().ok_or_else(|| err!("tile dimension not an unsigned integer"))?;
    Ok(if d == 0 { usize::MAX } else { d })
}

fn sample_to_json(s: &Sample) -> Json {
    let mut m = BTreeMap::new();
    m.insert("model".into(), Json::Str(s.model.clone()));
    m.insert("class".into(), Json::Str(s.class.clone()));
    m.insert("planes".into(), Json::Num(s.planes as f64));
    m.insert("rows".into(), Json::Num(s.rows as f64));
    m.insert("cols".into(), Json::Num(s.cols as f64));
    m.insert("kernel_width".into(), Json::Num(s.kernel_width as f64));
    match s.tile {
        Some(t) => {
            m.insert("tile_rows".into(), tile_dim_to_json(t.rows));
            m.insert("tile_cols".into(), tile_dim_to_json(t.cols));
        }
        None => {
            m.insert("tile_rows".into(), Json::Null);
            m.insert("tile_cols".into(), Json::Null);
        }
    }
    m.insert("fused".into(), Json::Bool(s.fused));
    m.insert("agglomeration".into(), Json::Num(s.agglomeration as f64));
    m.insert("units".into(), Json::Num(s.units as f64));
    m.insert("workers".into(), Json::Num(s.workers as f64));
    m.insert("ms".into(), Json::Num(s.ms));
    m.insert("reps".into(), Json::Num(s.reps as f64));
    m.insert("warmup".into(), Json::Num(s.warmup as f64));
    Json::Obj(m)
}

fn sample_from_json(v: &Json) -> Result<Sample> {
    let tile = match (v.get("tile_rows"), v.get("tile_cols")) {
        (Json::Null, Json::Null) => None,
        (r, c) => Some(TileSpec::new(tile_dim_from_json(r)?, tile_dim_from_json(c)?)),
    };
    Ok(Sample {
        model: v.req_str("model")?.to_string(),
        // pre-class artifacts carry no class field; everything they
        // measured was the separable ladder.
        class: v.get("class").as_str().unwrap_or("separable").to_string(),
        planes: v.req_usize("planes")?,
        rows: v.req_usize("rows")?,
        cols: v.req_usize("cols")?,
        kernel_width: v.req_usize("kernel_width")?,
        tile,
        fused: v.req_bool("fused")?,
        agglomeration: v.req_usize("agglomeration")?,
        units: v.req_usize("units")?,
        workers: v.req_usize("workers")?,
        ms: v.req_f64("ms")?,
        reps: v.req_usize("reps")?,
        warmup: v.req_usize("warmup")?,
    })
}

fn group_to_json(g: &GroupFit) -> Json {
    let mut m = BTreeMap::new();
    m.insert("model".into(), Json::Str(g.model.clone()));
    m.insert("class".into(), Json::Str(g.class.clone()));
    m.insert("fused".into(), Json::Bool(g.fused));
    m.insert("tiled".into(), Json::Bool(g.tiled));
    m.insert("n_samples".into(), Json::Num(g.n_samples as f64));
    match &g.fit {
        Some(f) => {
            m.insert("coeffs".into(), Json::Arr(f.coeffs.iter().map(|c| Json::Num(*c)).collect()));
            m.insert("r2".into(), Json::Num(f.r2));
            m.insert("n".into(), Json::Num(f.n as f64));
        }
        None => {
            m.insert("coeffs".into(), Json::Null);
            m.insert("r2".into(), Json::Null);
            m.insert("n".into(), Json::Null);
        }
    }
    Json::Obj(m)
}

fn group_from_json(v: &Json) -> Result<GroupFit> {
    // `null` coefficients — whether the whole array or any entry (a
    // non-finite coefficient serializes as null) — mean *invalid
    // model*, never zero: silently zeroing a coefficient would turn a
    // known-bad fit into confidently wrong predictions.
    let fit = match v.get("coeffs") {
        Json::Null => None,
        Json::Arr(cs) => {
            let coeffs: Vec<f64> = cs.iter().filter_map(|c| c.as_f64()).collect();
            if coeffs.len() != NFEATURES + 1 || cs.len() != NFEATURES + 1 {
                None
            } else {
                // null r2 (NaN at save time) loads as NaN → unusable.
                let r2 = v.get("r2").as_f64().unwrap_or(f64::NAN);
                let n = v.get("n").as_usize().unwrap_or(0);
                Some(LinearModel { coeffs, r2, n })
            }
        }
        other => bail!("coeffs is neither null nor an array: {other}"),
    };
    Ok(GroupFit {
        model: v.req_str("model")?.to_string(),
        class: v.get("class").as_str().unwrap_or("separable").to_string(),
        fused: v.req_bool("fused")?,
        tiled: v.req_bool("tiled")?,
        n_samples: v.req_usize("n_samples")?,
        fit,
    })
}

/// Predicted-vs-measured accuracy table over a shape set (shared by
/// `phi-conv tune --predict` and `cargo bench --bench costmodel`). For
/// each (model, size) the cost model's chosen candidate is built as a
/// real plan and measured; rows report predicted ms, measured ms, and
/// relative error — or name the low-R² sweep fallback when the model
/// declines to predict.
pub fn accuracy_table(cfg: &RunConfig, cm: &CostModel, sizes: &[usize]) -> Result<Table> {
    cfg.validate()?;
    let kernel = cfg.kernel_spec();
    let mut out = Table::new(
        format!(
            "Cost-model accuracy: predicted vs measured ms ({} planes, w{} kernel, {} threads)",
            cfg.planes, cfg.kernel_width, cfg.threads
        ),
        &["Model", "Shape", "Chosen config", "Predicted ms", "Measured ms", "Rel err"],
    );
    let openmp = OpenMpModel::new(cfg.threads);
    let opencl = OpenClModel::new(cfg.threads, 16);
    let gprm = GprmModel::new(cfg.threads, cfg.cutoff).with_agglomeration(cfg.agglomeration.max(1));
    let mut gprm_variants: std::collections::HashMap<usize, GprmModel> =
        std::collections::HashMap::new();
    for &size in sizes {
        let img = synth_image(cfg.planes, size, size, cfg.pattern, cfg.seed);
        for model_ix in 0..3usize {
            let base: &dyn ExecutionModel = match model_ix {
                0 => &openmp,
                1 => &opencl,
                _ => &gprm,
            };
            let shape = format!("{}x{size}x{size} w{}", cfg.planes, cfg.kernel_width);
            let Some(pred) = cm.choose(
                base.name(),
                cfg.planes,
                size,
                size,
                cfg.kernel_width,
                base.workers(),
            ) else {
                out.row(vec![
                    base.name().to_string(),
                    shape,
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "low-R² fallback (sweep)".to_string(),
                ]);
                continue;
            };
            let cand = pred.candidate;
            let model: &dyn ExecutionModel = if model_ix == 2 && cand.agglomeration > 1 {
                &*gprm_variants
                    .entry(cand.agglomeration)
                    .or_insert_with(|| gprm.respawn_with_agglomeration(cand.agglomeration))
            } else {
                base
            };
            let plan = ConvPlan::builder()
                .kernel(kernel)
                .kernel_class(cand.class)
                .tile_opt(cand.tile)
                .fuse(cand.fused)
                .shape(cfg.planes, size, size)
                .build()?;
            let mut arena = ScratchArena::new();
            let measured = time_reps(
                || plan.execute_discard(Some(model), &img, &mut arena).expect("accuracy execution"),
                cfg.warmup,
                cfg.reps,
            )
            .median();
            let rel = if measured > 0.0 { (pred.ms - measured).abs() / measured } else { 0.0 };
            out.row(vec![
                base.name().to_string(),
                shape,
                cand.label(),
                format!("{:.3}", pred.ms),
                format!("{measured:.3}"),
                format!("{:.1}%", rel * 100.0),
            ]);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::KernelClass;

    fn sample(
        model: &str,
        rows: usize,
        cols: usize,
        width: usize,
        tile: Option<TileSpec>,
        fused: bool,
        ms: f64,
    ) -> Sample {
        class_sample(model, "separable", rows, cols, width, tile, fused, ms)
    }

    #[allow(clippy::too_many_arguments)]
    fn class_sample(
        model: &str,
        class: &str,
        rows: usize,
        cols: usize,
        width: usize,
        tile: Option<TileSpec>,
        fused: bool,
        ms: f64,
    ) -> Sample {
        let workers = 4;
        Sample {
            model: model.to_string(),
            class: class.to_string(),
            planes: 3,
            rows,
            cols,
            kernel_width: width,
            tile,
            fused,
            agglomeration: 1,
            units: dispatch_units(rows, cols, tile, workers),
            workers,
            ms,
            reps: 3,
            warmup: 1,
        }
    }

    /// Linear ground truth used by the synthetic tests; the multiplier
    /// makes (fused=false, tiled=false) the most expensive group so
    /// choose() has a real decision to make.
    fn truth_ms(fused: bool, tiled: bool, f: &[f64; NFEATURES]) -> f64 {
        let base = 0.2 + 1.5e-6 * f[0] + 2.0e-7 * f[2] + 1e-3 * f[3];
        let mult = match (fused, tiled) {
            (false, false) => 4.0,
            (true, false) => 3.0,
            (false, true) => 2.0,
            (true, true) => 1.0,
        };
        base * mult
    }

    fn synthetic_samples(model: &str) -> Vec<Sample> {
        let mut out = Vec::new();
        let tiles = [None, Some(TileSpec::new(16, usize::MAX)), Some(TileSpec::new(32, 32))];
        for (rows, cols) in [(64, 64), (80, 96), (96, 128), (128, 128), (160, 96), (192, 192)] {
            for width in [3usize, 5, 7] {
                for tile in tiles {
                    for fused in [false, true] {
                        let units = dispatch_units(rows, cols, tile, 4);
                        let f = features(3, rows, cols, width, units);
                        let ms = truth_ms(fused, tile.is_some(), &f);
                        out.push(sample(model, rows, cols, width, tile, fused, ms));
                    }
                }
            }
        }
        out
    }

    #[test]
    fn fit_groups_and_predicts_noise_free_truth() {
        let cm = CostModel::fit(synthetic_samples("OpenMP"), 0.8);
        assert_eq!(cm.groups().len(), 4, "2 fused × 2 tiled groups");
        assert_eq!(cm.usable_groups(), 4);
        for g in cm.groups() {
            let f = g.fit.as_ref().expect("noise-free fit");
            assert!(f.r2 > 0.999999, "{:?}: r2 {}", (g.fused, g.tiled), f.r2);
        }
        // Held-out shape: 100x100 is not in the training grid.
        for fused in [false, true] {
            for tile in [None, Some(TileSpec::new(32, 32))] {
                let units = dispatch_units(100, 100, tile, 4);
                let want = truth_ms(fused, tile.is_some(), &features(3, 100, 100, 5, units));
                let got = cm
                    .predict_ms("OpenMP", fused, tile, 3, 100, 100, 5, 4)
                    .expect("usable group");
                assert!(
                    (got - want).abs() <= 1e-6 * want,
                    "fused={fused} tile={tile:?}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn chain_prediction_is_the_sum_of_stage_fits() {
        let cm = CostModel::fit(synthetic_samples("OpenMP"), 0.8);
        let widths = [3usize, 5, 7];
        let want: f64 = widths
            .iter()
            .map(|&w| cm.predict_ms("OpenMP", true, None, 3, 100, 100, w, 4).unwrap())
            .sum();
        let got = cm.predict_chain_ms("OpenMP", 3, 100, 100, &widths, 4).expect("usable fits");
        assert_eq!(got.to_bits(), want.to_bits(), "chain = sum of fused stage fits");
        // any unpredictable stage poisons the whole chain prediction
        assert!(cm.predict_chain_ms("NoSuchModel", 3, 100, 100, &widths, 4).is_none());
        assert!(cm.predict_chain_ms("OpenMP", 3, 100, 100, &[], 4).is_none());
    }

    #[test]
    fn choose_prefers_cheapest_group_and_keeps_baseline_comparison() {
        let cm = CostModel::fit(synthetic_samples("OpenMP"), 0.8);
        let p = cm.choose("OpenMP", 3, 100, 100, 5, 4).expect("predictable");
        // truth makes fused+tiled 4x cheaper than the untiled baseline
        assert!(p.candidate.fused, "fused wins by construction: {:?}", p.candidate);
        assert!(p.candidate.tile.is_some(), "tiled wins by construction: {:?}", p.candidate);
        assert!(p.ms <= p.baseline_ms, "winner never predicted worse than baseline");
        assert!(p.baseline_ms / p.ms > 2.0, "the 4x multiplier should show through");
        // unknown model name → no baseline group → sweep fallback
        assert!(cm.choose("NoSuchModel", 3, 100, 100, 5, 4).is_none());
    }

    #[test]
    fn low_r2_gate_forces_fallback() {
        // Noise swamps the signal → R² collapses → choose() declines.
        let mut prng = crate::util::prng::Prng::new(0xbad_f17);
        let mut samples = synthetic_samples("OpenMP");
        for s in &mut samples {
            let u = (prng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
            s.ms = 1.0 + 100.0 * u; // unrelated to the features
        }
        let cm = CostModel::fit(samples, 0.8);
        assert_eq!(cm.usable_groups(), 0, "noise must not pass an 0.8 R² gate");
        assert!(cm.choose("OpenMP", 3, 100, 100, 5, 4).is_none());
        // the fits exist but are gated — to_table names the fallback
        let text = cm.to_table().to_text();
        assert!(text.contains("fallback"), "table: {text}");
    }

    #[test]
    fn per_class_fits_route_large_kernels_to_fft() {
        // Direct-arithmetic classes cost ∝ pixels·width; the transform
        // class is flat in width. The fitted groups must reproduce the
        // crossover so a never-swept large kernel routes to FFT.
        let mut samples = Vec::new();
        for (rows, cols) in [(64, 64), (96, 96), (128, 128), (160, 160), (192, 192), (128, 192)] {
            for width in [3usize, 7, 15, 31, 61] {
                let f = features(3, rows, cols, width, 4);
                samples.push(class_sample(
                    "OpenMP", "separable", rows, cols, width, None, false,
                    0.1 + 1.0e-6 * f[2],
                ));
                samples.push(class_sample(
                    "OpenMP", "direct2d", rows, cols, width, None, false,
                    0.1 + 2.0e-6 * f[2],
                ));
                samples.push(class_sample(
                    "OpenMP", "fft", rows, cols, width, None, false,
                    0.4 + 6.0e-6 * f[0],
                ));
            }
        }
        let cm = CostModel::fit(samples, 0.8);
        // small kernel on a held-out shape: the separable baseline wins
        let p = cm.choose("OpenMP", 3, 100, 100, 3, 4).expect("predictable");
        assert_eq!(p.candidate.class, KernelClass::Separable, "small kernel: {:?}", p.candidate);
        // large never-seen kernel: the fft group predicts cheaper
        let p = cm.choose("OpenMP", 3, 100, 100, 63, 4).expect("predictable");
        assert_eq!(p.candidate.class, KernelClass::Fft, "large kernel: {:?}", p.candidate);
        assert!(p.ms < p.baseline_ms, "{} !< {}", p.ms, p.baseline_ms);
        // choose() compared exactly what the per-class twin predicts
        let fft_ms = cm
            .predict_class_ms("OpenMP", "fft", false, None, 3, 100, 100, 63, 4)
            .expect("fft group usable");
        assert_eq!(p.ms.to_bits(), fft_ms.to_bits());
        // the legacy signature still means the separable class
        let sep = cm.predict_ms("OpenMP", false, None, 3, 100, 100, 63, 4).unwrap();
        let sep_explicit =
            cm.predict_class_ms("OpenMP", "separable", false, None, 3, 100, 100, 63, 4).unwrap();
        assert_eq!(sep.to_bits(), sep_explicit.to_bits());
    }

    #[test]
    fn json_roundtrip_is_bitwise_for_predictions() {
        let cm = CostModel::fit(synthetic_samples("GPRM"), 0.8);
        let reloaded = CostModel::from_json(&Json::parse(&cm.to_json().to_string()).unwrap())
            .expect("artifact loads");
        assert_eq!(reloaded.samples().len(), cm.samples().len());
        assert_eq!(reloaded.groups(), cm.groups(), "coefficients restored verbatim");
        for fused in [false, true] {
            for tile in [None, Some(TileSpec::new(16, usize::MAX))] {
                let a = cm.predict_ms("GPRM", fused, tile, 3, 100, 100, 5, 4).unwrap();
                let b = reloaded.predict_ms("GPRM", fused, tile, 3, 100, 100, 5, 4).unwrap();
                assert_eq!(a.to_bits(), b.to_bits(), "bitwise-identical predictions");
            }
        }
        assert_eq!(cm.choose("GPRM", 3, 100, 100, 5, 4), reloaded.choose("GPRM", 3, 100, 100, 5, 4));
    }

    #[test]
    fn null_coefficients_load_as_invalid_model_not_zero() {
        let text = r#"{
            "bench": "costmodel", "r2_min": 0.8,
            "features": ["pixels", "width", "pixels_width", "units"],
            "samples": [],
            "models": [
                {"model": "OpenMP", "fused": false, "tiled": false,
                 "n_samples": 9, "coeffs": null, "r2": null, "n": null},
                {"model": "OpenMP", "fused": true, "tiled": false,
                 "n_samples": 9, "coeffs": [0.1, null, 0.0, 0.0, 0.0],
                 "r2": 0.99, "n": 9}
            ]
        }"#;
        let cm = CostModel::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(cm.groups().len(), 2);
        assert!(cm.groups()[0].fit.is_none(), "null coeffs → no model");
        assert!(cm.groups()[1].fit.is_none(), "a null entry inside coeffs → no model, not zero");
        assert_eq!(cm.usable_groups(), 0);
        assert!(cm.choose("OpenMP", 3, 100, 100, 5, 4).is_none());
    }

    #[test]
    fn loader_rejects_wrong_feature_layout() {
        let text = r#"{"bench": "costmodel", "r2_min": 0.8,
            "features": ["pixels", "width"], "samples": [], "models": []}"#;
        assert!(CostModel::from_json(&Json::parse(text).unwrap()).is_err());
        let text = r#"{"bench": "serving"}"#;
        assert!(CostModel::from_json(&Json::parse(text).unwrap()).is_err());
    }

    #[test]
    fn tile_dims_roundtrip_including_full_extent() {
        let s = sample("OpenCL", 64, 64, 5, Some(TileSpec::new(16, usize::MAX)), true, 1.0);
        let back = sample_from_json(&sample_to_json(&s)).unwrap();
        assert_eq!(back, s, "usize::MAX tile extent survives via the 0 convention");
        let s = sample("OpenCL", 64, 64, 5, None, false, 1.0);
        assert_eq!(sample_from_json(&sample_to_json(&s)).unwrap(), s);
    }
}
