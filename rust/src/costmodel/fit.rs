//! In-tree least-squares regression core for the cost model.
//!
//! Fits `predicted_ms = c0 + c1·pixels + c2·width + c3·pixels·width +
//! c4·units` by normal equations — four features plus an intercept is
//! well inside the regime where that is numerically fine *provided* the
//! design is not rank-deficient. Real tune data is rank-deficient all
//! the time (one tune run holds the kernel width constant, so the width
//! column is collinear with the intercept and pixels·width with pixels),
//! so [`fit`] prunes dependent columns by greedy Gram–Schmidt before
//! solving and reports the dropped columns as exact-zero coefficients.
//! Degenerate designs never panic: they come back as `None` (too few
//! samples for the surviving columns, singular system) or as a model
//! whose R² fails [`LinearModel::usable`] (zero-variance targets → NaN
//! R²), and every `None`/unusable outcome routes the caller back to
//! empirical sweeping.

/// Number of regression features (the intercept is implicit and comes
/// first in [`LinearModel::coeffs`]).
pub const NFEATURES: usize = 4;

/// Feature names, in the exact order of the feature vector. Persisted
/// artifacts embed this list so a loader can reject files written for a
/// different feature layout.
pub const FEATURE_NAMES: [&str; NFEATURES] = ["pixels", "width", "pixels_width", "units"];

/// A fitted linear model for one (model, fused, tiled) sample group.
#[derive(Debug, Clone, PartialEq)]
pub struct LinearModel {
    /// `NFEATURES + 1` coefficients, intercept first. Columns pruned as
    /// linearly dependent during fitting hold exactly `0.0`.
    pub coeffs: Vec<f64>,
    /// Coefficient of determination on the training set. `NaN` when the
    /// targets had zero variance (all-identical samples) — NaN fails
    /// every `>=` comparison, so such a model is never usable, and it
    /// serializes as JSON `null`, which the loader maps back to an
    /// invalid model rather than to zero.
    pub r2: f64,
    /// Number of training samples.
    pub n: usize,
}

impl LinearModel {
    /// Predicted milliseconds for a feature vector. Fixed evaluation
    /// order (intercept, then features in declaration order) so a
    /// saved-then-loaded model reproduces in-memory predictions
    /// bitwise.
    pub fn predict(&self, feats: &[f64; NFEATURES]) -> f64 {
        let mut ms = self.coeffs[0];
        for (i, f) in feats.iter().enumerate() {
            ms += self.coeffs[i + 1] * f;
        }
        ms
    }

    /// Whether the fit is trustworthy at an acceptance threshold.
    /// NaN R² (degenerate fit, or a `null` in a loaded artifact) is
    /// never usable.
    pub fn usable(&self, r2_min: f64) -> bool {
        self.r2.is_finite() && self.r2 >= r2_min
    }
}

/// Least-squares fit of `ys` against the feature rows `xs`.
///
/// Returns `None` — the structured "fall back to sweeping" signal —
/// when the design cannot support a fit at all: mismatched or empty
/// input, fewer samples than surviving columns + 2, a singular system,
/// or non-finite fitted coefficients. Rank deficiency short of that is
/// handled by pruning: columns are max-abs scaled, then admitted in
/// order (intercept, then features) only if their residual after
/// projecting onto the already-kept columns exceeds `1e-6` of their own
/// norm; pruned columns get coefficient exactly `0.0`.
pub fn fit(xs: &[[f64; NFEATURES]], ys: &[f64]) -> Option<LinearModel> {
    let n = xs.len();
    if n == 0 || ys.len() != n {
        return None;
    }
    if xs.iter().flatten().any(|v| !v.is_finite()) || ys.iter().any(|v| !v.is_finite()) {
        return None;
    }

    // Design matrix columns: intercept first, then the features.
    let ncols = NFEATURES + 1;
    let mut cols: Vec<Vec<f64>> = Vec::with_capacity(ncols);
    cols.push(vec![1.0; n]);
    for j in 0..NFEATURES {
        cols.push(xs.iter().map(|row| row[j]).collect());
    }

    // Max-abs scaling keeps the Gram matrix conditioned despite feature
    // magnitudes spanning ~1 (width) to ~1e7 (pixels·width).
    let mut scale = vec![0.0f64; ncols];
    for (j, col) in cols.iter_mut().enumerate() {
        let m = col.iter().fold(0.0f64, |acc, v| acc.max(v.abs()));
        scale[j] = m;
        if m > 0.0 {
            for v in col.iter_mut() {
                *v /= m;
            }
        }
    }

    // Greedy Gram–Schmidt column pruning: keep a column only if it adds
    // direction beyond the columns already kept. A constant feature
    // folds into the intercept; pixels·width under constant width folds
    // into pixels; an all-zero column never survives scaling.
    const PRUNE_REL: f64 = 1e-6;
    let mut kept: Vec<usize> = Vec::with_capacity(ncols);
    let mut basis: Vec<Vec<f64>> = Vec::with_capacity(ncols);
    for (j, col) in cols.iter().enumerate() {
        if scale[j] == 0.0 {
            continue;
        }
        let norm0 = dot(col, col).sqrt();
        if norm0 == 0.0 {
            continue;
        }
        let mut resid = col.clone();
        for q in &basis {
            let proj = dot(&resid, q);
            for (r, qv) in resid.iter_mut().zip(q) {
                *r -= proj * qv;
            }
        }
        let rnorm = dot(&resid, &resid).sqrt();
        if rnorm <= PRUNE_REL * norm0 {
            continue;
        }
        for v in resid.iter_mut() {
            *v /= rnorm;
        }
        basis.push(resid);
        kept.push(j);
    }
    let k = kept.len();
    // Require a little slack beyond exact interpolation; an exactly- or
    // under-determined system has no error structure to trust.
    if k == 0 || n < k + 2 {
        return None;
    }

    // Normal equations on the kept, scaled columns.
    let mut a = vec![vec![0.0f64; k]; k];
    let mut b = vec![0.0f64; k];
    for (ri, &ci) in kept.iter().enumerate() {
        for (rj, &cj) in kept.iter().enumerate() {
            a[ri][rj] = dot(&cols[ci], &cols[cj]);
        }
        b[ri] = dot(&cols[ci], ys);
    }
    let solved = solve(&mut a, &mut b)?;

    // Unscale back to raw-feature coefficients; pruned columns are
    // exactly zero.
    let mut coeffs = vec![0.0f64; ncols];
    for (ri, &ci) in kept.iter().enumerate() {
        coeffs[ci] = solved[ri] / scale[ci];
    }
    if coeffs.iter().any(|c| !c.is_finite()) {
        return None;
    }

    // R² on the training set, computed from the raw features in the
    // same order predict() uses.
    let mean = ys.iter().sum::<f64>() / n as f64;
    let mut ss_res = 0.0f64;
    let mut ss_tot = 0.0f64;
    let model = LinearModel { coeffs, r2: f64::NAN, n };
    for (row, &y) in xs.iter().zip(ys) {
        let e = y - model.predict(row);
        ss_res += e * e;
        let d = y - mean;
        ss_tot += d * d;
    }
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { f64::NAN };
    Some(LinearModel { r2, ..model })
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Gaussian elimination with partial pivoting; `None` on a (near-)
/// singular pivot. Column pruning should prevent that, but measured
/// noise can still produce pathological Gram matrices.
fn solve(a: &mut [Vec<f64>], b: &mut [f64]) -> Option<Vec<f64>> {
    let k = b.len();
    for col in 0..k {
        let pivot = (col..k)
            .max_by(|&i, &j| a[i][col].abs().total_cmp(&a[j][col].abs()))
            .expect("non-empty pivot range");
        if a[pivot][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, pivot);
        b.swap(col, pivot);
        for row in col + 1..k {
            let f = a[row][col] / a[col][col];
            for c in col..k {
                a[row][c] -= f * a[col][c];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0f64; k];
    for col in (0..k).rev() {
        let mut v = b[col];
        for c in col + 1..k {
            v -= a[col][c] * x[c];
        }
        x[col] = v / a[col][col];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn truth(c: [f64; NFEATURES + 1], f: &[f64; NFEATURES]) -> f64 {
        c[0] + c[1] * f[0] + c[2] * f[1] + c[3] * f[2] + c[4] * f[3]
    }

    fn grid() -> Vec<[f64; NFEATURES]> {
        let mut xs = Vec::new();
        for s in [64.0f64, 96.0, 128.0, 192.0] {
            for w in [3.0f64, 5.0, 7.0] {
                for units in [4.0f64, 16.0, 64.0] {
                    let pixels = 3.0 * s * s;
                    xs.push([pixels, w, pixels * w, units]);
                }
            }
        }
        xs
    }

    #[test]
    fn recovers_known_coefficients() {
        let c = [0.4, 2.5e-6, 0.02, 3.0e-7, 0.005];
        let xs = grid();
        let ys: Vec<f64> = xs.iter().map(|f| truth(c, f)).collect();
        let m = fit(&xs, &ys).expect("full-rank design fits");
        assert!(m.r2 > 0.999999, "noise-free fit: r2 = {}", m.r2);
        assert!(m.usable(0.8));
        assert_eq!(m.n, xs.len());
        for (got, want) in m.coeffs.iter().zip(&c) {
            assert!(
                (got - want).abs() <= 1e-6 * want.abs().max(1.0),
                "coefficient {got} vs {want}"
            );
        }
        let probe = [3.0 * 100.0 * 100.0, 5.0, 3.0 * 100.0 * 100.0 * 5.0, 8.0];
        let err = (m.predict(&probe) - truth(c, &probe)).abs();
        assert!(err <= 1e-6 * truth(c, &probe), "held-out prediction error {err}");
    }

    #[test]
    fn constant_width_folds_into_intercept_and_pixels() {
        // One tune run: width fixed at 5 → width collinear with the
        // intercept, pixels·width exactly collinear with pixels. Naive
        // normal equations are singular here; pruning must absorb both
        // into the kept columns and still predict perfectly at width 5.
        let c = [0.4, 2.5e-6, 0.02, 3.0e-7, 0.005];
        let xs: Vec<[f64; NFEATURES]> = grid().into_iter().filter(|f| f[1] == 5.0).collect();
        let ys: Vec<f64> = xs.iter().map(|f| truth(c, f)).collect();
        let m = fit(&xs, &ys).expect("rank-deficient design still fits after pruning");
        assert!(m.r2 > 0.999999, "r2 = {}", m.r2);
        assert_eq!(m.coeffs[2], 0.0, "width column pruned to exact zero");
        assert_eq!(m.coeffs[3], 0.0, "pixels·width column pruned to exact zero");
        for f in &xs {
            let err = (m.predict(f) - truth(c, f)).abs();
            assert!(err <= 1e-6 * truth(c, f), "in-slice prediction error {err}");
        }
    }

    #[test]
    fn fewer_samples_than_columns_is_structured_none() {
        let c = [0.4, 2.5e-6, 0.02, 3.0e-7, 0.005];
        let xs: Vec<[f64; NFEATURES]> = grid().into_iter().take(4).collect();
        let ys: Vec<f64> = xs.iter().map(|f| truth(c, f)).collect();
        assert!(fit(&xs, &ys).is_none(), "n < kept + 2 must refuse, not panic");
        assert!(fit(&[], &[]).is_none());
        assert!(fit(&xs, &ys[..2]).is_none(), "length mismatch refuses");
    }

    #[test]
    fn identical_samples_yield_unusable_model_not_panic() {
        let f = [3.0 * 64.0 * 64.0, 5.0, 3.0 * 64.0 * 64.0 * 5.0, 4.0];
        let xs = vec![f; 8];
        let ys = vec![1.25f64; 8];
        // Every feature column is constant → pruned into the intercept;
        // zero target variance → NaN R² → unusable at any threshold.
        let m = fit(&xs, &ys).expect("intercept-only fit succeeds");
        assert!(m.r2.is_nan(), "zero-variance targets give NaN R²");
        assert!(!m.usable(0.0));
        assert!(!m.usable(0.8));
        assert!((m.predict(&f) - 1.25).abs() < 1e-12, "intercept carries the mean");
    }

    #[test]
    fn non_finite_inputs_refused() {
        let xs = grid();
        let mut ys: Vec<f64> = xs.iter().map(|f| truth([0.4, 1e-6, 0.0, 0.0, 0.0], f)).collect();
        ys[3] = f64::NAN;
        assert!(fit(&xs, &ys).is_none());
        let mut xs2 = xs.clone();
        xs2[0][0] = f64::INFINITY;
        let ys2 = vec![1.0; xs2.len()];
        assert!(fit(&xs2, &ys2).is_none());
    }
}
