//! # phi-conv
//!
//! Reproduction of *"2D Image Convolution using Three Parallel Programming
//! Models on the Xeon Phi"* (Tousimojarad, Vanderbauwhede, Cockshott, 2017)
//! as a three-layer Rust + JAX + Pallas system.
//!
//! The paper benchmarks separable 5×5 Gaussian convolution under three
//! parallel programming models — OpenMP, OpenCL and GPRM — on a 60-core
//! Intel Xeon Phi 5110P. This crate rebuilds every piece of that study:
//!
//! * [`image`] — planar f32 images, synthetic generators, PGM/PPM I/O and
//!   Gaussian kernel construction (the data substrate).
//! * [`conv`] — native convolution engines mirroring the paper's
//!   optimisation ladder: naive, unrolled, SIMD-shaped, two-pass,
//!   single-pass-no-copy (the algorithm substrate), at width 5 (unrolled
//!   fast path) and any odd width (generic engines).
//! * [`plan`] — the execution-plan layer: a validating builder resolves
//!   `{algorithm, variant, layout, kernel, tile, fuse, shape}` into a
//!   [`plan::ConvPlan`] pass pipeline that every consumer (sequential
//!   drivers, parallel driver, coordinator, harness, benches) executes
//!   through, against a reusable [`plan::ScratchArena`] (fused plans
//!   lease per-worker row-rings from it).
//! * [`models`] — the paper's three parallel programming models as
//!   pluggable execution engines over a shared worker-pool substrate:
//!   OpenMP-style fork-join static chunking, OpenCL-style NDRange
//!   work-groups, and GPRM-style task graphs with cutoff + stealing +
//!   task agglomeration. Both row-range `dispatch` and 2-D tiled
//!   `dispatch2d` (the agglomeration axis) are part of the contract.
//! * [`autotune`] — sweeps tile shapes and agglomeration factors per
//!   (model, image shape, kernel width), mirrors the paper's
//!   agglomeration experiment as a harness table, and keeps the winners
//!   in an in-memory tuning table (`phi-conv tune`).
//! * [`costmodel`] — regression-fit plan selection: per-(model, fused,
//!   tiled) linear cost models fitted from autotune samples with
//!   R²-gated validity, persisted as `BENCH_costmodel.json`, consulted
//!   by the tuning table and coordinator admission for
//!   never-before-seen shapes (`phi-conv tune --save/--load/--predict`).
//! * [`phisim`] — a calibrated analytic timing model of the Xeon Phi
//!   5110P that regenerates the paper's Tables 1–2 and Figures 1–4
//!   (the hardware substitute; DESIGN.md §1).
//! * [`runtime`] — the PJRT bridge: loads the AOT-compiled HLO artifacts
//!   produced by the Python/Pallas build path and executes them on the
//!   request path with no Python anywhere.
//! * [`coordinator`] — request router + batcher serving convolution jobs
//!   through any execution model (the L3 serving loop).
//! * [`loadgen`] — the scale-factor load harness: deterministic
//!   Zipf-skewed traffic mixes (seeded PRNG, no wall-clock in the plan)
//!   driving the coordinator end-to-end under open-loop Poisson or
//!   closed-loop workers, reporting p50/p95/p99 latency, shed/expired
//!   rates and batch/plan-decision mixes per scale factor
//!   (`phi-conv load`, `BENCH_load.json`).
//! * [`metrics`] — timing statistics, latency histograms and
//!   paper-style table rendering.
//! * [`harness`] — one generator per paper exhibit (fig1…fig4, table1,
//!   table2) in both *simulated* (phisim) and *measured* (host) modes.
//! * [`config`] — TOML + CLI configuration for all of the above.
//! * [`util`] — in-tree infrastructure substrates (JSON, TOML, CLI, PRNG);
//!   the offline build has no access to crates.io beyond the vendored
//!   `xla` closure, so these are built from scratch (DESIGN.md §1).

// CI runs `cargo clippy -- -D warnings`; these lints are allowlisted
// crate-wide because the flagged shapes are deliberate here: the band
// kernels take the paper's full (src, dst, rows, cols, taps, band)
// argument tuple, and indexed numeric loops are kept in the exact form
// whose auto-vectorisation we measure (rewriting them for the lint
// would change the benchmark subject).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_memcpy)]
#![allow(clippy::new_without_default)]

// `util` must be declared first and with `#[macro_use]`: `util::error`'s
// `macro_rules!` macros (`err!`, `bail!`, `ensure!`) are textually
// scoped, and the modules below use them unqualified. (External crates —
// tests, benches, the binary — import them as `use phi_conv::{bail, …}`,
// which `#[macro_export]` provides.)
#[macro_use]
pub mod util;

pub mod autotune;
pub mod config;
pub mod conv;
pub mod coordinator;
pub mod costmodel;
pub mod harness;
pub mod image;
pub mod loadgen;
pub mod metrics;
pub mod models;
pub mod phisim;
pub mod plan;
pub mod runtime;

/// Crate-wide error and result types (see [`util::error`]).
pub use util::error::{Context, Error, ErrorKind, Result};
