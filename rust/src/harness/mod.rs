//! Bench harness: one generator per paper exhibit (DESIGN.md §6).
//!
//! Each exhibit exists twice:
//! * **simulated** ([`sim_tables`]) — the phisim cost model at the
//!   paper's sizes, printed side-by-side with the paper's values;
//! * **measured** ([`measured`]) — real host runs of the native engines
//!   under the three execution models at the scaled sizes.
//!
//! `phi-conv bench-table <exhibit> [--measured]` is the CLI entry;
//! `cargo bench` runs the same generators under `rust/benches/`.
//!
//! Serving has its own macro-exhibit outside this module: the
//! scale-factor load harness ([`crate::loadgen`], `phi-conv load`,
//! `benches/loadgen.rs`) quotes the per-scale latency SLO curve.

pub mod measured;
pub mod paper;
pub mod sim_tables;

use crate::util::error::Result;

use crate::config::RunConfig;
use crate::metrics::Table;
use crate::models::Layout;

/// All exhibit names.
pub const EXHIBITS: [&str; 11] = [
    "fig1", "fig2", "fig3", "fig4", "table1", "table2", "threads", "ablations", "tiling",
    "fused", "all",
];

/// Generate the simulated rendition of an exhibit.
pub fn simulated(exhibit: &str) -> Result<Vec<Table>> {
    Ok(match exhibit {
        "fig1" => vec![sim_tables::fig1()],
        "fig2" => vec![sim_tables::fig2()],
        "fig3" => vec![sim_tables::fig3()],
        "fig4" => vec![sim_tables::fig4()],
        "table1" => vec![sim_tables::table1()],
        "table2" => vec![sim_tables::table2()],
        "threads" => vec![sim_tables::threads_sweep()],
        // ablations are host-measured only (cutoff is already a sim knob)
        "ablations" => vec![sim_tables::threads_sweep()],
        // the tiling sweep is host-measured; its simulated counterpart
        // is the paper's own agglomeration exhibit (Fig. 3)
        "tiling" => vec![sim_tables::fig3()],
        // fusion is host-measured (a memory-traffic effect the phisim
        // cost model does not separate); the closest simulated exhibit
        // is the two-pass speedup figure
        "fused" => vec![sim_tables::fig2()],
        "all" => vec![
            sim_tables::fig1(),
            sim_tables::table1(),
            sim_tables::table2(),
            sim_tables::fig2(),
            sim_tables::fig3(),
            sim_tables::fig4(),
            sim_tables::threads_sweep(),
        ],
        other => bail!("unknown exhibit {other:?}; expected one of {EXHIBITS:?}"),
    })
}

/// Generate the measured rendition of an exhibit on this host.
pub fn run_measured(exhibit: &str, cfg: &RunConfig) -> Result<Vec<Table>> {
    // structured config validation at the harness entry point — the
    // exhibit generators (and `Measured::plan`) assume a valid spec and
    // non-empty shapes
    cfg.validate()?;
    let m = measured::Measured::new(cfg);
    Ok(match exhibit {
        "fig1" => vec![m.fig1()],
        "fig2" => vec![m.fig23(Layout::PerPlane)],
        "fig3" => vec![m.fig23(Layout::Agglomerated)],
        "fig4" => vec![m.fig4()],
        "table1" => vec![m.table1()],
        "table2" => vec![m.table2()],
        "threads" => {
            let max = cfg.threads;
            let counts: Vec<usize> =
                [1, 2, max / 2, max, max * 2].into_iter().filter(|&c| c >= 1).collect();
            vec![m.threads_sweep(&counts)]
        }
        "ablations" => m.ablations(),
        // fused-vs-unfused two-pass: time plus estimated bytes moved
        "fused" => vec![m.fused()],
        "tiling" => {
            // the agglomeration-sweep exhibit: one table per size plus
            // the tuned-winner summary (see crate::autotune)
            let mut table = crate::autotune::TuningTable::new();
            let mut out = Vec::new();
            for &size in &cfg.sizes {
                out.push(crate::autotune::sweep_shape(cfg, size, &mut table)?);
            }
            out.push(table.to_table());
            out
        }
        "all" => vec![
            m.fig1(),
            m.table1(),
            m.table2(),
            m.fig23(Layout::PerPlane),
            m.fig23(Layout::Agglomerated),
            m.fig4(),
        ],
        other => bail!("unknown exhibit {other:?}; expected one of {EXHIBITS:?}"),
    })
}
