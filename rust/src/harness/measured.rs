//! Measured exhibits: the same tables as `sim_tables`, but measured on
//! the host with the native engines and real execution models.
//!
//! Sizes are the scaled-down artifact set (default 288/576/1152) so a
//! full sweep finishes in seconds; the claims being validated are the
//! *relative* ones (orderings, crossovers, vectorisation gains, overhead
//! amortisation) — DESIGN.md §2 "dual measurement strategy".

use crate::config::RunConfig;
use crate::conv::{Algorithm, Variant};
use crate::image::{gaussian_kernel, synth_image, PlanarImage};
use crate::metrics::{time_reps, Table};
use crate::models::{ExecutionModel, GprmModel, Layout, OpenClModel, OpenMpModel};
use crate::plan::{ConvPlan, ScratchArena};

/// Shared context: models are built once (pools are persistent).
pub struct Measured {
    pub cfg: RunConfig,
    pub kernel: Vec<f32>,
    pub openmp: OpenMpModel,
    pub opencl: OpenClModel,
    pub gprm: GprmModel,
}

impl Measured {
    pub fn new(cfg: &RunConfig) -> Self {
        Self {
            cfg: cfg.clone(),
            kernel: gaussian_kernel(cfg.kernel_width, cfg.sigma),
            openmp: OpenMpModel::new(cfg.threads),
            opencl: OpenClModel::new(cfg.threads, 16),
            gprm: GprmModel::new(cfg.threads, cfg.cutoff)
                .with_agglomeration(cfg.agglomeration.max(1)),
        }
    }

    fn image(&self, size: usize) -> PlanarImage {
        synth_image(self.cfg.planes, size, size, self.cfg.pattern, self.cfg.seed)
    }

    /// Build the plan a measurement runs (built once, outside the timed
    /// loop — exactly how a serving executor amortises it). Honours the
    /// run's `--fuse` default for two-pass exhibits.
    fn plan(&self, img: &PlanarImage, alg: Algorithm, variant: Variant, layout: Layout) -> ConvPlan {
        self.plan_with_fuse(img, alg, variant, layout, self.cfg.fuse && alg == Algorithm::TwoPass)
    }

    fn plan_with_fuse(
        &self,
        img: &PlanarImage,
        alg: Algorithm,
        variant: Variant,
        layout: Layout,
        fuse: bool,
    ) -> ConvPlan {
        ConvPlan::builder()
            .algorithm(alg)
            .variant(variant)
            .layout(layout)
            .kernel_taps(self.kernel.clone())
            .fuse(fuse)
            .shape(img.planes, img.rows, img.cols)
            .build()
            .expect("measured exhibit plan (validated by run_measured)")
    }

    /// median ms of one parallel convolution (arena-reusing, like the
    /// paper's 1000-rep loop over the same arrays — §Perf iteration 1)
    fn par_ms(
        &self,
        model: &dyn ExecutionModel,
        img: &PlanarImage,
        alg: Algorithm,
        variant: Variant,
        layout: Layout,
    ) -> f64 {
        let plan = self.plan(img, alg, variant, layout);
        let mut arena = ScratchArena::new();
        time_reps(
            || plan.execute_discard(Some(model), img, &mut arena).unwrap(),
            self.cfg.warmup,
            self.cfg.reps,
        )
        .median()
    }

    /// median ms of one sequential convolution (arena-reusing)
    fn seq_ms(&self, img: &PlanarImage, alg: Algorithm, variant: Variant) -> f64 {
        let plan = self.plan(img, alg, variant, Layout::PerPlane);
        let mut arena = ScratchArena::new();
        time_reps(
            || plan.execute_discard(None, img, &mut arena).unwrap(),
            self.cfg.warmup,
            self.cfg.reps,
        )
        .median()
    }

    /// Table 1 measured: vectorisation effect on the parallel two-pass.
    pub fn table1(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Table 1 (measured, host, {} threads): parallel two-pass ms/image (SIMD gain)",
                self.cfg.threads
            ),
            &["Image Size", "OpenMP no-vec", "OpenCL no-vec", "GPRM no-vec", "OpenMP SIMD", "OpenCL SIMD", "GPRM SIMD"],
        );
        for &size in &self.cfg.sizes {
            let img = self.image(size);
            let models: [&dyn ExecutionModel; 3] = [&self.openmp, &self.opencl, &self.gprm];
            let novec: Vec<f64> = models
                .iter()
                .map(|m| self.par_ms(*m, &img, Algorithm::TwoPass, Variant::Scalar, Layout::PerPlane))
                .collect();
            let simd: Vec<f64> = models
                .iter()
                .map(|m| self.par_ms(*m, &img, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane))
                .collect();
            t.row(vec![
                format!("{size}x{size}"),
                format!("{:.2}", novec[0]),
                format!("{:.2}", novec[1]),
                format!("{:.2}", novec[2]),
                format!("{:.2} ({:.1}x)", simd[0], novec[0] / simd[0]),
                format!("{:.2} ({:.1}x)", simd[1], novec[1] / simd[1]),
                format!("{:.2} ({:.1}x)", simd[2], novec[2] / simd[2]),
            ]);
        }
        t
    }

    /// Table 2 measured: totals + empty-dispatch overhead split (the
    /// paper's empty-task methodology, applied for real).
    pub fn table2(&self) -> Table {
        let mut t = Table::new(
            "Table 2 (measured): per-image ms and dispatch-overhead split",
            &["Image Size", "OpenMP", "OpenCL", "GPRM-total", "OpenCL-compute", "GPRM-compute", "GPRM-overhead"],
        );
        for &size in &self.cfg.sizes {
            let img = self.image(size);
            let omp = self.par_ms(&self.openmp, &img, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane);
            let ocl = self.par_ms(&self.opencl, &img, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane);
            let gprm = self.par_ms(&self.gprm, &img, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane);
            // empty-task probes: same dispatch count as the real run;
            // warmup follows the run config (not the old hardcoded 2)
            let dispatches = 2 * self.cfg.planes;
            let warmup = self.cfg.warmup;
            let ocl_ov =
                self.opencl.overhead_probe_with(size, warmup, 10).median() * dispatches as f64;
            let gprm_ov =
                self.gprm.overhead_probe_with(size, warmup, 10).median() * dispatches as f64;
            t.row(vec![
                format!("{size}x{size}"),
                format!("{omp:.2}"),
                format!("{ocl:.2}"),
                format!("{gprm:.2}"),
                format!("{:.2}", ocl - ocl_ov),
                format!("{:.2}", gprm - gprm_ov),
                format!("{gprm_ov:.3}"),
            ]);
        }
        t
    }

    /// Figure 1 measured: the ladder with copy-back baseline.
    pub fn fig1(&self) -> Table {
        self.ladder(Algorithm::SinglePassCopyBack, "Figure 1 (measured): ladder, copy-back baseline")
    }

    /// Figure 4 measured: no-copy ladder + GPRM 3R×C + ratio checks.
    pub fn fig4(&self) -> Table {
        let mut t = self.ladder(Algorithm::SinglePassNoCopy, "Figure 4 (measured): ladder, no-copy baseline");
        let size = *self.cfg.sizes.last().unwrap();
        let img = self.image(size);
        let base = self.seq_ms(&img, Algorithm::SinglePassNoCopy, Variant::Naive);
        let g_nv = self.par_ms(&self.gprm, &img, Algorithm::SinglePassNoCopy, Variant::Scalar, Layout::Agglomerated);
        let g_s = self.par_ms(&self.gprm, &img, Algorithm::SinglePassNoCopy, Variant::Simd, Layout::Agglomerated);
        let o_s = self.par_ms(&self.opencl, &img, Algorithm::SinglePassNoCopy, Variant::Simd, Layout::PerPlane);
        let o_tp = self.par_ms(&self.opencl, &img, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane);
        t.row(vec![format!("Par-5 single-pass GPRM 3RxC no-vec @{size}"), format!("{:.1}x", base / g_nv), "-".into()]);
        t.row(vec![format!("Par-6 single-pass GPRM 3RxC SIMD @{size}"), format!("{:.1}x", base / g_s), "-".into()]);
        t.row(vec![format!("Par-7 single-pass OpenCL SIMD @{size}"), format!("{:.1}x", base / o_s), "-".into()]);
        t.row(vec![format!("Par-8 two-pass OpenCL SIMD @{size}"), format!("{:.1}x", base / o_tp), "-".into()]);
        t
    }

    fn ladder(&self, base_alg: Algorithm, title: &str) -> Table {
        let mut t = Table::new(title, &["Stage", "Speedup (measured)", "ms"]);
        // the section 5.2 averages use the largest images; host uses the
        // configured top size to keep runtime bounded
        let size = *self.cfg.sizes.last().unwrap();
        let img = self.image(size);
        let base = self.seq_ms(&img, base_alg, Variant::Naive);
        let mut push = |label: String, ms: f64| {
            t.row(vec![label, format!("{:.1}x", base / ms), format!("{ms:.2}")]);
        };
        push("Opt-0 naive single-pass no-vec".into(), base);
        push("Opt-1 single-pass unrolled no-vec".into(), self.seq_ms(&img, base_alg, Variant::Scalar));
        push("Opt-2 single-pass unrolled SIMD".into(), self.seq_ms(&img, base_alg, Variant::Simd));
        push("Opt-3 two-pass unrolled no-vec".into(), self.seq_ms(&img, Algorithm::TwoPass, Variant::Scalar));
        push("Opt-4 two-pass unrolled SIMD".into(), self.seq_ms(&img, Algorithm::TwoPass, Variant::Simd));
        push(
            "Par-1 single-pass unrolled no-vec (OpenMP)".into(),
            self.par_ms(&self.openmp, &img, base_alg, Variant::Scalar, Layout::PerPlane),
        );
        push(
            "Par-2 single-pass unrolled SIMD (OpenMP)".into(),
            self.par_ms(&self.openmp, &img, base_alg, Variant::Simd, Layout::PerPlane),
        );
        push(
            "Par-3 two-pass unrolled no-vec (OpenMP)".into(),
            self.par_ms(&self.openmp, &img, Algorithm::TwoPass, Variant::Scalar, Layout::PerPlane),
        );
        push(
            "Par-4 two-pass unrolled SIMD (OpenMP)".into(),
            self.par_ms(&self.openmp, &img, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane),
        );
        t
    }

    /// Figures 2/3 measured: speedup of parallel vectorised two-pass over
    /// the sequential Opt-4, per layout.
    pub fn fig23(&self, layout: Layout) -> Table {
        let mut t = Table::new(
            format!("Figure {} (measured): two-pass SIMD speedup vs Opt-4 sequential, {}",
                if layout == Layout::PerPlane { 2 } else { 3 }, layout.label()),
            &["Image Size", "OpenMP", "OpenCL", "GPRM"],
        );
        for &size in &self.cfg.sizes {
            let img = self.image(size);
            let seq = self.seq_ms(&img, Algorithm::TwoPass, Variant::Simd);
            let models: [&dyn ExecutionModel; 3] = [&self.openmp, &self.opencl, &self.gprm];
            let cells: Vec<String> = models
                .iter()
                .map(|m| {
                    let ms = self.par_ms(*m, &img, Algorithm::TwoPass, Variant::Simd, layout);
                    format!("{:.1}x", seq / ms)
                })
                .collect();
            let mut row = vec![format!("{size}x{size}")];
            row.extend(cells);
            t.row(row);
        }
        t
    }

    /// Ablations over the design choices DESIGN.md calls out: GPRM
    /// cutoff, GPRM steal policy, OpenMP schedule, OpenCL local size.
    pub fn ablations(&self) -> Vec<Table> {
        use crate::models::{Schedule, StealPolicy};
        let size = *self.cfg.sizes.last().unwrap();
        let img = self.image(size);
        let mut out = Vec::new();

        // GPRM cutoff sweep: the paper's "magic number 100" choice
        let mut t = Table::new(
            format!("Ablation: GPRM cutoff (two-pass SIMD @{size}, {} threads)", self.cfg.threads),
            &["cutoff", "total ms", "empty-dispatch ms"],
        );
        for cutoff in [1usize, 10, 50, 100, 240, 480, 1000] {
            let m = self.gprm.with_cutoff(cutoff);
            let total = self.par_ms(&m, &img, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane);
            let ov = m.overhead_probe_with(size, self.cfg.warmup, 8).median();
            t.row(vec![cutoff.to_string(), format!("{total:.2}"), format!("{ov:.4}")]);
        }
        out.push(t);

        // GPRM steal policy
        let mut t = Table::new(
            format!("Ablation: GPRM steal policy (two-pass SIMD @{size})"),
            &["policy", "total ms"],
        );
        for (label, policy) in [("ring", StealPolicy::Ring), ("random", StealPolicy::Random)] {
            let m = crate::models::GprmModel::with_policy(self.cfg.threads, self.cfg.cutoff, policy);
            let total = self.par_ms(&m, &img, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane);
            t.row(vec![label.into(), format!("{total:.2}")]);
        }
        out.push(t);

        // OpenMP schedule
        let mut t = Table::new(
            format!("Ablation: OpenMP schedule (two-pass SIMD @{size})"),
            &["schedule", "total ms"],
        );
        for schedule in [Schedule::Static, Schedule::Dynamic(1), Schedule::Dynamic(16), Schedule::Guided(1)] {
            let m = OpenMpModel::with_schedule(self.cfg.threads, schedule);
            let total = self.par_ms(&m, &img, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane);
            t.row(vec![schedule.label(), format!("{total:.2}")]);
        }
        out.push(t);

        // OpenCL local size (the paper's nths=16 finding)
        let mut t = Table::new(
            format!("Ablation: OpenCL local size (two-pass SIMD @{size})"),
            &["local size", "total ms"],
        );
        for local in [1usize, 4, 16, 64, 256] {
            let m = crate::models::OpenClModel::new(self.cfg.threads, local);
            let total = self.par_ms(&m, &img, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane);
            t.row(vec![local.to_string(), format!("{total:.2}")]);
        }
        out.push(t);
        out
    }

    /// Fused-vs-unfused two-pass exhibit: per-image ms **and** the
    /// estimated bytes each plan moves through main memory — on
    /// bandwidth-bound hardware the traffic column, not the FLOP count,
    /// explains the speedup (Hofmann et al., PAPERS.md). The unfused
    /// column doubles as the correctness anchor: both plans produce
    /// equivalent pixels (differential suite in `tests/fused.rs`).
    pub fn fused(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Fused two-pass (measured, {} threads): rolling row-ring vs separate passes",
                self.cfg.threads
            ),
            &[
                "Image Size",
                "Model",
                "unfused ms",
                "fused ms",
                "speedup",
                "unfused MB",
                "fused MB",
                "traffic",
            ],
        );
        for &size in &self.cfg.sizes {
            let img = self.image(size);
            let (alg, var, lay) = (Algorithm::TwoPass, Variant::Simd, Layout::PerPlane);
            let unfused = self.plan_with_fuse(&img, alg, var, lay, false);
            let fused = self.plan_with_fuse(&img, alg, var, lay, true);
            let (tr_u, tr_f) = (unfused.traffic_estimate(), fused.traffic_estimate());
            let models: [&dyn ExecutionModel; 3] = [&self.openmp, &self.opencl, &self.gprm];
            for model in models {
                let mut arena = ScratchArena::new();
                let u = time_reps(
                    || unfused.execute_discard(Some(model), &img, &mut arena).unwrap(),
                    self.cfg.warmup,
                    self.cfg.reps,
                )
                .median();
                let f = time_reps(
                    || fused.execute_discard(Some(model), &img, &mut arena).unwrap(),
                    self.cfg.warmup,
                    self.cfg.reps,
                )
                .median();
                t.row(vec![
                    format!("{size}x{size}"),
                    model.name().to_string(),
                    format!("{u:.2}"),
                    format!("{f:.2}"),
                    format!("{:.2}x", if f > 0.0 { u / f } else { 1.0 }),
                    format!("{:.1}", tr_u.total_mb()),
                    format!("{:.1}", tr_f.total_mb()),
                    format!("{:.2}x", tr_f.total_bytes() as f64 / tr_u.total_bytes() as f64),
                ]);
            }
        }
        t
    }

    /// Thread sweep (section 7 note): single-pass-nocopy SIMD OpenMP.
    pub fn threads_sweep(&self, counts: &[usize]) -> Table {
        let mut header: Vec<String> = vec!["Image Size".into()];
        header.extend(counts.iter().map(|c| format!("{c} thr")));
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new("Thread sweep (measured): single-pass-nocopy SIMD OpenMP, ms", &header_refs);
        for &size in &self.cfg.sizes {
            let img = self.image(size);
            let mut row = vec![format!("{size}x{size}")];
            for &c in counts {
                let m = OpenMpModel::new(c);
                row.push(format!(
                    "{:.2}",
                    self.par_ms(&m, &img, Algorithm::SinglePassNoCopy, Variant::Simd, Layout::PerPlane)
                ));
            }
            t.row(row);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> RunConfig {
        RunConfig {
            sizes: vec![64, 96],
            reps: 2,
            warmup: 1,
            threads: 4,
            ..Default::default()
        }
    }

    #[test]
    fn measured_tables_render() {
        let m = Measured::new(&tiny_cfg());
        for t in [m.table1(), m.table2(), m.fig23(Layout::PerPlane)] {
            assert!(t.n_rows() >= 2);
            assert!(t.to_text().len() > 50);
        }
    }

    #[test]
    fn measured_ladders_render() {
        let m = Measured::new(&tiny_cfg());
        assert_eq!(m.fig1().n_rows(), 9);
        assert_eq!(m.fig4().n_rows(), 13);
    }

    #[test]
    fn threads_sweep_renders() {
        let m = Measured::new(&tiny_cfg());
        let t = m.threads_sweep(&[1, 2, 4]);
        assert_eq!(t.n_rows(), 2);
    }

    #[test]
    fn run_measured_rejects_invalid_kernel_config() {
        let cfg = RunConfig { kernel_width: 4, ..tiny_cfg() };
        assert!(crate::harness::run_measured("table1", &cfg).is_err());
        let cfg = RunConfig { sigma: 0.0, ..tiny_cfg() };
        assert!(crate::harness::run_measured("fig1", &cfg).is_err());
        // degenerate shapes are structured errors, not plan-builder panics
        let cfg = RunConfig { sizes: vec![64, 0], ..tiny_cfg() };
        assert!(crate::harness::run_measured("table1", &cfg).is_err());
        let cfg = RunConfig { planes: 0, ..tiny_cfg() };
        assert!(crate::harness::run_measured("fig2", &cfg).is_err());
    }

    #[test]
    fn tiling_exhibit_renders_sweep_and_winners() {
        let cfg = RunConfig { sizes: vec![40], reps: 1, warmup: 0, threads: 2, ..Default::default() };
        let tables = crate::harness::run_measured("tiling", &cfg).unwrap();
        // one sweep table per size plus the tuned-winner summary
        assert_eq!(tables.len(), 2);
        assert!(tables[0].to_text().contains("tuned"));
        assert_eq!(tables[1].n_rows(), 3, "one winner per model");
    }

    #[test]
    fn fused_exhibit_renders_traffic_columns() {
        let cfg =
            RunConfig { sizes: vec![48], reps: 1, warmup: 0, threads: 2, ..Default::default() };
        let tables = crate::harness::run_measured("fused", &cfg).unwrap();
        assert_eq!(tables.len(), 1);
        let t = &tables[0];
        assert_eq!(t.n_rows(), 3, "one row per model at one size");
        let text = t.to_text();
        assert!(text.contains("OpenMP") && text.contains("GPRM"));
        assert!(text.contains("0.50x"), "fused plans move half the bytes: {text}");
        // the JSON dump round-trips (the machine-readable satellite)
        let json = t.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        assert_eq!(parsed.req_arr("rows").unwrap().len(), 3);
    }

    #[test]
    fn measured_exhibits_honour_fuse_default() {
        // --fuse flows into every two-pass exhibit plan without
        // disturbing single-pass exhibits
        let cfg = RunConfig {
            fuse: true,
            sizes: vec![48],
            reps: 1,
            warmup: 0,
            threads: 2,
            ..Default::default()
        };
        let tables = crate::harness::run_measured("fig2", &cfg).unwrap();
        assert_eq!(tables.len(), 1);
        assert!(tables[0].n_rows() >= 1);
        let tables = crate::harness::run_measured("fig1", &cfg).unwrap();
        assert_eq!(tables[0].n_rows(), 9, "ladder (single-pass rungs included) still renders");
    }

    #[test]
    fn measured_tables_render_at_width3() {
        // non-default kernel widths flow through the whole harness
        let cfg = RunConfig { kernel_width: 3, ..tiny_cfg() };
        let tables = crate::harness::run_measured("fig2", &cfg).unwrap();
        assert_eq!(tables.len(), 1);
        assert!(tables[0].n_rows() >= 2);
    }
}
