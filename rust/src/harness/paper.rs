//! The paper's published numbers, embedded verbatim so every harness
//! table can print *simulated/measured vs paper* side by side.
//!
//! Source: Tousimojarad, Vanderbauwhede, Cockshott — "2D Image
//! Convolution using Three Parallel Programming Models on the Xeon Phi"
//! (2017), Tables 1–2 and the speedups quoted in sections 5.2 / 7.

/// The six image sizes of the test set (section 4).
pub const SIZES: [usize; 6] = [1152, 1728, 2592, 3888, 5832, 8748];

/// The three largest sizes (used by the section 5.2 / 7 averages).
pub const LARGE_SIZES: [usize; 3] = [3888, 5832, 8748];

/// Table 1: parallel two-pass per-image ms — `(size, omp_novec,
/// ocl_novec, gprm_novec, omp_simd, ocl_simd, gprm_simd)`.
pub const TABLE1: [(usize, f64, f64, f64, f64, f64, f64); 6] = [
    (1152, 3.9, 5.4, 27.2, 0.8, 2.0, 26.1),
    (1728, 8.5, 12.3, 32.8, 2.0, 3.8, 26.6),
    (2592, 16.7, 26.9, 40.5, 4.1, 7.8, 27.8),
    (3888, 39.9, 61.6, 60.4, 8.8, 16.5, 32.5),
    (5832, 86.7, 146.2, 105.8, 19.6, 38.1, 36.8),
    (8748, 195.4, 334.0, 216.9, 59.2, 91.5, 60.1),
];

/// Table 2: running time per image (ms) — `(size, omp, ocl, gprm_total,
/// ocl_compute, gprm_compute)`.
pub const TABLE2: [(usize, f64, f64, f64, f64, f64); 6] = [
    (1152, 0.8, 2.0, 26.1, 1.8, 0.6),
    (1728, 2.0, 3.8, 26.6, 3.6, 1.1),
    (2592, 4.1, 7.8, 27.8, 7.5, 2.3),
    (3888, 8.8, 16.5, 32.5, 16.2, 7.0),
    (5832, 19.6, 38.1, 36.8, 37.7, 11.3),
    (8748, 59.2, 91.5, 60.1, 91.0, 34.6),
];

/// GPRM's measured constant communication overhead (ms/image, R×C).
pub const GPRM_OVERHEAD_RXC_MS: f64 = 25.5;
/// …and after 3R×C task agglomeration.
pub const GPRM_OVERHEAD_AGG_MS: f64 = 8.5;
/// OpenCL empty-kernel overhead band (ms/image).
pub const OCL_OVERHEAD_MS: (f64, f64) = (0.25, 0.4);

/// Figure 1 ladder: average speedups over the naive single-pass
/// *with copy-back* baseline (three largest images, section 5.2).
pub const FIG1_LADDER: [(&str, f64); 9] = [
    ("Opt-0 naive single-pass no-vec", 1.0),
    ("Opt-1 single-pass unrolled no-vec", 2.5),
    ("Opt-2 single-pass unrolled SIMD", 22.0),
    ("Opt-3 two-pass unrolled no-vec", 5.5),
    ("Opt-4 two-pass unrolled SIMD", 47.1),
    ("Par-1 single-pass unrolled no-vec 100thr", 191.1),
    ("Par-2 single-pass unrolled SIMD 100thr", 1268.8),
    ("Par-3 two-pass unrolled no-vec 100thr", 393.7),
    ("Par-4 two-pass unrolled SIMD 100thr", 1611.7),
];

/// Section 7 headline claims for the no-copy-back study (Figure 4).
pub struct Fig4Claims {
    /// sequential optimised two-pass vs single-pass-nocopy average gain
    pub seq_twopass_gain: f64,
    /// parallel optimised single-pass-nocopy vs two-pass average gain
    pub par_singlepass_gain: f64,
    /// SIMD gain of parallel single-pass over its no-vec version
    pub par_sp_simd_gain: f64,
    /// SIMD gain of parallel two-pass over its no-vec version
    pub par_tp_simd_gain: f64,
    /// GPRM 3R×C single-pass-nocopy speedup over baseline at 8748²
    pub gprm_8748_speedup: f64,
    /// best observed speedup (OpenMP, 5832²)
    pub best_speedup: f64,
    /// with 120 threads
    pub best_speedup_120thr: f64,
}

pub const FIG4: Fig4Claims = Fig4Claims {
    seq_twopass_gain: 1.6,
    par_singlepass_gain: 1.2,
    par_sp_simd_gain: 9.4,
    par_tp_simd_gain: 4.1,
    gprm_8748_speedup: 1850.0,
    best_speedup: 1970.0,
    best_speedup_120thr: 2160.0,
};

/// The paper's "magic numbers".
pub const OMP_THREADS: usize = 100;
pub const GPRM_CUTOFF: usize = 100;
pub const OCL_NGROUPS: usize = 236;
pub const OCL_NTHS: usize = 16;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_cover_all_sizes() {
        assert_eq!(TABLE1.map(|r| r.0), SIZES);
        assert_eq!(TABLE2.map(|r| r.0), SIZES);
    }

    #[test]
    fn table2_total_equals_compute_plus_overhead() {
        // the paper derives GPRM-compute = total − 25.5 ms
        for (_, _, _, gprm_total, _, gprm_compute) in TABLE2 {
            assert!((gprm_total - gprm_compute - GPRM_OVERHEAD_RXC_MS).abs() < 0.11);
        }
    }

    #[test]
    fn table1_simd_columns_match_table2() {
        for ((_, _, _, _, omp_s, ocl_s, gprm_s), (_, omp2, ocl2, gprm2, _, _)) in
            TABLE1.iter().zip(TABLE2.iter())
        {
            assert_eq!(omp_s, omp2);
            assert_eq!(ocl_s, ocl2);
            assert_eq!(gprm_s, gprm2);
        }
    }
}
