//! Simulated exhibits: regenerate every table/figure of the paper from
//! the phisim cost model, printing paper values alongside for the delta.

use crate::conv::{Algorithm, Variant};
use crate::metrics::Table;
use crate::models::Layout;
use crate::phisim::{simulate, Calibration, Estimate, PhiMachine, SimRun, SimWorkload};

use super::paper;

fn sim(w: &SimWorkload, run: &SimRun) -> Estimate {
    simulate(&PhiMachine::default(), &Calibration::default(), w, run)
}

fn tp(size: usize, variant: Variant) -> SimWorkload {
    SimWorkload::paper(size, Algorithm::TwoPass, variant)
}

fn fmt(v: f64) -> String {
    if v >= 100.0 {
        format!("{v:.0}")
    } else if v >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    }
}

/// Table 1: effect of vectorisation on parallel two-pass (ms), simulated
/// vs paper, 3 models × 6 sizes × {no-vec, SIMD}.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1 (simulated Xeon Phi): vectorisation effect on parallel two-pass, ms/image [sim | paper]",
        &[
            "Image Size",
            "OpenMP no-vec",
            "OpenCL no-vec",
            "GPRM no-vec",
            "OpenMP SIMD",
            "OpenCL SIMD",
            "GPRM SIMD",
        ],
    );
    for (size, p_omp_nv, p_ocl_nv, p_gprm_nv, p_omp_s, p_ocl_s, p_gprm_s) in paper::TABLE1 {
        let omp = SimRun::openmp(paper::OMP_THREADS);
        let ocl = SimRun::opencl();
        let gprm = SimRun::gprm(paper::GPRM_CUTOFF, Layout::PerPlane);
        let cell = |v: f64, p: f64| format!("{} | {}", fmt(v), fmt(p));
        t.row(vec![
            format!("{size}x{size}"),
            cell(sim(&tp(size, Variant::Scalar), &omp).total_ms(), p_omp_nv),
            cell(sim(&tp(size, Variant::Scalar), &ocl).total_ms(), p_ocl_nv),
            cell(sim(&tp(size, Variant::Scalar), &gprm).total_ms(), p_gprm_nv),
            cell(sim(&tp(size, Variant::Simd), &omp).total_ms(), p_omp_s),
            cell(sim(&tp(size, Variant::Simd), &ocl).total_ms(), p_ocl_s),
            cell(sim(&tp(size, Variant::Simd), &gprm).total_ms(), p_gprm_s),
        ]);
    }
    t
}

/// Table 2: per-image ms with the compute/overhead split.
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table 2 (simulated): running time per image, ms [sim | paper]",
        &["Image Size", "OpenMP", "OpenCL", "GPRM-total", "OpenCL-compute", "GPRM-compute"],
    );
    for (size, p_omp, p_ocl, p_gt, p_oc, p_gc) in paper::TABLE2 {
        let w = tp(size, Variant::Simd);
        let omp = sim(&w, &SimRun::openmp(paper::OMP_THREADS));
        let ocl = sim(&w, &SimRun::opencl());
        let gprm = sim(&w, &SimRun::gprm(paper::GPRM_CUTOFF, Layout::PerPlane));
        let cell = |v: f64, p: f64| format!("{} | {}", fmt(v), fmt(p));
        t.row(vec![
            format!("{size}x{size}"),
            cell(omp.total_ms(), p_omp),
            cell(ocl.total_ms(), p_ocl),
            cell(gprm.total_ms(), p_gt),
            // the paper's "compute" = total − measured empty-task overhead
            cell(ocl.total_ms() - ocl.overhead_ms, p_oc),
            cell(gprm.total_ms() - gprm.overhead_ms, p_gc),
        ]);
    }
    t
}

/// Figure 1: the optimisation ladder, speedups over naive single-pass
/// with copy-back (average of the three largest images).
pub fn fig1() -> Table {
    ladder(Algorithm::SinglePassCopyBack, "Figure 1 (simulated): naive → parallelised-optimised speedups [sim | paper]", true)
}

/// Figure 4: the ladder with the no-copy-back single-pass baseline, plus
/// the GPRM 3R×C and OpenCL rungs.
pub fn fig4() -> Table {
    let mut t = ladder(
        Algorithm::SinglePassNoCopy,
        "Figure 4 (simulated): ladder without copy-back [sim | paper where quoted]",
        false,
    );
    // Par-5/6: GPRM 3R×C single-pass; Par-7/8: OpenCL single/two-pass.
    let base = avg_large(|size| {
        sim(&SimWorkload::paper(size, Algorithm::SinglePassNoCopy, Variant::Naive), &SimRun::sequential()).total_ms()
    });
    let gprm_run = SimRun::gprm(paper::GPRM_CUTOFF, Layout::Agglomerated);
    let rows: Vec<(&str, Algorithm, Variant, SimRun, Option<f64>)> = vec![
        ("Par-5 single-pass GPRM 3RxC no-vec", Algorithm::SinglePassNoCopy, Variant::Scalar, gprm_run, None),
        ("Par-6 single-pass GPRM 3RxC SIMD", Algorithm::SinglePassNoCopy, Variant::Simd, gprm_run, Some(paper::FIG4.gprm_8748_speedup)),
        ("Par-7 single-pass OpenCL SIMD", Algorithm::SinglePassNoCopy, Variant::Simd, SimRun::opencl(), None),
        ("Par-8 two-pass OpenCL SIMD", Algorithm::TwoPass, Variant::Simd, SimRun::opencl(), None),
    ];
    for (label, alg, variant, run, paper_val) in rows {
        let ms = avg_large(|size| sim(&SimWorkload::paper(size, alg, variant), &run).total_ms());
        t.row(vec![
            label.to_string(),
            format!("{:.1}x", base / ms),
            paper_val.map(|p| format!("{p:.0}x @8748")).unwrap_or_else(|| "-".into()),
        ]);
    }
    t
}

fn avg_large(f: impl Fn(usize) -> f64) -> f64 {
    let s: f64 = paper::LARGE_SIZES.iter().map(|&n| f(n)).sum();
    s / paper::LARGE_SIZES.len() as f64
}

fn ladder(base_alg: Algorithm, title: &str, with_paper: bool) -> Table {
    let mut t = Table::new(title, &["Stage", "Speedup (sim)", "Paper"]);
    let base = avg_large(|size| {
        sim(&SimWorkload::paper(size, base_alg, Variant::Naive), &SimRun::sequential()).total_ms()
    });
    let omp = SimRun::openmp(paper::OMP_THREADS);
    let rungs: Vec<(&str, Algorithm, Variant, SimRun)> = vec![
        ("Opt-0 naive single-pass no-vec", base_alg, Variant::Naive, SimRun::sequential()),
        ("Opt-1 single-pass unrolled no-vec", base_alg, Variant::Scalar, SimRun::sequential()),
        ("Opt-2 single-pass unrolled SIMD", base_alg, Variant::Simd, SimRun::sequential()),
        ("Opt-3 two-pass unrolled no-vec", Algorithm::TwoPass, Variant::Scalar, SimRun::sequential()),
        ("Opt-4 two-pass unrolled SIMD", Algorithm::TwoPass, Variant::Simd, SimRun::sequential()),
        ("Par-1 single-pass unrolled no-vec 100thr", base_alg, Variant::Scalar, omp),
        ("Par-2 single-pass unrolled SIMD 100thr", base_alg, Variant::Simd, omp),
        ("Par-3 two-pass unrolled no-vec 100thr", Algorithm::TwoPass, Variant::Scalar, omp),
        ("Par-4 two-pass unrolled SIMD 100thr", Algorithm::TwoPass, Variant::Simd, omp),
    ];
    for (i, (label, alg, variant, run)) in rungs.into_iter().enumerate() {
        let ms = avg_large(|size| sim(&SimWorkload::paper(size, alg, variant), &run).total_ms());
        let paper_col = if with_paper {
            format!("{:.1}x", paper::FIG1_LADDER[i].1)
        } else {
            "-".into()
        };
        t.row(vec![label.to_string(), format!("{:.1}x", base / ms), paper_col]);
    }
    t
}

/// Figure 2: speedup of the parallel vectorised two-pass over Opt-4
/// sequential, R×C layout. Paper reference points derived from Table 1.
pub fn fig2() -> Table {
    fig23(Layout::PerPlane, "Figure 2 (simulated): speedup of vectorised two-pass vs Opt-4, RxC")
}

/// Figure 3: same with 3R×C task agglomeration.
pub fn fig3() -> Table {
    fig23(Layout::Agglomerated, "Figure 3 (simulated): speedup of vectorised two-pass vs Opt-4, 3RxC")
}

fn fig23(layout: Layout, title: &str) -> Table {
    let mut t = Table::new(title, &["Image Size", "OpenMP", "OpenCL", "GPRM", "GPRM (paper, RxC)"]);
    for (size, .., p_gprm_simd) in paper::TABLE1 {
        let w = tp(size, Variant::Simd);
        let seq = sim(&w, &SimRun::sequential()).total_ms();
        let omp = sim(&w, &SimRun::openmp(paper::OMP_THREADS)).total_ms();
        let ocl = sim(&w, &SimRun::opencl()).total_ms();
        let gprm = sim(&w, &SimRun::gprm(paper::GPRM_CUTOFF, layout)).total_ms();
        // paper reference: Opt-4 sequential isn't tabulated; report the
        // paper's GPRM ms converted to a speedup using our simulated
        // sequential time (the GPRM column is the exhibit's subject).
        t.row(vec![
            format!("{size}x{size}"),
            format!("{:.1}x", seq / omp),
            format!("{:.1}x", seq / ocl),
            format!("{:.1}x", seq / gprm),
            format!("{:.1}x", seq / p_gprm_simd),
        ]);
    }
    t
}

/// The section-7 thread-tuning note: OpenMP single-pass sweep over
/// thread counts (the 120-thread +10–15 % claim).
pub fn threads_sweep() -> Table {
    let mut t = Table::new(
        "Thread sweep (simulated): single-pass-nocopy SIMD OpenMP, ms/image",
        &["Image Size", "60 thr", "100 thr", "120 thr", "180 thr", "240 thr"],
    );
    for size in [3888usize, 5832, 8748] {
        let w = SimWorkload::paper(size, Algorithm::SinglePassNoCopy, Variant::Simd);
        let cells: Vec<String> = [60usize, 100, 120, 180, 240]
            .iter()
            .map(|&thr| fmt(sim(&w, &SimRun::openmp(thr)).total_ms()))
            .collect();
        let mut row = vec![format!("{size}x{size}")];
        row.extend(cells);
        t.row(row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_exhibits_render() {
        for t in [table1(), table2(), fig1(), fig2(), fig3(), fig4(), threads_sweep()] {
            let txt = t.to_text();
            assert!(txt.len() > 100);
            assert!(t.n_rows() >= 3);
        }
    }

    #[test]
    fn table1_sim_within_2x_of_paper_everywhere() {
        // parse-free re-check against the cost model directly
        for (size, p1, p2, p3, p4, p5, p6) in paper::TABLE1 {
            let omp = SimRun::openmp(paper::OMP_THREADS);
            let ocl = SimRun::opencl();
            let gprm = SimRun::gprm(paper::GPRM_CUTOFF, Layout::PerPlane);
            let checks = [
                (sim(&tp(size, Variant::Scalar), &omp).total_ms(), p1, "omp novec"),
                (sim(&tp(size, Variant::Scalar), &ocl).total_ms(), p2, "ocl novec"),
                (sim(&tp(size, Variant::Scalar), &gprm).total_ms(), p3, "gprm novec"),
                (sim(&tp(size, Variant::Simd), &omp).total_ms(), p4, "omp simd"),
                (sim(&tp(size, Variant::Simd), &ocl).total_ms(), p5, "ocl simd"),
                (sim(&tp(size, Variant::Simd), &gprm).total_ms(), p6, "gprm simd"),
            ];
            for (got, want, what) in checks {
                let r = got / want;
                assert!(
                    (0.4..2.5).contains(&r),
                    "{size} {what}: sim {got:.2} vs paper {want} (x{r:.2})"
                );
            }
        }
    }

    #[test]
    fn fig1_ladder_order_preserved() {
        // Paper ordering between rungs: Opt0 < Opt1 < Opt3 < Opt2 < Opt4
        // in speedup terms: 1 < 2.5 < 5.5 < 22 < 47.1; and Par-1 < Par-3,
        // Par-2 < Par-4 (copy-back world).
        let speed = |alg, v, run: SimRun| {
            let base = avg_large(|s| {
                sim(&SimWorkload::paper(s, Algorithm::SinglePassCopyBack, Variant::Naive), &SimRun::sequential()).total_ms()
            });
            base / avg_large(|s| sim(&SimWorkload::paper(s, alg, v), &run).total_ms())
        };
        let seq = SimRun::sequential();
        let omp = SimRun::openmp(100);
        let o1 = speed(Algorithm::SinglePassCopyBack, Variant::Scalar, seq);
        let o2 = speed(Algorithm::SinglePassCopyBack, Variant::Simd, seq);
        let o3 = speed(Algorithm::TwoPass, Variant::Scalar, seq);
        let o4 = speed(Algorithm::TwoPass, Variant::Simd, seq);
        let p1 = speed(Algorithm::SinglePassCopyBack, Variant::Scalar, omp);
        let p3 = speed(Algorithm::TwoPass, Variant::Scalar, omp);
        let p2 = speed(Algorithm::SinglePassCopyBack, Variant::Simd, omp);
        let p4 = speed(Algorithm::TwoPass, Variant::Simd, omp);
        assert!(1.0 < o1 && o1 < o3 && o3 < o2 && o2 < o4, "{o1:.1} {o3:.1} {o2:.1} {o4:.1}");
        assert!(p1 < p3, "copy-back parallel: two-pass beats single-pass");
        assert!(p2 < p4, "{p2:.0} vs {p4:.0}");
    }
}
