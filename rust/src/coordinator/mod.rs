//! L3 coordinator: request router + batcher serving convolution jobs.
//!
//! The serving loop a downstream user would deploy: requests (images +
//! algorithm choice) enter a **bounded admission queue** (capacity and
//! per-request deadlines from `RunConfig`; overload is shed with
//! structured `QueueFull` / `DeadlineExceeded` / `Shutdown` errors,
//! never a panic — see [`queue`]); requests are sharded by `PlanKey`
//! hash across per-executor queues, and each executor drains its shard
//! in plan-keyed batches (up to `--batch-max` coalesced per
//! `ConvPlan::execute_batch` call) running on a backend —
//!
//! * **native** engines under any of the three execution models, or
//! * the **PJRT** path: the AOT-compiled Pallas artifacts loaded by
//!   [`crate::runtime`] (Python never runs here; artifacts were lowered
//!   at build time).
//!
//! Routing encodes the paper's own conclusion as policy
//! ([`RoutePolicy::PaperAdaptive`]): OpenMP-style scheduling for small
//! images, GPRM-style with 3R×C task agglomeration for large ones
//! ("in terms of performance, OpenMP is the winning model, except for
//! very large images where GPRM shows better performance after using
//! task agglomeration").
//!
//! With a tuning tier installed (`Coordinator::set_tuning`), admission
//! additionally resolves tile/fusion for requests that pin neither:
//! exact swept winners first, then the fitted cost model's prediction
//! for never-before-seen shapes ([`crate::costmodel`]) — zero warm-up
//! sweeps — with `CoordinatorStats` counters distinguishing predicted,
//! swept, and default decisions.

mod affinity;
pub mod queue;
mod request;
mod router;
mod server;

pub use queue::{AdmissionQueue, Batch, Pop, PopBatch, QueueCounters, Rejected};
pub use request::{ConvRequest, ConvResponse, GraphSpec};
pub use router::{Backend, RoutePolicy};
pub use server::{Coordinator, CoordinatorStats, ReplyReceiver};
