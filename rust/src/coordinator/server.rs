//! The coordinator itself: queue, executor threads, metrics.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::util::error::{Context, Result};

use crate::config::RunConfig;
use crate::conv::Algorithm;
use crate::image::PlanarImage;
use crate::metrics::SampleSet;
use crate::models::{GprmModel, Layout, OpenClModel, OpenMpModel};
use crate::runtime::{Manifest, PjrtHandle};

use super::request::{ConvRequest, ConvResponse};
use super::router::{Backend, RoutePolicy};

struct Job {
    req: ConvRequest,
    enqueued: Instant,
    reply: Sender<Result<ConvResponse>>,
}

/// Per-backend serving statistics.
#[derive(Debug, Default, Clone)]
pub struct CoordinatorStats {
    pub served: u64,
    pub errors: u64,
    pub pjrt_fallbacks: u64,
    pub service_ms: HashMap<&'static str, SampleSet>,
    pub queue_ms: SampleSet,
}

struct Inner {
    policy: RoutePolicy,
    openmp: OpenMpModel,
    opencl: OpenClModel,
    gprm: GprmModel,
    kernel: Vec<f32>,
    /// manifest (shape lookups, caller side) + execution handle (actor)
    pjrt: Option<(Manifest, PjrtHandle)>,
    stats: Mutex<CoordinatorStats>,
    seq: AtomicU64,
}

/// The serving loop (see module docs).
pub struct Coordinator {
    inner: Arc<Inner>,
    tx: Option<Sender<Job>>,
    executors: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Build from a run config. `with_pjrt` loads the artifact pool (set
    /// false for native-only serving, e.g. when artifacts aren't built).
    pub fn new(cfg: &RunConfig, policy: RoutePolicy, executors: usize, with_pjrt: bool) -> Result<Self> {
        let pjrt = if with_pjrt {
            let manifest = Manifest::load(&cfg.artifacts_dir)?;
            let handle = PjrtHandle::spawn(&cfg.artifacts_dir).context("starting PJRT actor")?;
            Some((manifest, handle))
        } else {
            None
        };
        let inner = Arc::new(Inner {
            policy,
            openmp: OpenMpModel::new(cfg.threads),
            opencl: OpenClModel::new(cfg.threads, 16),
            gprm: GprmModel::new(cfg.threads, cfg.cutoff),
            kernel: crate::image::gaussian_kernel(cfg.kernel_width, cfg.sigma),
            pjrt,
            stats: Mutex::new(CoordinatorStats::default()),
            seq: AtomicU64::new(0),
        });
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let executors = (0..executors.max(1))
            .map(|i| {
                let inner = inner.clone();
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("phi-conv-executor-{i}"))
                    .spawn(move || executor_loop(inner, rx))
                    .expect("spawn executor")
            })
            .collect();
        Ok(Self { inner, tx: Some(tx), executors })
    }

    /// Enqueue a request; the receiver yields the response when served.
    pub fn submit(&self, req: ConvRequest) -> Receiver<Result<ConvResponse>> {
        let (reply, rx) = channel();
        let job = Job { req, enqueued: Instant::now(), reply };
        self.tx.as_ref().expect("coordinator live").send(job).expect("executors alive");
        rx
    }

    /// Submit and wait.
    pub fn serve(&self, req: ConvRequest) -> Result<ConvResponse> {
        self.submit(req).recv().context("coordinator dropped reply")?
    }

    pub fn stats(&self) -> CoordinatorStats {
        self.inner.stats.lock().unwrap().clone()
    }

    /// True when the PJRT backend is loaded.
    pub fn has_pjrt(&self) -> bool {
        self.inner.pjrt.is_some()
    }

    /// Pre-compile the full-image artifacts for the given sizes so the
    /// first PJRT-routed request doesn't pay compile latency. Returns
    /// (artifact, compile ms) pairs.
    pub fn warm_pjrt(&self, planes: usize, sizes: &[usize]) -> Result<Vec<(String, f64)>> {
        let (manifest, handle) = match &self.inner.pjrt {
            Some(p) => p,
            None => return Ok(vec![]),
        };
        let mut names = Vec::new();
        for &n in sizes {
            for name in [
                format!("twopass_p{planes}_{n}"),
                format!("singlepass_p{planes}_{n}"),
                format!("twopass_agg_{n}"),
            ] {
                if manifest.get(&name).is_ok() {
                    names.push(name);
                }
            }
        }
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let times = handle.warm(&refs)?;
        Ok(names.into_iter().zip(times).collect())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.take(); // close the queue; executors drain and exit
        for h in self.executors.drain(..) {
            let _ = h.join();
        }
    }
}

fn executor_loop(inner: Arc<Inner>, rx: Arc<Mutex<Receiver<Job>>>) {
    // per-executor reusable buffers (§Perf iteration 1: no per-request
    // image allocations on the native path)
    let mut ws = crate::conv::Workspace::new();
    loop {
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => return, // queue closed
        };
        let queue_ms = job.enqueued.elapsed().as_secs_f64() * 1e3;
        let result = serve_one(&inner, &mut ws, job.req, queue_ms);
        let mut st = inner.stats.lock().unwrap();
        match &result {
            Ok(resp) => {
                st.served += 1;
                st.queue_ms.push(resp.queue_ms);
                st.service_ms
                    .entry(resp.backend.label())
                    .or_default()
                    .push(resp.service_ms);
            }
            Err(_) => st.errors += 1,
        }
        drop(st);
        let _ = job.reply.send(result); // receiver may have gone away
    }
}

fn serve_one(
    inner: &Inner,
    ws: &mut crate::conv::Workspace,
    req: ConvRequest,
    queue_ms: f64,
) -> Result<ConvResponse> {
    let seq = inner.seq.fetch_add(1, Ordering::Relaxed);
    let (mut backend, mut layout) = match (req.backend, req.layout) {
        (Some(b), Some(l)) => (b, l),
        (Some(b), None) => (b, inner.policy.route(req.image.rows, seq).1),
        (None, Some(l)) => (inner.policy.route(req.image.rows, seq).0, l),
        (None, None) => inner.policy.route(req.image.rows, seq),
    };

    // PJRT can only serve shapes it has artifacts for; fall back to the
    // adaptive native choice otherwise.
    if backend == Backend::Pjrt && !pjrt_can_serve(inner, &req, layout) {
        inner.stats.lock().unwrap().pjrt_fallbacks += 1;
        let (b, l) = RoutePolicy::paper_default().route(req.image.rows, seq);
        backend = b;
        layout = l;
    }

    let t0 = Instant::now();
    let image = match backend {
        Backend::Pjrt => run_pjrt(inner, &req, layout)?,
        Backend::NativeOpenMp | Backend::NativeOpenCl | Backend::NativeGprm => {
            let model: &dyn crate::models::ExecutionModel = match backend {
                Backend::NativeOpenMp => &inner.openmp,
                Backend::NativeOpenCl => &inner.opencl,
                _ => &inner.gprm,
            };
            let out = crate::models::convolve_parallel_into(
                ws,
                model,
                &req.image,
                &inner.kernel,
                req.algorithm,
                req.variant,
                layout,
            )?;
            match layout {
                Layout::PerPlane => PlanarImage::from_vec(
                    req.image.planes,
                    req.image.rows,
                    req.image.cols,
                    out.to_vec(),
                )?,
                Layout::Agglomerated => PlanarImage::from_agglomerated(
                    req.image.planes,
                    req.image.rows,
                    req.image.cols,
                    out,
                )?,
            }
        }
    };
    let service_ms = t0.elapsed().as_secs_f64() * 1e3;
    Ok(ConvResponse { id: req.id, image, backend, layout, queue_ms, service_ms })
}

fn pjrt_artifact_name(req: &ConvRequest, layout: Layout) -> Option<String> {
    if req.image.rows != req.image.cols {
        return None; // full-image artifacts are square
    }
    let n = req.image.rows;
    Some(match (layout, req.algorithm) {
        (Layout::Agglomerated, Algorithm::TwoPass) => format!("twopass_agg_{n}"),
        (Layout::Agglomerated, _) => return None,
        (_, Algorithm::TwoPass) => format!("twopass_p{}_{n}", req.image.planes),
        // copy-back and no-copy have identical pixels; one artifact serves both
        (_, Algorithm::SinglePassCopyBack | Algorithm::SinglePassNoCopy) => {
            format!("singlepass_p{}_{n}", req.image.planes)
        }
    })
}

fn pjrt_can_serve(inner: &Inner, req: &ConvRequest, layout: Layout) -> bool {
    match (&inner.pjrt, pjrt_artifact_name(req, layout)) {
        (Some((manifest, _)), Some(name)) => manifest.get(&name).is_ok(),
        _ => false,
    }
}

fn run_pjrt(inner: &Inner, req: &ConvRequest, layout: Layout) -> Result<PlanarImage> {
    let (_, handle) = inner.pjrt.as_ref().context("PJRT backend not loaded")?;
    let name = pjrt_artifact_name(req, layout).context("no artifact for this request shape")?;
    let out = handle.run1(&name, vec![req.image.data.clone(), inner.kernel.clone()])?;
    PlanarImage::from_vec(req.image.planes, req.image.rows, req.image.cols, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{convolve_image, Variant};
    use crate::image::{synth_image, Pattern};

    fn cfg() -> RunConfig {
        RunConfig { threads: 4, ..Default::default() }
    }

    #[test]
    fn serves_native_request_correctly() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 2, false).unwrap();
        let img = synth_image(3, 32, 28, Pattern::Noise, 1);
        let k = crate::image::gaussian_kernel(5, 1.0);
        let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        let resp = c.serve(ConvRequest::new(1, img)).unwrap();
        assert_eq!(resp.image, want);
        assert_eq!(resp.backend, Backend::NativeOpenMp);
        assert!(resp.service_ms >= 0.0);
    }

    #[test]
    fn round_robin_spreads_backends() {
        let c = Coordinator::new(&cfg(), RoutePolicy::RoundRobin, 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 2);
        let mut seen = std::collections::HashSet::new();
        for i in 0..6 {
            let resp = c.serve(ConvRequest::new(i, img.clone())).unwrap();
            seen.insert(resp.backend);
        }
        assert_eq!(seen.len(), 3, "all three native backends used");
        let st = c.stats();
        assert_eq!(st.served, 6);
        assert_eq!(st.errors, 0);
    }

    #[test]
    fn adaptive_policy_routes_by_size() {
        let c = Coordinator::new(
            &cfg(),
            RoutePolicy::PaperAdaptive { large_threshold: 30 },
            1,
            false,
        )
        .unwrap();
        let small = synth_image(3, 24, 24, Pattern::Noise, 3);
        let large = synth_image(3, 40, 40, Pattern::Noise, 4);
        let r1 = c.serve(ConvRequest::new(1, small)).unwrap();
        assert_eq!((r1.backend, r1.layout), (Backend::NativeOpenMp, Layout::PerPlane));
        let r2 = c.serve(ConvRequest::new(2, large)).unwrap();
        assert_eq!((r2.backend, r2.layout), (Backend::NativeGprm, Layout::Agglomerated));
    }

    #[test]
    fn explicit_backend_respected() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 5);
        let resp = c
            .serve(ConvRequest::new(1, img).with_backend(Backend::NativeGprm))
            .unwrap();
        assert_eq!(resp.backend, Backend::NativeGprm);
    }

    #[test]
    fn concurrent_submissions_all_served() {
        let c = Coordinator::new(&cfg(), RoutePolicy::RoundRobin, 3, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 6);
        let receivers: Vec<_> = (0..20)
            .map(|i| c.submit(ConvRequest::new(i, img.clone())))
            .collect();
        for rx in receivers {
            assert!(rx.recv().unwrap().is_ok());
        }
        assert_eq!(c.stats().served, 20);
    }

    #[test]
    fn pjrt_fallback_when_no_artifact_shape() {
        // 24x24 has no artifact; explicit Pjrt backend must fall back, not fail
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::Pjrt), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 7);
        let resp = c.serve(ConvRequest::new(1, img)).unwrap();
        assert_ne!(resp.backend, Backend::Pjrt);
        assert_eq!(c.stats().pjrt_fallbacks, 1);
    }
}
