//! The coordinator itself: bounded admission queue, executor threads,
//! sharded metrics.
//!
//! **Intake** goes through the [`AdmissionQueue`]: capacity and the
//! default per-request deadline come from `RunConfig`
//! (`--queue-capacity` / `--deadline-ms`), and every refusal is a
//! structured error — [`ErrorKind::QueueFull`] when shedding,
//! [`ErrorKind::DeadlineExceeded`] when a TTL lapses,
//! [`ErrorKind::Shutdown`] once the coordinator is dropped. Nothing on
//! the submit path panics; [`Coordinator::submit`] returns
//! `Result<ReplyReceiver>` and callers pick their admission flavour
//! (`submit` blocks for space, `try_submit` sheds immediately,
//! `submit_timeout` bounds the wait).
//!
//! **Executors** run every native request through the plan layer: each
//! executor thread owns a [`ScratchArena`] (scratch planes recycle
//! across requests — zero scratch allocations after warm-up, fused
//! row-rings included) and a cache of built [`ConvPlan`]s keyed by
//! `(algorithm, variant, layout, shape, kernel, tile, fuse)`, so
//! repeated traffic at a shape pays plan validation once.
//!
//! **Stats are sharded**: each executor accumulates into its own
//! `Mutex<CoordinatorStats>` slot — uncontended on the hot path — and
//! the shards are only merged (plus the queue's own counters) when
//! [`Coordinator::stats`] is called. The old design took one global
//! lock per request, serializing all executors on metrics bookkeeping.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

use crate::util::error::{Context, Error, ErrorKind, Result};

use crate::config::RunConfig;
use crate::conv::{Algorithm, Variant};
use crate::image::PlanarImage;
use crate::metrics::SampleSet;
use crate::models::{GprmModel, Layout, OpenClModel, OpenMpModel};
use crate::plan::{ConvPlan, KernelSpec, ScratchArena, TileSpec};
use crate::runtime::{Manifest, PjrtHandle};

use super::queue::{AdmissionQueue, Pop};
use super::request::{ConvRequest, ConvResponse};
use super::router::{Backend, RoutePolicy};

/// Receiver side of a submitted job's reply channel.
pub type ReplyReceiver = Receiver<Result<ConvResponse>>;

struct Job {
    req: ConvRequest,
    enqueued: Instant,
    reply: Sender<Result<ConvResponse>>,
}

/// Serving statistics: executor-side tallies plus the admission queue's
/// own counters (merged view returned by [`Coordinator::stats`]).
#[derive(Debug, Default, Clone)]
pub struct CoordinatorStats {
    pub served: u64,
    /// execution failures returned to callers (not shed/expired traffic)
    pub errors: u64,
    pub pjrt_fallbacks: u64,
    pub service_ms: HashMap<&'static str, SampleSet>,
    pub queue_ms: SampleSet,
    /// admissions refused because the queue was at capacity
    pub shed: u64,
    /// request deadlines lapsed (at admission, waiting, or dequeue)
    pub expired: u64,
    /// queue depth when this snapshot was taken
    pub depth: usize,
    /// high-water mark of queue depth since construction
    pub depth_peak: usize,
}

impl CoordinatorStats {
    /// Fold another shard into this one. Counters add, sample sets
    /// concatenate, the depth high-water mark takes the max.
    pub fn merge(&mut self, other: &CoordinatorStats) {
        self.served += other.served;
        self.errors += other.errors;
        self.pjrt_fallbacks += other.pjrt_fallbacks;
        self.queue_ms.extend_from(&other.queue_ms);
        for (backend, set) in &other.service_ms {
            self.service_ms.entry(backend).or_default().extend_from(set);
        }
        self.shed += other.shed;
        self.expired += other.expired;
        self.depth += other.depth;
        self.depth_peak = self.depth_peak.max(other.depth_peak);
    }
}

struct Inner {
    policy: RoutePolicy,
    openmp: OpenMpModel,
    opencl: OpenClModel,
    gprm: GprmModel,
    /// configured default kernel spec (requests may override)
    kernel: KernelSpec,
    /// configured default tile decomposition for native execution
    /// (requests may override; `None` = untiled row bands)
    tile: Option<TileSpec>,
    /// configured default for two-pass fusion (requests may override
    /// with `with_fuse`; single-pass algorithms ignore it)
    fuse: bool,
    /// taps the PJRT path executes with: the manifest's reference
    /// kernel when PJRT is loaded, the configured default otherwise
    kernel_taps: Vec<f32>,
    /// manifest (shape lookups, caller side) + execution handle (actor)
    pjrt: Option<(Manifest, PjrtHandle)>,
    /// one stats shard per executor; shard `i` is only ever locked by
    /// executor `i` (hot path, uncontended) and by `stats()` (merge)
    shards: Vec<Mutex<CoordinatorStats>>,
    /// default TTL stamped on requests that don't carry their own
    default_deadline: Option<Duration>,
    /// round-robin counter: advanced only when the policy itself picks
    /// a backend, so pinned traffic (PJRT included) can't skew it
    native_seq: AtomicU64,
}

impl Inner {
    fn next_seq(&self) -> u64 {
        self.native_seq.fetch_add(1, Ordering::Relaxed)
    }
}

/// Per-executor cache bounds. Shapes and kernels are request-controlled,
/// so without a cap an adversarial mix of distinct (shape, kernel)
/// combinations would grow the plan cache and scratch pool without
/// bound; past the cap the whole cache is dropped (requests simply
/// rebuild plans / re-lease scratch — correctness is unaffected).
const PLAN_CACHE_MAX: usize = 64;
const ARENA_POOL_MAX: usize = 16;

/// Plan-cache key: everything a [`ConvPlan`] is built from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct PlanKey {
    algorithm: Algorithm,
    variant: Variant,
    layout: Layout,
    planes: usize,
    rows: usize,
    cols: usize,
    kernel: (usize, u64),
    /// tile decomposition (`None` = untiled row bands)
    tile: Option<(usize, usize)>,
    /// two-pass fusion (always false for single-pass algorithms)
    fused: bool,
}

/// The serving loop (see module docs).
pub struct Coordinator {
    inner: Arc<Inner>,
    queue: Arc<AdmissionQueue<Job>>,
    executors: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Build from a run config. `with_pjrt` loads the artifact pool (set
    /// false for native-only serving, e.g. when artifacts aren't built).
    /// Queue capacity and the default deadline come from
    /// `cfg.queue_capacity` / `cfg.deadline_ms` (0 = no deadline).
    pub fn new(
        cfg: &RunConfig,
        policy: RoutePolicy,
        executors: usize,
        with_pjrt: bool,
    ) -> Result<Self> {
        let pjrt = if with_pjrt {
            let manifest = Manifest::load(&cfg.artifacts_dir)?;
            let handle = PjrtHandle::spawn(&cfg.artifacts_dir).context("starting PJRT actor")?;
            Some((manifest, handle))
        } else {
            None
        };
        let kernel = KernelSpec::new(cfg.kernel_width, cfg.sigma);
        kernel.validate().context("invalid configured kernel")?;
        // the PJRT path always executes with the artifacts' reference
        // taps (`pjrt_can_serve` guarantees the request's effective
        // kernel matches them, even when the configured default differs)
        let kernel_taps = match &pjrt {
            Some((manifest, _)) => KernelSpec::new(manifest.kernel_width, manifest.gaussian_sigma)
                .taps()
                .context("manifest kernel spec")?,
            None => kernel.taps()?,
        };
        let n = executors.max(1);
        let inner = Arc::new(Inner {
            policy,
            openmp: OpenMpModel::new(cfg.threads),
            opencl: OpenClModel::new(cfg.threads, 16),
            // agglomeration only applies under tiled dispatch; a raw
            // config with 0 is treated as 1 (validate() enforces >= 1 at
            // the CLI/TOML entry points)
            gprm: GprmModel::new(cfg.threads, cfg.cutoff)
                .with_agglomeration(cfg.agglomeration.max(1)),
            kernel,
            tile: cfg.tile_spec(),
            fuse: cfg.fuse,
            kernel_taps,
            pjrt,
            shards: (0..n).map(|_| Mutex::new(CoordinatorStats::default())).collect(),
            default_deadline: (cfg.deadline_ms > 0)
                .then(|| Duration::from_millis(cfg.deadline_ms)),
            native_seq: AtomicU64::new(0),
        });
        let queue = Arc::new(AdmissionQueue::new(cfg.queue_capacity));
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let inner = inner.clone();
            let queue_ref = queue.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("phi-conv-executor-{i}"))
                .spawn(move || executor_loop(inner, queue_ref, i));
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // wake and join whatever already spawned before
                    // surfacing the error, or those executors would
                    // block on the queue forever (no Coordinator means
                    // no Drop to close it)
                    queue.close();
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(Error::from(e).context(format!("spawning executor {i}")));
                }
            }
        }
        Ok(Self { inner, queue, executors: handles })
    }

    /// The request's effective admission deadline: its own TTL, or the
    /// coordinator's configured default. A TTL so large that
    /// `now + ttl` overflows `Instant` is treated as "no deadline" —
    /// `Instant + Duration` would panic, and the submit path guarantees
    /// it never does.
    fn deadline_of(&self, req: &ConvRequest) -> Option<Instant> {
        req.deadline
            .or(self.inner.default_deadline)
            .and_then(|ttl| Instant::now().checked_add(ttl))
    }

    fn job(req: ConvRequest) -> (Job, ReplyReceiver) {
        let (reply, rx) = channel();
        (Job { req, enqueued: Instant::now(), reply }, rx)
    }

    /// Enqueue a request; the receiver yields the response (or a
    /// structured error) when served. Blocks while the queue is at
    /// capacity — backpressure — bounded by the request's deadline.
    /// Never panics: refusals are `QueueFull` / `DeadlineExceeded` /
    /// `Shutdown` errors.
    pub fn submit(&self, req: ConvRequest) -> Result<ReplyReceiver> {
        let deadline = self.deadline_of(&req);
        let (job, rx) = Self::job(req);
        self.queue
            .push(job, deadline)
            .map_err(|r| r.to_error(self.queue.capacity()))?;
        Ok(rx)
    }

    /// Non-blocking admission: sheds immediately with `QueueFull` when
    /// the queue is at capacity.
    pub fn try_submit(&self, req: ConvRequest) -> Result<ReplyReceiver> {
        let deadline = self.deadline_of(&req);
        let (job, rx) = Self::job(req);
        self.queue
            .try_push(job, deadline)
            .map_err(|r| r.to_error(self.queue.capacity()))?;
        Ok(rx)
    }

    /// Blocking admission bounded by `wait`: sheds with `QueueFull` if
    /// no slot frees in time.
    pub fn submit_timeout(&self, req: ConvRequest, wait: Duration) -> Result<ReplyReceiver> {
        let deadline = self.deadline_of(&req);
        let (job, rx) = Self::job(req);
        self.queue
            .push_timeout(job, deadline, wait)
            .map_err(|r| r.to_error(self.queue.capacity()))?;
        Ok(rx)
    }

    /// Submit and wait for the response.
    pub fn serve(&self, req: ConvRequest) -> Result<ConvResponse> {
        let rx = self.submit(req)?;
        match rx.recv() {
            Ok(result) => result,
            // the reply sender was dropped without a reply — only
            // possible if an executor died mid-request
            Err(_) => Err(Error::with_kind(
                ErrorKind::Shutdown,
                "coordinator dropped the reply channel",
            )),
        }
    }

    /// Merged statistics: all executor shards plus the queue counters.
    pub fn stats(&self) -> CoordinatorStats {
        let mut total = CoordinatorStats::default();
        for shard in &self.inner.shards {
            let guard = shard.lock().unwrap_or_else(PoisonError::into_inner);
            total.merge(&guard);
        }
        let q = self.queue.counters();
        total.shed = q.shed;
        total.expired = q.expired;
        total.depth = q.depth;
        total.depth_peak = q.depth_peak;
        total
    }

    /// Items currently waiting for an executor.
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// The admission queue's capacity.
    pub fn queue_capacity(&self) -> usize {
        self.queue.capacity()
    }

    /// True when the PJRT backend is loaded.
    pub fn has_pjrt(&self) -> bool {
        self.inner.pjrt.is_some()
    }

    /// Pre-compile the full-image artifacts for the given sizes so the
    /// first PJRT-routed request doesn't pay compile latency. Returns
    /// (artifact, compile ms) pairs.
    pub fn warm_pjrt(&self, planes: usize, sizes: &[usize]) -> Result<Vec<(String, f64)>> {
        let (manifest, handle) = match &self.inner.pjrt {
            Some(p) => p,
            None => return Ok(vec![]),
        };
        let mut names = Vec::new();
        for &n in sizes {
            for name in [
                format!("twopass_p{planes}_{n}"),
                format!("singlepass_p{planes}_{n}"),
                format!("twopass_agg_{n}"),
            ] {
                if manifest.get(&name).is_ok() {
                    names.push(name);
                }
            }
        }
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let times = handle.warm(&refs)?;
        Ok(names.into_iter().zip(times).collect())
    }
}

impl Drop for Coordinator {
    /// Graceful drain: refuse new admissions, let the executors finish
    /// everything already queued (expired items are rejected with
    /// structured `DeadlineExceeded` errors, live ones complete), then
    /// join them. Every outstanding reply channel resolves.
    fn drop(&mut self) {
        self.queue.close();
        for h in self.executors.drain(..) {
            let _ = h.join();
        }
    }
}

fn executor_loop(inner: Arc<Inner>, queue: Arc<AdmissionQueue<Job>>, shard: usize) {
    // per-executor state: scratch planes recycle across requests (zero
    // scratch allocations after warm-up) and plans are built once per
    // distinct request configuration
    let mut arena = ScratchArena::new();
    let mut plans: HashMap<PlanKey, ConvPlan> = HashMap::new();
    loop {
        let job = match queue.pop() {
            Pop::Closed => return, // drained and shut down
            Pop::Expired(job) => {
                let waited = job.enqueued.elapsed().as_secs_f64() * 1e3;
                let _ = job.reply.send(Err(Error::with_kind(
                    ErrorKind::DeadlineExceeded,
                    format!("request deadline exceeded after {waited:.1} ms in queue"),
                )));
                continue;
            }
            Pop::Job(job) => job,
        };
        let queue_ms = job.enqueued.elapsed().as_secs_f64() * 1e3;
        let mut pjrt_fell_back = false;
        let result =
            serve_one(&inner, &mut arena, &mut plans, &mut pjrt_fell_back, job.req, queue_ms);
        // this executor's own shard: uncontended unless stats() is
        // merging, and never held across the convolution above
        let mut st = inner.shards[shard].lock().unwrap_or_else(PoisonError::into_inner);
        if pjrt_fell_back {
            st.pjrt_fallbacks += 1;
        }
        match &result {
            Ok(resp) => {
                st.served += 1;
                st.queue_ms.push(resp.queue_ms);
                st.service_ms
                    .entry(resp.backend.label())
                    .or_default()
                    .push(resp.service_ms);
            }
            Err(_) => st.errors += 1,
        }
        drop(st);
        let _ = job.reply.send(result); // receiver may have gone away
    }
}

fn serve_one(
    inner: &Inner,
    arena: &mut ScratchArena,
    plans: &mut HashMap<PlanKey, ConvPlan>,
    pjrt_fell_back: &mut bool,
    req: ConvRequest,
    queue_ms: f64,
) -> Result<ConvResponse> {
    // request intake validation: a bad kernel or tile spec is a
    // structured error before any routing or execution happens
    let kernel = req.kernel.unwrap_or(inner.kernel);
    kernel.validate().context("invalid request kernel")?;
    let tile = req.tile.or(inner.tile);
    if let Some(t) = tile {
        t.validate().context("invalid request tile")?;
    }
    // fusion only applies to the two-pass algorithm; a fused serving
    // default must not refuse single-pass traffic, so it is silently
    // inapplicable there rather than a build error
    let fuse = req.fuse.unwrap_or(inner.fuse) && req.algorithm == Algorithm::TwoPass;

    // the round-robin counter advances only when the policy picks the
    // backend: explicitly pinned traffic (PJRT included) must not
    // consume native cycle slots, or the rotation silently skips
    // backends whenever pinned requests interleave
    let (mut backend, mut layout) = match (req.backend, req.layout) {
        (Some(b), Some(l)) => (b, l),
        (Some(b), None) => (b, inner.policy.route(req.image.rows, 0).1),
        (None, Some(l)) => (inner.policy.route(req.image.rows, inner.next_seq()).0, l),
        (None, None) => inner.policy.route(req.image.rows, inner.next_seq()),
    };

    // PJRT can only serve shapes it has artifacts for (and only the
    // configured default kernel the artifacts were lowered with); fall
    // back to the adaptive native choice otherwise.
    if backend == Backend::Pjrt && !pjrt_can_serve(inner, &req, layout) {
        *pjrt_fell_back = true;
        let (b, l) = RoutePolicy::paper_default().route(req.image.rows, 0);
        backend = b;
        layout = l;
    }

    let t0 = Instant::now();
    let image = match backend {
        Backend::Pjrt => run_pjrt(inner, &req, layout)?,
        Backend::NativeOpenMp | Backend::NativeOpenCl | Backend::NativeGprm => {
            let model: &dyn crate::models::ExecutionModel = match backend {
                Backend::NativeOpenMp => &inner.openmp,
                Backend::NativeOpenCl => &inner.opencl,
                _ => &inner.gprm,
            };
            let key = PlanKey {
                algorithm: req.algorithm,
                variant: req.variant,
                layout,
                planes: req.image.planes,
                rows: req.image.rows,
                cols: req.image.cols,
                kernel: kernel.cache_key(),
                tile: tile.map(|t| t.cache_key()),
                fused: fuse,
            };
            if !plans.contains_key(&key) {
                if plans.len() >= PLAN_CACHE_MAX {
                    plans.clear();
                }
                let plan = ConvPlan::builder()
                    .algorithm(req.algorithm)
                    .variant(req.variant)
                    .layout(layout)
                    .kernel(kernel)
                    .tile_opt(tile)
                    .fuse(fuse)
                    .shape(req.image.planes, req.image.rows, req.image.cols)
                    .build()
                    .context("invalid request plan")?;
                plans.insert(key, plan);
            }
            let plan = plans.get(&key).expect("plan just cached");
            let image = plan.execute_on(model, &req.image, arena)?;
            if arena.pooled() > ARENA_POOL_MAX {
                arena.clear();
            }
            image
        }
    };
    let service_ms = t0.elapsed().as_secs_f64() * 1e3;
    Ok(ConvResponse { id: req.id, image, backend, layout, queue_ms, service_ms })
}

fn pjrt_artifact_name(req: &ConvRequest, layout: Layout) -> Option<String> {
    if req.image.rows != req.image.cols {
        return None; // full-image artifacts are square
    }
    let n = req.image.rows;
    Some(match (layout, req.algorithm) {
        (Layout::Agglomerated, Algorithm::TwoPass) => format!("twopass_agg_{n}"),
        (Layout::Agglomerated, _) => return None,
        (_, Algorithm::TwoPass) => format!("twopass_p{}_{n}", req.image.planes),
        // copy-back and no-copy have identical pixels; one artifact serves both
        (_, Algorithm::SinglePassCopyBack | Algorithm::SinglePassNoCopy) => {
            format!("singlepass_p{}_{n}", req.image.planes)
        }
    })
}

fn pjrt_can_serve(inner: &Inner, req: &ConvRequest, layout: Layout) -> bool {
    let (manifest, _) = match &inner.pjrt {
        Some(p) => p,
        None => return false,
    };
    // the AOT artifacts bake in the manifest's reference kernel; the
    // request's effective kernel (its own spec, or the coordinator's
    // configured default) must match it exactly or take the native path
    let spec = req.kernel.unwrap_or(inner.kernel);
    if spec.width != manifest.kernel_width || spec.sigma != manifest.gaussian_sigma {
        return false;
    }
    match pjrt_artifact_name(req, layout) {
        Some(name) => manifest.get(&name).is_ok(),
        None => false,
    }
}

fn run_pjrt(inner: &Inner, req: &ConvRequest, layout: Layout) -> Result<PlanarImage> {
    let (_, handle) = inner.pjrt.as_ref().context("PJRT backend not loaded")?;
    let name = pjrt_artifact_name(req, layout).context("no artifact for this request shape")?;
    let out = handle.run1(&name, vec![req.image.data.clone(), inner.kernel_taps.clone()])?;
    PlanarImage::from_vec(req.image.planes, req.image.rows, req.image.cols, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{convolve_image, Variant};
    use crate::image::{synth_image, Pattern};

    fn cfg() -> RunConfig {
        RunConfig { threads: 4, ..Default::default() }
    }

    #[test]
    fn serves_native_request_correctly() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 2, false).unwrap();
        let img = synth_image(3, 32, 28, Pattern::Noise, 1);
        let k = crate::image::gaussian_kernel(5, 1.0);
        let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        let resp = c.serve(ConvRequest::new(1, img)).unwrap();
        assert_eq!(resp.image, want);
        assert_eq!(resp.backend, Backend::NativeOpenMp);
        assert!(resp.service_ms >= 0.0);
    }

    #[test]
    fn round_robin_unskewed_by_pinned_traffic() {
        // pinned traffic (PJRT included — it falls back natively here)
        // interleaves with policy-routed requests; the rotation must
        // still hand each native backend exactly its even share
        let c = Coordinator::new(&cfg(), RoutePolicy::RoundRobin, 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 2);
        let mut counts: HashMap<Backend, usize> = HashMap::new();
        for i in 0..12u64 {
            if i % 2 == 1 {
                // explicitly pinned: must not consume a rotation slot
                let pinned = c
                    .serve(ConvRequest::new(i, img.clone()).with_backend(Backend::Pjrt))
                    .unwrap();
                assert_ne!(pinned.backend, Backend::Pjrt, "no PJRT loaded: falls back");
                continue;
            }
            let resp = c.serve(ConvRequest::new(i, img.clone())).unwrap();
            *counts.entry(resp.backend).or_insert(0) += 1;
        }
        assert_eq!(counts.len(), 3, "all three native backends used: {counts:?}");
        for (backend, n) in &counts {
            assert_eq!(*n, 2, "{backend:?} must serve exactly 2 of 6 rotation slots");
        }
        let st = c.stats();
        assert_eq!(st.served, 12);
        assert_eq!(st.errors, 0);
    }

    #[test]
    fn adaptive_policy_routes_by_size() {
        let c = Coordinator::new(
            &cfg(),
            RoutePolicy::PaperAdaptive { large_threshold: 30 },
            1,
            false,
        )
        .unwrap();
        let small = synth_image(3, 24, 24, Pattern::Noise, 3);
        let large = synth_image(3, 40, 40, Pattern::Noise, 4);
        let r1 = c.serve(ConvRequest::new(1, small)).unwrap();
        assert_eq!((r1.backend, r1.layout), (Backend::NativeOpenMp, Layout::PerPlane));
        let r2 = c.serve(ConvRequest::new(2, large)).unwrap();
        assert_eq!((r2.backend, r2.layout), (Backend::NativeGprm, Layout::Agglomerated));
    }

    #[test]
    fn explicit_backend_respected() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 5);
        let resp = c
            .serve(ConvRequest::new(1, img).with_backend(Backend::NativeGprm))
            .unwrap();
        assert_eq!(resp.backend, Backend::NativeGprm);
    }

    #[test]
    fn concurrent_submissions_all_served() {
        let c = Coordinator::new(&cfg(), RoutePolicy::RoundRobin, 3, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 6);
        let receivers: Vec<_> = (0..20)
            .map(|i| c.submit(ConvRequest::new(i, img.clone())).unwrap())
            .collect();
        for rx in receivers {
            assert!(rx.recv().unwrap().is_ok());
        }
        assert_eq!(c.stats().served, 20);
    }

    #[test]
    fn burst_beyond_capacity_sheds_not_panics() {
        // tiny queue, one executor kept busy by real work: try_submit
        // must shed the overflow with structured QueueFull errors and
        // keep every admitted request servable
        let cfg = RunConfig { queue_capacity: 1, ..cfg() };
        let c = Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 128, 128, Pattern::Noise, 7);
        // requests pre-built so the burst loop is tight: the executor
        // cannot drain a capacity-1 queue as fast as try_submit refills
        let reqs: Vec<_> = (0..50u64).map(|i| ConvRequest::new(i, img.clone())).collect();
        let mut admitted = Vec::new();
        let mut shed = 0u64;
        for req in reqs {
            match c.try_submit(req) {
                Ok(rx) => admitted.push(rx),
                Err(e) => {
                    assert_eq!(e.kind(), ErrorKind::QueueFull, "got: {e:#}");
                    shed += 1;
                }
            }
        }
        assert!(shed >= 1, "a 50-burst into a capacity-1 queue must shed");
        for rx in admitted {
            assert!(rx.recv().unwrap().is_ok());
        }
        let st = c.stats();
        assert_eq!(st.shed, shed);
        assert_eq!(st.served + st.shed, 50);
        assert!(st.depth_peak >= 1);
    }

    #[test]
    fn zero_ttl_request_is_deadline_exceeded() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 8);
        let e = c
            .submit(ConvRequest::new(1, img).with_deadline(Duration::ZERO))
            .unwrap_err();
        assert_eq!(e.kind(), ErrorKind::DeadlineExceeded);
        assert_eq!(c.stats().expired, 1);
    }

    #[test]
    fn absurd_ttl_never_panics_the_submit_path() {
        // Instant::now() + Duration::MAX would overflow-panic; the
        // submit path must degrade it to "no deadline" instead
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 13);
        let resp = c.serve(ConvRequest::new(1, img).with_deadline(Duration::MAX));
        assert!(resp.is_ok(), "got: {resp:?}");
        assert_eq!(c.stats().expired, 0);
    }

    #[test]
    fn configured_default_deadline_applies() {
        // deadline_ms stamps every request lacking its own TTL; an
        // impossible 0-width window is exercised per-request instead
        // (deadline_ms = 0 means "no default"), so here we only check
        // that a generous default leaves normal serving untouched
        let cfg = RunConfig { deadline_ms: 60_000, ..cfg() };
        let c = Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 12);
        assert!(c.serve(ConvRequest::new(1, img)).is_ok());
        assert_eq!(c.stats().expired, 0);
    }

    #[test]
    fn per_request_kernel_served_natively() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 28, 28, Pattern::Noise, 8);
        for spec in [KernelSpec::new(3, 1.0), KernelSpec::new(7, 2.0)] {
            let k = crate::image::gaussian_kernel(spec.width, spec.sigma);
            let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
            let resp = c.serve(ConvRequest::new(1, img.clone()).with_kernel(spec)).unwrap();
            assert_eq!(resp.image, want, "{spec:?}");
        }
    }

    #[test]
    fn tiled_request_matches_untiled_pixels() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 30, 28, Pattern::Noise, 21);
        let want = c.serve(ConvRequest::new(1, img.clone())).unwrap();
        for tile in [TileSpec::new(4, 8), TileSpec::new(64, 64)] {
            let got = c.serve(ConvRequest::new(2, img.clone()).with_tile(tile)).unwrap();
            assert!(
                got.image.max_abs_diff(&want.image) <= 1e-6,
                "tile {}",
                tile.label()
            );
        }
        // every backend serves tiled requests
        for backend in [Backend::NativeOpenCl, Backend::NativeGprm] {
            let got = c
                .serve(
                    ConvRequest::new(3, img.clone())
                        .with_backend(backend)
                        .with_tile(TileSpec::new(8, 8)),
                )
                .unwrap();
            assert!(got.image.max_abs_diff(&want.image) <= 1e-6, "{backend:?}");
        }
    }

    #[test]
    fn fused_requests_match_unfused_pixels() {
        // per-request fusion on a default-unfused coordinator
        let policy = RoutePolicy::Fixed(Backend::NativeOpenMp);
        let c = Coordinator::new(&cfg(), policy, 1, false).unwrap();
        let img = synth_image(3, 30, 28, Pattern::Noise, 31);
        let want = c.serve(ConvRequest::new(1, img.clone())).unwrap();
        for backend in [Backend::NativeOpenMp, Backend::NativeOpenCl, Backend::NativeGprm] {
            let got = c
                .serve(ConvRequest::new(2, img.clone()).with_backend(backend).with_fuse(true))
                .unwrap();
            assert!(got.image.max_abs_diff(&want.image) <= 1e-6, "{backend:?}");
        }
        // fused composes with tiling on the serving path
        let got = c
            .serve(ConvRequest::new(3, img.clone()).with_fuse(true).with_tile(TileSpec::new(8, 8)))
            .unwrap();
        assert!(got.image.max_abs_diff(&want.image) <= 1e-6, "fused+tiled");

        // a --fuse coordinator default applies to two-pass requests and
        // is silently inapplicable to single-pass ones; with_fuse(false)
        // opts a request back out
        let cfg = RunConfig { fuse: true, ..cfg() };
        let c = Coordinator::new(&cfg, policy, 1, false).unwrap();
        let fused_default = c.serve(ConvRequest::new(4, img.clone())).unwrap();
        assert!(fused_default.image.max_abs_diff(&want.image) <= 1e-6);
        let opted_out = c.serve(ConvRequest::new(5, img.clone()).with_fuse(false)).unwrap();
        assert_eq!(opted_out.image, want.image);
        let single_pass = c
            .serve(ConvRequest::new(6, img).with_algorithm(Algorithm::SinglePassNoCopy))
            .unwrap();
        assert_eq!(single_pass.backend, Backend::NativeOpenMp);
        assert_eq!(c.stats().errors, 0, "single-pass under --fuse must not error");
    }

    #[test]
    fn configured_tile_default_applies_to_requests() {
        let cfg = RunConfig { tile_rows: 8, tile_cols: 8, agglomeration: 2, ..cfg() };
        let c = Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::NativeGprm), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 22);
        let k = crate::image::gaussian_kernel(5, 1.0);
        let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        let resp = c.serve(ConvRequest::new(1, img)).unwrap();
        assert!(resp.image.max_abs_diff(&want) <= 1e-6);
    }

    #[test]
    fn invalid_request_tile_is_structured_error() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 23);
        let err = c
            .serve(ConvRequest::new(1, img.clone()).with_tile(TileSpec::new(0, 8)))
            .unwrap_err();
        assert!(format!("{err:#}").contains("tile"), "got: {err:#}");
        // the coordinator keeps serving afterwards
        assert!(c.serve(ConvRequest::new(2, img)).is_ok());
    }

    #[test]
    fn invalid_request_kernel_is_structured_error() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 9);
        let err = c
            .serve(ConvRequest::new(1, img.clone()).with_kernel(KernelSpec::new(4, 1.0)))
            .unwrap_err();
        assert!(format!("{err:#}").contains("odd"), "got: {err:#}");
        assert_eq!(err.kind(), ErrorKind::Other, "execution errors are not refusals");
        // the coordinator keeps serving and counts the error
        assert!(c.serve(ConvRequest::new(2, img)).is_ok());
        let st = c.stats();
        assert_eq!((st.errors, st.served), (1, 1));
    }

    #[test]
    fn shape_churn_beyond_cache_caps_still_serves() {
        // more distinct shapes than PLAN_CACHE_MAX / ARENA_POOL_MAX:
        // the eviction path must kick in without affecting results
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let k = crate::image::gaussian_kernel(5, 1.0);
        for size in 8..(8 + PLAN_CACHE_MAX + 6) {
            let img = synth_image(1, size, size, Pattern::Noise, size as u64);
            let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
            let resp = c.serve(ConvRequest::new(size as u64, img)).unwrap();
            assert_eq!(resp.image, want, "size {size}");
        }
        assert_eq!(c.stats().errors, 0);
    }

    #[test]
    fn invalid_configured_kernel_rejected_at_construction() {
        let bad = RunConfig { kernel_width: 4, ..cfg() };
        assert!(Coordinator::new(&bad, RoutePolicy::RoundRobin, 1, false).is_err());
    }

    #[test]
    fn custom_kernel_never_routes_to_pjrt() {
        // explicit Pjrt backend + non-default kernel: must fall back to a
        // native backend (artifacts carry only the default taps)
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::Pjrt), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 10);
        let resp = c
            .serve(ConvRequest::new(1, img).with_kernel(KernelSpec::new(7, 1.0)))
            .unwrap();
        assert_ne!(resp.backend, Backend::Pjrt);
    }

    #[test]
    fn pjrt_fallback_when_no_artifact_shape() {
        // 24x24 has no artifact; explicit Pjrt backend must fall back, not fail
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::Pjrt), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 7);
        let resp = c.serve(ConvRequest::new(1, img)).unwrap();
        assert_ne!(resp.backend, Backend::Pjrt);
        assert_eq!(c.stats().pjrt_fallbacks, 1);
    }

    #[test]
    fn stats_merge_folds_shards() {
        let mut a = CoordinatorStats { served: 3, errors: 1, ..Default::default() };
        a.queue_ms.push(1.0);
        a.service_ms.entry("openmp").or_default().push(2.0);
        let mut b = CoordinatorStats { served: 2, pjrt_fallbacks: 4, ..Default::default() };
        b.queue_ms.push(3.0);
        b.service_ms.entry("openmp").or_default().push(4.0);
        b.service_ms.entry("gprm").or_default().push(5.0);
        a.merge(&b);
        assert_eq!((a.served, a.errors, a.pjrt_fallbacks), (5, 1, 4));
        assert_eq!(a.queue_ms.len(), 2);
        assert_eq!(a.service_ms["openmp"].len(), 2);
        assert_eq!(a.service_ms["gprm"].len(), 1);
    }
}
