//! The coordinator itself: sharded admission queues, batching executor
//! threads, sharded metrics.
//!
//! **Intake** goes through per-executor [`AdmissionQueue`] shards:
//! requests are resolved (routing, effective kernel/tile/fuse) at
//! submit and land on the shard their [`PlanKey`] hashes to, so
//! repeated traffic at one shape keeps hitting one executor's plan
//! cache and arena. Total capacity and the default per-request deadline
//! come from `RunConfig` (`--queue-capacity`, split ceiling-wise across
//! shards, / `--deadline-ms`), and every refusal is a structured error
//! — [`ErrorKind::QueueFull`] when shedding,
//! [`ErrorKind::DeadlineExceeded`] when a TTL lapses,
//! [`ErrorKind::Shutdown`] once the coordinator is dropped. Nothing on
//! the submit path panics; [`Coordinator::submit`] returns
//! `Result<ReplyReceiver>` and callers pick their admission flavour
//! (`submit` blocks for space, `try_submit` sheds immediately,
//! `submit_timeout` bounds the wait).
//!
//! **Executors batch**: at dequeue an executor drains up to
//! `--batch-max` queued jobs whose `PlanKey` (and backend) match the
//! head job — optionally holding the batch open `--batch-wait-us` for
//! stragglers — and serves them through one [`ConvPlan::execute_batch`]
//! call: one plan lookup, one warm [`ScratchArena`], one dispatch ramp
//! for the whole batch (the paper's agglomeration argument applied to
//! serving). Non-matching jobs keep their FIFO positions and deadlines
//! stay the fairness backstop: every member's TTL is re-checked at
//! execution start. Each executor owns a single-entry-LRU cache of
//! built [`ConvPlan`]s keyed by `(algorithm, variant, layout, shape,
//! kernel, tile, fuse)`, so repeated traffic at a shape pays plan
//! validation once. With `--pin-cores`, executor threads pin to cores
//! (best-effort) so shard-affine state stays cache-warm.
//!
//! **Stats are sharded**: each executor accumulates into its own
//! `Mutex<CoordinatorStats>` slot — uncontended on the hot path — and
//! the shards are only merged (plus the queues' own counters, which
//! accumulate rather than overwrite) when [`Coordinator::stats`] is
//! called. The old design took one global lock per request, serializing
//! all executors on metrics bookkeeping.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

use crate::util::error::{Context, Error, ErrorKind, Result};
use crate::util::json::Json;

use crate::autotune::{PlanDecision, TuningTable};
use crate::config::RunConfig;
use crate::conv::{Algorithm, Variant};
use crate::image::PlanarImage;
use crate::metrics::SampleSet;
use crate::models::{ExecutionModel, GprmModel, Layout, OpenClModel, OpenMpModel};
use crate::plan::{ConvPlan, FilterGraph, KernelClass, KernelSpec, ScratchArena, TileSpec};
use crate::runtime::{Manifest, PjrtHandle};

use super::affinity;
use super::queue::{AdmissionQueue, Batch, PopBatch};
use super::request::{ConvRequest, ConvResponse};
use super::router::{Backend, RoutePolicy};

/// Receiver side of a submitted job's reply channel.
pub type ReplyReceiver = Receiver<Result<ConvResponse>>;

/// A queued request, fully resolved at submit time: routing, effective
/// kernel/tile/fuse and the [`PlanKey`] are decided before admission so
/// the key can drive shard selection and dequeue-side coalescing.
/// Validation stays executor-side (a bad kernel/tile is an execution
/// error counted in `errors`, exactly as before).
struct Job {
    req: ConvRequest,
    backend: Backend,
    layout: Layout,
    kernel: KernelSpec,
    /// resolved kernel class: pinned by the request, implied by explicit
    /// 2-D taps, or picked by the tuning tier's crossover policy
    class: KernelClass,
    tile: Option<TileSpec>,
    fuse: bool,
    key: PlanKey,
    pjrt_fell_back: bool,
    enqueued: Instant,
    /// mirror of the queue slot's deadline, for the per-member re-check
    /// at batch execution start
    deadline: Option<Instant>,
    reply: Sender<Result<ConvResponse>>,
}

/// Serving statistics: executor-side tallies plus the admission queue's
/// own counters (merged view returned by [`Coordinator::stats`]).
#[derive(Debug, Default, Clone)]
pub struct CoordinatorStats {
    pub served: u64,
    /// execution failures returned to callers (not shed/expired traffic)
    pub errors: u64,
    pub pjrt_fallbacks: u64,
    pub service_ms: HashMap<&'static str, SampleSet>,
    pub queue_ms: SampleSet,
    /// admissions refused because the queue was at capacity
    pub shed: u64,
    /// request deadlines lapsed (at admission, waiting, dequeue, or the
    /// per-member re-check at batch execution start)
    pub expired: u64,
    /// queue depth when this snapshot was taken
    pub depth: usize,
    /// high-water mark of queue depth since construction
    pub depth_peak: usize,
    /// plans built by executors (cache misses; hot-shape traffic should
    /// pin this near the number of distinct plan keys, not the request
    /// count — the single-entry-LRU eviction test watches it)
    pub plans_built: u64,
    /// executed batch sizes, one sample per coalesced dispatch (all 1.0
    /// until `--batch-max` is raised)
    pub batch_sizes: SampleSet,
    /// tile/fusion decisions taken from the cost model's prediction for
    /// a never-swept shape (tuning tier installed via `set_tuning`)
    pub plans_predicted: u64,
    /// tile/fusion decisions taken from an exact swept tuning entry
    pub plans_swept: u64,
    /// tuning tier consulted but declined (no usable fit — low R² —
    /// for this shape's groups): config defaults applied, i.e. the
    /// empirical-sweep fallback path
    pub plans_default: u64,
    /// multi-stage graph requests served end-to-end (each was one
    /// admission-queue entry under one deadline; also counted in
    /// `served`)
    pub graphs_served: u64,
    /// inter-stage edges executed streamed (row-ring handoffs instead
    /// of materialised intermediate planes), summed over served graphs
    pub stages_fused: u64,
}

impl CoordinatorStats {
    /// Fold another shard into this one. Counters add and sample sets
    /// concatenate, but gauges (`depth`) and high-water marks
    /// (`depth_peak`) take the max — two snapshots that each observed
    /// the same queued items must not double-count them.
    pub fn merge(&mut self, other: &CoordinatorStats) {
        self.served += other.served;
        self.errors += other.errors;
        self.pjrt_fallbacks += other.pjrt_fallbacks;
        self.queue_ms.extend_from(&other.queue_ms);
        for (backend, set) in &other.service_ms {
            self.service_ms.entry(backend).or_default().extend_from(set);
        }
        self.shed += other.shed;
        self.expired += other.expired;
        self.depth = self.depth.max(other.depth);
        self.depth_peak = self.depth_peak.max(other.depth_peak);
        self.plans_built += other.plans_built;
        self.batch_sizes.extend_from(&other.batch_sizes);
        self.plans_predicted += other.plans_predicted;
        self.plans_swept += other.plans_swept;
        self.plans_default += other.plans_default;
        self.graphs_served += other.graphs_served;
        self.stages_fused += other.stages_fused;
    }

    /// The merged snapshot as JSON — counters exact, sample-set fields
    /// as their nullable summaries (the load harness embeds this in
    /// `BENCH_load.json`; all counters here fit f64 exactly).
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        let counters: [(&str, f64); 13] = [
            ("served", self.served as f64),
            ("errors", self.errors as f64),
            ("pjrt_fallbacks", self.pjrt_fallbacks as f64),
            ("shed", self.shed as f64),
            ("expired", self.expired as f64),
            ("depth", self.depth as f64),
            ("depth_peak", self.depth_peak as f64),
            ("plans_built", self.plans_built as f64),
            ("plans_predicted", self.plans_predicted as f64),
            ("plans_swept", self.plans_swept as f64),
            ("plans_default", self.plans_default as f64),
            ("graphs_served", self.graphs_served as f64),
            ("stages_fused", self.stages_fused as f64),
        ];
        for (key, v) in counters {
            o.insert(key.to_string(), Json::Num(v));
        }
        o.insert("queue_ms".to_string(), self.queue_ms.to_json());
        o.insert("batch_sizes".to_string(), self.batch_sizes.to_json());
        let mut svc = std::collections::BTreeMap::new();
        for (backend, set) in &self.service_ms {
            svc.insert(backend.to_string(), set.to_json());
        }
        o.insert("service_ms".to_string(), Json::Obj(svc));
        Json::Obj(o)
    }
}

struct Inner {
    policy: RoutePolicy,
    openmp: OpenMpModel,
    opencl: OpenClModel,
    gprm: GprmModel,
    /// configured default kernel spec (requests may override)
    kernel: KernelSpec,
    /// configured default tile decomposition for native execution
    /// (requests may override; `None` = untiled row bands)
    tile: Option<TileSpec>,
    /// configured default for two-pass fusion (requests may override
    /// with `with_fuse`; single-pass algorithms ignore it)
    fuse: bool,
    /// taps the PJRT path executes with: the manifest's reference
    /// kernel when PJRT is loaded, the configured default otherwise
    kernel_taps: Vec<f32>,
    /// manifest (shape lookups, caller side) + execution handle (actor)
    pjrt: Option<(Manifest, PjrtHandle)>,
    /// one stats shard per executor; shard `i` is only ever locked by
    /// executor `i` (hot path, uncontended) and by `stats()` (merge)
    shards: Vec<Mutex<CoordinatorStats>>,
    /// default TTL stamped on requests that don't carry their own
    default_deadline: Option<Duration>,
    /// round-robin counter: advanced only when the policy itself picks
    /// a backend, so pinned traffic (PJRT included) can't skew it
    native_seq: AtomicU64,
    /// max jobs coalesced into one plan-batched execution (total,
    /// including the head; 1 = no coalescing)
    batch_max: usize,
    /// how long a dequeuing executor holds a non-full batch open for
    /// same-key stragglers (zero = don't wait)
    batch_wait: Duration,
    /// pin executor threads to cores (best-effort, `--pin-cores`)
    pin_cores: bool,
}

impl Inner {
    fn next_seq(&self) -> u64 {
        self.native_seq.fetch_add(1, Ordering::Relaxed)
    }
}

/// Per-executor cache bounds. Shapes and kernels are request-controlled,
/// so without a cap an adversarial mix of distinct (shape, kernel)
/// combinations would grow the plan cache and scratch pool without
/// bound. At the cap the plan cache evicts exactly its least-recently-
/// used entry (it used to drop the whole cache, so one shape-churn burst
/// evicted every hot plan and triggered a rebuild stampede); the scratch
/// pool still clears wholesale — buffers are cheap to re-lease.
const PLAN_CACHE_MAX: usize = 64;
const ARENA_POOL_MAX: usize = 16;

/// Plan-cache key: everything a [`ConvPlan`] is built from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct PlanKey {
    algorithm: Algorithm,
    variant: Variant,
    layout: Layout,
    planes: usize,
    rows: usize,
    cols: usize,
    kernel: (usize, u64),
    /// resolved kernel class — part of plan identity, since each class
    /// lowers to different passes (separable two-pass, direct 2-D, FFT)
    class: KernelClass,
    /// digest of an explicit 2-D tap matrix (`None` = separable spec)
    k2d: Option<u64>,
    /// tile decomposition (`None` = untiled row bands)
    tile: Option<(usize, usize)>,
    /// two-pass fusion (always false for single-pass algorithms)
    fused: bool,
    /// `Some(digest)` for multi-stage graph requests — the chain's
    /// [`super::request::GraphSpec::digest`] — so equal chains batch
    /// together and cache one built [`FilterGraph`]; `kernel`/`tile`/
    /// `fused` are normalised (default/`None`/`false`) for graph keys
    graph: Option<u64>,
}

/// What an executor caches per [`PlanKey`]: a single convolution plan,
/// or a whole built filter graph for multi-stage requests.
enum CachedExec {
    Single(ConvPlan),
    Graph(FilterGraph),
}

/// Per-executor plan cache, bounded at [`PLAN_CACHE_MAX`] with
/// single-entry LRU eviction: inserting past the cap removes exactly the
/// least-recently-used plan, so a hot shape's plan survives arbitrary
/// cold-shape churn (the old clear-everything eviction rebuilt every hot
/// plan after each burst). Graph entries live in the same cache under
/// the same policy — one graph-shaped key, one validated `FilterGraph`.
struct PlanCache {
    /// key → (plan or graph, last-used tick)
    plans: HashMap<PlanKey, (CachedExec, u64)>,
    tick: u64,
    /// plans built so far (monotone; mirrored into `plans_built`)
    built: u64,
}

impl PlanCache {
    fn new() -> Self {
        Self { plans: HashMap::new(), tick: 0, built: 0 }
    }

    fn built(&self) -> u64 {
        self.built
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.plans.len()
    }

    /// The plan (or graph) for `key`, building (and caching) it on a
    /// miss. Every hit refreshes the entry's recency.
    fn get_or_build(
        &mut self,
        key: &PlanKey,
        build: impl FnOnce() -> Result<CachedExec>,
    ) -> Result<&CachedExec> {
        self.tick += 1;
        let tick = self.tick;
        if !self.plans.contains_key(key) {
            if self.plans.len() >= PLAN_CACHE_MAX {
                let lru = self
                    .plans
                    .iter()
                    .min_by_key(|(_, &(_, used))| used)
                    .map(|(&k, _)| k)
                    .expect("cache at cap is non-empty");
                self.plans.remove(&lru);
            }
            let plan = build()?;
            self.built += 1;
            self.plans.insert(*key, (plan, tick));
        }
        let entry = self.plans.get_mut(key).expect("present or just inserted");
        entry.1 = tick;
        Ok(&entry.0)
    }
}

/// The serving loop (see module docs).
pub struct Coordinator {
    inner: Arc<Inner>,
    /// one intake shard per executor; a request lands on the shard its
    /// `PlanKey` hashes to (shard affinity is the contract — there is
    /// deliberately no work stealing, so a shape's traffic always meets
    /// the same warm plan cache and arena)
    queues: Vec<Arc<AdmissionQueue<Job>>>,
    executors: Vec<std::thread::JoinHandle<()>>,
    /// optional tuning tier (swept winners + cost-model predictions)
    /// consulted at admission for requests that pin neither tile nor
    /// fusion; installed with [`Coordinator::set_tuning`]
    tuning: Option<TuningTable>,
    /// admission-side decision counters (the submit path is `&self`
    /// from many threads, so these are atomics, not shard tallies)
    plans_predicted: AtomicU64,
    plans_swept: AtomicU64,
    plans_default: AtomicU64,
}

impl Coordinator {
    /// Build from a run config. `with_pjrt` loads the artifact pool (set
    /// false for native-only serving, e.g. when artifacts aren't built).
    /// Queue capacity and the default deadline come from
    /// `cfg.queue_capacity` / `cfg.deadline_ms` (0 = no deadline).
    pub fn new(
        cfg: &RunConfig,
        policy: RoutePolicy,
        executors: usize,
        with_pjrt: bool,
    ) -> Result<Self> {
        let pjrt = if with_pjrt {
            let manifest = Manifest::load(&cfg.artifacts_dir)?;
            let handle = PjrtHandle::spawn(&cfg.artifacts_dir).context("starting PJRT actor")?;
            Some((manifest, handle))
        } else {
            None
        };
        let kernel = KernelSpec::new(cfg.kernel_width, cfg.sigma);
        kernel.validate().context("invalid configured kernel")?;
        // the PJRT path always executes with the artifacts' reference
        // taps (`pjrt_can_serve` guarantees the request's effective
        // kernel matches them, even when the configured default differs)
        let kernel_taps = match &pjrt {
            Some((manifest, _)) => KernelSpec::new(manifest.kernel_width, manifest.gaussian_sigma)
                .taps()
                .context("manifest kernel spec")?,
            None => kernel.taps()?,
        };
        let n = executors.max(1);
        let inner = Arc::new(Inner {
            policy,
            openmp: OpenMpModel::new(cfg.threads),
            opencl: OpenClModel::new(cfg.threads, 16),
            // agglomeration only applies under tiled dispatch; a raw
            // config with 0 is treated as 1 (validate() enforces >= 1 at
            // the CLI/TOML entry points)
            gprm: GprmModel::new(cfg.threads, cfg.cutoff)
                .with_agglomeration(cfg.agglomeration.max(1)),
            kernel,
            tile: cfg.tile_spec(),
            fuse: cfg.fuse,
            kernel_taps,
            pjrt,
            shards: (0..n).map(|_| Mutex::new(CoordinatorStats::default())).collect(),
            default_deadline: (cfg.deadline_ms > 0)
                .then(|| Duration::from_millis(cfg.deadline_ms)),
            native_seq: AtomicU64::new(0),
            batch_max: cfg.batch_max.max(1),
            batch_wait: Duration::from_micros(cfg.batch_wait_us),
            pin_cores: cfg.pin_cores,
        });
        // the configured capacity divides (ceiling) across the intake
        // shards: a single hot key sees its shard's slice, never the sum
        let per_shard = cfg.queue_capacity.div_ceil(n).max(1);
        let queues: Vec<Arc<AdmissionQueue<Job>>> =
            (0..n).map(|_| Arc::new(AdmissionQueue::new(per_shard))).collect();
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let inner = inner.clone();
            let queue_ref = queues[i].clone();
            let spawned = std::thread::Builder::new()
                .name(format!("phi-conv-executor-{i}"))
                .spawn(move || executor_loop(inner, queue_ref, i));
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // wake and join whatever already spawned before
                    // surfacing the error, or those executors would
                    // block on their queues forever (no Coordinator
                    // means no Drop to close them)
                    for q in &queues {
                        q.close();
                    }
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(Error::from(e).context(format!("spawning executor {i}")));
                }
            }
        }
        Ok(Self {
            inner,
            queues,
            executors: handles,
            tuning: None,
            plans_predicted: AtomicU64::new(0),
            plans_swept: AtomicU64::new(0),
            plans_default: AtomicU64::new(0),
        })
    }

    /// Install the tuning tier (swept winners plus an optional fitted
    /// cost model) consulted at admission for native two-pass SIMD
    /// requests that pin neither tile nor fusion. With this installed,
    /// a never-before-seen shape gets a tiled/fused plan from the cost
    /// model's prediction with zero warm-up sweeps — the serving path
    /// has no sweep entry point at all.
    pub fn set_tuning(&mut self, tuning: TuningTable) {
        self.tuning = Some(tuning);
    }

    pub fn tuning(&self) -> Option<&TuningTable> {
        self.tuning.as_ref()
    }

    /// The request's effective admission deadline: its own TTL, or the
    /// coordinator's configured default. A TTL so large that
    /// `now + ttl` overflows `Instant` is treated as "no deadline" —
    /// `Instant + Duration` would panic, and the submit path guarantees
    /// it never does.
    fn deadline_of(&self, req: &ConvRequest) -> Option<Instant> {
        req.deadline
            .or(self.inner.default_deadline)
            .and_then(|ttl| Instant::now().checked_add(ttl))
    }

    /// Resolve a request at admission: routing, effective
    /// kernel/tile/fuse, and the [`PlanKey`] that drives shard selection
    /// and dequeue-side coalescing. Resolution moved from serve-time to
    /// submit-time in the batching PR; the routing rules themselves are
    /// unchanged, and the round-robin counter still advances in
    /// submission order — exactly what serve-time resolution observed,
    /// since executors dequeued in FIFO order.
    fn job(&self, req: ConvRequest, deadline: Option<Instant>) -> (Job, ReplyReceiver) {
        let inner = &self.inner;
        let kernel = req.kernel.unwrap_or(inner.kernel);
        // the round-robin counter advances only when the policy picks
        // the backend: explicitly pinned traffic (PJRT included) must
        // not consume native cycle slots, or the rotation silently skips
        // backends whenever pinned requests interleave
        let (mut backend, mut layout) = match (req.backend, req.layout) {
            (Some(b), Some(l)) => (b, l),
            (Some(b), None) => (b, inner.policy.route(req.image.rows, 0).1),
            (None, Some(l)) => (inner.policy.route(req.image.rows, inner.next_seq()).0, l),
            (None, None) => inner.policy.route(req.image.rows, inner.next_seq()),
        };
        // PJRT can only serve shapes it has artifacts for (and only the
        // kernel the artifacts were lowered with) and executes single
        // plans only, so graph requests fall back like unservable
        // shapes; the adaptive native choice takes over
        let graph_digest = req.graph.as_ref().map(|g| g.digest());
        // PJRT executes the separable reference artifacts only, so a
        // request wanting a non-separable class (pinned, or implied by
        // explicit 2-D taps) falls back natively like unservable shapes
        let wants_nonseparable = req.kernel2d.is_some()
            || req.kernel_class.is_some_and(|c| c != KernelClass::Separable);
        let mut pjrt_fell_back = false;
        if backend == Backend::Pjrt
            && (graph_digest.is_some()
                || wants_nonseparable
                || !pjrt_can_serve(inner, &req, layout))
        {
            pjrt_fell_back = true;
            let (b, l) = RoutePolicy::paper_default().route(req.image.rows, 0);
            backend = b;
            layout = l;
        }
        // Kernel class resolves alongside tile/fusion. A pinned class
        // (or explicit 2-D taps, whose natural class is direct2d) skips
        // the tuning tier; otherwise the tier's chosen candidate carries
        // the class, which is where the measured direct-vs-FFT crossover
        // routes never-swept large kernels to the transform.
        let pinned_class = match (req.kernel_class, &req.kernel2d) {
            (Some(c), _) => Some(c),
            (None, Some(_)) => Some(KernelClass::Direct2d),
            (None, None) => None,
        };
        // Tile/fusion resolve after the backend so the tuning tier can
        // key on the resolved execution model. Precedence: a request's
        // explicit class/tile/fuse always wins; then a swept or
        // predicted tuning decision; then the configured defaults. Graph
        // requests skip all of it — the chain's own stages and edge
        // policies are the plan, so single-plan knobs normalise out of
        // the key.
        let tuned = if graph_digest.is_none()
            && pinned_class.is_none()
            && req.tile.is_none()
            && req.fuse.is_none()
        {
            self.tuned_decision(&req, backend, &kernel)
        } else {
            None
        };
        let (tile, fuse, class) = match tuned {
            Some(decision) => decision,
            None => (
                req.tile.or(inner.tile),
                req.fuse.unwrap_or(inner.fuse),
                if graph_digest.is_some() {
                    KernelClass::Separable
                } else {
                    pinned_class.unwrap_or_default()
                },
            ),
        };
        // fusion only applies to the separable two-pass algorithm; a
        // fused serving default must not refuse single-pass or
        // non-separable traffic, so it is silently inapplicable there
        // rather than a build error. FFT plans are untiled by contract.
        let fuse = fuse
            && req.algorithm == Algorithm::TwoPass
            && graph_digest.is_none()
            && class == KernelClass::Separable;
        let tile =
            if graph_digest.is_some() || class == KernelClass::Fft { None } else { tile };
        let k2d = if graph_digest.is_some() {
            None
        } else {
            req.kernel2d.as_ref().map(|k| k.digest())
        };
        let key = PlanKey {
            algorithm: req.algorithm,
            variant: req.variant,
            layout,
            planes: req.image.planes,
            rows: req.image.rows,
            cols: req.image.cols,
            kernel: kernel.cache_key(),
            class,
            k2d,
            tile: tile.map(|t| t.cache_key()),
            fused: fuse,
            graph: graph_digest,
        };
        let (reply, rx) = channel();
        let job = Job {
            req,
            backend,
            layout,
            kernel,
            class,
            tile,
            fuse,
            key,
            pjrt_fell_back,
            enqueued: Instant::now(),
            deadline,
            reply,
        };
        (job, rx)
    }

    /// Consult the tuning tier for a request that pinned neither tile
    /// nor fusion. Only native two-pass SIMD traffic is tuned — that is
    /// what the sweeps and the cost model measure; PJRT executes fixed
    /// artifacts and other algorithm/variant mixes keep the configured
    /// defaults without touching the counters. A swept candidate's GPRM
    /// agglomeration factor is a model-level knob (the serving pool is
    /// built once from config), so only its tile, fusion and kernel
    /// class apply here. Returns the (tile, fuse, class) to build with,
    /// or `None` to fall through to the config defaults.
    fn tuned_decision(
        &self,
        req: &ConvRequest,
        backend: Backend,
        kernel: &KernelSpec,
    ) -> Option<(Option<TileSpec>, bool, KernelClass)> {
        let table = self.tuning.as_ref()?;
        if backend == Backend::Pjrt
            || req.algorithm != Algorithm::TwoPass
            || req.variant != Variant::Simd
        {
            return None;
        }
        let inner = &self.inner;
        let (name, workers) = match backend {
            Backend::NativeOpenMp => (inner.openmp.name(), inner.openmp.workers()),
            Backend::NativeOpenCl => (inner.opencl.name(), inner.opencl.workers()),
            Backend::NativeGprm => (inner.gprm.name(), inner.gprm.workers()),
            Backend::Pjrt => return None,
        };
        match table.choose(
            name,
            req.image.planes,
            req.image.rows,
            req.image.cols,
            kernel.width,
            workers,
        ) {
            Some(PlanDecision::Swept(t)) => {
                self.plans_swept.fetch_add(1, Ordering::Relaxed);
                Some((t.candidate.tile, t.candidate.fused, t.candidate.class))
            }
            Some(PlanDecision::Predicted(p)) => {
                self.plans_predicted.fetch_add(1, Ordering::Relaxed);
                Some((p.candidate.tile, p.candidate.fused, p.candidate.class))
            }
            None => {
                self.plans_default.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// The intake shard a plan key's traffic lands on. The backend is
    /// deliberately not hashed: one shape = one shard = one warm plan
    /// cache, whichever backend each request resolves to.
    fn shard_of(&self, key: &PlanKey) -> usize {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.queues.len()
    }

    /// Enqueue a request; the receiver yields the response (or a
    /// structured error) when served. Blocks while the shard is at
    /// capacity — backpressure — bounded by the request's deadline.
    /// Never panics: refusals are `QueueFull` / `DeadlineExceeded` /
    /// `Shutdown` errors.
    pub fn submit(&self, req: ConvRequest) -> Result<ReplyReceiver> {
        let deadline = self.deadline_of(&req);
        let (job, rx) = self.job(req, deadline);
        let q = &self.queues[self.shard_of(&job.key)];
        q.push(job, deadline).map_err(|r| r.to_error(q.capacity()))?;
        Ok(rx)
    }

    /// Non-blocking admission: sheds immediately with `QueueFull` when
    /// the shard is at capacity.
    pub fn try_submit(&self, req: ConvRequest) -> Result<ReplyReceiver> {
        let deadline = self.deadline_of(&req);
        let (job, rx) = self.job(req, deadline);
        let q = &self.queues[self.shard_of(&job.key)];
        q.try_push(job, deadline).map_err(|r| r.to_error(q.capacity()))?;
        Ok(rx)
    }

    /// Blocking admission bounded by `wait`: sheds with `QueueFull` if
    /// no slot frees in time.
    pub fn submit_timeout(&self, req: ConvRequest, wait: Duration) -> Result<ReplyReceiver> {
        let deadline = self.deadline_of(&req);
        let (job, rx) = self.job(req, deadline);
        let q = &self.queues[self.shard_of(&job.key)];
        q.push_timeout(job, deadline, wait).map_err(|r| r.to_error(q.capacity()))?;
        Ok(rx)
    }

    /// Submit and wait for the response.
    pub fn serve(&self, req: ConvRequest) -> Result<ConvResponse> {
        let rx = self.submit(req)?;
        match rx.recv() {
            Ok(result) => result,
            // the reply sender was dropped without a reply — only
            // possible if an executor died mid-request
            Err(_) => Err(Error::with_kind(
                ErrorKind::Shutdown,
                "coordinator dropped the reply channel",
            )),
        }
    }

    /// Merged statistics: all executor shards plus the queue counters.
    pub fn stats(&self) -> CoordinatorStats {
        let mut total = CoordinatorStats::default();
        for shard in &self.inner.shards {
            let guard = shard.lock().unwrap_or_else(PoisonError::into_inner);
            total.merge(&guard);
        }
        // the queues' counters ACCUMULATE into the shard totals —
        // executors tally their own expiries (batch members re-checked
        // at execution start), and overwriting used to discard them.
        // Counters add; `depth` is a gauge but the shard queues are
        // disjoint, so the instantaneous total is their sum; the
        // high-water marks peaked at different instants and can only be
        // combined by max.
        for q in &self.queues {
            let c = q.counters();
            total.shed += c.shed;
            total.expired += c.expired;
            total.depth += c.depth;
            total.depth_peak = total.depth_peak.max(c.depth_peak);
        }
        // admission-side decision counters live on the coordinator, not
        // in the executor shards (decisions happen at submit)
        total.plans_predicted += self.plans_predicted.load(Ordering::Relaxed);
        total.plans_swept += self.plans_swept.load(Ordering::Relaxed);
        total.plans_default += self.plans_default.load(Ordering::Relaxed);
        total
    }

    /// Items currently waiting for an executor (all shards).
    pub fn queue_depth(&self) -> usize {
        self.queues.iter().map(|q| q.depth()).sum()
    }

    /// Total admission capacity (summed over the per-executor shards;
    /// the ceiling split means this can slightly exceed the configured
    /// `queue_capacity`, never undercut it).
    pub fn queue_capacity(&self) -> usize {
        self.queues.iter().map(|q| q.capacity()).sum()
    }

    /// Executor (= intake-shard) count.
    pub fn executors(&self) -> usize {
        self.queues.len()
    }

    /// Test-only: mutate one executor shard's stats in place, simulating
    /// executor-side tallies without racing real timing.
    #[cfg(test)]
    fn bump_shard(&self, shard: usize, f: impl FnOnce(&mut CoordinatorStats)) {
        let mut st = self.inner.shards[shard].lock().unwrap_or_else(PoisonError::into_inner);
        f(&mut st);
    }

    /// True when the PJRT backend is loaded.
    pub fn has_pjrt(&self) -> bool {
        self.inner.pjrt.is_some()
    }

    /// Pre-compile the full-image artifacts for the given sizes so the
    /// first PJRT-routed request doesn't pay compile latency. Returns
    /// (artifact, compile ms) pairs.
    pub fn warm_pjrt(&self, planes: usize, sizes: &[usize]) -> Result<Vec<(String, f64)>> {
        let (manifest, handle) = match &self.inner.pjrt {
            Some(p) => p,
            None => return Ok(vec![]),
        };
        let mut names = Vec::new();
        for &n in sizes {
            for name in [
                format!("twopass_p{planes}_{n}"),
                format!("singlepass_p{planes}_{n}"),
                format!("twopass_agg_{n}"),
            ] {
                if manifest.get(&name).is_ok() {
                    names.push(name);
                }
            }
        }
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let times = handle.warm(&refs)?;
        Ok(names.into_iter().zip(times).collect())
    }
}

impl Drop for Coordinator {
    /// Graceful drain: refuse new admissions, let the executors finish
    /// everything already queued (expired items are rejected with
    /// structured `DeadlineExceeded` errors, live ones complete), then
    /// join them. Every outstanding reply channel resolves.
    fn drop(&mut self) {
        for q in &self.queues {
            q.close();
        }
        for h in self.executors.drain(..) {
            let _ = h.join();
        }
    }
}

fn executor_loop(inner: Arc<Inner>, queue: Arc<AdmissionQueue<Job>>, shard: usize) {
    if inner.pin_cores {
        // best-effort: shard i → core i (mod cores); a refused pin (odd
        // cgroup mask, non-linux target) leaves the executor floating.
        // Note the compute pools inside the execution models are shared
        // across executors, so pinning covers the executor threads (and
        // whatever runs inline on them), not the pool workers.
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let _ = affinity::pin_current_thread(shard % cores);
    }
    // per-executor state: scratch planes recycle across requests (zero
    // scratch allocations after warm-up) and plans are built once per
    // distinct request configuration, evicted one LRU entry at a time
    let mut arena = ScratchArena::new();
    let mut cache = PlanCache::new();
    // coalescing key: the plan key plus the backend — a batch must be
    // servable by one plan on one execution model
    let key_of = |j: &Job| (j.key, j.backend);
    let straggler =
        (inner.batch_max > 1 && !inner.batch_wait.is_zero()).then_some(inner.batch_wait);
    loop {
        match queue.pop_batch(inner.batch_max, straggler, None, &key_of) {
            PopBatch::Closed => return, // own shard drained and shut down
            PopBatch::Empty => continue, // unreachable: the idle wait is unbounded
            PopBatch::Batch(batch) => serve_batch(&inner, &mut arena, &mut cache, shard, batch),
        }
    }
}

/// Reply `DeadlineExceeded` to members whose TTL lapsed in queue or at
/// the execution boundary.
fn reject_expired(jobs: Vec<Job>) {
    for job in jobs {
        let waited = job.enqueued.elapsed().as_secs_f64() * 1e3;
        let _ = job.reply.send(Err(Error::with_kind(
            ErrorKind::DeadlineExceeded,
            format!("request deadline exceeded after {waited:.1} ms in queue"),
        )));
    }
}

/// Serve one coalesced batch: reject its expired members, execute the
/// live ones through a single plan dispatch, record stats (under the
/// shard lock, *before* any reply is sent — a caller that observed its
/// reply must find it already counted), then reply to every member.
fn serve_batch(
    inner: &Inner,
    arena: &mut ScratchArena,
    cache: &mut PlanCache,
    shard: usize,
    batch: Batch<Job>,
) {
    // queue-side expiries first (the queue already counted them): their
    // rejection must not wait for the batch's convolution
    reject_expired(batch.expired);

    // the fairness backstop: every member's TTL is re-checked at
    // execution start — a member that lapsed during the straggler
    // window (or behind a slow predecessor) is rejected, not executed
    let now = Instant::now();
    let (live, late): (Vec<Job>, Vec<Job>) =
        batch.jobs.into_iter().partition(|j| !j.deadline.is_some_and(|d| d <= now));
    let exec_expired = late.len() as u64;
    if live.is_empty() {
        if exec_expired > 0 {
            let mut st = inner.shards[shard].lock().unwrap_or_else(PoisonError::into_inner);
            st.expired += exec_expired;
        }
        reject_expired(late);
        return;
    }

    let n = live.len();
    let built_before = cache.built();
    // per-member queue time, measured at execution start: it includes
    // any in-batch straggler wait (time spent not-yet-executing)
    let queue_ms: Vec<f64> =
        live.iter().map(|j| j.enqueued.elapsed().as_secs_f64() * 1e3).collect();
    let t0 = Instant::now();
    let outcome = execute_batch_jobs(inner, arena, cache, &live);
    // members share the batch's wall time evenly: the amortised
    // per-request cost is exactly what coalescing buys
    let service_each = t0.elapsed().as_secs_f64() * 1e3 / n as f64;

    {
        // this executor's own shard: uncontended unless stats() is
        // merging, and never held across the convolution above
        let mut st = inner.shards[shard].lock().unwrap_or_else(PoisonError::into_inner);
        st.expired += exec_expired;
        st.plans_built += cache.built() - built_before;
        st.batch_sizes.push(n as f64);
        for job in &live {
            if job.pjrt_fell_back {
                st.pjrt_fallbacks += 1;
            }
        }
        match &outcome {
            Ok(_) => {
                st.served += n as u64;
                for (job, q) in live.iter().zip(&queue_ms) {
                    st.queue_ms.push(*q);
                    st.service_ms.entry(job.backend.label()).or_default().push(service_each);
                    if let Some(g) = &job.req.graph {
                        st.graphs_served += 1;
                        st.stages_fused += g.streamed_edges() as u64;
                    }
                }
            }
            Err(_) => st.errors += n as u64,
        }
    }

    reject_expired(late);
    match outcome {
        // execute_batch maps inputs to outputs in order, so zipping
        // restores each member's own pixels
        Ok(images) => {
            for ((job, image), q) in live.into_iter().zip(images).zip(queue_ms) {
                let resp = ConvResponse {
                    id: job.req.id,
                    image,
                    backend: job.backend,
                    layout: job.layout,
                    queue_ms: q,
                    service_ms: service_each,
                    batch_len: n,
                    kernel_class: job.class,
                };
                let _ = job.reply.send(Ok(resp)); // receiver may have gone away
            }
        }
        Err(e) => {
            // Error is not Clone: reconstruct one per member, preserving
            // the kind and the full context chain callers match on
            let kind = e.kind();
            let msg = format!("{e:#}");
            for job in live {
                let _ = job.reply.send(Err(Error::with_kind(kind, msg.clone())));
            }
        }
    }
}

/// Execute a batch of same-key jobs through one plan. The head job
/// defines the plan (all members share its `PlanKey` and backend);
/// kernel/tile validation happens here so a bad spec is a structured
/// execution error counted in `errors`, exactly as single serving did.
fn execute_batch_jobs(
    inner: &Inner,
    arena: &mut ScratchArena,
    cache: &mut PlanCache,
    jobs: &[Job],
) -> Result<Vec<PlanarImage>> {
    let head = &jobs[0];
    head.kernel.validate().context("invalid request kernel")?;
    if let Some(t) = head.tile {
        t.validate().context("invalid request tile")?;
    }
    match head.backend {
        Backend::Pjrt => jobs.iter().map(|j| run_pjrt(inner, &j.req, j.layout)).collect(),
        Backend::NativeOpenMp | Backend::NativeOpenCl | Backend::NativeGprm => {
            let model: &dyn crate::models::ExecutionModel = match head.backend {
                Backend::NativeOpenMp => &inner.openmp,
                Backend::NativeOpenCl => &inner.opencl,
                _ => &inner.gprm,
            };
            let exec = cache.get_or_build(&head.key, || match &head.req.graph {
                Some(spec) => {
                    spec.validate().context("invalid request graph")?;
                    spec.build(
                        head.req.image.planes,
                        head.req.image.rows,
                        head.req.image.cols,
                        head.req.variant,
                        head.layout,
                    )
                    .context("invalid request graph")
                    .map(CachedExec::Graph)
                }
                None => {
                    let mut b = ConvPlan::builder()
                        .algorithm(head.req.algorithm)
                        .variant(head.req.variant)
                        .layout(head.layout)
                        .kernel(head.kernel)
                        .kernel_class(head.class)
                        .tile_opt(head.tile)
                        .fuse(head.fuse)
                        .shape(head.req.image.planes, head.req.image.rows, head.req.image.cols);
                    if let Some(k) = &head.req.kernel2d {
                        b = b.kernel2d(k.clone());
                    }
                    b.build().context("invalid request plan").map(CachedExec::Single)
                }
            })?;
            let images = match exec {
                CachedExec::Single(plan) => {
                    plan.execute_batch(Some(model), jobs.iter().map(|j| &j.req.image), arena)?
                }
                // a graph member is one deadline-scoped queue entry whose
                // whole chain executes in a single serve; members share
                // the cached graph and the warm arena
                CachedExec::Graph(graph) => {
                    let mut out = Vec::with_capacity(jobs.len());
                    for j in jobs {
                        out.push(graph.execute_single(Some(model), &j.req.image, arena)?);
                    }
                    out
                }
            };
            if arena.pooled() > ARENA_POOL_MAX {
                arena.clear();
            }
            Ok(images)
        }
    }
}

fn pjrt_artifact_name(req: &ConvRequest, layout: Layout) -> Option<String> {
    if req.image.rows != req.image.cols {
        return None; // full-image artifacts are square
    }
    let n = req.image.rows;
    Some(match (layout, req.algorithm) {
        (Layout::Agglomerated, Algorithm::TwoPass) => format!("twopass_agg_{n}"),
        (Layout::Agglomerated, _) => return None,
        (_, Algorithm::TwoPass) => format!("twopass_p{}_{n}", req.image.planes),
        // copy-back and no-copy have identical pixels; one artifact serves both
        (_, Algorithm::SinglePassCopyBack | Algorithm::SinglePassNoCopy) => {
            format!("singlepass_p{}_{n}", req.image.planes)
        }
    })
}

fn pjrt_can_serve(inner: &Inner, req: &ConvRequest, layout: Layout) -> bool {
    let (manifest, _) = match &inner.pjrt {
        Some(p) => p,
        None => return false,
    };
    // the AOT artifacts bake in the manifest's reference kernel; the
    // request's effective kernel (its own spec, or the coordinator's
    // configured default) must match it exactly or take the native path
    let spec = req.kernel.unwrap_or(inner.kernel);
    if spec.width != manifest.kernel_width || spec.sigma != manifest.gaussian_sigma {
        return false;
    }
    match pjrt_artifact_name(req, layout) {
        Some(name) => manifest.get(&name).is_ok(),
        None => false,
    }
}

fn run_pjrt(inner: &Inner, req: &ConvRequest, layout: Layout) -> Result<PlanarImage> {
    let (_, handle) = inner.pjrt.as_ref().context("PJRT backend not loaded")?;
    let name = pjrt_artifact_name(req, layout).context("no artifact for this request shape")?;
    let out = handle.run1(&name, vec![req.image.data.clone(), inner.kernel_taps.clone()])?;
    PlanarImage::from_vec(req.image.planes, req.image.rows, req.image.cols, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{convolve_image, Variant};
    use crate::image::{synth_image, Pattern};

    fn cfg() -> RunConfig {
        RunConfig { threads: 4, ..Default::default() }
    }

    #[test]
    fn serves_native_request_correctly() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 2, false).unwrap();
        let img = synth_image(3, 32, 28, Pattern::Noise, 1);
        let k = crate::image::gaussian_kernel(5, 1.0);
        let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        let resp = c.serve(ConvRequest::new(1, img)).unwrap();
        assert_eq!(resp.image, want);
        assert_eq!(resp.backend, Backend::NativeOpenMp);
        assert!(resp.service_ms >= 0.0);
    }

    #[test]
    fn serves_graph_request_end_to_end() {
        use crate::coordinator::GraphSpec;
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 2, false)
            .unwrap();
        let img = synth_image(2, 30, 26, Pattern::Noise, 8);
        let spec = GraphSpec::chain(vec![KernelSpec::new(3, 0.8), KernelSpec::new(7, 1.5)]);
        // oracle: the same stages, one materialised plan at a time
        let mut arena = ScratchArena::new();
        let want = spec
            .build(2, 30, 26, Variant::Simd, Layout::PerPlane)
            .unwrap()
            .execute_materialized(None, &img, &mut arena)
            .unwrap()
            .pop()
            .unwrap();
        let resp = c.serve(ConvRequest::new(1, img.clone()).with_graph(spec.clone())).unwrap();
        assert_eq!(resp.image, want, "streamed chain serving is bitwise for generic widths");
        assert_eq!(resp.batch_len, 1, "one chain = one queue entry");
        // a second identical chain hits the cached FilterGraph
        let resp2 = c.serve(ConvRequest::new(2, img).with_graph(spec)).unwrap();
        assert_eq!(resp2.image, want);
        let st = c.stats();
        assert_eq!(st.served, 2);
        assert_eq!(st.graphs_served, 2);
        assert_eq!(st.stages_fused, 2, "one streamed edge per chain");
        assert_eq!(st.plans_built, 1, "the graph was built once and cached");
        assert_eq!(st.errors, 0);
    }

    #[test]
    fn graph_request_with_bad_stage_is_a_structured_error() {
        use crate::coordinator::GraphSpec;
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false)
            .unwrap();
        let img = synth_image(1, 16, 16, Pattern::Noise, 9);
        let spec = GraphSpec::chain(vec![KernelSpec::new(4, 1.0)]); // even width
        let e = c.serve(ConvRequest::new(1, img).with_graph(spec)).unwrap_err();
        assert!(format!("{e:#}").contains("invalid request graph"), "{e:#}");
        assert_eq!(e.kind(), ErrorKind::InvalidKernel, "kernel kind survives the graph path");
        assert_eq!(c.stats().errors, 1);
        assert_eq!(c.stats().graphs_served, 0);
    }

    #[test]
    fn round_robin_unskewed_by_pinned_traffic() {
        // pinned traffic (PJRT included — it falls back natively here)
        // interleaves with policy-routed requests; the rotation must
        // still hand each native backend exactly its even share
        let c = Coordinator::new(&cfg(), RoutePolicy::RoundRobin, 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 2);
        let mut counts: HashMap<Backend, usize> = HashMap::new();
        for i in 0..12u64 {
            if i % 2 == 1 {
                // explicitly pinned: must not consume a rotation slot
                let pinned = c
                    .serve(ConvRequest::new(i, img.clone()).with_backend(Backend::Pjrt))
                    .unwrap();
                assert_ne!(pinned.backend, Backend::Pjrt, "no PJRT loaded: falls back");
                continue;
            }
            let resp = c.serve(ConvRequest::new(i, img.clone())).unwrap();
            *counts.entry(resp.backend).or_insert(0) += 1;
        }
        assert_eq!(counts.len(), 3, "all three native backends used: {counts:?}");
        for (backend, n) in &counts {
            assert_eq!(*n, 2, "{backend:?} must serve exactly 2 of 6 rotation slots");
        }
        let st = c.stats();
        assert_eq!(st.served, 12);
        assert_eq!(st.errors, 0);
    }

    #[test]
    fn adaptive_policy_routes_by_size() {
        let c = Coordinator::new(
            &cfg(),
            RoutePolicy::PaperAdaptive { large_threshold: 30 },
            1,
            false,
        )
        .unwrap();
        let small = synth_image(3, 24, 24, Pattern::Noise, 3);
        let large = synth_image(3, 40, 40, Pattern::Noise, 4);
        let r1 = c.serve(ConvRequest::new(1, small)).unwrap();
        assert_eq!((r1.backend, r1.layout), (Backend::NativeOpenMp, Layout::PerPlane));
        let r2 = c.serve(ConvRequest::new(2, large)).unwrap();
        assert_eq!((r2.backend, r2.layout), (Backend::NativeGprm, Layout::Agglomerated));
    }

    #[test]
    fn explicit_backend_respected() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 5);
        let resp = c
            .serve(ConvRequest::new(1, img).with_backend(Backend::NativeGprm))
            .unwrap();
        assert_eq!(resp.backend, Backend::NativeGprm);
    }

    #[test]
    fn concurrent_submissions_all_served() {
        let c = Coordinator::new(&cfg(), RoutePolicy::RoundRobin, 3, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 6);
        let receivers: Vec<_> = (0..20)
            .map(|i| c.submit(ConvRequest::new(i, img.clone())).unwrap())
            .collect();
        for rx in receivers {
            assert!(rx.recv().unwrap().is_ok());
        }
        assert_eq!(c.stats().served, 20);
    }

    #[test]
    fn burst_beyond_capacity_sheds_not_panics() {
        // tiny queue, one executor kept busy by real work: try_submit
        // must shed the overflow with structured QueueFull errors and
        // keep every admitted request servable
        let cfg = RunConfig { queue_capacity: 1, ..cfg() };
        let c = Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 128, 128, Pattern::Noise, 7);
        // requests pre-built so the burst loop is tight: the executor
        // cannot drain a capacity-1 queue as fast as try_submit refills
        let reqs: Vec<_> = (0..50u64).map(|i| ConvRequest::new(i, img.clone())).collect();
        let mut admitted = Vec::new();
        let mut shed = 0u64;
        for req in reqs {
            match c.try_submit(req) {
                Ok(rx) => admitted.push(rx),
                Err(e) => {
                    assert_eq!(e.kind(), ErrorKind::QueueFull, "got: {e:#}");
                    shed += 1;
                }
            }
        }
        assert!(shed >= 1, "a 50-burst into a capacity-1 queue must shed");
        for rx in admitted {
            assert!(rx.recv().unwrap().is_ok());
        }
        let st = c.stats();
        assert_eq!(st.shed, shed);
        assert_eq!(st.served + st.shed, 50);
        assert!(st.depth_peak >= 1);
    }

    #[test]
    fn zero_ttl_request_is_deadline_exceeded() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 8);
        let e = c
            .submit(ConvRequest::new(1, img).with_deadline(Duration::ZERO))
            .unwrap_err();
        assert_eq!(e.kind(), ErrorKind::DeadlineExceeded);
        assert_eq!(c.stats().expired, 1);
    }

    #[test]
    fn absurd_ttl_never_panics_the_submit_path() {
        // Instant::now() + Duration::MAX would overflow-panic; the
        // submit path must degrade it to "no deadline" instead
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 13);
        let resp = c.serve(ConvRequest::new(1, img).with_deadline(Duration::MAX));
        assert!(resp.is_ok(), "got: {resp:?}");
        assert_eq!(c.stats().expired, 0);
    }

    #[test]
    fn configured_default_deadline_applies() {
        // deadline_ms stamps every request lacking its own TTL; an
        // impossible 0-width window is exercised per-request instead
        // (deadline_ms = 0 means "no default"), so here we only check
        // that a generous default leaves normal serving untouched
        let cfg = RunConfig { deadline_ms: 60_000, ..cfg() };
        let c = Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 12);
        assert!(c.serve(ConvRequest::new(1, img)).is_ok());
        assert_eq!(c.stats().expired, 0);
    }

    #[test]
    fn per_request_kernel_served_natively() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 28, 28, Pattern::Noise, 8);
        for spec in [KernelSpec::new(3, 1.0), KernelSpec::new(7, 2.0)] {
            let k = crate::image::gaussian_kernel(spec.width, spec.sigma);
            let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
            let resp = c.serve(ConvRequest::new(1, img.clone()).with_kernel(spec)).unwrap();
            assert_eq!(resp.image, want, "{spec:?}");
        }
    }

    #[test]
    fn tiled_request_matches_untiled_pixels() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 30, 28, Pattern::Noise, 21);
        let want = c.serve(ConvRequest::new(1, img.clone())).unwrap();
        for tile in [TileSpec::new(4, 8), TileSpec::new(64, 64)] {
            let got = c.serve(ConvRequest::new(2, img.clone()).with_tile(tile)).unwrap();
            assert!(
                got.image.max_abs_diff(&want.image) <= 1e-6,
                "tile {}",
                tile.label()
            );
        }
        // every backend serves tiled requests
        for backend in [Backend::NativeOpenCl, Backend::NativeGprm] {
            let got = c
                .serve(
                    ConvRequest::new(3, img.clone())
                        .with_backend(backend)
                        .with_tile(TileSpec::new(8, 8)),
                )
                .unwrap();
            assert!(got.image.max_abs_diff(&want.image) <= 1e-6, "{backend:?}");
        }
    }

    #[test]
    fn fused_requests_match_unfused_pixels() {
        // per-request fusion on a default-unfused coordinator
        let policy = RoutePolicy::Fixed(Backend::NativeOpenMp);
        let c = Coordinator::new(&cfg(), policy, 1, false).unwrap();
        let img = synth_image(3, 30, 28, Pattern::Noise, 31);
        let want = c.serve(ConvRequest::new(1, img.clone())).unwrap();
        for backend in [Backend::NativeOpenMp, Backend::NativeOpenCl, Backend::NativeGprm] {
            let got = c
                .serve(ConvRequest::new(2, img.clone()).with_backend(backend).with_fuse(true))
                .unwrap();
            assert!(got.image.max_abs_diff(&want.image) <= 1e-6, "{backend:?}");
        }
        // fused composes with tiling on the serving path
        let got = c
            .serve(ConvRequest::new(3, img.clone()).with_fuse(true).with_tile(TileSpec::new(8, 8)))
            .unwrap();
        assert!(got.image.max_abs_diff(&want.image) <= 1e-6, "fused+tiled");

        // a --fuse coordinator default applies to two-pass requests and
        // is silently inapplicable to single-pass ones; with_fuse(false)
        // opts a request back out
        let cfg = RunConfig { fuse: true, ..cfg() };
        let c = Coordinator::new(&cfg, policy, 1, false).unwrap();
        let fused_default = c.serve(ConvRequest::new(4, img.clone())).unwrap();
        assert!(fused_default.image.max_abs_diff(&want.image) <= 1e-6);
        let opted_out = c.serve(ConvRequest::new(5, img.clone()).with_fuse(false)).unwrap();
        assert_eq!(opted_out.image, want.image);
        let single_pass = c
            .serve(ConvRequest::new(6, img).with_algorithm(Algorithm::SinglePassNoCopy))
            .unwrap();
        assert_eq!(single_pass.backend, Backend::NativeOpenMp);
        assert_eq!(c.stats().errors, 0, "single-pass under --fuse must not error");
    }

    #[test]
    fn configured_tile_default_applies_to_requests() {
        let cfg = RunConfig { tile_rows: 8, tile_cols: 8, agglomeration: 2, ..cfg() };
        let c = Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::NativeGprm), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 22);
        let k = crate::image::gaussian_kernel(5, 1.0);
        let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        let resp = c.serve(ConvRequest::new(1, img)).unwrap();
        assert!(resp.image.max_abs_diff(&want) <= 1e-6);
    }

    #[test]
    fn invalid_request_tile_is_structured_error() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 23);
        let err = c
            .serve(ConvRequest::new(1, img.clone()).with_tile(TileSpec::new(0, 8)))
            .unwrap_err();
        assert!(format!("{err:#}").contains("tile"), "got: {err:#}");
        // the coordinator keeps serving afterwards
        assert!(c.serve(ConvRequest::new(2, img)).is_ok());
    }

    #[test]
    fn invalid_request_kernel_is_structured_error() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 9);
        let err = c
            .serve(ConvRequest::new(1, img.clone()).with_kernel(KernelSpec::new(4, 1.0)))
            .unwrap_err();
        assert!(format!("{err:#}").contains("odd"), "got: {err:#}");
        assert_eq!(err.kind(), ErrorKind::InvalidKernel, "structured kernel refusal");
        // the coordinator keeps serving and counts the error
        assert!(c.serve(ConvRequest::new(2, img)).is_ok());
        let st = c.stats();
        assert_eq!((st.errors, st.served), (1, 1));
    }

    #[test]
    fn shape_churn_beyond_cache_caps_still_serves() {
        // more distinct shapes than PLAN_CACHE_MAX / ARENA_POOL_MAX:
        // the eviction path must kick in without affecting results
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let k = crate::image::gaussian_kernel(5, 1.0);
        for size in 8..(8 + PLAN_CACHE_MAX + 6) {
            let img = synth_image(1, size, size, Pattern::Noise, size as u64);
            let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
            let resp = c.serve(ConvRequest::new(size as u64, img)).unwrap();
            assert_eq!(resp.image, want, "size {size}");
        }
        assert_eq!(c.stats().errors, 0);
    }

    #[test]
    fn invalid_configured_kernel_rejected_at_construction() {
        let bad = RunConfig { kernel_width: 4, ..cfg() };
        assert!(Coordinator::new(&bad, RoutePolicy::RoundRobin, 1, false).is_err());
    }

    #[test]
    fn custom_kernel_never_routes_to_pjrt() {
        // explicit Pjrt backend + non-default kernel: must fall back to a
        // native backend (artifacts carry only the default taps)
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::Pjrt), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 10);
        let resp = c
            .serve(ConvRequest::new(1, img).with_kernel(KernelSpec::new(7, 1.0)))
            .unwrap();
        assert_ne!(resp.backend, Backend::Pjrt);
    }

    #[test]
    fn pjrt_fallback_when_no_artifact_shape() {
        // 24x24 has no artifact; explicit Pjrt backend must fall back, not fail
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::Pjrt), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 7);
        let resp = c.serve(ConvRequest::new(1, img)).unwrap();
        assert_ne!(resp.backend, Backend::Pjrt);
        assert_eq!(c.stats().pjrt_fallbacks, 1);
    }

    /// Noise-free linear training samples for one execution model, with
    /// fused+tiled constructed 4x cheaper than the untiled baseline so
    /// the predictive tier has a decisive winner.
    fn synthetic_samples(model: &str, workers: usize) -> Vec<crate::costmodel::Sample> {
        use crate::costmodel::{dispatch_units, Sample};
        let mut out = Vec::new();
        let tiles = [None, Some(TileSpec::new(16, usize::MAX)), Some(TileSpec::new(32, 32))];
        for (rows, cols) in [(64, 64), (80, 96), (96, 128), (128, 128), (160, 96), (192, 192)] {
            for width in [3usize, 5, 7] {
                for tile in tiles {
                    for fused in [false, true] {
                        let units = dispatch_units(rows, cols, tile, workers);
                        let pixels = (3 * rows * cols) as f64;
                        let base = 0.2 + 1.5e-6 * pixels + 2.0e-7 * pixels * width as f64
                            + 1e-3 * units as f64;
                        let mult = match (fused, tile.is_some()) {
                            (false, false) => 4.0,
                            (true, false) => 3.0,
                            (false, true) => 2.0,
                            (true, true) => 1.0,
                        };
                        out.push(Sample {
                            model: model.to_string(),
                            class: "separable".to_string(),
                            planes: 3,
                            rows,
                            cols,
                            kernel_width: width,
                            tile,
                            fused,
                            agglomeration: 1,
                            units,
                            workers,
                            ms: base * mult,
                            reps: 3,
                            warmup: 1,
                        });
                    }
                }
            }
        }
        out
    }

    #[test]
    fn predicted_decision_serves_unseen_shape_without_sweep() {
        use crate::costmodel::CostModel;
        let mut c =
            Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let mut table = TuningTable::new();
        table.set_cost_model(CostModel::fit(synthetic_samples("OpenMP", 4), 0.8));
        c.set_tuning(table);
        // 3x100x100 w5 appears in no swept entry and no training row;
        // the cost model must hand admission a fused+tiled plan. The
        // serving path has no sweep entry point at all, so the predicted
        // counter incrementing (and swept/default staying zero) *is* the
        // no-sweep guarantee.
        let decision = c.tuning().unwrap().choose("OpenMP", 3, 100, 100, 5, 4);
        match decision {
            Some(PlanDecision::Predicted(p)) => {
                assert!(p.candidate.fused && p.candidate.tile.is_some(), "{:?}", p.candidate);
                assert!(p.ms <= p.baseline_ms);
            }
            other => panic!("expected a prediction, got {other:?}"),
        }
        let img = synth_image(3, 100, 100, Pattern::Noise, 99);
        let k = crate::image::gaussian_kernel(5, 1.0);
        let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        let resp = c.serve(ConvRequest::new(1, img)).unwrap();
        assert!(
            resp.image.max_abs_diff(&want) <= 1e-6,
            "predicted fused+tiled plan matches the oracle"
        );
        let st = c.stats();
        assert_eq!(st.plans_predicted, 1, "exactly one predicted decision");
        assert_eq!((st.plans_swept, st.plans_default), (0, 0));
        assert_eq!(st.plans_built, 1, "one plan, built once, no sweep");
        assert_eq!((st.served, st.errors), (1, 0));
    }

    /// Per-class training rows: direct-arithmetic classes scale with
    /// pixels·width while the FFT class is flat in width, so the fitted
    /// crossover routes large kernels to the transform.
    fn class_samples(model: &str, workers: usize) -> Vec<crate::costmodel::Sample> {
        use crate::costmodel::Sample;
        let mut out = Vec::new();
        for (rows, cols) in [(64, 64), (96, 96), (128, 128), (160, 160), (192, 192), (128, 192)] {
            for width in [3usize, 7, 15, 31, 61] {
                let pixels = (3 * rows * cols) as f64;
                for (class, ms) in [
                    ("separable", 0.1 + 1.0e-6 * pixels * width as f64),
                    ("fft", 0.4 + 6.0e-6 * pixels),
                ] {
                    out.push(Sample {
                        model: model.to_string(),
                        class: class.to_string(),
                        planes: 3,
                        rows,
                        cols,
                        kernel_width: width,
                        tile: None,
                        fused: false,
                        agglomeration: 1,
                        units: workers,
                        workers,
                        ms,
                        reps: 3,
                        warmup: 1,
                    });
                }
            }
        }
        out
    }

    #[test]
    fn predicted_crossover_routes_large_kernel_to_fft() {
        use crate::costmodel::CostModel;
        let mut c =
            Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let mut table = TuningTable::new();
        table.set_cost_model(CostModel::fit(class_samples("OpenMP", 4), 0.8));
        c.set_tuning(table);
        // a 61-wide kernel on a shape no sweep ever measured: admission
        // must pick the FFT class purely from the fitted prediction
        let img = synth_image(1, 96, 96, Pattern::Noise, 61);
        let spec = KernelSpec::new(61, 8.0);
        let direct = ConvPlan::builder()
            .kernel(spec)
            .kernel_class(KernelClass::Direct2d)
            .shape(1, 96, 96)
            .build()
            .unwrap();
        let mut arena = ScratchArena::new();
        let want = direct.execute(&img, &mut arena).unwrap();
        let resp = c.serve(ConvRequest::new(1, img.clone()).with_kernel(spec)).unwrap();
        assert_eq!(resp.kernel_class, KernelClass::Fft, "large kernel routes to the transform");
        assert!(
            resp.image.max_abs_diff(&want) <= 1e-4,
            "fft pixels match direct arithmetic: {}",
            resp.image.max_abs_diff(&want)
        );
        // a small kernel under the same model stays on the separable ladder
        let resp5 = c.serve(ConvRequest::new(2, img).with_kernel(KernelSpec::new(5, 1.0))).unwrap();
        assert_eq!(resp5.kernel_class, KernelClass::Separable);
        let st = c.stats();
        assert_eq!(st.plans_predicted, 2, "both classes came from the fitted crossover");
        assert_eq!((st.plans_swept, st.plans_default), (0, 0));
        assert_eq!((st.served, st.errors), (2, 0));
    }

    #[test]
    fn kernel2d_request_serves_nonseparable_taps() {
        let c =
            Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(2, 28, 26, Pattern::Noise, 77);
        let lap = crate::plan::Kernel2d::new(
            vec![0.0, 1.0, 0.0, 1.0, -4.0, 1.0, 0.0, 1.0, 0.0],
            3,
            3,
        )
        .unwrap();
        let plan =
            ConvPlan::builder().kernel2d(lap.clone()).shape(2, 28, 26).build().unwrap();
        let mut arena = ScratchArena::new();
        let want = plan.execute(&img, &mut arena).unwrap();
        let resp = c.serve(ConvRequest::new(1, img.clone()).with_kernel2d(lap.clone())).unwrap();
        assert_eq!(resp.kernel_class, KernelClass::Direct2d, "explicit taps imply direct2d");
        assert!(resp.image.max_abs_diff(&want) <= 1e-6);
        // pinning fft on the same taps serves the same pixels
        let resp_fft = c
            .serve(
                ConvRequest::new(2, img)
                    .with_kernel2d(lap)
                    .with_kernel_class(KernelClass::Fft),
            )
            .unwrap();
        assert_eq!(resp_fft.kernel_class, KernelClass::Fft);
        assert!(resp_fft.image.max_abs_diff(&want) <= 1e-4);
        let st = c.stats();
        assert_eq!((st.served, st.errors), (2, 0));
        assert_eq!(st.plans_built, 2, "direct and fft are distinct plan keys");
    }

    #[test]
    fn swept_entry_takes_precedence_over_prediction() {
        use crate::autotune::{Candidate, TuneKey, Tuned};
        use crate::costmodel::CostModel;
        let mut c =
            Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let mut table = TuningTable::new();
        table.set_cost_model(CostModel::fit(synthetic_samples("OpenMP", 4), 0.8));
        table.record(
            TuneKey { model: "OpenMP".into(), planes: 3, rows: 40, cols: 40, kernel_width: 5 },
            Tuned { candidate: Candidate::untiled(), ms: 1.0, baseline_ms: 1.0 },
        );
        c.set_tuning(table);
        let img = synth_image(3, 40, 40, Pattern::Noise, 41);
        assert!(c.serve(ConvRequest::new(1, img)).is_ok());
        let st = c.stats();
        assert_eq!(st.plans_swept, 1, "the exact swept winner was used");
        assert_eq!((st.plans_predicted, st.plans_default), (0, 0));
    }

    #[test]
    fn unusable_tuning_falls_back_to_defaults_and_counts() {
        // a tuning tier with no cost model (or a low-R² one) declines:
        // config defaults apply and plans_default records the fallback
        let mut c =
            Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        c.set_tuning(TuningTable::new());
        let img = synth_image(3, 24, 24, Pattern::Noise, 42);
        let k = crate::image::gaussian_kernel(5, 1.0);
        let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        let resp = c.serve(ConvRequest::new(1, img)).unwrap();
        assert_eq!(resp.image, want, "default untiled path unchanged");
        let st = c.stats();
        assert_eq!(st.plans_default, 1);
        assert_eq!((st.plans_predicted, st.plans_swept), (0, 0));
    }

    #[test]
    fn explicit_tile_or_fuse_bypasses_tuning_counters() {
        use crate::costmodel::CostModel;
        let mut c =
            Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let mut table = TuningTable::new();
        table.set_cost_model(CostModel::fit(synthetic_samples("OpenMP", 4), 0.8));
        c.set_tuning(table);
        let img = synth_image(3, 100, 100, Pattern::Noise, 43);
        // a request that pins its own tile (or fusion) is never second-
        // guessed by the tuning tier
        assert!(c
            .serve(ConvRequest::new(1, img.clone()).with_tile(TileSpec::new(8, 8)))
            .is_ok());
        assert!(c.serve(ConvRequest::new(2, img).with_fuse(false)).is_ok());
        let st = c.stats();
        assert_eq!(
            (st.plans_predicted, st.plans_swept, st.plans_default),
            (0, 0, 0),
            "explicit requests never touch the decision counters"
        );
    }

    #[test]
    fn stats_merge_folds_shards() {
        let mut a = CoordinatorStats { served: 3, errors: 1, ..Default::default() };
        a.queue_ms.push(1.0);
        a.service_ms.entry("openmp").or_default().push(2.0);
        let mut b = CoordinatorStats { served: 2, pjrt_fallbacks: 4, ..Default::default() };
        b.queue_ms.push(3.0);
        b.service_ms.entry("openmp").or_default().push(4.0);
        b.service_ms.entry("gprm").or_default().push(5.0);
        b.plans_built = 2;
        b.batch_sizes.push(3.0);
        b.plans_predicted = 3;
        b.plans_swept = 2;
        b.plans_default = 1;
        a.plans_predicted = 1;
        a.merge(&b);
        assert_eq!((a.served, a.errors, a.pjrt_fallbacks), (5, 1, 4));
        assert_eq!(a.queue_ms.len(), 2);
        assert_eq!(a.service_ms["openmp"].len(), 2);
        assert_eq!(a.service_ms["gprm"].len(), 1);
        assert_eq!(a.plans_built, 2);
        assert_eq!(a.batch_sizes.len(), 1);
        assert_eq!((a.plans_predicted, a.plans_swept, a.plans_default), (4, 2, 1));
    }

    #[test]
    fn stats_merge_treats_depth_as_gauge_not_counter() {
        // regression: merge used to sum `depth`, so folding two
        // snapshots double-counted queue depth. Gauges and high-water
        // marks combine by max; monotone counters still add.
        let a0 = CoordinatorStats {
            depth: 3,
            depth_peak: 5,
            shed: 1,
            expired: 2,
            ..Default::default()
        };
        let b = CoordinatorStats {
            depth: 2,
            depth_peak: 9,
            shed: 4,
            expired: 1,
            ..Default::default()
        };
        let mut a = a0.clone();
        a.merge(&b);
        assert_eq!(a.depth, 3, "gauge takes the max, never the sum");
        assert_eq!(a.depth_peak, 9);
        assert_eq!((a.shed, a.expired), (5, 3), "counters still accumulate");
        // merging the other way agrees on the gauge
        let mut c = b.clone();
        c.merge(&a0);
        assert_eq!(c.depth, 3);
    }

    #[test]
    fn stats_to_json_round_trips() {
        let mut st = CoordinatorStats {
            served: 7,
            shed: 2,
            expired: 1,
            depth_peak: 5,
            plans_default: 4,
            graphs_served: 3,
            ..Default::default()
        };
        st.batch_sizes.push(2.0);
        st.service_ms.entry("openmp").or_default().push(1.5);
        let parsed = Json::parse(&st.to_json().to_string()).expect("stats dump is valid JSON");
        assert_eq!(parsed.req_usize("served").unwrap(), 7);
        assert_eq!(parsed.req_usize("shed").unwrap(), 2);
        assert_eq!(parsed.req_usize("depth_peak").unwrap(), 5);
        assert_eq!(parsed.req_usize("plans_default").unwrap(), 4);
        assert_eq!(parsed.req_usize("graphs_served").unwrap(), 3);
        assert_eq!(parsed.get("batch_sizes").req_usize("n").unwrap(), 1);
        assert_eq!(parsed.get("service_ms").get("openmp").req_usize("n").unwrap(), 1);
        // empty sample sets stay nullable, not NaN
        let empty = Json::parse(&CoordinatorStats::default().to_json().to_string()).unwrap();
        assert_eq!(empty.get("queue_ms").get("p50"), &Json::Null);
    }

    #[test]
    fn executor_side_tallies_survive_into_stats() {
        // regression: stats() used to overwrite shed/expired/depth_peak
        // with the queue counters, discarding anything an executor
        // tallied on its shard (batch members rejected at execution
        // start land exactly there)
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 2, false)
            .unwrap();
        c.bump_shard(0, |st| {
            st.expired += 2;
            st.shed += 1;
            st.depth_peak = st.depth_peak.max(7);
        });
        // a queue-side expiry on top: both sources must accumulate
        let img = synth_image(3, 24, 24, Pattern::Noise, 40);
        let e = c.submit(ConvRequest::new(1, img).with_deadline(Duration::ZERO)).unwrap_err();
        assert_eq!(e.kind(), ErrorKind::DeadlineExceeded);
        let st = c.stats();
        assert_eq!(st.expired, 3, "executor-side 2 + queue-side 1");
        assert_eq!(st.shed, 1);
        assert!(st.depth_peak >= 7, "shard peak survives: {}", st.depth_peak);
    }

    #[test]
    fn plan_cache_evicts_single_lru_entry() {
        // regression: at PLAN_CACHE_MAX the cache used to clear()
        // wholesale, so churn evicted the hot plan and every burst
        // triggered a rebuild stampede
        let mut cache = PlanCache::new();
        let build = |rows: usize| {
            ConvPlan::builder()
                .kernel(KernelSpec::new(5, 1.0))
                .shape(1, rows, 16)
                .build()
                .unwrap()
        };
        let key = |rows: usize| PlanKey {
            algorithm: Algorithm::TwoPass,
            variant: Variant::Simd,
            layout: Layout::PerPlane,
            planes: 1,
            rows,
            cols: 16,
            kernel: KernelSpec::new(5, 1.0).cache_key(),
            class: KernelClass::Separable,
            k2d: None,
            tile: None,
            fused: false,
            graph: None,
        };
        let hot = key(1000);
        cache.get_or_build(&hot, || Ok(CachedExec::Single(build(1000)))).unwrap();
        // cold churn well past the cap, re-touching the hot key so its
        // recency keeps it off the LRU end
        let churn = PLAN_CACHE_MAX + 8;
        for r in 0..churn {
            cache.get_or_build(&key(8 + r), || Ok(CachedExec::Single(build(8 + r)))).unwrap();
            cache.get_or_build(&hot, || Ok(CachedExec::Single(build(1000)))).unwrap();
        }
        assert_eq!(cache.len(), PLAN_CACHE_MAX, "size pinned at the cap");
        assert_eq!(
            cache.built(),
            1 + churn as u64,
            "one build per distinct key — the hot plan was never rebuilt"
        );
    }

    #[test]
    fn hot_plan_survives_shape_churn_past_the_cache_cap() {
        // end-to-end flavour of the eviction fix: a hot shape keeps
        // serving through cold churn past PLAN_CACHE_MAX and its plan is
        // built exactly once (plans_built counts cache misses)
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false)
            .unwrap();
        let hot = synth_image(1, 200, 200, Pattern::Noise, 50);
        let k = crate::image::gaussian_kernel(5, 1.0);
        let want = convolve_image(hot.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        assert_eq!(c.serve(ConvRequest::new(0, hot.clone())).unwrap().image, want);
        let churn = PLAN_CACHE_MAX + 10;
        for i in 0..churn {
            let size = 8 + i;
            let img = synth_image(1, size, size, Pattern::Noise, size as u64);
            c.serve(ConvRequest::new(i as u64, img)).unwrap();
            if i % 8 == 0 {
                // keep the hot plan recent — and correct
                assert_eq!(c.serve(ConvRequest::new(900, hot.clone())).unwrap().image, want);
            }
        }
        let st = c.stats();
        assert_eq!(st.errors, 0);
        assert_eq!(
            st.plans_built,
            1 + churn as u64,
            "hot plan built once; every churn shape built once"
        );
    }

    #[test]
    fn unbatched_default_reports_batch_len_one() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false)
            .unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 60);
        let resp = c.serve(ConvRequest::new(1, img)).unwrap();
        assert_eq!(resp.batch_len, 1);
        let st = c.stats();
        assert_eq!(st.batch_sizes.len(), 1);
        assert_eq!(st.batch_sizes.max(), 1.0, "no coalescing until --batch-max is raised");
    }

    #[test]
    fn hot_shape_jobs_coalesce_into_one_batch() {
        // one executor pinned on a big blocker while six same-key small
        // requests pile up: with batch_max 8 they must coalesce (the
        // batch-size histogram shows > 1) and every member's pixels must
        // match the oracle
        let cfg = RunConfig { queue_capacity: 32, batch_max: 8, ..cfg() };
        let c = Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false)
            .unwrap();
        let blocker = c.submit(ConvRequest::new(0, synth_image(3, 512, 512, Pattern::Noise, 70)))
            .unwrap();
        let k = crate::image::gaussian_kernel(5, 1.0);
        let mut rxs = Vec::new();
        let mut wants = Vec::new();
        for i in 1..=6u64 {
            let img = synth_image(3, 48, 48, Pattern::Noise, 70 + i);
            wants.push(convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap());
            rxs.push(c.submit(ConvRequest::new(i, img)).unwrap());
        }
        assert!(blocker.recv().unwrap().is_ok());
        let mut max_batch = 0usize;
        for (rx, want) in rxs.into_iter().zip(&wants) {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.image, *want, "batched pixels match the oracle");
            max_batch = max_batch.max(resp.batch_len);
        }
        assert!(max_batch >= 2, "queued same-key jobs must coalesce, got {max_batch}");
        let st = c.stats();
        assert_eq!((st.served, st.errors), (7, 0));
        assert!(st.batch_sizes.max() >= 2.0);
        assert_eq!(st.plans_built, 2, "one blocker plan + one shared hot plan");
    }

    #[test]
    fn same_shape_lands_on_one_shard() {
        // PlanKey-hash sharding without stealing: repeated traffic at
        // one shape is served by a single executor, so exactly one plan
        // is ever built across 4 executors
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 4, false)
            .unwrap();
        let img = synth_image(3, 26, 26, Pattern::Noise, 80);
        for i in 0..8u64 {
            assert!(c.serve(ConvRequest::new(i, img.clone())).is_ok());
        }
        let st = c.stats();
        assert_eq!(st.served, 8);
        assert_eq!(st.plans_built, 1, "one shard, one warm plan cache");
    }

    #[test]
    fn pinned_coordinator_serves_normally() {
        // --pin-cores is a best-effort hint: serving must be identical
        // whether or not the pin takes on this host
        let cfg = RunConfig { pin_cores: true, ..cfg() };
        let c = Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::NativeOpenMp), 2, false)
            .unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 90);
        for i in 0..4u64 {
            assert!(c.serve(ConvRequest::new(i, img.clone())).is_ok());
        }
        assert_eq!(c.stats().served, 4);
    }

    #[test]
    fn total_capacity_splits_across_shards() {
        let cfg = RunConfig { queue_capacity: 7, ..cfg() };
        let c = Coordinator::new(&cfg, RoutePolicy::Fixed(Backend::NativeOpenMp), 3, false)
            .unwrap();
        // ceil(7/3) = 3 per shard, 9 total: never undercuts the config
        assert_eq!(c.queue_capacity(), 9);
        assert_eq!(c.queue_depth(), 0);
    }
}
