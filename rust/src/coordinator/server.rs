//! The coordinator itself: queue, executor threads, metrics.
//!
//! Executors run every native request through the plan layer: each
//! executor thread owns a [`ScratchArena`] (scratch planes recycle
//! across requests — zero scratch allocations after warm-up) and a cache
//! of built [`ConvPlan`]s keyed by `(algorithm, variant, layout, shape,
//! kernel)`, so repeated traffic at a shape pays plan validation once.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::util::error::{Context, Result};

use crate::config::RunConfig;
use crate::conv::{Algorithm, Variant};
use crate::image::PlanarImage;
use crate::metrics::SampleSet;
use crate::models::{GprmModel, Layout, OpenClModel, OpenMpModel};
use crate::plan::{ConvPlan, KernelSpec, ScratchArena};
use crate::runtime::{Manifest, PjrtHandle};

use super::request::{ConvRequest, ConvResponse};
use super::router::{Backend, RoutePolicy};

struct Job {
    req: ConvRequest,
    enqueued: Instant,
    reply: Sender<Result<ConvResponse>>,
}

/// Per-backend serving statistics.
#[derive(Debug, Default, Clone)]
pub struct CoordinatorStats {
    pub served: u64,
    pub errors: u64,
    pub pjrt_fallbacks: u64,
    pub service_ms: HashMap<&'static str, SampleSet>,
    pub queue_ms: SampleSet,
}

struct Inner {
    policy: RoutePolicy,
    openmp: OpenMpModel,
    opencl: OpenClModel,
    gprm: GprmModel,
    /// configured default kernel spec (requests may override)
    kernel: KernelSpec,
    /// taps the PJRT path executes with: the manifest's reference
    /// kernel when PJRT is loaded, the configured default otherwise
    kernel_taps: Vec<f32>,
    /// manifest (shape lookups, caller side) + execution handle (actor)
    pjrt: Option<(Manifest, PjrtHandle)>,
    stats: Mutex<CoordinatorStats>,
    seq: AtomicU64,
}

/// Per-executor cache bounds. Shapes and kernels are request-controlled,
/// so without a cap an adversarial mix of distinct (shape, kernel)
/// combinations would grow the plan cache and scratch pool without
/// bound; past the cap the whole cache is dropped (requests simply
/// rebuild plans / re-lease scratch — correctness is unaffected).
const PLAN_CACHE_MAX: usize = 64;
const ARENA_POOL_MAX: usize = 16;

/// Plan-cache key: everything a [`ConvPlan`] is built from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct PlanKey {
    algorithm: Algorithm,
    variant: Variant,
    layout: Layout,
    planes: usize,
    rows: usize,
    cols: usize,
    kernel: (usize, u64),
}

/// The serving loop (see module docs).
pub struct Coordinator {
    inner: Arc<Inner>,
    tx: Option<Sender<Job>>,
    executors: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Build from a run config. `with_pjrt` loads the artifact pool (set
    /// false for native-only serving, e.g. when artifacts aren't built).
    pub fn new(cfg: &RunConfig, policy: RoutePolicy, executors: usize, with_pjrt: bool) -> Result<Self> {
        let pjrt = if with_pjrt {
            let manifest = Manifest::load(&cfg.artifacts_dir)?;
            let handle = PjrtHandle::spawn(&cfg.artifacts_dir).context("starting PJRT actor")?;
            Some((manifest, handle))
        } else {
            None
        };
        let kernel = KernelSpec::new(cfg.kernel_width, cfg.sigma);
        kernel.validate().context("invalid configured kernel")?;
        // the PJRT path always executes with the artifacts' reference
        // taps (`pjrt_can_serve` guarantees the request's effective
        // kernel matches them, even when the configured default differs)
        let kernel_taps = match &pjrt {
            Some((manifest, _)) => KernelSpec::new(manifest.kernel_width, manifest.gaussian_sigma)
                .taps()
                .context("manifest kernel spec")?,
            None => kernel.taps()?,
        };
        let inner = Arc::new(Inner {
            policy,
            openmp: OpenMpModel::new(cfg.threads),
            opencl: OpenClModel::new(cfg.threads, 16),
            gprm: GprmModel::new(cfg.threads, cfg.cutoff),
            kernel,
            kernel_taps,
            pjrt,
            stats: Mutex::new(CoordinatorStats::default()),
            seq: AtomicU64::new(0),
        });
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let executors = (0..executors.max(1))
            .map(|i| {
                let inner = inner.clone();
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("phi-conv-executor-{i}"))
                    .spawn(move || executor_loop(inner, rx))
                    .expect("spawn executor")
            })
            .collect();
        Ok(Self { inner, tx: Some(tx), executors })
    }

    /// Enqueue a request; the receiver yields the response when served.
    pub fn submit(&self, req: ConvRequest) -> Receiver<Result<ConvResponse>> {
        let (reply, rx) = channel();
        let job = Job { req, enqueued: Instant::now(), reply };
        self.tx.as_ref().expect("coordinator live").send(job).expect("executors alive");
        rx
    }

    /// Submit and wait.
    pub fn serve(&self, req: ConvRequest) -> Result<ConvResponse> {
        self.submit(req).recv().context("coordinator dropped reply")?
    }

    pub fn stats(&self) -> CoordinatorStats {
        self.inner.stats.lock().unwrap().clone()
    }

    /// True when the PJRT backend is loaded.
    pub fn has_pjrt(&self) -> bool {
        self.inner.pjrt.is_some()
    }

    /// Pre-compile the full-image artifacts for the given sizes so the
    /// first PJRT-routed request doesn't pay compile latency. Returns
    /// (artifact, compile ms) pairs.
    pub fn warm_pjrt(&self, planes: usize, sizes: &[usize]) -> Result<Vec<(String, f64)>> {
        let (manifest, handle) = match &self.inner.pjrt {
            Some(p) => p,
            None => return Ok(vec![]),
        };
        let mut names = Vec::new();
        for &n in sizes {
            for name in [
                format!("twopass_p{planes}_{n}"),
                format!("singlepass_p{planes}_{n}"),
                format!("twopass_agg_{n}"),
            ] {
                if manifest.get(&name).is_ok() {
                    names.push(name);
                }
            }
        }
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let times = handle.warm(&refs)?;
        Ok(names.into_iter().zip(times).collect())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.take(); // close the queue; executors drain and exit
        for h in self.executors.drain(..) {
            let _ = h.join();
        }
    }
}

fn executor_loop(inner: Arc<Inner>, rx: Arc<Mutex<Receiver<Job>>>) {
    // per-executor state: scratch planes recycle across requests (zero
    // scratch allocations after warm-up) and plans are built once per
    // distinct request configuration
    let mut arena = ScratchArena::new();
    let mut plans: HashMap<PlanKey, ConvPlan> = HashMap::new();
    loop {
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => return, // queue closed
        };
        let queue_ms = job.enqueued.elapsed().as_secs_f64() * 1e3;
        let result = serve_one(&inner, &mut arena, &mut plans, job.req, queue_ms);
        let mut st = inner.stats.lock().unwrap();
        match &result {
            Ok(resp) => {
                st.served += 1;
                st.queue_ms.push(resp.queue_ms);
                st.service_ms
                    .entry(resp.backend.label())
                    .or_default()
                    .push(resp.service_ms);
            }
            Err(_) => st.errors += 1,
        }
        drop(st);
        let _ = job.reply.send(result); // receiver may have gone away
    }
}

fn serve_one(
    inner: &Inner,
    arena: &mut ScratchArena,
    plans: &mut HashMap<PlanKey, ConvPlan>,
    req: ConvRequest,
    queue_ms: f64,
) -> Result<ConvResponse> {
    let seq = inner.seq.fetch_add(1, Ordering::Relaxed);
    // request intake validation: a bad kernel spec is a structured error
    // before any routing or execution happens
    let kernel = req.kernel.unwrap_or(inner.kernel);
    kernel.validate().context("invalid request kernel")?;

    let (mut backend, mut layout) = match (req.backend, req.layout) {
        (Some(b), Some(l)) => (b, l),
        (Some(b), None) => (b, inner.policy.route(req.image.rows, seq).1),
        (None, Some(l)) => (inner.policy.route(req.image.rows, seq).0, l),
        (None, None) => inner.policy.route(req.image.rows, seq),
    };

    // PJRT can only serve shapes it has artifacts for (and only the
    // configured default kernel the artifacts were lowered with); fall
    // back to the adaptive native choice otherwise.
    if backend == Backend::Pjrt && !pjrt_can_serve(inner, &req, layout) {
        inner.stats.lock().unwrap().pjrt_fallbacks += 1;
        let (b, l) = RoutePolicy::paper_default().route(req.image.rows, seq);
        backend = b;
        layout = l;
    }

    let t0 = Instant::now();
    let image = match backend {
        Backend::Pjrt => run_pjrt(inner, &req, layout)?,
        Backend::NativeOpenMp | Backend::NativeOpenCl | Backend::NativeGprm => {
            let model: &dyn crate::models::ExecutionModel = match backend {
                Backend::NativeOpenMp => &inner.openmp,
                Backend::NativeOpenCl => &inner.opencl,
                _ => &inner.gprm,
            };
            let key = PlanKey {
                algorithm: req.algorithm,
                variant: req.variant,
                layout,
                planes: req.image.planes,
                rows: req.image.rows,
                cols: req.image.cols,
                kernel: kernel.cache_key(),
            };
            if !plans.contains_key(&key) {
                if plans.len() >= PLAN_CACHE_MAX {
                    plans.clear();
                }
                let plan = ConvPlan::builder()
                    .algorithm(req.algorithm)
                    .variant(req.variant)
                    .layout(layout)
                    .kernel(kernel)
                    .shape(req.image.planes, req.image.rows, req.image.cols)
                    .build()
                    .context("invalid request plan")?;
                plans.insert(key, plan);
            }
            let plan = plans.get(&key).expect("plan just cached");
            let image = plan.execute_on(model, &req.image, arena)?;
            if arena.pooled() > ARENA_POOL_MAX {
                arena.clear();
            }
            image
        }
    };
    let service_ms = t0.elapsed().as_secs_f64() * 1e3;
    Ok(ConvResponse { id: req.id, image, backend, layout, queue_ms, service_ms })
}

fn pjrt_artifact_name(req: &ConvRequest, layout: Layout) -> Option<String> {
    if req.image.rows != req.image.cols {
        return None; // full-image artifacts are square
    }
    let n = req.image.rows;
    Some(match (layout, req.algorithm) {
        (Layout::Agglomerated, Algorithm::TwoPass) => format!("twopass_agg_{n}"),
        (Layout::Agglomerated, _) => return None,
        (_, Algorithm::TwoPass) => format!("twopass_p{}_{n}", req.image.planes),
        // copy-back and no-copy have identical pixels; one artifact serves both
        (_, Algorithm::SinglePassCopyBack | Algorithm::SinglePassNoCopy) => {
            format!("singlepass_p{}_{n}", req.image.planes)
        }
    })
}

fn pjrt_can_serve(inner: &Inner, req: &ConvRequest, layout: Layout) -> bool {
    let (manifest, _) = match &inner.pjrt {
        Some(p) => p,
        None => return false,
    };
    // the AOT artifacts bake in the manifest's reference kernel; the
    // request's effective kernel (its own spec, or the coordinator's
    // configured default) must match it exactly or take the native path
    let spec = req.kernel.unwrap_or(inner.kernel);
    if spec.width != manifest.kernel_width || spec.sigma != manifest.gaussian_sigma {
        return false;
    }
    match pjrt_artifact_name(req, layout) {
        Some(name) => manifest.get(&name).is_ok(),
        None => false,
    }
}

fn run_pjrt(inner: &Inner, req: &ConvRequest, layout: Layout) -> Result<PlanarImage> {
    let (_, handle) = inner.pjrt.as_ref().context("PJRT backend not loaded")?;
    let name = pjrt_artifact_name(req, layout).context("no artifact for this request shape")?;
    let out = handle.run1(&name, vec![req.image.data.clone(), inner.kernel_taps.clone()])?;
    PlanarImage::from_vec(req.image.planes, req.image.rows, req.image.cols, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{convolve_image, Variant};
    use crate::image::{synth_image, Pattern};

    fn cfg() -> RunConfig {
        RunConfig { threads: 4, ..Default::default() }
    }

    #[test]
    fn serves_native_request_correctly() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 2, false).unwrap();
        let img = synth_image(3, 32, 28, Pattern::Noise, 1);
        let k = crate::image::gaussian_kernel(5, 1.0);
        let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        let resp = c.serve(ConvRequest::new(1, img)).unwrap();
        assert_eq!(resp.image, want);
        assert_eq!(resp.backend, Backend::NativeOpenMp);
        assert!(resp.service_ms >= 0.0);
    }

    #[test]
    fn round_robin_spreads_backends() {
        let c = Coordinator::new(&cfg(), RoutePolicy::RoundRobin, 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 2);
        let mut seen = std::collections::HashSet::new();
        for i in 0..6 {
            let resp = c.serve(ConvRequest::new(i, img.clone())).unwrap();
            seen.insert(resp.backend);
        }
        assert_eq!(seen.len(), 3, "all three native backends used");
        let st = c.stats();
        assert_eq!(st.served, 6);
        assert_eq!(st.errors, 0);
    }

    #[test]
    fn adaptive_policy_routes_by_size() {
        let c = Coordinator::new(
            &cfg(),
            RoutePolicy::PaperAdaptive { large_threshold: 30 },
            1,
            false,
        )
        .unwrap();
        let small = synth_image(3, 24, 24, Pattern::Noise, 3);
        let large = synth_image(3, 40, 40, Pattern::Noise, 4);
        let r1 = c.serve(ConvRequest::new(1, small)).unwrap();
        assert_eq!((r1.backend, r1.layout), (Backend::NativeOpenMp, Layout::PerPlane));
        let r2 = c.serve(ConvRequest::new(2, large)).unwrap();
        assert_eq!((r2.backend, r2.layout), (Backend::NativeGprm, Layout::Agglomerated));
    }

    #[test]
    fn explicit_backend_respected() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 5);
        let resp = c
            .serve(ConvRequest::new(1, img).with_backend(Backend::NativeGprm))
            .unwrap();
        assert_eq!(resp.backend, Backend::NativeGprm);
    }

    #[test]
    fn concurrent_submissions_all_served() {
        let c = Coordinator::new(&cfg(), RoutePolicy::RoundRobin, 3, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 6);
        let receivers: Vec<_> = (0..20)
            .map(|i| c.submit(ConvRequest::new(i, img.clone())))
            .collect();
        for rx in receivers {
            assert!(rx.recv().unwrap().is_ok());
        }
        assert_eq!(c.stats().served, 20);
    }

    #[test]
    fn per_request_kernel_served_natively() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 28, 28, Pattern::Noise, 8);
        for spec in [KernelSpec::new(3, 1.0), KernelSpec::new(7, 2.0)] {
            let k = crate::image::gaussian_kernel(spec.width, spec.sigma);
            let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
            let resp = c.serve(ConvRequest::new(1, img.clone()).with_kernel(spec)).unwrap();
            assert_eq!(resp.image, want, "{spec:?}");
        }
    }

    #[test]
    fn invalid_request_kernel_is_structured_error() {
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 9);
        let err = c
            .serve(ConvRequest::new(1, img.clone()).with_kernel(KernelSpec::new(4, 1.0)))
            .unwrap_err();
        assert!(format!("{err:#}").contains("odd"), "got: {err:#}");
        // the coordinator keeps serving and counts the error
        assert!(c.serve(ConvRequest::new(2, img)).is_ok());
        let st = c.stats();
        assert_eq!((st.errors, st.served), (1, 1));
    }

    #[test]
    fn shape_churn_beyond_cache_caps_still_serves() {
        // more distinct shapes than PLAN_CACHE_MAX / ARENA_POOL_MAX:
        // the eviction path must kick in without affecting results
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::NativeOpenMp), 1, false).unwrap();
        let k = crate::image::gaussian_kernel(5, 1.0);
        for size in 8..(8 + PLAN_CACHE_MAX + 6) {
            let img = synth_image(1, size, size, Pattern::Noise, size as u64);
            let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
            let resp = c.serve(ConvRequest::new(size as u64, img)).unwrap();
            assert_eq!(resp.image, want, "size {size}");
        }
        assert_eq!(c.stats().errors, 0);
    }

    #[test]
    fn invalid_configured_kernel_rejected_at_construction() {
        let bad = RunConfig { kernel_width: 4, ..cfg() };
        assert!(Coordinator::new(&bad, RoutePolicy::RoundRobin, 1, false).is_err());
    }

    #[test]
    fn custom_kernel_never_routes_to_pjrt() {
        // explicit Pjrt backend + non-default kernel: must fall back to a
        // native backend (artifacts carry only the default taps)
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::Pjrt), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 10);
        let resp = c
            .serve(ConvRequest::new(1, img).with_kernel(KernelSpec::new(7, 1.0)))
            .unwrap();
        assert_ne!(resp.backend, Backend::Pjrt);
    }

    #[test]
    fn pjrt_fallback_when_no_artifact_shape() {
        // 24x24 has no artifact; explicit Pjrt backend must fall back, not fail
        let c = Coordinator::new(&cfg(), RoutePolicy::Fixed(Backend::Pjrt), 1, false).unwrap();
        let img = synth_image(3, 24, 24, Pattern::Noise, 7);
        let resp = c.serve(ConvRequest::new(1, img)).unwrap();
        assert_ne!(resp.backend, Backend::Pjrt);
        assert_eq!(c.stats().pjrt_fallbacks, 1);
    }
}
