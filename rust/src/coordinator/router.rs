//! Routing policy: which backend + layout serves a request.

use crate::models::Layout;

/// An executable backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Backend {
    /// native engines under OpenMP-style static fork-join
    NativeOpenMp,
    /// native engines under OpenCL-style NDRange work-groups
    NativeOpenCl,
    /// native engines under GPRM-style task scheduling
    NativeGprm,
    /// the AOT Pallas artifact through PJRT (full-image graphs)
    Pjrt,
}

impl Backend {
    pub fn label(&self) -> &'static str {
        match self {
            Backend::NativeOpenMp => "openmp",
            Backend::NativeOpenCl => "opencl",
            Backend::NativeGprm => "gprm",
            Backend::Pjrt => "pjrt",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "openmp" => Backend::NativeOpenMp,
            "opencl" => Backend::NativeOpenCl,
            "gprm" => Backend::NativeGprm,
            "pjrt" => Backend::Pjrt,
            _ => return None,
        })
    }
}

/// How unrouted requests are assigned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// everything to one backend
    Fixed(Backend),
    /// cycle through the three native models (load comparison runs)
    RoundRobin,
    /// the paper's conclusion as policy: OpenMP R×C below the size
    /// threshold, GPRM 3R×C at/above it (section 9: "OpenMP is the
    /// winning model, except for very large images where GPRM shows
    /// better performance after using task agglomeration").
    PaperAdaptive {
        /// row count at/above which GPRM+agglomeration wins
        large_threshold: usize,
    },
}

impl RoutePolicy {
    /// Default adaptive threshold: the paper's crossover is at its
    /// largest image (8748); scaled to host measurement sizes we use the
    /// top artifact size.
    pub fn paper_default() -> Self {
        RoutePolicy::PaperAdaptive { large_threshold: 1152 }
    }

    /// Decide (backend, layout) for a request of `rows` rows, given how
    /// many requests were routed before it (for round-robin).
    ///
    /// `seq` contract: the caller must advance it only for requests
    /// whose *backend* this policy actually chooses. Explicitly pinned
    /// traffic (including PJRT-pinned requests that later fall back to
    /// a native backend) must not consume a slot, or the round-robin
    /// rotation silently skips backends whenever such traffic
    /// interleaves. Layout-only lookups may pass any value (layout
    /// never depends on `seq`).
    pub fn route(&self, rows: usize, seq: u64) -> (Backend, Layout) {
        match *self {
            RoutePolicy::Fixed(b) => (b, Layout::PerPlane),
            RoutePolicy::RoundRobin => {
                let b = match seq % 3 {
                    0 => Backend::NativeOpenMp,
                    1 => Backend::NativeOpenCl,
                    _ => Backend::NativeGprm,
                };
                (b, Layout::PerPlane)
            }
            RoutePolicy::PaperAdaptive { large_threshold } => {
                if rows >= large_threshold {
                    (Backend::NativeGprm, Layout::Agglomerated)
                } else {
                    (Backend::NativeOpenMp, Layout::PerPlane)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_routes_everything() {
        let p = RoutePolicy::Fixed(Backend::Pjrt);
        assert_eq!(p.route(64, 0), (Backend::Pjrt, Layout::PerPlane));
        assert_eq!(p.route(8748, 9), (Backend::Pjrt, Layout::PerPlane));
    }

    #[test]
    fn round_robin_cycles() {
        let p = RoutePolicy::RoundRobin;
        assert_eq!(p.route(64, 0).0, Backend::NativeOpenMp);
        assert_eq!(p.route(64, 1).0, Backend::NativeOpenCl);
        assert_eq!(p.route(64, 2).0, Backend::NativeGprm);
        assert_eq!(p.route(64, 3).0, Backend::NativeOpenMp);
    }

    #[test]
    fn paper_adaptive_crossover() {
        let p = RoutePolicy::PaperAdaptive { large_threshold: 1000 };
        assert_eq!(p.route(999, 0), (Backend::NativeOpenMp, Layout::PerPlane));
        assert_eq!(p.route(1000, 0), (Backend::NativeGprm, Layout::Agglomerated));
    }

    #[test]
    fn backend_labels_roundtrip() {
        for b in [Backend::NativeOpenMp, Backend::NativeOpenCl, Backend::NativeGprm, Backend::Pjrt] {
            assert_eq!(Backend::parse(b.label()), Some(b));
        }
        assert_eq!(Backend::parse("bogus"), None);
    }
}
