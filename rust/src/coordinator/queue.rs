//! Bounded admission queue: the coordinator's intake path.
//!
//! A hand-rolled fixed-capacity ring buffer behind one `Mutex` + two
//! `Condvar`s (zero external deps — DESIGN.md §1). This replaces the
//! unbounded `mpsc` channel the coordinator originally used, which had
//! three failure modes under load:
//!
//! * **unbounded growth** — a burst simply accumulated jobs until OOM;
//!   here admission is refused at `capacity` ([`ErrorKind::QueueFull`]),
//! * **panicking intake** — `send().expect(..)` panicked the *calling*
//!   thread once an executor died; here every refusal is a structured
//!   [`Rejected`] value the caller turns into an error reply,
//! * **no latency bound** — jobs could wait forever; here a per-item
//!   deadline is checked at admission, while blocked waiting for space,
//!   and again at dequeue ([`ErrorKind::DeadlineExceeded`]).
//!
//! The lock is held only for O(1) slot bookkeeping — never across the
//! convolution itself — so executors no longer serialize on a
//! `Mutex<Receiver>` around a blocking `recv()`.
//!
//! Shutdown is cooperative: [`AdmissionQueue::close`] refuses new pushes
//! ([`ErrorKind::Shutdown`]) while consumers keep draining; queued items
//! whose deadline already lapsed come back as [`Pop::Expired`] so the
//! owner can reject them, and live ones as [`Pop::Job`] so in-flight
//! work completes. [`Pop::Closed`] is the consumers' exit signal.

use std::fmt;
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::util::error::{Error, ErrorKind};

/// Why an admission attempt was refused. Carries the item back to the
/// caller (so a reply channel inside it can be failed, not leaked).
pub struct Rejected<T> {
    pub item: T,
    pub kind: ErrorKind,
}

impl<T> fmt::Debug for Rejected<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Rejected({:?})", self.kind)
    }
}

impl<T> Rejected<T> {
    fn new(item: T, kind: ErrorKind) -> Self {
        Self { item, kind }
    }

    /// The refusal as a structured [`Error`] (kind-preserving).
    pub fn to_error(&self, capacity: usize) -> Error {
        match self.kind {
            ErrorKind::QueueFull => Error::with_kind(
                ErrorKind::QueueFull,
                format!("admission queue full (capacity {capacity}); request shed"),
            ),
            ErrorKind::DeadlineExceeded => Error::with_kind(
                ErrorKind::DeadlineExceeded,
                "request deadline exceeded before admission",
            ),
            _ => Error::with_kind(ErrorKind::Shutdown, "coordinator is shut down"),
        }
    }
}

/// One dequeue outcome.
pub enum Pop<T> {
    /// A live item, still within its deadline — execute it.
    Job(T),
    /// An item whose deadline lapsed while queued — reject it.
    Expired(T),
    /// The queue is closed and fully drained — the consumer exits.
    Closed,
}

/// One keyed multi-pop outcome: the head item plus every queued item
/// whose key matched it (see [`AdmissionQueue::pop_batch`]).
pub struct Batch<T> {
    /// live same-key items in FIFO order — execute them together
    pub jobs: Vec<T>,
    /// same-key items whose deadline lapsed while queued (plus the head
    /// itself when *it* lapsed — then `jobs` is empty) — reject them
    pub expired: Vec<T>,
}

/// One [`AdmissionQueue::pop_batch`] outcome.
pub enum PopBatch<T> {
    /// At least one item (`jobs` + `expired` together are non-empty).
    Batch(Batch<T>),
    /// The bounded idle wait elapsed with nothing queued.
    Empty,
    /// The queue is closed and fully drained — the consumer exits.
    Closed,
}

/// Monotonic intake counters, exported into `CoordinatorStats`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueCounters {
    /// items currently waiting (gauge, sampled at read time)
    pub depth: usize,
    /// high-water mark of `depth` since construction
    pub depth_peak: usize,
    /// admissions refused because the queue was at capacity
    pub shed: u64,
    /// deadlines lapsed (at admission, while waiting, or at dequeue)
    pub expired: u64,
}

struct Slot<T> {
    item: T,
    deadline: Option<Instant>,
}

struct State<T> {
    /// fixed-size ring: `ring[(head + i) % capacity]` is the i-th queued
    /// slot; cells outside `[head, head+len)` are `None`
    ring: Vec<Option<Slot<T>>>,
    head: usize,
    len: usize,
    closed: bool,
    depth_peak: usize,
    shed: u64,
    expired: u64,
}

/// The bounded, deadline-aware MPMC admission queue (see module docs).
pub struct AdmissionQueue<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

/// Poison-proof lock: a consumer that panicked mid-pop must not turn
/// every later `submit` into a second panic — the state it guards is
/// plain bookkeeping that stays consistent (mutations are single-step).
fn relock<'a, T>(
    r: Result<MutexGuard<'a, T>, PoisonError<MutexGuard<'a, T>>>,
) -> MutexGuard<'a, T> {
    r.unwrap_or_else(PoisonError::into_inner)
}

impl<T> AdmissionQueue<T> {
    /// A queue holding at most `capacity` items (clamped to ≥ 1).
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self {
            state: Mutex::new(State {
                ring: std::iter::repeat_with(|| None).take(capacity).collect(),
                head: 0,
                len: 0,
                closed: false,
                depth_peak: 0,
                shed: 0,
                expired: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Items currently queued.
    pub fn depth(&self) -> usize {
        relock(self.state.lock()).len
    }

    pub fn is_closed(&self) -> bool {
        relock(self.state.lock()).closed
    }

    /// Snapshot of the intake counters.
    pub fn counters(&self) -> QueueCounters {
        let st = relock(self.state.lock());
        QueueCounters {
            depth: st.len,
            depth_peak: st.depth_peak,
            shed: st.shed,
            expired: st.expired,
        }
    }

    /// Non-blocking admission: refused immediately with `QueueFull` when
    /// at capacity (load shedding), `DeadlineExceeded` when the deadline
    /// already lapsed, `Shutdown` after [`close`](Self::close).
    pub fn try_push(&self, item: T, deadline: Option<Instant>) -> Result<(), Rejected<T>> {
        let st = relock(self.state.lock());
        self.admit(st, item, deadline, AdmitWait::None)
    }

    /// Blocking admission: waits for a free slot until `wait` elapses
    /// (refused with `QueueFull` on timeout). The item's own deadline
    /// still bounds the wait, whichever comes first. A `wait` so large
    /// that `now + wait` overflows `Instant` degrades to an unbounded
    /// wait rather than panicking.
    pub fn push_timeout(
        &self,
        item: T,
        deadline: Option<Instant>,
        wait: Duration,
    ) -> Result<(), Rejected<T>> {
        // the give-up instant is computed BEFORE taking the lock: under
        // contention the acquisition itself takes time, and charging it
        // to the caller would stretch the effective bound to
        // lock-wait + `wait` (regression: it used to be computed after)
        let give_up = match Instant::now().checked_add(wait) {
            Some(g) => AdmitWait::Until(g),
            None => AdmitWait::Forever,
        };
        let st = relock(self.state.lock());
        self.admit(st, item, deadline, give_up)
    }

    /// Blocking admission with no caller timeout: backpressure. Waits
    /// until a slot frees, the item's deadline lapses, or the queue
    /// closes.
    pub fn push(&self, item: T, deadline: Option<Instant>) -> Result<(), Rejected<T>> {
        let st = relock(self.state.lock());
        self.admit(st, item, deadline, AdmitWait::Forever)
    }

    /// The single admission loop behind the three push variants.
    fn admit<'q>(
        &'q self,
        mut st: MutexGuard<'q, State<T>>,
        item: T,
        deadline: Option<Instant>,
        wait: AdmitWait,
    ) -> Result<(), Rejected<T>> {
        loop {
            if st.closed {
                return Err(Rejected::new(item, ErrorKind::Shutdown));
            }
            let now = Instant::now();
            if deadline.is_some_and(|d| d <= now) {
                st.expired += 1;
                // this producer may have consumed a not_full wakeup
                // while it slept; if capacity is free, hand the
                // notification on — otherwise another blocked producer
                // sleeps through an open slot (lost wakeup)
                let slot_free = st.len < self.capacity;
                drop(st);
                if slot_free {
                    self.not_full.notify_one();
                }
                return Err(Rejected::new(item, ErrorKind::DeadlineExceeded));
            }
            if st.len < self.capacity {
                let idx = (st.head + st.len) % self.capacity;
                st.ring[idx] = Some(Slot { item, deadline });
                st.len += 1;
                st.depth_peak = st.depth_peak.max(st.len);
                drop(st);
                self.not_empty.notify_one();
                return Ok(());
            }
            // full: shed, or sleep until whichever bound fires first
            let bound = match wait {
                AdmitWait::None => None,
                AdmitWait::Until(g) => Some(match deadline {
                    Some(d) => g.min(d),
                    None => g,
                }),
                AdmitWait::Forever => deadline,
            };
            match bound {
                None if matches!(wait, AdmitWait::Forever) => {
                    st = relock(self.not_full.wait(st));
                }
                None => {
                    st.shed += 1;
                    return Err(Rejected::new(item, ErrorKind::QueueFull));
                }
                Some(b) => {
                    if b <= now {
                        // timed out waiting for space; if it was the
                        // item's own deadline the next loop iteration
                        // classifies it as expired
                        if deadline.is_some_and(|d| d <= b) {
                            continue;
                        }
                        st.shed += 1;
                        return Err(Rejected::new(item, ErrorKind::QueueFull));
                    }
                    st = match self.not_full.wait_timeout(st, b - now) {
                        Ok((g, _)) => g,
                        Err(p) => p.into_inner().0,
                    };
                }
            }
        }
    }

    /// Blocking dequeue. Returns [`Pop::Closed`] only once the queue is
    /// both closed and drained — items queued before [`close`] are still
    /// handed out (live ones to complete, expired ones to reject).
    pub fn pop(&self) -> Pop<T> {
        let mut st = relock(self.state.lock());
        loop {
            if st.len > 0 {
                let head = st.head;
                let slot = st.ring[head].take().expect("occupied slot in [head, head+len)");
                st.head = (head + 1) % self.capacity;
                st.len -= 1;
                let expired = slot.deadline.is_some_and(|d| d <= Instant::now());
                if expired {
                    st.expired += 1;
                }
                drop(st);
                self.not_full.notify_one();
                return if expired { Pop::Expired(slot.item) } else { Pop::Job(slot.item) };
            }
            if st.closed {
                return Pop::Closed;
            }
            st = relock(self.not_empty.wait(st));
        }
    }

    /// Keyed multi-pop: the batching dequeue. Blocks until an item is
    /// queued (bounded by `idle_wait`: `None` waits indefinitely,
    /// `Some(ZERO)` is non-blocking, `Some(d)` polls at most `d` before
    /// returning [`PopBatch::Empty`]), takes the head item, then drains
    /// up to `max - 1` additional queued items whose `key_of` value
    /// equals the head's. Matches come out in FIFO order; non-matching
    /// items keep their ring positions and their FIFO order, so
    /// coalescing can never starve a minority key past its normal turn.
    ///
    /// A head whose deadline already lapsed anchors no batch: it is
    /// returned alone in [`Batch::expired`] so the next call re-evaluates
    /// a fresh head. Matching items that lapsed while queued also land in
    /// `expired` (counted here) and do not consume batch room.
    ///
    /// With room left after the first drain, `straggler_wait` — bounded
    /// additionally by the head's own deadline — lets late same-key
    /// arrivals join before execution; freed slots are handed to blocked
    /// producers *before* the wait, so the awaited stragglers can
    /// actually be admitted. An `idle_wait` so large that `now + wait`
    /// overflows `Instant` degrades to an unbounded wait, mirroring
    /// [`push_timeout`](Self::push_timeout).
    pub fn pop_batch<K, F>(
        &self,
        max: usize,
        straggler_wait: Option<Duration>,
        idle_wait: Option<Duration>,
        key_of: &F,
    ) -> PopBatch<T>
    where
        K: PartialEq,
        F: Fn(&T) -> K,
    {
        let max = max.max(1);
        // idle bound computed before locking (same discipline as
        // push_timeout: lock contention must not stretch it)
        let idle_until = idle_wait.and_then(|w| Instant::now().checked_add(w));
        let mut st = relock(self.state.lock());
        loop {
            if st.len > 0 {
                break;
            }
            if st.closed {
                return PopBatch::Closed;
            }
            match (idle_wait, idle_until) {
                (None, _) | (Some(_), None) => st = relock(self.not_empty.wait(st)),
                (Some(_), Some(until)) => {
                    let now = Instant::now();
                    if until <= now {
                        return PopBatch::Empty;
                    }
                    st = match self.not_empty.wait_timeout(st, until - now) {
                        Ok((g, _)) => g,
                        Err(p) => p.into_inner().0,
                    };
                }
            }
        }

        let head = st.head;
        let slot = st.ring[head].take().expect("occupied slot in [head, head+len)");
        st.head = (head + 1) % self.capacity;
        st.len -= 1;
        let mut freed = 1usize;
        if slot.deadline.is_some_and(|d| d <= Instant::now()) {
            st.expired += 1;
            drop(st);
            self.not_full.notify_one();
            return PopBatch::Batch(Batch { jobs: vec![], expired: vec![slot.item] });
        }
        let head_deadline = slot.deadline;
        let key = key_of(&slot.item);
        let mut jobs = vec![slot.item];
        let mut expired = Vec::new();
        if max > 1 {
            freed += self.drain_matching(&mut st, &key, key_of, max - 1, &mut jobs, &mut expired);
        }

        if let Some(wait) = straggler_wait {
            if jobs.len() < max && !wait.is_zero() && !st.closed {
                // hand the freed slots to blocked producers before
                // sleeping, or the awaited stragglers can't be admitted
                self.not_full.notify_all();
                freed = 0;
                // the window never outlasts the head's own deadline —
                // waiting for company must not expire the whole batch
                let give_up = Instant::now().checked_add(wait).map(|g| match head_deadline {
                    Some(d) => g.min(d),
                    None => g,
                });
                while let Some(g) = give_up {
                    let now = Instant::now();
                    if now >= g || jobs.len() >= max || st.closed {
                        break;
                    }
                    let timed_out;
                    (st, timed_out) = match self.not_empty.wait_timeout(st, g - now) {
                        Ok((g, t)) => (g, t.timed_out()),
                        Err(p) => {
                            let (g, t) = p.into_inner();
                            (g, t.timed_out())
                        }
                    };
                    freed += self.drain_matching(
                        &mut st,
                        &key,
                        key_of,
                        max - jobs.len(),
                        &mut jobs,
                        &mut expired,
                    );
                    if timed_out {
                        break;
                    }
                }
            }
        }
        drop(st);
        if freed > 1 {
            self.not_full.notify_all();
        } else if freed == 1 {
            self.not_full.notify_one();
        }
        PopBatch::Batch(Batch { jobs, expired })
    }

    /// Scan the ring FIFO-first, pulling out up to `room` live items
    /// whose key matches and every matching item that expired en route
    /// (classified into `expired`, counted, no batch room consumed);
    /// non-matching items compact toward the head preserving order.
    /// Returns how many slots were freed. The compaction writes only
    /// into cells already vacated by `take()`, so the ring invariant
    /// (cells outside `[head, head+len)` are `None`) is preserved.
    fn drain_matching<K, F>(
        &self,
        st: &mut State<T>,
        key: &K,
        key_of: &F,
        room: usize,
        jobs: &mut Vec<T>,
        expired: &mut Vec<T>,
    ) -> usize
    where
        K: PartialEq,
        F: Fn(&T) -> K,
    {
        if room == 0 || st.len == 0 {
            return 0;
        }
        let now = Instant::now();
        let (head, len) = (st.head, st.len);
        let mut write = 0usize;
        let mut taken_live = 0usize;
        for read in 0..len {
            let ri = (head + read) % self.capacity;
            let matches = {
                let slot = st.ring[ri].as_ref().expect("occupied slot in [head, head+len)");
                key_of(&slot.item) == *key
            };
            if matches {
                let slot = st.ring[ri].take().expect("occupied slot in [head, head+len)");
                if slot.deadline.is_some_and(|d| d <= now) {
                    st.expired += 1;
                    expired.push(slot.item);
                    continue;
                }
                if taken_live < room {
                    taken_live += 1;
                    jobs.push(slot.item);
                    continue;
                }
                // no room left for this live match: it stays queued
                st.ring[ri] = Some(slot);
            }
            if write != read {
                let wi = (head + write) % self.capacity;
                st.ring[wi] = st.ring[ri].take();
            }
            write += 1;
        }
        st.len = write;
        len - write
    }

    /// Begin shutdown: new pushes are refused with `Shutdown`; consumers
    /// drain what is already queued and then observe [`Pop::Closed`].
    /// Blocked producers and consumers are woken.
    pub fn close(&self) {
        relock(self.state.lock()).closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// How long an admission attempt may block when the queue is full.
#[derive(Clone, Copy)]
enum AdmitWait {
    /// not at all (`try_push`)
    None,
    /// until this instant (`push_timeout`)
    Until(Instant),
    /// indefinitely — bounded only by deadline/close (`push`)
    Forever,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn fifo_order_and_wraparound() {
        let q = AdmissionQueue::new(3);
        for round in 0..4u64 {
            for i in 0..3 {
                q.try_push(round * 10 + i, None).unwrap();
            }
            assert_eq!(q.depth(), 3);
            for i in 0..3 {
                match q.pop() {
                    Pop::Job(v) => assert_eq!(v, round * 10 + i),
                    _ => panic!("expected live job"),
                }
            }
        }
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn capacity_zero_clamps_to_one() {
        let q = AdmissionQueue::new(0);
        assert_eq!(q.capacity(), 1);
        q.try_push(1u8, None).unwrap();
        assert!(q.try_push(2u8, None).is_err());
    }

    #[test]
    fn full_queue_sheds_with_queue_full() {
        let q = AdmissionQueue::new(2);
        q.try_push(1, None).unwrap();
        q.try_push(2, None).unwrap();
        let rej = q.try_push(3, None).unwrap_err();
        assert_eq!(rej.kind, ErrorKind::QueueFull);
        assert_eq!(rej.item, 3); // the item comes back to the caller
        let e = rej.to_error(q.capacity());
        assert_eq!(e.kind(), ErrorKind::QueueFull);
        assert!(format!("{e}").contains("capacity 2"), "got: {e}");
        assert_eq!(q.counters().shed, 1);
    }

    #[test]
    fn expired_at_admission_rejected() {
        let q = AdmissionQueue::new(4);
        let past = Instant::now() - ms(1);
        let rej = q.try_push(7, Some(past)).unwrap_err();
        assert_eq!(rej.kind, ErrorKind::DeadlineExceeded);
        assert_eq!(q.counters().expired, 1);
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn expired_at_dequeue_reported() {
        let q = AdmissionQueue::new(4);
        q.try_push(1, Some(Instant::now() + ms(2))).unwrap();
        q.try_push(2, None).unwrap();
        std::thread::sleep(ms(10));
        match q.pop() {
            Pop::Expired(v) => assert_eq!(v, 1),
            _ => panic!("first item should have expired in queue"),
        }
        match q.pop() {
            Pop::Job(v) => assert_eq!(v, 2),
            _ => panic!("second item has no deadline"),
        }
        assert_eq!(q.counters().expired, 1);
    }

    #[test]
    fn push_timeout_gives_up_with_queue_full() {
        let q = AdmissionQueue::new(1);
        q.try_push(1, None).unwrap();
        let t0 = Instant::now();
        let rej = q.push_timeout(2, None, ms(20)).unwrap_err();
        assert_eq!(rej.kind, ErrorKind::QueueFull);
        assert!(t0.elapsed() >= ms(15), "must actually have waited");
        assert_eq!(q.counters().shed, 1);
    }

    #[test]
    fn push_timeout_admits_when_space_frees() {
        let q = Arc::new(AdmissionQueue::new(1));
        q.try_push(1u32, None).unwrap();
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(ms(10));
            match q2.pop() {
                Pop::Job(v) => assert_eq!(v, 1),
                _ => panic!("expected job"),
            }
        });
        q.push_timeout(2u32, None, Duration::from_secs(10)).unwrap();
        h.join().unwrap();
        assert_eq!(q.depth(), 1);
        assert_eq!(q.counters().shed, 0);
    }

    #[test]
    fn deadline_bounds_blocking_push() {
        let q = AdmissionQueue::new(1);
        q.try_push(1, None).unwrap();
        // blocked waiting for space, the item's own deadline lapses:
        // classified DeadlineExceeded, not QueueFull
        let rej = q.push(2, Some(Instant::now() + ms(15))).unwrap_err();
        assert_eq!(rej.kind, ErrorKind::DeadlineExceeded);
        assert_eq!(q.counters().expired, 1);
    }

    #[test]
    fn expired_producer_forwards_the_wakeup() {
        // regression (lost wakeup): producer A, parked on a full queue
        // with a TTL, can consume the single not_full notification from
        // a pop and then exit DeadlineExceeded; it must hand the
        // notification on, or producer B (no TTL) sleeps through the
        // free slot. The exact interleaving is a narrow race, so this
        // runs many rounds; every interleaving must leave B admitted
        // promptly (a lost wakeup strands B until its own 10 s bound).
        for round in 0..50 {
            let q = Arc::new(AdmissionQueue::new(1));
            q.try_push(0u32, None).unwrap();
            let qa = q.clone();
            let a = std::thread::spawn(move || {
                qa.push(1u32, Some(Instant::now() + ms(2))).is_ok()
            });
            let qb = q.clone();
            let b = std::thread::spawn(move || {
                let t0 = Instant::now();
                let ok = qb.push_timeout(2u32, None, Duration::from_secs(10)).is_ok();
                (ok, t0.elapsed())
            });
            std::thread::sleep(ms(2)); // pop lands around A's TTL lapse
            assert!(matches!(q.pop(), Pop::Job(0)), "round {round}");
            if a.join().unwrap() {
                // A won the freed slot before its TTL lapsed (also a
                // valid interleaving): free another so B's admission
                // doesn't depend on A's item
                assert!(
                    matches!(q.pop(), Pop::Job(1) | Pop::Expired(1)),
                    "round {round}"
                );
            }
            let (admitted, waited) = b.join().unwrap();
            assert!(admitted, "round {round}: B must admit into a freed slot");
            assert!(
                waited < Duration::from_secs(5),
                "round {round}: B waited {waited:?} — the wakeup was lost"
            );
            assert!(matches!(q.pop(), Pop::Job(2)), "round {round}");
        }
    }

    #[test]
    fn close_rejects_new_pushes_but_drains_old() {
        let q = AdmissionQueue::new(4);
        q.try_push(1, None).unwrap();
        q.try_push(2, Some(Instant::now() + ms(2))).unwrap();
        std::thread::sleep(ms(10));
        q.close();
        let rej = q.try_push(3, None).unwrap_err();
        assert_eq!(rej.kind, ErrorKind::Shutdown);
        assert_eq!(rej.to_error(4).kind(), ErrorKind::Shutdown);
        // drain semantics: live items handed out to complete, expired
        // ones handed out to reject, then Closed
        assert!(matches!(q.pop(), Pop::Job(1)));
        assert!(matches!(q.pop(), Pop::Expired(2)));
        assert!(matches!(q.pop(), Pop::Closed));
        assert!(matches!(q.pop(), Pop::Closed)); // idempotent
    }

    #[test]
    fn close_wakes_blocked_consumers() {
        let q = Arc::new(AdmissionQueue::<u32>::new(2));
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || matches!(q.pop(), Pop::Closed))
            })
            .collect();
        std::thread::sleep(ms(10));
        q.close();
        for h in handles {
            assert!(h.join().unwrap(), "blocked consumer must see Closed");
        }
    }

    #[test]
    fn close_wakes_blocked_producers() {
        let q = Arc::new(AdmissionQueue::new(1));
        q.try_push(1u32, None).unwrap();
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.push(2u32, None).unwrap_err().kind);
        std::thread::sleep(ms(10));
        q.close();
        assert_eq!(h.join().unwrap(), ErrorKind::Shutdown);
    }

    #[test]
    fn depth_counters_track_watermark() {
        let q = AdmissionQueue::new(8);
        for i in 0..5 {
            q.try_push(i, None).unwrap();
        }
        assert!(matches!(q.pop(), Pop::Job(0)));
        let c = q.counters();
        assert_eq!(c.depth, 4);
        assert_eq!(c.depth_peak, 5);
        assert_eq!((c.shed, c.expired), (0, 0));
    }

    #[test]
    fn mpmc_under_contention_delivers_everything_once() {
        let q = Arc::new(AdmissionQueue::new(16));
        let producers = 4u64;
        let per = 500u64;
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        match q.pop() {
                            Pop::Job(v) => got.push(v),
                            Pop::Expired(_) => panic!("no deadlines in this test"),
                            Pop::Closed => return got,
                        }
                    }
                })
            })
            .collect();
        let prod: Vec<_> = (0..producers)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        q.push(p * per + i, None).unwrap();
                    }
                })
            })
            .collect();
        for h in prod {
            h.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = consumers.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        let want: Vec<u64> = (0..producers * per).collect();
        assert_eq!(all, want, "every item delivered exactly once");
    }

    #[test]
    fn push_timeout_bound_excludes_lock_acquisition() {
        // regression: the give-up instant used to be computed after
        // acquiring the state lock, so a contended lock stretched the
        // effective bound to lock-wait + `wait`. With the bound fixed
        // before locking, a bounded submit into a full queue hammered by
        // other threads still returns within a small multiple of its
        // timeout.
        use std::sync::atomic::{AtomicBool, Ordering};
        let q = Arc::new(AdmissionQueue::new(1));
        q.try_push(0u64, None).unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let contenders: Vec<_> = (0..4)
            .map(|_| {
                let q = q.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let _ = q.try_push(9, None);
                        let _ = q.depth();
                    }
                })
            })
            .collect();
        let t0 = Instant::now();
        let rej = q.push_timeout(1u64, None, ms(30)).unwrap_err();
        let waited = t0.elapsed();
        stop.store(true, Ordering::Relaxed);
        for h in contenders {
            h.join().unwrap();
        }
        assert_eq!(rej.kind, ErrorKind::QueueFull);
        assert!(waited >= ms(30), "must wait its full bound: {waited:?}");
        assert!(waited < ms(500), "bound must not stretch under contention: {waited:?}");
    }

    // key for the pop_batch tests: the tens digit, so 10/11/12 coalesce
    // while 20 does not
    fn tens(v: &u64) -> u64 {
        *v / 10
    }

    #[test]
    fn pop_batch_coalesces_matching_run_and_keeps_fifo() {
        let q = AdmissionQueue::new(8);
        for v in [10u64, 11, 20, 12] {
            q.try_push(v, None).unwrap();
        }
        match q.pop_batch(8, None, Some(Duration::ZERO), &tens) {
            PopBatch::Batch(b) => {
                assert_eq!(b.jobs, vec![10, 11, 12], "matches drain in FIFO order");
                assert!(b.expired.is_empty());
            }
            _ => panic!("expected a batch"),
        }
        // the non-matching item kept its place as the new head
        assert_eq!(q.depth(), 1);
        assert!(matches!(q.pop(), Pop::Job(20)));
    }

    #[test]
    fn pop_batch_respects_max() {
        let q = AdmissionQueue::new(8);
        for v in 10..15u64 {
            q.try_push(v, None).unwrap();
        }
        match q.pop_batch(3, None, Some(Duration::ZERO), &tens) {
            PopBatch::Batch(b) => assert_eq!(b.jobs, vec![10, 11, 12]),
            _ => panic!("expected a batch"),
        }
        match q.pop_batch(3, None, Some(Duration::ZERO), &tens) {
            PopBatch::Batch(b) => assert_eq!(b.jobs, vec![13, 14], "overflow stays FIFO"),
            _ => panic!("expected a batch"),
        }
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn pop_batch_of_one_degrades_to_plain_pop() {
        let q = AdmissionQueue::new(4);
        q.try_push(10u64, None).unwrap();
        q.try_push(11, None).unwrap();
        match q.pop_batch(1, None, Some(Duration::ZERO), &tens) {
            PopBatch::Batch(b) => assert_eq!(b.jobs, vec![10], "max 1 never coalesces"),
            _ => panic!("expected a batch"),
        }
        assert_eq!(q.depth(), 1);
    }

    #[test]
    fn pop_batch_classifies_expired_members() {
        let q = AdmissionQueue::new(8);
        q.try_push(10u64, None).unwrap();
        q.try_push(11, Some(Instant::now() + ms(2))).unwrap();
        q.try_push(12, None).unwrap();
        std::thread::sleep(ms(10));
        match q.pop_batch(8, None, Some(Duration::ZERO), &tens) {
            PopBatch::Batch(b) => {
                assert_eq!(b.jobs, vec![10, 12], "live members in FIFO order");
                assert_eq!(b.expired, vec![11], "lapsed member classified, not executed");
            }
            _ => panic!("expected a batch"),
        }
        assert_eq!(q.counters().expired, 1);
    }

    #[test]
    fn pop_batch_expired_head_anchors_no_batch() {
        let q = AdmissionQueue::new(8);
        q.try_push(10u64, Some(Instant::now() + ms(2))).unwrap();
        q.try_push(11, None).unwrap();
        std::thread::sleep(ms(10));
        match q.pop_batch(8, None, Some(Duration::ZERO), &tens) {
            PopBatch::Batch(b) => {
                assert!(b.jobs.is_empty(), "an expired head must not drag a batch");
                assert_eq!(b.expired, vec![10]);
            }
            _ => panic!("expected the expired head"),
        }
        // the live item behind it anchors the next batch
        match q.pop_batch(8, None, Some(Duration::ZERO), &tens) {
            PopBatch::Batch(b) => assert_eq!(b.jobs, vec![11]),
            _ => panic!("expected a batch"),
        }
        assert_eq!(q.counters().expired, 1);
    }

    #[test]
    fn pop_batch_empty_and_closed() {
        let q = AdmissionQueue::<u64>::new(4);
        assert!(matches!(q.pop_batch(4, None, Some(Duration::ZERO), &tens), PopBatch::Empty));
        let t0 = Instant::now();
        assert!(matches!(q.pop_batch(4, None, Some(ms(10)), &tens), PopBatch::Empty));
        assert!(t0.elapsed() >= ms(5), "bounded idle wait must actually wait");
        q.close();
        assert!(matches!(q.pop_batch(4, None, Some(Duration::ZERO), &tens), PopBatch::Closed));
        assert!(matches!(q.pop_batch(4, None, None, &tens), PopBatch::Closed));
    }

    #[test]
    fn pop_batch_straggler_wait_picks_up_late_arrival() {
        let q = Arc::new(AdmissionQueue::new(4));
        q.try_push(10u64, None).unwrap();
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(ms(10));
            q2.try_push(11u64, None).unwrap();
        });
        // the window is generous; the batch tops up to max and returns
        // as soon as the straggler lands, well before 5 s
        match q.pop_batch(2, Some(Duration::from_secs(5)), Some(Duration::ZERO), &tens) {
            PopBatch::Batch(b) => assert_eq!(b.jobs, vec![10, 11], "straggler joined"),
            _ => panic!("expected a batch"),
        }
        h.join().unwrap();
    }

    #[test]
    fn pop_batch_straggler_wait_is_bounded() {
        let q = AdmissionQueue::new(4);
        q.try_push(10u64, None).unwrap();
        let t0 = Instant::now();
        match q.pop_batch(4, Some(ms(20)), Some(Duration::ZERO), &tens) {
            PopBatch::Batch(b) => assert_eq!(b.jobs, vec![10]),
            _ => panic!("expected a batch"),
        }
        let waited = t0.elapsed();
        assert!(waited >= ms(15), "must have held the straggler window: {waited:?}");
        assert!(waited < Duration::from_secs(5), "window must be bounded: {waited:?}");
    }

    #[test]
    fn pop_batch_straggler_window_capped_by_head_deadline() {
        // head carries a 20 ms TTL; a 10 s straggler window must not
        // hold it past that (the wait is min'd with the head deadline),
        // and the head must come back live, not expired
        let q = AdmissionQueue::new(4);
        q.try_push(10u64, Some(Instant::now() + ms(20))).unwrap();
        let t0 = Instant::now();
        match q.pop_batch(4, Some(Duration::from_secs(10)), Some(Duration::ZERO), &tens) {
            PopBatch::Batch(b) => assert_eq!(b.jobs, vec![10], "head stays live"),
            _ => panic!("expected a batch"),
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "window capped by head TTL");
    }

    #[test]
    fn pop_batch_wraparound_compaction_preserves_order() {
        // force the ring to wrap, then coalesce out of the middle: the
        // survivors must compact toward the head in their original order
        let q = AdmissionQueue::new(4);
        for v in [90u64, 91] {
            q.try_push(v, None).unwrap();
        }
        assert!(matches!(q.pop(), Pop::Job(90)));
        assert!(matches!(q.pop(), Pop::Job(91)));
        // head is now at index 2; these four wrap around the ring end
        for v in [10u64, 20, 11, 21] {
            q.try_push(v, None).unwrap();
        }
        match q.pop_batch(8, None, Some(Duration::ZERO), &tens) {
            PopBatch::Batch(b) => assert_eq!(b.jobs, vec![10, 11]),
            _ => panic!("expected a batch"),
        }
        assert!(matches!(q.pop(), Pop::Job(20)), "survivors keep FIFO order");
        assert!(matches!(q.pop(), Pop::Job(21)));
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn pop_batch_frees_slots_for_blocked_producers() {
        // a full queue, a blocked producer: a draining pop_batch must
        // hand the freed slots on (notify_all), or the producer sleeps
        // through them
        let q = Arc::new(AdmissionQueue::new(2));
        q.try_push(10u64, None).unwrap();
        q.try_push(11, None).unwrap();
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.push(12u64, None).is_ok());
        std::thread::sleep(ms(10)); // let the producer park
        match q.pop_batch(8, None, Some(Duration::ZERO), &tens) {
            PopBatch::Batch(b) => assert_eq!(b.jobs, vec![10, 11]),
            _ => panic!("expected a batch"),
        }
        assert!(h.join().unwrap(), "blocked producer admitted into a freed slot");
        assert_eq!(q.depth(), 1);
    }
}
