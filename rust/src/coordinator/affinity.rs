//! Best-effort core pinning for executor threads (zero dependencies).
//!
//! Sharding executors by `PlanKey` keeps a shape's plan cache and
//! `ScratchArena` on one thread; pinning that thread keeps them near one
//! core's cache as well — Hofmann et al.'s Xeon Phi study (PAPERS.md)
//! shows affinity-aware placement, not just parallelism, decides
//! sustained throughput on many-core parts. Pinning is opt-in
//! (`--pin-cores`) and strictly best-effort: an unsupported target or a
//! refused syscall reports `false` and serving proceeds unpinned —
//! affinity is a performance hint, never a correctness dependency.

/// Pin the calling thread to `cpu`. Returns whether the pin took.
///
/// Implemented as a raw `sched_setaffinity(2)` syscall on Linux/x86-64
/// (the crate links no libc); everywhere else it is a no-op returning
/// `false`.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
pub fn pin_current_thread(cpu: usize) -> bool {
    // 1024-bit mask, the kernel's default cpu_set_t width; an out-of-
    // range cpu wraps into the mask and the kernel rejects it with
    // EINVAL if that core doesn't exist — reported as `false`, no panic
    let mut mask = [0u64; 16];
    mask[(cpu / 64) % mask.len()] |= 1u64 << (cpu % 64);
    let ret: i64;
    // SAFETY: syscall 203 (sched_setaffinity) reads `rsi` bytes from the
    // pointer in `rdx` and touches no other memory; pid 0 = the calling
    // thread. The syscall instruction clobbers rcx/r11 and rflags.
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") 203i64 => ret,
            in("rdi") 0usize,
            in("rsi") std::mem::size_of_val(&mask),
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    ret == 0
}

/// Non-Linux / non-x86-64 fallback: affinity stays a no-op hint.
#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
pub fn pin_current_thread(_cpu: usize) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pinning_is_best_effort_and_never_panics() {
        // on linux/x86-64 pinning to cpu 0 generally succeeds; elsewhere
        // the stub reports false — either way: no panic, thread runs on
        let _took = pin_current_thread(0);
        let _far = pin_current_thread(10_000); // absurd cpu: refused, not fatal
        assert!(std::thread::spawn(|| {
            pin_current_thread(0);
            1 + 1
        })
        .join()
        .is_ok());
    }
}
