//! Request/response types of the serving API.

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::time::Duration;

use crate::conv::{Algorithm, Variant};
use crate::image::PlanarImage;
use crate::models::Layout;
use crate::plan::{FilterGraph, Kernel2d, KernelClass, KernelSpec, TileSpec};
use crate::util::error::Result;

use super::router::Backend;

/// A multi-stage filter chain carried by one request: Gaussian stages
/// applied in order, streamed through the row-ring cascade by default.
/// The whole chain is one admission-queue entry with one deadline;
/// executors cache one built [`FilterGraph`] per distinct
/// [`GraphSpec::digest`].
#[derive(Debug, Clone, PartialEq)]
pub struct GraphSpec {
    /// stages in application order (each feeds the next)
    pub stages: Vec<KernelSpec>,
    /// `false` materialises every inter-stage plane (the differential /
    /// traffic baseline); `true` streams every eligible edge
    pub streamed: bool,
}

impl GraphSpec {
    /// A streamed linear chain of Gaussian stages.
    pub fn chain(stages: Vec<KernelSpec>) -> Self {
        Self { stages, streamed: true }
    }

    pub fn materialized(mut self) -> Self {
        self.streamed = false;
        self
    }

    /// Intake validation: non-empty, every stage an odd positive-sigma
    /// Gaussian (same rules as single-kernel requests).
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.stages.is_empty(), "graph request has no stages");
        for spec in &self.stages {
            spec.validate()?;
        }
        Ok(())
    }

    /// Inter-stage edges a built chain streams: all of them when
    /// `streamed` (a linear chain resolves to one cascade segment —
    /// matches [`FilterGraph::streamed_edges`], since demotions only
    /// arise from fan-out, which a linear spec cannot express), none
    /// otherwise. Feeds the coordinator's `stages_fused` counter.
    pub fn streamed_edges(&self) -> usize {
        if self.streamed {
            self.stages.len().saturating_sub(1)
        } else {
            0
        }
    }

    /// Stable identity of the chain (stage widths/sigmas + policy) —
    /// the graph-shaped component of the executor `PlanKey`, so equal
    /// chains batch together and cache one built graph.
    pub fn digest(&self) -> u64 {
        let mut h = DefaultHasher::new();
        self.streamed.hash(&mut h);
        for spec in &self.stages {
            spec.cache_key().hash(&mut h);
        }
        h.finish()
    }

    /// Build the executable [`FilterGraph`] for a concrete shape: a
    /// linear chain `s0 -> s1 -> ...`, every edge streamed or every
    /// edge materialised per the spec.
    pub fn build(
        &self,
        planes: usize,
        rows: usize,
        cols: usize,
        variant: Variant,
        layout: Layout,
    ) -> Result<FilterGraph> {
        let mut b =
            FilterGraph::builder().shape(planes, rows, cols).variant(variant).layout(layout);
        for (i, spec) in self.stages.iter().enumerate() {
            b = b.stage(&format!("s{i}"), *spec);
            if !self.streamed {
                b = b.materialized();
            }
        }
        b.build()
    }
}

/// One convolution job.
#[derive(Debug, Clone)]
pub struct ConvRequest {
    pub id: u64,
    pub image: PlanarImage,
    pub algorithm: Algorithm,
    pub variant: Variant,
    /// `None` → the coordinator's routing policy decides.
    pub backend: Option<Backend>,
    /// `None` → policy decides (paper-adaptive picks 3R×C for large).
    pub layout: Option<Layout>,
    /// `None` → the coordinator's configured default kernel. A request
    /// may carry its own Gaussian spec; executors cache one plan per
    /// distinct `(algorithm, variant, layout, shape, kernel)` key.
    pub kernel: Option<KernelSpec>,
    /// `Some` carries an explicit (possibly non-separable) tap matrix
    /// instead of a Gaussian spec; takes precedence over `kernel` and
    /// defaults the class to [`KernelClass::Direct2d`] unless
    /// `kernel_class` pins it. Validated at intake (odd extents, finite
    /// taps) with a structured `InvalidKernel` refusal.
    pub kernel2d: Option<Kernel2d>,
    /// `None` → the tuning tier picks the class per shape (cost-model
    /// crossover: large kernels route to FFT by prediction) and
    /// otherwise the source's natural class. `Some` pins the class and
    /// skips class selection.
    pub kernel_class: Option<KernelClass>,
    /// `None` → the coordinator's tuning tier (swept winner or
    /// cost-model prediction, when installed via
    /// `Coordinator::set_tuning`) and otherwise its configured tile
    /// decomposition (untiled row bands unless
    /// `--tile-rows`/`--tile-cols` were set). A request may carry its
    /// own tile; executors cache one plan per distinct
    /// `(algorithm, variant, layout, shape, kernel, tile, fuse)` key.
    pub tile: Option<TileSpec>,
    /// `None` → the coordinator's tuning tier (see `tile` above) and
    /// otherwise its configured default (`--fuse`).
    /// Fusion only applies to two-pass requests; for single-pass
    /// algorithms it is silently inapplicable rather than an error, so
    /// a `--fuse` serving default never refuses single-pass traffic.
    pub fuse: Option<bool>,
    /// Time-to-live from submission. `None` → the coordinator's
    /// configured default (`--deadline-ms`; no deadline if that is 0).
    /// Checked at admission, while blocked waiting for a queue slot,
    /// and again at dequeue — a lapsed request is refused with a
    /// structured `DeadlineExceeded` error instead of executing.
    pub deadline: Option<Duration>,
    /// `Some` turns this into a multi-stage graph request: the chain is
    /// served end-to-end as this one queue entry under this one
    /// deadline, and `kernel`/`tile`/`fuse` are ignored in favour of
    /// the chain's own stages and edge policies. Graph requests run on
    /// the native backends (PJRT executes single plans only, so routing
    /// falls back rather than refusing).
    pub graph: Option<GraphSpec>,
}

impl ConvRequest {
    /// A default request: two-pass SIMD, routing left to policy.
    pub fn new(id: u64, image: PlanarImage) -> Self {
        Self {
            id,
            image,
            algorithm: Algorithm::TwoPass,
            variant: Variant::Simd,
            backend: None,
            layout: None,
            kernel: None,
            kernel2d: None,
            kernel_class: None,
            tile: None,
            fuse: None,
            deadline: None,
            graph: None,
        }
    }

    pub fn with_algorithm(mut self, a: Algorithm) -> Self {
        self.algorithm = a;
        self
    }

    pub fn with_variant(mut self, v: Variant) -> Self {
        self.variant = v;
        self
    }

    pub fn with_backend(mut self, b: Backend) -> Self {
        self.backend = Some(b);
        self
    }

    pub fn with_layout(mut self, l: Layout) -> Self {
        self.layout = Some(l);
        self
    }

    /// Carry a per-request kernel (width + sigma); validated at intake.
    pub fn with_kernel(mut self, spec: KernelSpec) -> Self {
        self.kernel = Some(spec);
        self
    }

    /// Carry an explicit (possibly non-separable) 2-D tap matrix;
    /// validated at intake. Takes precedence over `with_kernel`.
    pub fn with_kernel2d(mut self, k: Kernel2d) -> Self {
        self.kernel2d = Some(k);
        self
    }

    /// Pin the kernel class (separable / direct2d / fft), bypassing the
    /// tuning tier's class selection.
    pub fn with_kernel_class(mut self, class: KernelClass) -> Self {
        self.kernel_class = Some(class);
        self
    }

    /// Carry a per-request 2-D tile decomposition (overrides the
    /// coordinator's configured default); validated at plan build.
    pub fn with_tile(mut self, spec: TileSpec) -> Self {
        self.tile = Some(spec);
        self
    }

    /// Fuse (or explicitly unfuse) this request's two-pass pipeline,
    /// overriding the coordinator's `--fuse` default.
    pub fn with_fuse(mut self, fuse: bool) -> Self {
        self.fuse = Some(fuse);
        self
    }

    /// Give this request its own time-to-live (overrides the
    /// coordinator's `--deadline-ms` default).
    pub fn with_deadline(mut self, ttl: Duration) -> Self {
        self.deadline = Some(ttl);
        self
    }

    /// Serve a multi-stage filter chain instead of a single kernel;
    /// validated at intake.
    pub fn with_graph(mut self, graph: GraphSpec) -> Self {
        self.graph = Some(graph);
        self
    }
}

/// The served result.
#[derive(Debug)]
pub struct ConvResponse {
    pub id: u64,
    pub image: PlanarImage,
    /// which backend actually ran it
    pub backend: Backend,
    pub layout: Layout,
    /// time spent waiting in the queue (for batched members this
    /// includes any straggler window the executor held the batch open)
    pub queue_ms: f64,
    /// time spent convolving; members of one coalesced batch share its
    /// wall time evenly (the amortised per-request cost)
    pub service_ms: f64,
    /// how many requests the executor coalesced into the plan batch
    /// that produced this response (`1` = served singly, which is the
    /// default until `--batch-max` is raised)
    pub batch_len: usize,
    /// which kernel class the admitted plan ran (pinned by the request,
    /// or picked by the tuning tier's measured/predicted crossover)
    pub kernel_class: KernelClass,
}

impl ConvResponse {
    pub fn latency_ms(&self) -> f64 {
        self.queue_ms + self.service_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::{synth_image, Pattern};

    #[test]
    fn builder_chain() {
        let img = synth_image(3, 16, 16, Pattern::Noise, 0);
        let r = ConvRequest::new(7, img)
            .with_algorithm(Algorithm::SinglePassNoCopy)
            .with_variant(Variant::Scalar)
            .with_backend(Backend::NativeOpenMp)
            .with_layout(Layout::Agglomerated)
            .with_kernel(KernelSpec::new(7, 2.0))
            .with_tile(TileSpec::new(16, 32))
            .with_fuse(true)
            .with_deadline(Duration::from_millis(250))
            .with_kernel_class(KernelClass::Separable);
        assert_eq!(r.id, 7);
        assert_eq!(r.algorithm, Algorithm::SinglePassNoCopy);
        assert_eq!(r.variant, Variant::Scalar);
        assert_eq!(r.backend, Some(Backend::NativeOpenMp));
        assert_eq!(r.layout, Some(Layout::Agglomerated));
        assert_eq!(r.kernel, Some(KernelSpec::new(7, 2.0)));
        assert_eq!(r.tile, Some(TileSpec::new(16, 32)));
        assert_eq!(r.fuse, Some(true));
        assert_eq!(r.deadline, Some(Duration::from_millis(250)));
        assert_eq!(r.kernel_class, Some(KernelClass::Separable));
    }

    #[test]
    fn kernel2d_rides_along_with_a_pinned_class() {
        let img = synth_image(1, 16, 16, Pattern::Noise, 0);
        let lap = Kernel2d::new(vec![0.0, 1.0, 0.0, 1.0, -4.0, 1.0, 0.0, 1.0, 0.0], 3, 3).unwrap();
        let r = ConvRequest::new(2, img)
            .with_kernel2d(lap.clone())
            .with_kernel_class(KernelClass::Fft);
        assert_eq!(r.kernel2d.as_ref().map(|k| k.digest()), Some(lap.digest()));
        assert_eq!(r.kernel_class, Some(KernelClass::Fft));
    }

    #[test]
    fn defaults_leave_routing_to_policy() {
        let img = synth_image(3, 16, 16, Pattern::Noise, 0);
        let r = ConvRequest::new(1, img);
        assert!(r.backend.is_none());
        assert!(r.layout.is_none());
        assert!(r.kernel.is_none());
        assert!(r.kernel2d.is_none());
        assert!(r.kernel_class.is_none());
        assert!(r.tile.is_none());
        assert!(r.fuse.is_none());
        assert!(r.deadline.is_none());
        assert!(r.graph.is_none());
        assert_eq!(r.algorithm, Algorithm::TwoPass);
    }

    #[test]
    fn graph_spec_digest_and_validation() {
        let spec = GraphSpec::chain(vec![KernelSpec::new(9, 1.8), KernelSpec::new(5, 1.0)]);
        spec.validate().unwrap();
        assert_eq!(spec.digest(), spec.clone().digest(), "deterministic");
        assert_ne!(
            spec.digest(),
            spec.clone().materialized().digest(),
            "policy is part of the identity"
        );
        assert_ne!(
            spec.digest(),
            GraphSpec::chain(vec![KernelSpec::new(5, 1.0), KernelSpec::new(9, 1.8)]).digest(),
            "stage order is part of the identity"
        );
        assert!(GraphSpec::chain(vec![]).validate().is_err());
        assert!(GraphSpec::chain(vec![KernelSpec::new(4, 1.0)]).validate().is_err());
        let g = spec.build(1, 20, 20, Variant::Simd, Layout::PerPlane).unwrap();
        assert_eq!(g.stages().len(), 2);
        assert_eq!(g.streamed_edges(), 1);
        let m = spec.materialized().build(1, 20, 20, Variant::Simd, Layout::PerPlane).unwrap();
        assert_eq!(m.streamed_edges(), 0);
    }
}
