//! Machine description + calibrated cost constants (provenance in
//! `phisim/mod.rs` docs).

/// The Intel Xeon Phi 5110P (paper section 2).
#[derive(Debug, Clone)]
pub struct PhiMachine {
    pub cores: usize,
    pub smt: usize,
    pub ghz: f64,
    pub vpu_lanes_f32: usize,
    pub l2_kb_per_core: usize,
}

impl Default for PhiMachine {
    fn default() -> Self {
        Self { cores: 60, smt: 4, ghz: 1.053, vpu_lanes_f32: 16, l2_kb_per_core: 512 }
    }
}

impl PhiMachine {
    pub fn hw_threads(&self) -> usize {
        self.cores * self.smt
    }
}

/// Calibrated cost constants. Defaults reproduce the paper's testbed;
/// every field is overridable for ablations (`bench-table` exposes them).
#[derive(Debug, Clone)]
pub struct Calibration {
    // -- compute rates, flops/second/thread at the 100-thread operating
    //    point (absorbing SMT sharing; see mod docs) --------------------
    /// naive 4-loop code, `-no-vec` (Opt-0)
    pub rate_naive: f64,
    /// unrolled scalar code, `-no-vec` (Opt-1/3)
    pub rate_unrolled: f64,
    /// unrolled + `#pragma simd` (Opt-2/4): 16-lane VPU at ~55 % issue
    pub rate_simd: f64,

    // -- memory system --------------------------------------------------
    /// streaming bandwidth one thread can pull (GB/s)
    pub bw_thread_gbs: f64,
    /// aggregate sustained GDDR5 bandwidth (GB/s)
    pub bw_peak_gbs: f64,

    // -- OpenMP runtime --------------------------------------------------
    /// fork-join/barrier cost per parallel region: base + per-thread
    pub omp_dispatch_base_us: f64,
    pub omp_dispatch_per_thread_ns: f64,

    // -- OpenCL runtime ---------------------------------------------------
    /// enqueue+finish cost per kernel launch; ≈0.33 ms per 6-launch image
    /// (paper: empty-kernel overhead 0.25–0.4 ms per image)
    pub ocl_enqueue_ms: f64,
    /// per-work-item index computation (div/mod in the kernel, List. 2)
    pub ocl_item_ns: f64,
    /// compute-efficiency factor vs the OpenMP binary (harder
    /// vectorisation without pragmas)
    pub ocl_eff: f64,
    /// scalar-mode efficiency when only one processing element per
    /// compute unit is used (the paper's vectorisation-disable trick):
    /// the implicit vectoriser's scalar fallback is poor
    pub ocl_scalar_eff: f64,
    /// aggregate bandwidth achieved by the OpenCL runtime (GB/s)
    pub ocl_bw_gbs: f64,
    /// SIMD efficiency of the 25-tap single-pass kernel under OpenCL's
    /// implicit vectoriser (paper section 7: single-pass OpenCL is ~50 %
    /// slower than two-pass — the strided 5-row stencil defeats it)
    pub ocl_singlepass_eff: f64,

    // -- GPRM runtime -----------------------------------------------------
    /// task creation + communication cost per task instance
    pub gprm_task_us: f64,
    /// task-graph construction per dispatch
    pub gprm_graph_ms: f64,
    /// compute factor vs OpenMP when vectorised (Table 2: GPRM-compute
    /// ≈ 0.6 × OpenMP — pinned tasks, no per-region fork)
    pub gprm_compute_factor_simd: f64,
    /// compute factor vs OpenMP scalar (Table 1 no-vec: ≈ 0.98)
    pub gprm_compute_factor_scalar: f64,
}

impl Default for Calibration {
    fn default() -> Self {
        Self {
            rate_naive: 1.2e8,
            rate_unrolled: 3.0e8,
            rate_simd: 2.63e9,
            bw_thread_gbs: 5.5,
            bw_peak_gbs: 80.0,
            omp_dispatch_base_us: 2.0,
            omp_dispatch_per_thread_ns: 150.0,
            ocl_enqueue_ms: 0.055,
            ocl_item_ns: 6.25,
            ocl_scalar_eff: 0.2,
            ocl_eff: 0.75,
            ocl_bw_gbs: 55.0,
            ocl_singlepass_eff: 0.25,
            gprm_task_us: 40.0,
            gprm_graph_ms: 0.25,
            gprm_compute_factor_simd: 0.6,
            gprm_compute_factor_scalar: 0.98,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn machine_defaults_match_5110p() {
        let m = PhiMachine::default();
        assert_eq!(m.cores, 60);
        assert_eq!(m.hw_threads(), 240);
        assert_eq!(m.vpu_lanes_f32, 16);
        assert!((m.ghz - 1.053).abs() < 1e-9);
    }

    #[test]
    fn ladder_ratios_embedded() {
        let c = Calibration::default();
        // Opt-1 gain ≈ 2.5× (paper section 5.2)
        assert!((c.rate_unrolled / c.rate_naive - 2.5).abs() < 0.01);
        // SIMD rate ≈ 16 lanes at ~55 % issue over the unrolled rate
        let lanes_eff = c.rate_simd / c.rate_unrolled / 16.0;
        assert!(lanes_eff > 0.4 && lanes_eff < 0.7, "{lanes_eff}");
    }

    #[test]
    fn gprm_image_overhead_matches_paper() {
        // 6 dispatches (2 passes × 3 planes) × (100 tasks × 40 µs + 0.25 ms)
        let c = Calibration::default();
        let per_dispatch = 100.0 * c.gprm_task_us / 1e3 + c.gprm_graph_ms;
        let rxc = 6.0 * per_dispatch;
        let agg = 2.0 * per_dispatch;
        assert!((rxc - 25.5).abs() < 0.2, "RxC overhead {rxc} vs paper 25.5ms");
        assert!((agg - 8.5).abs() < 0.1, "3RxC overhead {agg} vs paper 8.5ms");
    }
}
