//! The cost-model evaluator: workload × run-configuration → estimate.

use crate::conv::{Algorithm, Variant};
use crate::models::Layout;

use super::calibration::{Calibration, PhiMachine};

/// Which runtime schedules the work (Sequential = the ladder's Opt rungs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimModel {
    Sequential,
    OpenMp,
    OpenCl,
    Gprm,
}

impl SimModel {
    pub fn label(&self) -> &'static str {
        match self {
            SimModel::Sequential => "Sequential",
            SimModel::OpenMp => "OpenMP",
            SimModel::OpenCl => "OpenCL",
            SimModel::Gprm => "GPRM",
        }
    }
}

/// The image + algorithm being convolved.
#[derive(Debug, Clone, Copy)]
pub struct SimWorkload {
    pub rows: usize,
    pub cols: usize,
    pub planes: usize,
    pub algorithm: Algorithm,
    pub variant: Variant,
}

impl SimWorkload {
    pub fn paper(size: usize, algorithm: Algorithm, variant: Variant) -> Self {
        Self { rows: size, cols: size, planes: 3, algorithm, variant }
    }

    pub fn pixels(&self) -> f64 {
        (self.rows * self.cols * self.planes) as f64
    }

    /// The barrier-separated passes of the algorithm, as
    /// `(flops_per_pixel, dram_bytes_per_pixel)` pairs. Each pass is a
    /// separate parallel region (its own dispatch + its own roofline):
    /// neighbour reads hit the L2 row-reuse window, so DRAM traffic per
    /// pass is stream-read + stream-write = 8 B/px.
    pub fn passes(&self) -> Vec<(f64, f64)> {
        match self.algorithm {
            // horizontal 5 mul + 4 add, then vertical the same
            Algorithm::TwoPass => vec![(9.0, 8.0), (9.0, 8.0)],
            // 25 mul + 24 add in one sweep
            Algorithm::SinglePassNoCopy => vec![(49.0, 8.0)],
            // …plus the copy-back sweep (pure memory, ~1 move-op)
            Algorithm::SinglePassCopyBack => vec![(49.0, 8.0), (1.0, 8.0)],
        }
    }

    /// total flops per pixel (all passes)
    pub fn flops_per_pixel(&self) -> f64 {
        self.passes().iter().map(|p| p.0).sum()
    }

    /// total streamed DRAM bytes per pixel (all passes)
    pub fn bytes_per_pixel(&self) -> f64 {
        self.passes().iter().map(|p| p.1).sum()
    }

    /// parallel regions per image under a layout (each pass of each
    /// plane-sweep is one dispatch; copy-back is a dispatch of its own).
    pub fn dispatches(&self, layout: Layout) -> usize {
        let passes = match self.algorithm {
            Algorithm::TwoPass => 2,
            Algorithm::SinglePassNoCopy => 1,
            Algorithm::SinglePassCopyBack => 2,
        };
        match layout {
            Layout::PerPlane => passes * self.planes,
            Layout::Agglomerated => passes,
        }
    }
}

/// Scheduling configuration.
#[derive(Debug, Clone, Copy)]
pub struct SimRun {
    pub model: SimModel,
    pub threads: usize,
    /// GPRM task cutoff (ignored elsewhere).
    pub cutoff: usize,
    pub layout: Layout,
}

impl SimRun {
    pub fn sequential() -> Self {
        Self { model: SimModel::Sequential, threads: 1, cutoff: 1, layout: Layout::PerPlane }
    }

    pub fn openmp(threads: usize) -> Self {
        Self { model: SimModel::OpenMp, threads, cutoff: 0, layout: Layout::PerPlane }
    }

    pub fn opencl() -> Self {
        // the paper: all compute units; ngroups×nths cover the device
        Self { model: SimModel::OpenCl, threads: 236, cutoff: 0, layout: Layout::PerPlane }
    }

    pub fn gprm(cutoff: usize, layout: Layout) -> Self {
        // GPRM pins threads = hw threads; concurrency comes from tasks
        Self { model: SimModel::Gprm, threads: 240, cutoff, layout }
    }
}

/// Per-image time estimate with its roofline breakdown.
#[derive(Debug, Clone, Copy)]
pub struct Estimate {
    /// raw compute term (before roofline combination)
    pub compute_ms: f64,
    /// raw memory term
    pub memory_ms: f64,
    /// combined busy time (max() when threaded, sum when sequential,
    /// with the GPRM pinning factor applied)
    pub busy_ms: f64,
    /// runtime dispatch/communication overhead
    pub overhead_ms: f64,
}

impl Estimate {
    pub fn total_ms(&self) -> f64 {
        self.busy_ms + self.overhead_ms
    }
}

/// Evaluate the cost model (see `phisim/mod.rs` for the formula and the
/// calibration provenance).
pub fn simulate(
    machine: &PhiMachine,
    cal: &Calibration,
    w: &SimWorkload,
    run: &SimRun,
) -> Estimate {
    let threads = run.threads.clamp(1, machine.hw_threads()) as f64;
    let px = w.pixels();

    // effective concurrency for GPRM: tasks, not threads, are the unit —
    // with cutoff < threads only `cutoff` workers are busy ("some threads
    // can be asleep during the execution").
    let workers = match run.model {
        SimModel::Sequential => 1.0,
        SimModel::Gprm => (run.cutoff.max(1) as f64).min(threads),
        _ => threads,
    };

    // -- per-pass terms ----------------------------------------------------
    let base_rate = match w.variant {
        Variant::Naive => cal.rate_naive,
        Variant::Scalar => cal.rate_unrolled,
        Variant::Simd => cal.rate_simd,
    };
    let rate = match run.model {
        // the paper's no-vec OpenCL mode (one PE per CU) wastes the VPU
        // entirely and its scalar fallback is poor — separate constant
        SimModel::OpenCl => match (w.variant, w.algorithm) {
            // the 25-tap stencil defeats OpenCL's implicit vectoriser
            (Variant::Simd, Algorithm::SinglePassCopyBack | Algorithm::SinglePassNoCopy) => {
                base_rate * workers * cal.ocl_singlepass_eff
            }
            (Variant::Simd, _) => base_rate * workers * cal.ocl_eff,
            _ => base_rate * workers * cal.ocl_scalar_eff,
        },
        _ => base_rate * workers,
    };
    let bw_cap = match run.model {
        SimModel::OpenCl => cal.ocl_bw_gbs,
        _ => cal.bw_peak_gbs,
    };
    let bw = (workers * cal.bw_thread_gbs).min(bw_cap) * 1e9;

    // Each pass is a barrier-separated parallel region with its own
    // roofline. Multi-threaded runs overlap memory latency behind other
    // threads' compute (the purpose of the Phi's 4-way SMT): busy time
    // per pass is max(compute, memory). A single in-order thread cannot
    // overlap: the sum. This asymmetry is what makes the paper's
    // sequential SIMD gain (8.6×) exceed the 100-thread gain (4.2×).
    let mut compute_ms = 0.0;
    let mut memory_ms = 0.0;
    let mut busy_ms = 0.0;
    for (flops_px, bytes_px) in w.passes() {
        let mut c = px * flops_px / rate * 1e3;
        if run.model == SimModel::OpenCl {
            // per-work-item indexing (global id → r, c via div/mod)
            c += px * cal.ocl_item_ns / workers / 1e6;
        }
        let m = px * bytes_px / bw * 1e3;
        compute_ms += c;
        memory_ms += m;
        busy_ms += if workers > 1.5 { c.max(m) } else { c + m };
    }

    // GPRM's pinned tasks avoid per-region fork/barrier losses: Table 2's
    // GPRM-compute column ≈ factor × the OpenMP time, applied to the
    // whole busy term.
    if run.model == SimModel::Gprm {
        busy_ms *= match w.variant {
            Variant::Simd => cal.gprm_compute_factor_simd,
            _ => cal.gprm_compute_factor_scalar,
        };
    }

    // -- overhead term -------------------------------------------------------
    let dispatches = w.dispatches(run.layout) as f64;
    let overhead_ms = match run.model {
        SimModel::Sequential => 0.0,
        SimModel::OpenMp => {
            dispatches * (cal.omp_dispatch_base_us + threads * cal.omp_dispatch_per_thread_ns / 1e3)
                / 1e3
        }
        SimModel::OpenCl => dispatches * cal.ocl_enqueue_ms,
        SimModel::Gprm => {
            dispatches * (run.cutoff as f64 * cal.gprm_task_us / 1e3 + cal.gprm_graph_ms)
        }
    };

    Estimate { compute_ms, memory_ms, busy_ms, overhead_ms }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(w: &SimWorkload, run: &SimRun) -> Estimate {
        simulate(&PhiMachine::default(), &Calibration::default(), w, run)
    }

    fn paper_w(size: usize, alg: Algorithm, variant: Variant) -> SimWorkload {
        SimWorkload::paper(size, alg, variant)
    }

    /// Paper Table 1, OpenMP SIMD column — the anchor calibration row.
    #[test]
    fn table1_openmp_simd_within_tolerance() {
        let paper: [(usize, f64); 6] = [
            (1152, 0.8),
            (1728, 2.0),
            (2592, 4.1),
            (3888, 8.8),
            (5832, 19.6),
            (8748, 59.2),
        ];
        for (size, want) in paper {
            let w = paper_w(size, Algorithm::TwoPass, Variant::Simd);
            let got = sim(&w, &SimRun::openmp(100)).total_ms();
            let ratio = got / want;
            assert!(
                (0.5..2.0).contains(&ratio),
                "{size}: simulated {got:.2}ms vs paper {want}ms (ratio {ratio:.2})"
            );
        }
    }

    /// Paper Table 1: vectorisation gain at 100 threads ≈ 4.2× for OpenMP.
    #[test]
    fn vectorisation_gain_parallel_shape() {
        let mut gains = vec![];
        for size in [1152usize, 2592, 5832] {
            let novec = sim(&paper_w(size, Algorithm::TwoPass, Variant::Scalar), &SimRun::openmp(100)).total_ms();
            let simd = sim(&paper_w(size, Algorithm::TwoPass, Variant::Simd), &SimRun::openmp(100)).total_ms();
            gains.push(novec / simd);
        }
        let avg = gains.iter().sum::<f64>() / gains.len() as f64;
        assert!((2.5..7.0).contains(&avg), "avg parallel SIMD gain {avg:.1} (paper 4.2)");
    }

    /// Sequential vectorisation gain must exceed the parallel one (paper:
    /// 8.6× sequential vs 4.2× at 100 threads — BW saturation).
    #[test]
    fn sequential_simd_gain_exceeds_parallel() {
        let size = 2592;
        let seq_novec = sim(&paper_w(size, Algorithm::TwoPass, Variant::Scalar), &SimRun::sequential()).total_ms();
        let seq_simd = sim(&paper_w(size, Algorithm::TwoPass, Variant::Simd), &SimRun::sequential()).total_ms();
        let par_novec = sim(&paper_w(size, Algorithm::TwoPass, Variant::Scalar), &SimRun::openmp(100)).total_ms();
        let par_simd = sim(&paper_w(size, Algorithm::TwoPass, Variant::Simd), &SimRun::openmp(100)).total_ms();
        assert!(seq_novec / seq_simd > par_novec / par_simd);
    }

    /// Paper Table 2: GPRM ≈ 26 ms at 1152² (overhead-dominated), and the
    /// GPRM overhead constant ≈ 25.5 ms RxC.
    #[test]
    fn gprm_small_image_overhead_dominated() {
        let w = paper_w(1152, Algorithm::TwoPass, Variant::Simd);
        let e = sim(&w, &SimRun::gprm(100, Layout::PerPlane));
        assert!((20.0..32.0).contains(&e.total_ms()), "total {:.1}", e.total_ms());
        assert!(e.overhead_ms > 0.8 * e.total_ms(), "overhead should dominate");
    }

    /// Paper Fig. 3: agglomeration cuts GPRM overhead to one third and
    /// makes GPRM beat OpenMP on the largest image.
    #[test]
    fn agglomeration_rescues_gprm_largest_image() {
        let w = paper_w(8748, Algorithm::TwoPass, Variant::Simd);
        let gprm_rxc = sim(&w, &SimRun::gprm(100, Layout::PerPlane));
        let gprm_agg = sim(&w, &SimRun::gprm(100, Layout::Agglomerated));
        let omp = sim(&w, &SimRun::openmp(100));
        assert!((gprm_rxc.overhead_ms / gprm_agg.overhead_ms - 3.0).abs() < 0.2);
        assert!(gprm_agg.total_ms() < omp.total_ms(), "GPRM 3RxC must win at 8748²");
        // ...but still lose at the smallest image even agglomerated
        let w1 = paper_w(1152, Algorithm::TwoPass, Variant::Simd);
        let gprm1 = sim(&w1, &SimRun::gprm(100, Layout::Agglomerated));
        let omp1 = sim(&w1, &SimRun::openmp(100));
        assert!(gprm1.total_ms() > omp1.total_ms(), "OpenMP must keep winning at 1152²");
    }

    /// OpenCL sits between OpenMP and GPRM for small images and is the
    /// worst of the three at the largest (paper section 7).
    #[test]
    fn opencl_ordering() {
        let w = paper_w(1152, Algorithm::TwoPass, Variant::Simd);
        let omp = sim(&w, &SimRun::openmp(100)).total_ms();
        let ocl = sim(&w, &SimRun::opencl()).total_ms();
        let gprm = sim(&w, &SimRun::gprm(100, Layout::PerPlane)).total_ms();
        assert!(omp < ocl && ocl < gprm, "1152: omp {omp:.1} < ocl {ocl:.1} < gprm {gprm:.1}");

        let w8 = paper_w(8748, Algorithm::TwoPass, Variant::Simd);
        let omp8 = sim(&w8, &SimRun::openmp(100)).total_ms();
        let ocl8 = sim(&w8, &SimRun::opencl()).total_ms();
        let gprm8 = sim(&w8, &SimRun::gprm(100, Layout::Agglomerated)).total_ms();
        assert!(gprm8 < omp8 && omp8 < ocl8, "8748: gprm {gprm8:.1} < omp {omp8:.1} < ocl {ocl8:.1}");
    }

    /// Paper Fig. 4: parallel single-pass-nocopy SIMD beats parallel
    /// two-pass SIMD (≈1.2×) even though sequentially two-pass wins 1.6×.
    #[test]
    fn fig4_crossover() {
        let size = 5832;
        let seq_sp = sim(&paper_w(size, Algorithm::SinglePassNoCopy, Variant::Simd), &SimRun::sequential()).total_ms();
        let seq_tp = sim(&paper_w(size, Algorithm::TwoPass, Variant::Simd), &SimRun::sequential()).total_ms();
        assert!(seq_tp < seq_sp, "sequential: two-pass must win");
        let par_sp = sim(&paper_w(size, Algorithm::SinglePassNoCopy, Variant::Simd), &SimRun::openmp(100)).total_ms();
        let par_tp = sim(&paper_w(size, Algorithm::TwoPass, Variant::Simd), &SimRun::openmp(100)).total_ms();
        assert!(par_sp < par_tp, "parallel: single-pass-nocopy must win ({par_sp:.1} vs {par_tp:.1})");
    }

    /// Figure 1 ladder: monotone improvement Opt-0 → Par-4, with the
    /// paper's approximate gains.
    #[test]
    fn fig1_ladder_monotone() {
        let size = 5832;
        let base = sim(&paper_w(size, Algorithm::SinglePassCopyBack, Variant::Naive), &SimRun::sequential()).total_ms();
        let opt1 = sim(&paper_w(size, Algorithm::SinglePassCopyBack, Variant::Scalar), &SimRun::sequential()).total_ms();
        let opt2 = sim(&paper_w(size, Algorithm::SinglePassCopyBack, Variant::Simd), &SimRun::sequential()).total_ms();
        let opt3 = sim(&paper_w(size, Algorithm::TwoPass, Variant::Scalar), &SimRun::sequential()).total_ms();
        let opt4 = sim(&paper_w(size, Algorithm::TwoPass, Variant::Simd), &SimRun::sequential()).total_ms();
        let par4 = sim(&paper_w(size, Algorithm::TwoPass, Variant::Simd), &SimRun::openmp(100)).total_ms();
        assert!(base > opt1 && opt1 > opt2, "unroll then simd improve");
        assert!(opt1 > opt3 && opt3 > opt4, "two-pass improves each rung");
        assert!(opt4 > par4, "parallelism improves");
        let g1 = base / opt1;
        assert!((2.0..3.0).contains(&g1), "Opt-1 gain {g1:.1} (paper 2.5)");
        let g2 = base / opt2;
        assert!((15.0..30.0).contains(&g2), "Opt-2 gain {g2:.1} (paper 22)");
        let g4 = base / opt4;
        assert!((30.0..70.0).contains(&g4), "Opt-4 gain {g4:.1} (paper 47)");
    }

    /// Paper section 7: "the results of the OpenCL kernel for the
    /// single-pass implementation are on average about 50% slower than
    /// for the two-pass implementation".
    #[test]
    fn opencl_singlepass_slower_than_twopass() {
        let mut ratios = vec![];
        for size in [3888usize, 5832, 8748] {
            let sp = sim(&paper_w(size, Algorithm::SinglePassNoCopy, Variant::Simd), &SimRun::opencl()).total_ms();
            let tp = sim(&paper_w(size, Algorithm::TwoPass, Variant::Simd), &SimRun::opencl()).total_ms();
            ratios.push(sp / tp);
        }
        let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!((1.1..2.5).contains(&avg), "avg sp/tp ratio {avg:.2} (paper ≈ 1.5)");
    }

    #[test]
    fn gprm_cutoff_below_threads_limits_concurrency() {
        let w = paper_w(2592, Algorithm::TwoPass, Variant::Scalar);
        let few = sim(&w, &SimRun::gprm(10, Layout::PerPlane));
        let many = sim(&w, &SimRun::gprm(100, Layout::PerPlane));
        assert!(few.compute_ms > many.compute_ms, "10 tasks can only use 10 workers");
    }
}
