//! Analytic timing simulator of the Intel Xeon Phi 5110P testbed.
//!
//! The paper's hardware (60-core Phi, Intel compilers, MIC OpenCL, GPRM)
//! is unavailable (DESIGN.md §1), so this module substitutes a calibrated
//! cost model that regenerates the paper's evaluation — Tables 1–2 and
//! Figures 1–4 — from first principles plus a small set of constants
//! calibrated against the paper's *own* published measurements.
//!
//! ## Model
//!
//! Per-image time is estimated as
//!
//! ```text
//! total = compute + memory + overhead
//! compute  = flops / (e(rung) · f_clock · threads)
//! memory   = traffic / min(threads · bw_thread, bw_peak(model))
//! overhead = per-model dispatch cost × dispatches(layout, algorithm)
//! ```
//!
//! The *additive* (non-overlapping) roofline reflects the Phi's in-order
//! cores, which do not hide memory latency behind compute the way OoO
//! cores do; the paper's own observation that the workload is "heavily
//! memory-fetch bound" while still scaling with vectorisation is exactly
//! this regime.
//!
//! ## Calibration provenance (every constant traceable to the paper)
//!
//! * `e_naive` — Opt-0 sequential rate, from the ≈2000× headline spread.
//! * `e_unrolled = 2.5 × e_naive` — the paper's Opt-1 gain.
//! * `e_simd` — from the Opt-2 gain (22×) = 16-lane VPU at ~55 % issue.
//! * `bw_thread` ≈ 5.5 GB/s, `bw_peak` ≈ 80 GB/s — back-computed from
//!   Table 1's OpenMP SIMD column (63.7 MB of two-pass traffic in 0.8 ms
//!   at 1152²; 3.67 GB in 59.2 ms at 8748²).
//! * OpenCL: 0.3 ms enqueue (paper: "0.25–0.4 ms"), per-work-item
//!   indexing cost and a 0.75 efficiency factor — from Table 1's
//!   OpenCL columns ("OpenMP vectorisation is more efficient…").
//! * GPRM: 40 µs/task + graph setup — from the paper's measured 25.5 ms
//!   per R×C image (6 dispatches × 100 tasks) and 8.5 ms agglomerated
//!   (2 dispatches); compute factors from Table 2's GPRM-compute column.
//!
//! Calibrating *sequential* rates and *overhead* constants from the paper
//! and then **predicting** the parallel tables is the validation: the
//! harness (`bench-table`) prints simulated vs paper values side by side
//! and EXPERIMENTS.md records the deltas.

mod calibration;
mod estimate;

pub use calibration::{Calibration, PhiMachine};
pub use estimate::{simulate, Estimate, SimModel, SimRun, SimWorkload};
