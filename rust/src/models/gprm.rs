//! GPRM-style execution model: pure task-based scheduling with cutoff,
//! compile-time initial mapping and work stealing.
//!
//! The Glasgow Parallel Reduction Machine (paper section 3.3, Listings
//! 3/4) abstracts threads away entirely: the programmer chooses a task
//! *cutoff* (number of task instances); the runtime pins one thread per
//! core and distributes tasks. GPRM combines "compile-time (source to
//! IR) and runtime (stealing) techniques": the initial task→thread
//! mapping is decided statically, then idle threads steal.
//!
//! This model reproduces that structure:
//!
//! * `dispatch` first **creates `cutoff` task instances** — each task is
//!   `par_cont_for(ind)`: rows `[n·ind/cutoff, n·(ind+1)/cutoff)`;
//! * tasks are placed round-robin onto per-thread deques (the
//!   compile-time mapping of instance → thread tile);
//! * every worker drains its own deque LIFO, then **steals** FIFO from
//!   the next occupied victim ("steal locally, share globally");
//! * the barrier at the end is the `#pragma gprm seq` boundary between
//!   the horizontal-tasks and vertical-tasks phases.
//!
//! The per-dispatch task-graph construction and deque traffic is GPRM's
//! real, measurable fixed overhead — the quantity the paper isolates as
//! 25.5 ms/image on the Phi (Table 2) and cuts to a third by task
//! agglomeration (Fig. 3). `overhead_probe` measures it the same way
//! (empty tasks).

use std::collections::VecDeque;
use std::sync::Mutex;

use super::pool::WorkerPool;
use super::{static_chunk, ExecutionModel, Tile, TileGrid, TileSpec};

/// Victim-selection policy for work stealing (ablation subject; the
/// GPRM papers describe "steal locally, share globally" ring order, and
/// the Intel OpenMP task runtime the paper contrasts uses random
/// victims).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StealPolicy {
    /// scan victims in ring order from the thief's tile
    Ring,
    /// probe victims pseudo-randomly (seeded per dispatch, deterministic)
    Random,
}

pub struct GprmModel {
    pool: WorkerPool,
    cutoff: usize,
    steal: StealPolicy,
    /// tiles fused per task instance under `dispatch2d` (the paper's
    /// task-agglomeration factor; 1 = one task per tile)
    agglomeration: usize,
}

impl GprmModel {
    /// GPRM pins threads = cores at startup; `cutoff` is chosen per
    /// program (the paper's magic number is 100). Ring stealing, no
    /// tile agglomeration.
    pub fn new(threads: usize, cutoff: usize) -> Self {
        Self::with_policy(threads, cutoff, StealPolicy::Ring)
    }

    pub fn with_policy(threads: usize, cutoff: usize, steal: StealPolicy) -> Self {
        assert!(cutoff > 0, "cutoff must be ≥ 1");
        Self { pool: WorkerPool::new(threads), cutoff, steal, agglomeration: 1 }
    }

    /// Set the `dispatch2d` agglomeration factor: how many tiles each
    /// task instance fuses (the knob the paper's Fig. 3 experiment
    /// turns). Builder-style; 1 = maximally fine-grained.
    pub fn with_agglomeration(mut self, factor: usize) -> Self {
        assert!(factor >= 1, "agglomeration factor must be ≥ 1");
        self.agglomeration = factor;
        self
    }

    pub fn cutoff(&self) -> usize {
        self.cutoff
    }

    pub fn agglomeration(&self) -> usize {
        self.agglomeration
    }

    pub fn steal_policy(&self) -> StealPolicy {
        self.steal
    }

    /// A copy of this model with a different cutoff, sharing nothing
    /// (new thread tiles) — used by the cutoff-sweep ablation.
    pub fn with_cutoff(&self, cutoff: usize) -> Self {
        Self::with_policy(self.pool.len(), cutoff, self.steal)
            .with_agglomeration(self.agglomeration)
    }

    /// A copy with a different agglomeration factor (new thread tiles) —
    /// used by the autotune sweep.
    pub fn respawn_with_agglomeration(&self, factor: usize) -> Self {
        Self::with_policy(self.pool.len(), self.cutoff, self.steal).with_agglomeration(factor)
    }

    /// Task instances a `dispatch2d` over `n_tiles` creates: tiles
    /// fused `agglomeration` at a time (the 2-D analogue of `cutoff`).
    pub fn agglomerated_cutoff(&self, n_tiles: usize) -> usize {
        n_tiles.div_ceil(self.agglomeration)
    }

    /// The shared GPRM machinery: build `cutoff` task instances, map
    /// them round-robin onto per-thread deques (the compile-time
    /// mapping), then let every worker drain its own tile LIFO and
    /// steal FIFO per the policy. `run(ind)` executes task `ind`.
    fn run_graph(&self, cutoff: usize, run: &(dyn Fn(usize) + Sync)) {
        let t = self.pool.len();
        // --- "compile time": build the task instances and the initial
        // round-robin mapping onto thread tiles -------------------------
        let deques: Vec<Mutex<VecDeque<usize>>> =
            (0..t).map(|_| Mutex::new(VecDeque::new())).collect();
        for ind in 0..cutoff {
            deques[ind % t].lock().unwrap().push_back(ind);
        }
        // --- runtime: drain own tile, then steal ------------------------
        let steal = self.steal;
        self.pool.broadcast(&|id| {
            // own tasks, LIFO (hot cache end)
            loop {
                let task = deques[id].lock().unwrap().pop_back();
                match task {
                    Some(ind) => run(ind),
                    None => break,
                }
            }
            // steal from other tiles, FIFO (cold end)
            match steal {
                StealPolicy::Ring => {
                    for off in 1..t {
                        let victim = (id + off) % t;
                        drain_victim(&deques[victim], run);
                    }
                }
                StealPolicy::Random => {
                    // deterministic per-thief probe order (seeded PRNG);
                    // 2t probes then a ring sweep to guarantee drain
                    let mut rng = crate::util::prng::Prng::new(0x57EA1 ^ id as u64);
                    for _ in 0..2 * t {
                        let victim = rng.below(t);
                        if victim != id {
                            drain_victim(&deques[victim], run);
                        }
                    }
                    for off in 1..t {
                        drain_victim(&deques[(id + off) % t], run);
                    }
                }
            }
        });
    }
}

impl ExecutionModel for GprmModel {
    fn name(&self) -> &'static str {
        "GPRM"
    }

    fn workers(&self) -> usize {
        self.pool.len()
    }

    fn dispatch(&self, n: usize, job: &(dyn Fn(usize, usize) + Sync)) {
        let cutoff = self.cutoff;
        // task `ind` is `par_cont_for(ind)`: its contiguous share of the
        // `n` rows (paper Listing 3)
        self.run_graph(cutoff, &|ind| {
            let (r0, r1) = static_chunk(n, cutoff, ind);
            if r0 < r1 {
                job(r0, r1);
            }
        });
    }

    fn dispatch2d(&self, rows: usize, cols: usize, tile: TileSpec, job: &(dyn Fn(Tile) + Sync)) {
        // the cutoff of the 2-D graph is derived from the tile count:
        // each task instance fuses `agglomeration` consecutive tiles of
        // the row-major enumeration — exactly the paper's agglomeration
        // experiment, where coarsening tasks amortises graph overhead
        let grid = TileGrid::new(rows, cols, tile);
        let n_tiles = grid.len();
        if n_tiles == 0 {
            return;
        }
        let cutoff = self.agglomerated_cutoff(n_tiles);
        self.run_graph(cutoff, &|ind| {
            let (t0, t1) = static_chunk(n_tiles, cutoff, ind);
            for t in t0..t1 {
                job(grid.tile(t));
            }
        });
    }
}

/// Steal every currently-queued task of one victim tile.
#[inline]
fn drain_victim(deque: &Mutex<VecDeque<usize>>, run: &(dyn Fn(usize) + Sync)) {
    loop {
        let task = deque.lock().unwrap().pop_front();
        match task {
            Some(ind) => run(ind),
            None => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn covers_rows_exactly_once() {
        for cutoff in [1usize, 7, 100, 480] {
            let m = GprmModel::new(6, cutoff);
            let hits = Mutex::new(vec![0u32; 241]);
            m.dispatch(241, &|a, b| {
                let mut h = hits.lock().unwrap();
                for i in a..b {
                    h[i] += 1;
                }
            });
            assert!(
                hits.lock().unwrap().iter().all(|&h| h == 1),
                "cutoff {cutoff}"
            );
        }
    }

    #[test]
    fn task_count_equals_cutoff() {
        let m = GprmModel::new(4, 100);
        let count = Mutex::new(0usize);
        m.dispatch(1000, &|_, _| *count.lock().unwrap() += 1);
        assert_eq!(*count.lock().unwrap(), 100);
    }

    #[test]
    fn cutoff_larger_than_rows() {
        // tasks with empty row shares simply don't fire
        let m = GprmModel::new(4, 100);
        let count = Mutex::new(0usize);
        m.dispatch(10, &|a, b| {
            assert!(a < b);
            *count.lock().unwrap() += b - a;
        });
        assert_eq!(*count.lock().unwrap(), 10);
    }

    #[test]
    fn stealing_rebalances_skewed_load() {
        // All heavy tasks map to tile 0 (cutoff = threads means task i →
        // tile i; make task 0 slow): other threads must steal... here we
        // instead make every task sleep and check wall-clock beats serial.
        let threads = 4;
        let m = GprmModel::new(threads, 8);
        let t0 = std::time::Instant::now();
        m.dispatch(8, &|_, _| std::thread::sleep(std::time::Duration::from_millis(5)));
        let elapsed = t0.elapsed().as_millis() as f64;
        // serial would be 40ms; 4 threads ≈ 10ms + overhead
        assert!(elapsed < 30.0, "elapsed {elapsed}ms — no parallelism?");
    }

    #[test]
    fn with_cutoff_changes_granularity() {
        let m = GprmModel::new(2, 10);
        let m2 = m.with_cutoff(3);
        assert_eq!(m2.cutoff(), 3);
        let count = Mutex::new(0usize);
        m2.dispatch(100, &|_, _| *count.lock().unwrap() += 1);
        assert_eq!(*count.lock().unwrap(), 3);
    }

    #[test]
    fn random_steal_policy_covers_exactly_once() {
        for threads in [1usize, 3, 6] {
            let m = GprmModel::with_policy(threads, 50, StealPolicy::Random);
            let hits = Mutex::new(vec![0u32; 137]);
            m.dispatch(137, &|a, b| {
                let mut h = hits.lock().unwrap();
                for i in a..b {
                    h[i] += 1;
                }
            });
            assert!(
                hits.lock().unwrap().iter().all(|&h| h == 1),
                "threads {threads}"
            );
        }
    }

    #[test]
    fn steal_policies_same_pixels() {
        use crate::conv::{convolve_image, Algorithm, Variant};
        use crate::image::{gaussian_kernel, synth_image, Pattern};
        use crate::models::{convolve_parallel, Layout};
        let img = synth_image(3, 30, 26, Pattern::Noise, 3);
        let k = gaussian_kernel(5, 1.0);
        let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        for policy in [StealPolicy::Ring, StealPolicy::Random] {
            let m = GprmModel::with_policy(4, 23, policy);
            let got = convolve_parallel(&m, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane).unwrap();
            assert_eq!(got, want, "{policy:?}");
        }
    }

    #[test]
    fn dispatch2d_covers_exactly_once_across_agglomeration() {
        for agglomeration in [1usize, 3, 16, 1000] {
            let m = GprmModel::new(5, 50).with_agglomeration(agglomeration);
            let (rows, cols) = (31, 27);
            let hits = Mutex::new(vec![0u32; rows * cols]);
            m.dispatch2d(rows, cols, TileSpec::new(3, 5), &|t| {
                let mut h = hits.lock().unwrap();
                for i in t.r0..t.r1 {
                    for j in t.c0..t.c1 {
                        h[i * cols + j] += 1;
                    }
                }
            });
            assert!(
                hits.lock().unwrap().iter().all(|&h| h == 1),
                "agglomeration {agglomeration}"
            );
        }
    }

    #[test]
    fn agglomeration_fuses_tiles_into_tasks() {
        // 24x24 in 4x4 tiles = 36 tiles; agglomeration 6 ⇒ 6 task
        // instances, each running 6 consecutive tiles
        let m = GprmModel::new(4, 100).with_agglomeration(6);
        assert_eq!(m.agglomeration(), 6);
        assert_eq!(m.agglomerated_cutoff(36), 6);
        assert_eq!(m.agglomerated_cutoff(37), 7); // ragged tail gets a task
        assert_eq!(m.respawn_with_agglomeration(2).agglomeration(), 2);
        assert_eq!(m.with_cutoff(7).agglomeration(), 6, "with_cutoff keeps the factor");
        let count = Mutex::new(0usize);
        m.dispatch2d(24, 24, TileSpec::new(4, 4), &|_| *count.lock().unwrap() += 1);
        assert_eq!(*count.lock().unwrap(), 36, "every tile runs exactly once");
    }

    #[test]
    fn dispatch2d_empty_grid_is_noop() {
        let m = GprmModel::new(3, 10);
        m.dispatch2d(0, 8, TileSpec::new(2, 2), &|_| panic!("no tile expected"));
    }

    #[test]
    fn overhead_grows_with_cutoff() {
        // More tasks ⇒ more graph construction + deque traffic. Use a
        // wide margin: timing tests must not flake.
        let m_small = GprmModel::new(4, 4);
        let m_large = GprmModel::new(4, 4096);
        let small = m_small.overhead_probe(1 << 20, 15).median();
        let large = m_large.overhead_probe(1 << 20, 15).median();
        assert!(
            large > small,
            "4096-task dispatch ({large:.4}ms) should out-cost 4-task ({small:.4}ms)"
        );
    }
}
