//! The paper's three parallel programming models as pluggable execution
//! engines.
//!
//! Each model answers the same question — *how do `n` rows of work get
//! scheduled onto a fixed set of worker threads?* — with the discipline
//! of its namesake (DESIGN.md §1):
//!
//! * [`OpenMpModel`] — `#pragma omp parallel for schedule(static)`:
//!   fork-join over a persistent team, one contiguous chunk per thread,
//!   implicit barrier.
//! * [`OpenClModel`] — NDRange: the row space is covered by work-groups
//!   (`local_size` rows each) drained from a command queue by
//!   compute-unit threads; scheduling is dynamic, runtime-managed.
//! * [`GprmModel`] — pure task-based: `cutoff` task instances are created
//!   up front, mapped round-robin to thread tiles ("compile-time"
//!   mapping), executed with work stealing; `par_cont_for` index → row
//!   range, phases composed sequentially (`#pragma gprm seq`).
//!
//! All models guarantee the same contract: `dispatch(n, job)` invokes
//! `job` over a **disjoint cover** of `[0, n)` and returns after an
//! implicit barrier. Pixel-level equivalence with the sequential engines
//! is enforced by integration tests; cover-exactness by property tests.

pub mod convolve;
pub mod gprm;
pub mod opencl;
pub mod openmp;
pub mod pool;

pub use convolve::{convolve_parallel, convolve_plane_parallel, Layout};
pub use gprm::{GprmModel, StealPolicy};
pub use opencl::OpenClModel;
pub use openmp::{OpenMpModel, Schedule};

use crate::metrics::SampleSet;

/// A parallel execution model: schedules row-range jobs onto workers.
pub trait ExecutionModel: Send + Sync {
    /// Short name for tables ("OpenMP", "OpenCL", "GPRM").
    fn name(&self) -> &'static str;

    /// Worker threads backing the model.
    fn workers(&self) -> usize;

    /// Execute `job(r0, r1)` over a disjoint cover of `[0, n)`, barrier,
    /// return. Implementations choose the partition and the schedule.
    fn dispatch(&self, n: usize, job: &(dyn Fn(usize, usize) + Sync));

    /// Measure the model's fixed dispatch overhead: time `reps` empty
    /// dispatches of the same shape and return per-dispatch ms.
    ///
    /// This is exactly the paper's methodology for Table 2 ("we can
    /// create empty tasks and measure the overhead of distributing them
    /// across different threads").
    fn overhead_probe(&self, n: usize, reps: usize) -> SampleSet {
        crate::metrics::time_reps(|| self.dispatch(n, &|_, _| {}), 2, reps)
    }
}

/// The partition used by static schedulers: chunk `t` of `parts` covers
/// `[n·t/parts, n·(t+1)/parts)` — contiguous, balanced to ±1 row,
/// exactly OpenMP's `schedule(static)` / GPRM's `par_cont_for`.
pub fn static_chunk(n: usize, parts: usize, t: usize) -> (usize, usize) {
    (n * t / parts, n * (t + 1) / parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_chunk_covers_exactly() {
        for n in [0usize, 1, 7, 100, 241] {
            for parts in [1usize, 3, 16, 100] {
                let mut covered = vec![0u8; n];
                for t in 0..parts {
                    let (a, b) = static_chunk(n, parts, t);
                    assert!(a <= b && b <= n);
                    for c in covered.iter_mut().take(b).skip(a) {
                        *c += 1;
                    }
                }
                assert!(covered.iter().all(|&c| c == 1), "n={n} parts={parts}");
            }
        }
    }

    #[test]
    fn static_chunk_balanced() {
        let n = 103;
        let parts = 10;
        for t in 0..parts {
            let (a, b) = static_chunk(n, parts, t);
            let len = b - a;
            assert!(len == 10 || len == 11, "chunk {t} has {len}");
        }
    }
}
