//! The paper's three parallel programming models as pluggable execution
//! engines.
//!
//! Each model answers the same question — *how do `n` rows of work get
//! scheduled onto a fixed set of worker threads?* — with the discipline
//! of its namesake (DESIGN.md §1):
//!
//! * [`OpenMpModel`] — `#pragma omp parallel for schedule(static)`:
//!   fork-join over a persistent team, one contiguous chunk per thread,
//!   implicit barrier.
//! * [`OpenClModel`] — NDRange: the row space is covered by work-groups
//!   (`local_size` rows each) drained from a command queue by
//!   compute-unit threads; scheduling is dynamic, runtime-managed.
//! * [`GprmModel`] — pure task-based: `cutoff` task instances are created
//!   up front, mapped round-robin to thread tiles ("compile-time"
//!   mapping), executed with work stealing; `par_cont_for` index → row
//!   range, phases composed sequentially (`#pragma gprm seq`).
//!
//! All models guarantee the same contract: `dispatch(n, job)` invokes
//! `job` over a **disjoint cover** of `[0, n)` and returns after an
//! implicit barrier; `dispatch2d(rows, cols, tile, job)` does the same
//! over a **disjoint tile cover** of the `rows × cols` grid (the
//! agglomeration axis — see [`tile`]). Pixel-level equivalence with the
//! sequential engines is enforced by integration tests; cover-exactness
//! by property tests (`tests/tiling.rs`).

pub mod convolve;
pub mod gprm;
pub mod opencl;
pub mod openmp;
pub mod pool;
pub mod tile;

pub use convolve::{convolve_parallel, convolve_plane_parallel, Layout};
pub use gprm::{GprmModel, StealPolicy};
pub use opencl::OpenClModel;
pub use openmp::{OpenMpModel, Schedule};
pub use tile::{Tile, TileGrid, TileSpec};

use crate::metrics::SampleSet;

/// A parallel execution model: schedules row-range jobs onto workers.
pub trait ExecutionModel: Send + Sync {
    /// Short name for tables ("OpenMP", "OpenCL", "GPRM").
    fn name(&self) -> &'static str;

    /// Worker threads backing the model.
    fn workers(&self) -> usize;

    /// Execute `job(r0, r1)` over a disjoint cover of `[0, n)`, barrier,
    /// return. Implementations choose the partition and the schedule.
    fn dispatch(&self, n: usize, job: &(dyn Fn(usize, usize) + Sync));

    /// Execute `job(tile)` over a disjoint tile cover of the
    /// `rows × cols` grid, barrier, return (see [`TileGrid`] for the
    /// decomposition). The default adapter linearises the grid row-major
    /// and reuses `dispatch`'s 1-D schedule over tile indices; the three
    /// models override it natively — OpenMP stripes contiguous tile-rows
    /// per thread, OpenCL drains one tile per work-group from the
    /// command queue, GPRM agglomerates tiles into task instances.
    fn dispatch2d(&self, rows: usize, cols: usize, tile: TileSpec, job: &(dyn Fn(Tile) + Sync)) {
        let grid = TileGrid::new(rows, cols, tile);
        if grid.is_empty() {
            return;
        }
        self.dispatch(grid.len(), &|t0, t1| {
            for t in t0..t1 {
                job(grid.tile(t));
            }
        });
    }

    /// Measure the model's fixed dispatch overhead: time `reps` empty
    /// dispatches of the same shape and return per-dispatch ms.
    ///
    /// This is exactly the paper's methodology for Table 2 ("we can
    /// create empty tasks and measure the overhead of distributing them
    /// across different threads"). Warmup honours `PHI_BENCH_WARMUP`
    /// (default 2); use [`ExecutionModel::overhead_probe_with`] to pin
    /// it explicitly.
    fn overhead_probe(&self, n: usize, reps: usize) -> SampleSet {
        self.overhead_probe_with(n, overhead_warmup(), reps)
    }

    /// [`ExecutionModel::overhead_probe`] with an explicit warmup count
    /// (the harness passes its configured `RunConfig::warmup`).
    fn overhead_probe_with(&self, n: usize, warmup: usize, reps: usize) -> SampleSet {
        crate::metrics::time_reps(|| self.dispatch(n, &|_, _| {}), warmup, reps)
    }

    /// The empty-task probe at tile granularity: time `reps` empty
    /// `dispatch2d` calls of the given shape — the paper's Table-2
    /// methodology applied to the agglomeration experiment (more tiles
    /// per dispatch ⇒ more scheduling traffic to measure).
    fn overhead_probe2d(
        &self,
        rows: usize,
        cols: usize,
        tile: TileSpec,
        warmup: usize,
        reps: usize,
    ) -> SampleSet {
        crate::metrics::time_reps(|| self.dispatch2d(rows, cols, tile, &|_| {}), warmup, reps)
    }
}

/// Warmup count for [`ExecutionModel::overhead_probe`]: the
/// `PHI_BENCH_WARMUP` knob every measured bench honours (previously a
/// hardcoded 2 that silently ignored the env), defaulting to 2.
/// `RunConfig::from_bench_env` funnels through this too, so probe and
/// bench agree on what the knob means.
pub fn overhead_warmup() -> usize {
    parse_overhead_warmup(std::env::var("PHI_BENCH_WARMUP").ok())
}

/// Parse rule behind [`overhead_warmup`] (separate so tests never have
/// to mutate process-global env vars).
pub(crate) fn parse_overhead_warmup(v: Option<String>) -> usize {
    v.and_then(|v| v.parse().ok()).unwrap_or(2)
}

/// Worker-thread count for scheduling tests: `PHI_THREADS` if set (the
/// CI matrix runs the suite at 1 and 4 to exercise both the serial and
/// the contended paths), else `default`.
pub fn test_threads(default: usize) -> usize {
    parse_test_threads(std::env::var("PHI_THREADS").ok(), default)
}

/// Parse rule behind [`test_threads`]: nonsense values (unparsable, or
/// 0 — pools need at least one worker) fall back to the default.
pub(crate) fn parse_test_threads(v: Option<String>, default: usize) -> usize {
    v.and_then(|v| v.parse().ok()).filter(|&n: &usize| n >= 1).unwrap_or(default)
}

/// The partition used by static schedulers: chunk `t` of `parts` covers
/// `[n·t/parts, n·(t+1)/parts)` — contiguous, balanced to ±1 row,
/// exactly OpenMP's `schedule(static)` / GPRM's `par_cont_for`.
pub fn static_chunk(n: usize, parts: usize, t: usize) -> (usize, usize) {
    (n * t / parts, n * (t + 1) / parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_chunk_covers_exactly() {
        for n in [0usize, 1, 7, 100, 241] {
            for parts in [1usize, 3, 16, 100] {
                let mut covered = vec![0u8; n];
                for t in 0..parts {
                    let (a, b) = static_chunk(n, parts, t);
                    assert!(a <= b && b <= n);
                    for c in covered.iter_mut().take(b).skip(a) {
                        *c += 1;
                    }
                }
                assert!(covered.iter().all(|&c| c == 1), "n={n} parts={parts}");
            }
        }
    }

    #[test]
    fn static_chunk_balanced() {
        let n = 103;
        let parts = 10;
        for t in 0..parts {
            let (a, b) = static_chunk(n, parts, t);
            let len = b - a;
            assert!(len == 10 || len == 11, "chunk {t} has {len}");
        }
    }

    #[test]
    fn default_dispatch2d_adapter_covers_exactly() {
        // any model inherits a correct dispatch2d from its dispatch; use
        // OpenMP through the default adapter explicitly
        struct Adapter(OpenMpModel);
        impl ExecutionModel for Adapter {
            fn name(&self) -> &'static str {
                "adapter"
            }
            fn workers(&self) -> usize {
                self.0.workers()
            }
            fn dispatch(&self, n: usize, job: &(dyn Fn(usize, usize) + Sync)) {
                self.0.dispatch(n, job);
            }
            // dispatch2d intentionally NOT overridden
        }
        let m = Adapter(OpenMpModel::new(3));
        let (rows, cols) = (23, 17);
        let hits = std::sync::Mutex::new(vec![0u32; rows * cols]);
        m.dispatch2d(rows, cols, TileSpec::new(4, 5), &|t| {
            let mut h = hits.lock().unwrap();
            for i in t.r0..t.r1 {
                for j in t.c0..t.c1 {
                    h[i * cols + j] += 1;
                }
            }
        });
        assert!(hits.lock().unwrap().iter().all(|&h| h == 1));
        // empty grid: no job, no panic
        m.dispatch2d(0, 10, TileSpec::new(4, 4), &|_| panic!("no tile expected"));
    }

    #[test]
    fn overhead_probe_warmup_env_knob() {
        // PHI_BENCH_WARMUP drives the probe's unrecorded runs (was a
        // hardcoded 2); the parse rule is tested purely — mutating the
        // process env would race parallel tests that call overhead_probe
        assert_eq!(parse_overhead_warmup(Some("7".into())), 7);
        assert_eq!(parse_overhead_warmup(Some("not-a-number".into())), 2);
        assert_eq!(parse_overhead_warmup(None), 2);
    }

    #[test]
    fn test_threads_env_knob() {
        // pure parse rule: no process-global env mutation in tests
        assert_eq!(parse_test_threads(Some("3".into()), 8), 3);
        assert_eq!(parse_test_threads(Some("0".into()), 8), 8); // pools need >= 1
        assert_eq!(parse_test_threads(Some("bogus".into()), 8), 8);
        assert_eq!(parse_test_threads(None, 8), 8);
    }
}
