//! OpenCL-style execution model: NDRange work-groups over a command
//! queue.
//!
//! Mirrors the paper's OpenCL mapping on the Xeon Phi (section 5.4):
//! *compute units* ≈ hardware threads, *processing elements* ≈ vector
//! lanes, and the runtime — not the programmer — assigns work-groups to
//! compute units. Here:
//!
//! * the global range is the row space `[0, n)`;
//! * it is covered by work-groups of `local_size` consecutive rows
//!   (`ngroups = ceil(n / local_size)`), mirroring the paper's optimum
//!   `ngroups=236, nths=16` shape where indices are contiguous in the
//!   local id so the group vectorises;
//! * `compute_units` worker threads drain the group queue dynamically
//!   (an atomic cursor — OpenCL runtimes schedule groups to CUs as they
//!   free up, unlike OpenMP's static split);
//! * `dispatch` = `clEnqueueNDRangeKernel` + `clFinish`.
//!
//! The paper's "disable vectorisation" trick — "using only a single
//! processing element per compute unit" — is `local_size = 1` here, and
//! the vectorised/scalar band kernels plug in as the work-item body.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::pool::WorkerPool;
use super::{ExecutionModel, Tile, TileGrid, TileSpec};

pub struct OpenClModel {
    pool: WorkerPool,
    local_size: usize,
}

impl OpenClModel {
    /// `compute_units` CU threads, `local_size` rows per work-group.
    pub fn new(compute_units: usize, local_size: usize) -> Self {
        assert!(local_size > 0, "local_size must be ≥ 1");
        Self { pool: WorkerPool::new(compute_units), local_size }
    }

    pub fn local_size(&self) -> usize {
        self.local_size
    }
}

impl ExecutionModel for OpenClModel {
    fn name(&self) -> &'static str {
        "OpenCL"
    }

    fn workers(&self) -> usize {
        self.pool.len()
    }

    fn dispatch(&self, n: usize, job: &(dyn Fn(usize, usize) + Sync)) {
        let local = self.local_size;
        let ngroups = n.div_ceil(local);
        // the command queue: a cursor over group ids
        let cursor = AtomicUsize::new(0);
        self.pool.broadcast(&|_cu| loop {
            let g = cursor.fetch_add(1, Ordering::Relaxed);
            if g >= ngroups {
                break;
            }
            let r0 = g * local;
            let r1 = ((g + 1) * local).min(n);
            job(r0, r1);
        });
    }

    fn dispatch2d(&self, rows: usize, cols: usize, tile: TileSpec, job: &(dyn Fn(Tile) + Sync)) {
        // a 2-D NDRange: each tile IS one work-group (the tile shape
        // plays the role `local_size` plays in 1-D dispatch), and CU
        // threads drain groups dynamically from the command queue
        let grid = TileGrid::new(rows, cols, tile);
        if grid.is_empty() {
            return; // nothing enqueued: skip the broadcast barrier
        }
        let ngroups = grid.len();
        let cursor = AtomicUsize::new(0);
        self.pool.broadcast(&|_cu| loop {
            let g = cursor.fetch_add(1, Ordering::Relaxed);
            if g >= ngroups {
                break;
            }
            job(grid.tile(g));
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn covers_rows_exactly_once() {
        for local in [1usize, 4, 16, 64] {
            let m = OpenClModel::new(5, local);
            let hits = Mutex::new(vec![0u32; 103]);
            m.dispatch(103, &|a, b| {
                let mut h = hits.lock().unwrap();
                for i in a..b {
                    h[i] += 1;
                }
            });
            assert!(
                hits.lock().unwrap().iter().all(|&h| h == 1),
                "local_size {local}"
            );
        }
    }

    #[test]
    fn group_shapes_respect_local_size() {
        let m = OpenClModel::new(3, 16);
        let ranges = Mutex::new(vec![]);
        m.dispatch(50, &|a, b| ranges.lock().unwrap().push((a, b)));
        let mut r = ranges.lock().unwrap().clone();
        r.sort_unstable();
        assert_eq!(r, vec![(0, 16), (16, 32), (32, 48), (48, 50)]);
    }

    #[test]
    fn single_pe_mode_is_row_granular() {
        // the paper's "no-vec" OpenCL trick: one row per group
        let m = OpenClModel::new(4, 1);
        let ranges = Mutex::new(vec![]);
        m.dispatch(10, &|a, b| ranges.lock().unwrap().push((a, b)));
        let r = ranges.lock().unwrap();
        assert_eq!(r.len(), 10);
        assert!(r.iter().all(|&(a, b)| b - a == 1));
    }

    #[test]
    fn zero_rows_is_noop() {
        let m = OpenClModel::new(2, 8);
        m.dispatch(0, &|_, _| panic!("no group expected"));
    }

    #[test]
    fn dispatch2d_covers_exactly_once() {
        for tile in [TileSpec::new(1, 1), TileSpec::new(5, 8), TileSpec::new(1000, 1000)] {
            let m = OpenClModel::new(4, 16);
            let (rows, cols) = (29, 21);
            let hits = Mutex::new(vec![0u32; rows * cols]);
            m.dispatch2d(rows, cols, tile, &|t| {
                let mut h = hits.lock().unwrap();
                for i in t.r0..t.r1 {
                    for j in t.c0..t.c1 {
                        h[i * cols + j] += 1;
                    }
                }
            });
            assert!(
                hits.lock().unwrap().iter().all(|&h| h == 1),
                "tile {}",
                tile.label()
            );
        }
    }

    #[test]
    fn dispatch2d_tiles_are_workgroups() {
        // 10x10 in 4x4 tiles: 9 groups, interior ones exactly 4x4
        let m = OpenClModel::new(3, 1);
        let tiles = Mutex::new(vec![]);
        m.dispatch2d(10, 10, TileSpec::new(4, 4), &|t| tiles.lock().unwrap().push(t));
        let got = tiles.into_inner().unwrap();
        assert_eq!(got.len(), 9);
        assert!(got.iter().any(|t| t.rows() == 4 && t.cols() == 4));
        assert!(got.iter().any(|t| t.rows() == 2 && t.cols() == 2)); // corner
    }

    #[test]
    fn dispatch2d_empty_grid_is_noop() {
        let m = OpenClModel::new(2, 8);
        m.dispatch2d(0, 0, TileSpec::new(4, 4), &|_| panic!("no tile expected"));
    }

    #[test]
    fn dynamic_scheduling_balances_skew() {
        // one slow group must not serialise the rest: with 4 CUs and 8
        // groups where group 0 sleeps, wall time ≪ 8 × sleep.
        let m = OpenClModel::new(4, 1);
        let t0 = std::time::Instant::now();
        m.dispatch(8, &|a, _| {
            if a == 0 {
                std::thread::sleep(std::time::Duration::from_millis(20));
            } else {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        });
        let elapsed = t0.elapsed().as_millis();
        assert!(elapsed < 34 + 10, "elapsed {elapsed}ms suggests serialisation");
    }
}
