//! Parallel convolution driver: execution model × algorithm × variant ×
//! layout.
//!
//! This is the paper's benchmark inner loop: the outer row loop of each
//! pass is handed to an [`ExecutionModel`], the inner loops are the
//! [`crate::conv::band`] primitives. The `Layout` axis reproduces the
//! task-agglomeration study (paper section 6, Fig. 2 vs Fig. 3):
//!
//! * [`Layout::PerPlane`] — "R×C": each colour plane is a separate
//!   parallel sweep (3 sequential dispatches per pass), the paper's
//!   baseline layout;
//! * [`Layout::Agglomerated`] — "3R×C": planes are concatenated along
//!   columns, so one dispatch covers all planes; task size triples and
//!   per-dispatch overhead amortises to a third — the fix that lets GPRM
//!   win the largest image.

use crate::util::error::Result;

use crate::conv::{band, Algorithm, Variant};
use crate::image::{gaussian_kernel2d, PlanarImage};

use super::pool::RowBands;
use super::ExecutionModel;

/// Parallelisation layout (paper Figs. 2/3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Layout {
    /// R×C: per-plane sweeps, planes sequential.
    PerPlane,
    /// 3R×C: planes folded into one wide sweep (task agglomeration).
    Agglomerated,
}

impl Layout {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "rxc" | "per-plane" => Layout::PerPlane,
            "3rxc" | "agglomerated" => Layout::Agglomerated,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Layout::PerPlane => "RxC",
            Layout::Agglomerated => "3RxC",
        }
    }
}

/// One parallel pass: `model.dispatch` over the rows, each worker writing
/// its disjoint band of `dst`.
fn parallel_pass(
    model: &dyn ExecutionModel,
    rows: usize,
    cols: usize,
    src: &[f32],
    dst: &mut [f32],
    pass: &(dyn Fn(&[f32], &mut [f32], usize, usize) + Sync),
) {
    let bands = RowBands::new(dst, rows, cols);
    model.dispatch(rows, &|r0, r1| {
        // SAFETY: execution models dispatch disjoint covers of [0, rows)
        // (property-tested), so bands never overlap.
        let band = unsafe { bands.band(r0, r1) };
        pass(src, band, r0, r1);
    });
}

/// Convolve one plane in parallel. `a` is the source/result buffer, `b`
/// the scratch; semantics identical to [`crate::conv::convolve_plane`].
#[allow(clippy::too_many_arguments)]
pub fn convolve_plane_parallel(
    model: &dyn ExecutionModel,
    a: &mut [f32],
    b: &mut [f32],
    rows: usize,
    cols: usize,
    k: &[f32],
    algorithm: Algorithm,
    variant: Variant,
) -> Result<()> {
    if k.len() != 5 && variant != Variant::Naive {
        bail!("unrolled engines are specialised to width 5, got {}", k.len());
    }
    let k2d = gaussian_kernel2d(k);
    let k5: &[f32; 5] = if k.len() == 5 { k.try_into().unwrap() } else { &[0.0; 5] };
    let k25: &[f32; 25] = if k.len() == 5 { k2d.as_slice().try_into().unwrap() } else { &[0.0; 25] };

    match algorithm {
        Algorithm::TwoPass => {
            // horizontal a→b, barrier, vertical b→a (the paper's two
            // `#pragma omp parallel for` regions / GPRM's `seq` phases).
            match variant {
                Variant::Naive => bail!("the paper's naive rung is single-pass only"),
                Variant::Scalar => {
                    parallel_pass(model, rows, cols, a, b, &|s, d, r0, r1| {
                        band::horiz_band_scalar(s, d, rows, cols, k5, r0, r1)
                    });
                    parallel_pass(model, rows, cols, b, a, &|s, d, r0, r1| {
                        band::vert_band_scalar(s, d, rows, cols, k5, r0, r1)
                    });
                }
                Variant::Simd => {
                    parallel_pass(model, rows, cols, a, b, &|s, d, r0, r1| {
                        band::horiz_band_simd(s, d, rows, cols, k5, r0, r1)
                    });
                    parallel_pass(model, rows, cols, b, a, &|s, d, r0, r1| {
                        band::vert_band_simd(s, d, rows, cols, k5, r0, r1)
                    });
                }
            }
        }
        Algorithm::SinglePassCopyBack | Algorithm::SinglePassNoCopy => {
            let width = k.len();
            match variant {
                Variant::Naive => {
                    parallel_pass(model, rows, cols, a, b, &|s, d, r0, r1| {
                        band::singlepass_naive_band(s, d, rows, cols, &k2d, width, r0, r1)
                    });
                }
                Variant::Scalar => {
                    parallel_pass(model, rows, cols, a, b, &|s, d, r0, r1| {
                        band::singlepass_band_scalar(s, d, rows, cols, k25, r0, r1)
                    });
                }
                Variant::Simd => {
                    parallel_pass(model, rows, cols, a, b, &|s, d, r0, r1| {
                        band::singlepass_band_simd(s, d, rows, cols, k25, r0, r1)
                    });
                }
            }
            if algorithm == Algorithm::SinglePassCopyBack {
                // the copy-back is parallelised + vectorised too (paper
                // Par-2: "both convolution computation and the copy-back").
                match variant {
                    Variant::Simd => parallel_pass(model, rows, cols, b, a, &|s, d, r0, r1| {
                        band::copy_back_band_simd(s, d, cols, r0, r1)
                    }),
                    _ => parallel_pass(model, rows, cols, b, a, &|s, d, r0, r1| {
                        band::copy_back_band_scalar(s, d, cols, r0, r1)
                    }),
                }
            }
        }
    }
    Ok(())
}

/// Parallel convolution into caller-owned buffers (perf pass,
/// EXPERIMENTS.md §Perf iteration 1: avoids the two per-call image
/// allocations + first-touch faults). Returns the workspace slice
/// holding the result — plane-major `(P,R,C)` for `PerPlane`, wide
/// `(R, P·C)` for `Agglomerated`.
pub fn convolve_parallel_into<'ws>(
    ws: &'ws mut crate::conv::Workspace,
    model: &dyn ExecutionModel,
    img: &PlanarImage,
    k: &[f32],
    algorithm: Algorithm,
    variant: Variant,
    layout: Layout,
) -> Result<&'ws [f32]> {
    match layout {
        Layout::PerPlane => {
            ws.load(img);
            let (rows, cols) = (img.rows, img.cols);
            let plane_len = rows * cols;
            for p in 0..img.planes {
                let a = &mut ws.a[p * plane_len..(p + 1) * plane_len];
                let b = &mut ws.b[p * plane_len..(p + 1) * plane_len];
                convolve_plane_parallel(model, a, b, rows, cols, k, algorithm, variant)?;
            }
            Ok(match algorithm {
                Algorithm::SinglePassNoCopy => &ws.b,
                _ => &ws.a,
            })
        }
        Layout::Agglomerated => {
            let (rows, cols) = (img.rows, img.planes * img.cols);
            // agglomerate into the wide buffers without reallocating
            ws.wide_a.clear();
            let wc = cols;
            for i in 0..rows {
                for p in 0..img.planes {
                    let plane = img.plane(p);
                    ws.wide_a.extend_from_slice(&plane[i * img.cols..(i + 1) * img.cols]);
                }
            }
            debug_assert_eq!(ws.wide_a.len(), rows * wc);
            ws.wide_b.clear();
            ws.wide_b.extend_from_slice(&ws.wide_a);
            convolve_plane_parallel(
                model,
                &mut ws.wide_a,
                &mut ws.wide_b,
                rows,
                cols,
                k,
                algorithm,
                variant,
            )?;
            Ok(match algorithm {
                Algorithm::SinglePassNoCopy => &ws.wide_b,
                _ => &ws.wide_a,
            })
        }
    }
}

/// Convolve a whole image in parallel under a layout. Returns the
/// convolved image; pixels are identical to the sequential
/// [`crate::conv::convolve_image`] for `PerPlane`, and identical away
/// from plane seams for `Agglomerated` (DESIGN.md §4).
pub fn convolve_parallel(
    model: &dyn ExecutionModel,
    img: &PlanarImage,
    k: &[f32],
    algorithm: Algorithm,
    variant: Variant,
    layout: Layout,
) -> Result<PlanarImage> {
    match layout {
        Layout::PerPlane => {
            let mut a_img = img.clone();
            let mut b_img = img.clone(); // B starts as a copy of A (DESIGN.md §4)
            let (rows, cols) = (img.rows, img.cols);
            for p in 0..img.planes {
                let a = a_img.plane_mut(p);
                // disjoint planes: borrow b plane via split or clone view
                let b = b_img.plane_mut(p);
                convolve_plane_parallel(model, a, b, rows, cols, k, algorithm, variant)?;
            }
            Ok(match algorithm {
                Algorithm::SinglePassNoCopy => b_img,
                _ => a_img,
            })
        }
        Layout::Agglomerated => {
            let (rows, cols) = (img.rows, img.planes * img.cols);
            let mut a = img.agglomerate();
            let mut b = a.clone();
            convolve_plane_parallel(model, &mut a, &mut b, rows, cols, k, algorithm, variant)?;
            let result = match algorithm {
                Algorithm::SinglePassNoCopy => b,
                _ => a,
            };
            PlanarImage::from_agglomerated(img.planes, img.rows, img.cols, &result)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::convolve_image;
    use crate::image::{gaussian_kernel, synth_image, Pattern};
    use crate::models::{GprmModel, OpenClModel, OpenMpModel};

    fn models() -> Vec<Box<dyn ExecutionModel>> {
        vec![
            Box::new(OpenMpModel::new(4)),
            Box::new(OpenClModel::new(4, 3)),
            Box::new(GprmModel::new(4, 13)),
        ]
    }

    #[test]
    fn all_models_match_sequential_all_algorithms() {
        let img = synth_image(3, 40, 36, Pattern::Noise, 5);
        let k = gaussian_kernel(5, 1.0);
        for alg in [Algorithm::TwoPass, Algorithm::SinglePassCopyBack, Algorithm::SinglePassNoCopy] {
            for variant in [Variant::Scalar, Variant::Simd] {
                let want = convolve_image(img.clone(), &k, alg, variant).unwrap();
                for m in models() {
                    let got =
                        convolve_parallel(m.as_ref(), &img, &k, alg, variant, Layout::PerPlane)
                            .unwrap();
                    assert_eq!(
                        got, want,
                        "{} {alg:?} {variant:?} differs from sequential",
                        m.name()
                    );
                }
            }
        }
    }

    #[test]
    fn naive_variant_parallel_matches() {
        let img = synth_image(3, 30, 28, Pattern::Noise, 6);
        let k = gaussian_kernel(5, 1.0);
        let want = convolve_image(img.clone(), &k, Algorithm::SinglePassCopyBack, Variant::Naive).unwrap();
        let m = OpenMpModel::new(3);
        let got = convolve_parallel(&m, &img, &k, Algorithm::SinglePassCopyBack, Variant::Naive, Layout::PerPlane).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-6);
    }

    #[test]
    fn agglomerated_matches_away_from_seams() {
        let img = synth_image(3, 40, 36, Pattern::Noise, 7);
        let k = gaussian_kernel(5, 1.0);
        let per = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        for m in models() {
            let agg = convolve_parallel(m.as_ref(), &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::Agglomerated).unwrap();
            // inner columns (≥ 2h from plane seams) agree
            for p in 0..3 {
                for i in 0..40 {
                    for j in 4..32 {
                        let d = (agg.get(p, i, j) - per.get(p, i, j)).abs();
                        assert!(d < 1e-5, "{} ({p},{i},{j}) d={d}", m.name());
                    }
                }
            }
        }
    }

    #[test]
    fn agglomerated_seams_differ_from_per_plane() {
        // guard: 3RxC must actually convolve across seams, not fall back
        // to per-plane (plane 1's col 0..2 is interior in the wide image)
        let img = synth_image(3, 24, 20, Pattern::Noise, 8);
        let k = gaussian_kernel(5, 1.0);
        let per = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        let m = OpenMpModel::new(2);
        let agg = convolve_parallel(&m, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::Agglomerated).unwrap();
        let mut max_d = 0f32;
        for i in 4..20 {
            for j in 0..2 {
                max_d = max_d.max((agg.get(1, i, j) - per.get(1, i, j)).abs());
            }
        }
        assert!(max_d > 1e-6, "seam columns identical — agglomeration is fake?");
    }

    #[test]
    fn into_variant_matches_alloc_variant() {
        let img = synth_image(3, 40, 36, Pattern::Noise, 12);
        let k = gaussian_kernel(5, 1.0);
        let m = OpenMpModel::new(3);
        let mut ws = crate::conv::Workspace::new();
        for alg in [Algorithm::TwoPass, Algorithm::SinglePassNoCopy, Algorithm::SinglePassCopyBack] {
            let want = convolve_parallel(&m, &img, &k, alg, Variant::Simd, Layout::PerPlane).unwrap();
            let got = convolve_parallel_into(&mut ws, &m, &img, &k, alg, Variant::Simd, Layout::PerPlane)
                .unwrap()
                .to_vec();
            assert_eq!(got, want.data, "{alg:?}");
        }
        // agglomerated: wide result equals PlanarImage::agglomerate of the
        // alloc-variant's output
        let want = convolve_parallel(&m, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::Agglomerated)
            .unwrap()
            .agglomerate();
        let got = convolve_parallel_into(&mut ws, &m, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::Agglomerated)
            .unwrap()
            .to_vec();
        assert_eq!(got, want);
    }

    #[test]
    fn workspace_reuse_across_sizes() {
        let k = gaussian_kernel(5, 1.0);
        let m = OpenMpModel::new(2);
        let mut ws = crate::conv::Workspace::new();
        for size in [16usize, 48, 24] {
            let img = synth_image(3, size, size, Pattern::Noise, size as u64);
            let want = convolve_parallel(&m, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane).unwrap();
            let got = convolve_parallel_into(&mut ws, &m, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane)
                .unwrap()
                .to_vec();
            assert_eq!(got, want.data, "size {size}");
        }
    }

    #[test]
    fn layout_parse() {
        assert_eq!(Layout::parse("rxc"), Some(Layout::PerPlane));
        assert_eq!(Layout::parse("3rxc"), Some(Layout::Agglomerated));
        assert_eq!(Layout::parse("nope"), None);
    }

    #[test]
    fn single_worker_models_work() {
        let img = synth_image(1, 20, 18, Pattern::Noise, 9);
        let k = gaussian_kernel(5, 1.0);
        let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        for m in [
            Box::new(OpenMpModel::new(1)) as Box<dyn ExecutionModel>,
            Box::new(OpenClModel::new(1, 1)),
            Box::new(GprmModel::new(1, 1)),
        ] {
            let got = convolve_parallel(m.as_ref(), &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane).unwrap();
            assert_eq!(got, want, "{}", m.name());
        }
    }
}
