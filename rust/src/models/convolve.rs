//! Parallel convolution driver: execution model × algorithm × variant ×
//! layout.
//!
//! This is the paper's benchmark inner loop: the outer row loop of each
//! pass is handed to an [`ExecutionModel`], the inner loops are the
//! [`crate::conv::band`] primitives. Since the plan refactor the
//! dispatch itself — which band primitive, which pass order, which
//! scratch discipline — lives in [`crate::plan::ConvPlan`]; this module
//! keeps the [`Layout`] axis and thin whole-image wrappers.
//!
//! The `Layout` axis reproduces the task-agglomeration study (paper
//! section 6, Fig. 2 vs Fig. 3):
//!
//! * [`Layout::PerPlane`] — "R×C": each colour plane is a separate
//!   parallel sweep (3 sequential dispatches per pass), the paper's
//!   baseline layout;
//! * [`Layout::Agglomerated`] — "3R×C": planes are concatenated along
//!   columns, so one dispatch covers all planes; task size triples and
//!   per-dispatch overhead amortises to a third — the fix that lets GPRM
//!   win the largest image.

use crate::util::error::Result;

use crate::conv::{Algorithm, Variant};
use crate::image::PlanarImage;
use crate::plan::{ConvPlan, ScratchArena};

use super::ExecutionModel;

/// Parallelisation layout (paper Figs. 2/3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Layout {
    /// R×C: per-plane sweeps, planes sequential.
    PerPlane,
    /// 3R×C: planes folded into one wide sweep (task agglomeration).
    Agglomerated,
}

impl Layout {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "rxc" | "per-plane" => Layout::PerPlane,
            "3rxc" | "agglomerated" => Layout::Agglomerated,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Layout::PerPlane => "RxC",
            Layout::Agglomerated => "3RxC",
        }
    }
}

/// Convolve one plane in parallel. `a` is the source/result buffer, `b`
/// the scratch; semantics identical to [`crate::conv::convolve_plane`].
///
/// One-shot wrapper over [`ConvPlan::run_plane_on`] — build a plan once
/// instead when convolving repeatedly. Any odd kernel width is served
/// (width 5 unrolled, others generic); invalid widths are structured
/// errors, never the old zero-filled-kernel fallback.
pub fn convolve_plane_parallel(
    model: &dyn ExecutionModel,
    a: &mut [f32],
    b: &mut [f32],
    rows: usize,
    cols: usize,
    k: &[f32],
    algorithm: Algorithm,
    variant: Variant,
) -> Result<()> {
    let plan = ConvPlan::builder()
        .algorithm(algorithm)
        .variant(variant)
        .kernel_taps(k.to_vec())
        .shape(1, rows, cols)
        .build()?;
    plan.run_plane_on(model, a, b)
}

/// Convolve a whole image in parallel under a layout. Returns the
/// convolved image; pixels are identical to the sequential
/// [`crate::conv::convolve_image`] for `PerPlane`, and identical away
/// from plane seams for `Agglomerated` (DESIGN.md §4).
///
/// One-shot wrapper over [`ConvPlan::execute_on`]; serving paths hold a
/// plan + [`ScratchArena`] instead.
pub fn convolve_parallel(
    model: &dyn ExecutionModel,
    img: &PlanarImage,
    k: &[f32],
    algorithm: Algorithm,
    variant: Variant,
    layout: Layout,
) -> Result<PlanarImage> {
    let plan = ConvPlan::builder()
        .algorithm(algorithm)
        .variant(variant)
        .layout(layout)
        .kernel_taps(k.to_vec())
        .shape(img.planes, img.rows, img.cols)
        .build()?;
    let mut arena = ScratchArena::new();
    plan.execute_on(model, img, &mut arena)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::convolve_image;
    use crate::image::{gaussian_kernel, synth_image, Pattern};
    use crate::models::{GprmModel, OpenClModel, OpenMpModel};

    fn models() -> Vec<Box<dyn ExecutionModel>> {
        vec![
            Box::new(OpenMpModel::new(4)),
            Box::new(OpenClModel::new(4, 3)),
            Box::new(GprmModel::new(4, 13)),
        ]
    }

    #[test]
    fn all_models_match_sequential_all_algorithms() {
        let img = synth_image(3, 40, 36, Pattern::Noise, 5);
        let k = gaussian_kernel(5, 1.0);
        for alg in [Algorithm::TwoPass, Algorithm::SinglePassCopyBack, Algorithm::SinglePassNoCopy] {
            for variant in [Variant::Scalar, Variant::Simd] {
                let want = convolve_image(img.clone(), &k, alg, variant).unwrap();
                for m in models() {
                    let got =
                        convolve_parallel(m.as_ref(), &img, &k, alg, variant, Layout::PerPlane)
                            .unwrap();
                    assert_eq!(
                        got, want,
                        "{} {alg:?} {variant:?} differs from sequential",
                        m.name()
                    );
                }
            }
        }
    }

    #[test]
    fn naive_variant_parallel_matches() {
        let img = synth_image(3, 30, 28, Pattern::Noise, 6);
        let k = gaussian_kernel(5, 1.0);
        let want = convolve_image(img.clone(), &k, Algorithm::SinglePassCopyBack, Variant::Naive).unwrap();
        let m = OpenMpModel::new(3);
        let got = convolve_parallel(&m, &img, &k, Algorithm::SinglePassCopyBack, Variant::Naive, Layout::PerPlane).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-6);
    }

    #[test]
    fn agglomerated_matches_away_from_seams() {
        let img = synth_image(3, 40, 36, Pattern::Noise, 7);
        let k = gaussian_kernel(5, 1.0);
        let per = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        for m in models() {
            let agg = convolve_parallel(m.as_ref(), &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::Agglomerated).unwrap();
            // inner columns (≥ 2h from plane seams) agree
            for p in 0..3 {
                for i in 0..40 {
                    for j in 4..32 {
                        let d = (agg.get(p, i, j) - per.get(p, i, j)).abs();
                        assert!(d < 1e-5, "{} ({p},{i},{j}) d={d}", m.name());
                    }
                }
            }
        }
    }

    #[test]
    fn agglomerated_seams_differ_from_per_plane() {
        // guard: 3RxC must actually convolve across seams, not fall back
        // to per-plane (plane 1's col 0..2 is interior in the wide image)
        let img = synth_image(3, 24, 20, Pattern::Noise, 8);
        let k = gaussian_kernel(5, 1.0);
        let per = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        let m = OpenMpModel::new(2);
        let agg = convolve_parallel(&m, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::Agglomerated).unwrap();
        let mut max_d = 0f32;
        for i in 4..20 {
            for j in 0..2 {
                max_d = max_d.max((agg.get(1, i, j) - per.get(1, i, j)).abs());
            }
        }
        assert!(max_d > 1e-6, "seam columns identical — agglomeration is fake?");
    }

    #[test]
    fn plan_execute_into_matches_one_shot_wrapper() {
        let img = synth_image(3, 40, 36, Pattern::Noise, 12);
        let k = gaussian_kernel(5, 1.0);
        let m = OpenMpModel::new(3);
        let mut arena = ScratchArena::new();
        let mut out = Vec::new();
        for alg in [Algorithm::TwoPass, Algorithm::SinglePassNoCopy, Algorithm::SinglePassCopyBack] {
            let want = convolve_parallel(&m, &img, &k, alg, Variant::Simd, Layout::PerPlane).unwrap();
            let plan = ConvPlan::builder()
                .algorithm(alg)
                .kernel_taps(k.clone())
                .shape(3, 40, 36)
                .build()
                .unwrap();
            plan.execute_into(Some(&m), &img, &mut arena, &mut out).unwrap();
            assert_eq!(out, want.data, "{alg:?}");
        }
        // agglomerated: wide result equals PlanarImage::agglomerate of the
        // one-shot wrapper's output
        let want = convolve_parallel(&m, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::Agglomerated)
            .unwrap()
            .agglomerate();
        let plan = ConvPlan::builder()
            .layout(Layout::Agglomerated)
            .kernel_taps(k.clone())
            .shape(3, 40, 36)
            .build()
            .unwrap();
        plan.execute_into(Some(&m), &img, &mut arena, &mut out).unwrap();
        assert_eq!(out, want);
    }

    #[test]
    fn arena_reuse_across_sizes() {
        let k = gaussian_kernel(5, 1.0);
        let m = OpenMpModel::new(2);
        let mut arena = ScratchArena::new();
        for size in [16usize, 48, 24, 48, 16] {
            let img = synth_image(3, size, size, Pattern::Noise, size as u64);
            let want = convolve_parallel(&m, &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane).unwrap();
            let plan = ConvPlan::builder()
                .kernel_taps(k.clone())
                .shape(3, size, size)
                .build()
                .unwrap();
            let got = plan.execute_on(&m, &img, &mut arena).unwrap();
            assert_eq!(got, want, "size {size}");
        }
        // three distinct sizes → at most 6 scratch allocations ever
        assert_eq!(arena.allocations(), 6);
    }

    #[test]
    fn zero_kernel_fallback_is_gone() {
        // pre-plan, width-3 + Simd silently convolved with a zero-filled
        // width-5 kernel through the parallel driver; now it computes the
        // real width-3 result.
        let img = synth_image(1, 24, 24, Pattern::Noise, 13);
        let k3 = gaussian_kernel(3, 1.0);
        let m = OpenMpModel::new(2);
        let got = convolve_parallel(&m, &img, &k3, Algorithm::SinglePassNoCopy, Variant::Simd, Layout::PerPlane)
            .unwrap();
        let want = convolve_image(img.clone(), &k3, Algorithm::SinglePassNoCopy, Variant::Simd).unwrap();
        assert_eq!(got, want);
        // and a genuinely invalid (even) width is a structured error
        assert!(convolve_parallel(&m, &img, &[0.5, 0.5], Algorithm::TwoPass, Variant::Simd, Layout::PerPlane)
            .is_err());
    }

    #[test]
    fn layout_parse() {
        assert_eq!(Layout::parse("rxc"), Some(Layout::PerPlane));
        assert_eq!(Layout::parse("3rxc"), Some(Layout::Agglomerated));
        assert_eq!(Layout::parse("nope"), None);
    }

    #[test]
    fn single_worker_models_work() {
        let img = synth_image(1, 20, 18, Pattern::Noise, 9);
        let k = gaussian_kernel(5, 1.0);
        let want = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        for m in [
            Box::new(OpenMpModel::new(1)) as Box<dyn ExecutionModel>,
            Box::new(OpenClModel::new(1, 1)),
            Box::new(GprmModel::new(1, 1)),
        ] {
            let got = convolve_parallel(m.as_ref(), &img, &k, Algorithm::TwoPass, Variant::Simd, Layout::PerPlane).unwrap();
            assert_eq!(got, want, "{}", m.name());
        }
    }
}
