//! Persistent worker-thread pool: the shared substrate under all three
//! execution models.
//!
//! The pool plays the role the OS-thread layer plays on the Xeon Phi:
//! OpenMP teams, OpenCL compute units and GPRM's thread tiles are all,
//! underneath, a fixed set of kernel threads that a runtime parks and
//! wakes. `broadcast` wakes every worker once with the same job closure
//! and waits for all of them — each model builds its own scheduling
//! discipline (static chunks / group queue / task deques) inside the job.

use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Type-erased borrowed job. Lifetime is erased (`'static` transmute) —
/// sound because `broadcast` does not return until every worker has
/// finished running the job, so the borrow outlives all uses.
type JobRef = &'static (dyn Fn(usize) + Sync);

struct State {
    epoch: u64,
    job: Option<JobRef>,
    remaining: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    start: Condvar,
    done: Condvar,
}

/// Fixed-size persistent pool.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// serialises broadcasts (one parallel region at a time, like an
    /// OpenMP team)
    dispatch: Mutex<()>,
    n: usize,
}

impl WorkerPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "pool needs at least one worker");
        let shared = Arc::new(Shared {
            state: Mutex::new(State { epoch: 0, job: None, remaining: 0, shutdown: false }),
            start: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..n)
            .map(|id| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("phi-conv-worker-{id}"))
                    .spawn(move || Self::worker_loop(sh, id))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, handles, dispatch: Mutex::new(()), n }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    fn worker_loop(shared: Arc<Shared>, id: usize) {
        let mut seen = 0u64;
        loop {
            let job = {
                let mut st = shared.state.lock().unwrap();
                while !st.shutdown && st.epoch == seen {
                    st = shared.start.wait(st).unwrap();
                }
                if st.shutdown {
                    return;
                }
                seen = st.epoch;
                st.job.expect("job set with epoch")
            };
            job(id);
            let mut st = shared.state.lock().unwrap();
            st.remaining -= 1;
            if st.remaining == 0 {
                shared.done.notify_all();
            }
        }
    }

    /// Run `job(worker_id)` once on every worker; returns when all done.
    pub fn broadcast(&self, job: &(dyn Fn(usize) + Sync)) {
        let _serial = self.dispatch.lock().unwrap();
        // SAFETY: lifetime erasure only; we wait for remaining == 0 below,
        // so no worker touches `job` after this function returns.
        let job_static: JobRef = unsafe { std::mem::transmute(job) };
        let mut st = self.shared.state.lock().unwrap();
        st.job = Some(job_static);
        st.remaining = self.n;
        st.epoch += 1;
        self.shared.start.notify_all();
        while st.remaining > 0 {
            st = self.shared.done.wait(st).unwrap();
        }
        st.job = None;
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.start.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Hands out disjoint mutable row-band views of one plane buffer to
/// parallel workers.
///
/// Soundness contract: callers must request **disjoint** `[r0, r1)` row
/// ranges (the execution models guarantee this by construction; the
/// property tests verify their partitions). Each view is then a disjoint
/// sub-slice, equivalent to nested `split_at_mut`.
pub struct RowBands<'a> {
    ptr: *mut f32,
    rows: usize,
    cols: usize,
    _marker: std::marker::PhantomData<&'a mut [f32]>,
}

// SAFETY: access discipline (disjoint bands) is the caller contract above.
unsafe impl Send for RowBands<'_> {}
unsafe impl Sync for RowBands<'_> {}

impl<'a> RowBands<'a> {
    pub fn new(plane: &'a mut [f32], rows: usize, cols: usize) -> Self {
        assert_eq!(plane.len(), rows * cols);
        Self { ptr: plane.as_mut_ptr(), rows, cols, _marker: std::marker::PhantomData }
    }

    /// Mutable view of rows `[r0, r1)`.
    ///
    /// # Safety
    /// The range must not overlap any other outstanding band.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn band(&self, r0: usize, r1: usize) -> &mut [f32] {
        debug_assert!(r0 <= r1 && r1 <= self.rows);
        std::slice::from_raw_parts_mut(self.ptr.add(r0 * self.cols), (r1 - r0) * self.cols)
    }
}

/// Hands out disjoint mutable row-segment views of one plane buffer to
/// tiled parallel workers — the 2-D sibling of [`RowBands`].
///
/// Soundness contract: callers must only request segments belonging to
/// **disjoint** tiles (the execution models' `dispatch2d` covers are
/// disjoint by construction; the property tests verify the
/// decompositions). Each view is then a disjoint sub-slice of the plane.
pub struct TileCells<'a> {
    ptr: *mut f32,
    rows: usize,
    cols: usize,
    _marker: std::marker::PhantomData<&'a mut [f32]>,
}

// SAFETY: access discipline (disjoint tiles) is the caller contract above.
unsafe impl Send for TileCells<'_> {}
unsafe impl Sync for TileCells<'_> {}

impl<'a> TileCells<'a> {
    pub fn new(plane: &'a mut [f32], rows: usize, cols: usize) -> Self {
        assert_eq!(plane.len(), rows * cols);
        Self { ptr: plane.as_mut_ptr(), rows, cols, _marker: std::marker::PhantomData }
    }

    /// Mutable view of row `i`, columns `[c0, c1)`.
    ///
    /// # Safety
    /// The segment must not overlap any other outstanding view — i.e.
    /// `[c0, c1)` of row `i` must lie inside the caller's own tile.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_seg(&self, i: usize, c0: usize, c1: usize) -> &mut [f32] {
        debug_assert!(i < self.rows && c0 <= c1 && c1 <= self.cols);
        std::slice::from_raw_parts_mut(self.ptr.add(i * self.cols + c0), c1 - c0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn broadcast_runs_every_worker_once() {
        let pool = WorkerPool::new(4);
        let count = AtomicUsize::new(0);
        let seen = Mutex::new(vec![false; 4]);
        pool.broadcast(&|id| {
            count.fetch_add(1, Ordering::SeqCst);
            seen.lock().unwrap()[id] = true;
        });
        assert_eq!(count.load(Ordering::SeqCst), 4);
        assert!(seen.lock().unwrap().iter().all(|&s| s));
    }

    #[test]
    fn repeated_broadcasts() {
        let pool = WorkerPool::new(3);
        let count = AtomicUsize::new(0);
        for _ in 0..100 {
            pool.broadcast(&|_| {
                count.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(count.load(Ordering::SeqCst), 300);
    }

    #[test]
    fn broadcast_borrows_stack_data() {
        let pool = WorkerPool::new(2);
        let data = vec![1.0f32; 128];
        let sum = Mutex::new(0.0f32);
        pool.broadcast(&|id| {
            let part: f32 = data[id * 64..(id + 1) * 64].iter().sum();
            *sum.lock().unwrap() += part;
        });
        assert_eq!(*sum.lock().unwrap(), 128.0);
    }

    #[test]
    fn concurrent_broadcasts_serialise() {
        let pool = Arc::new(WorkerPool::new(2));
        let count = Arc::new(AtomicUsize::new(0));
        let mut joins = vec![];
        for _ in 0..4 {
            let p = pool.clone();
            let c = count.clone();
            joins.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    p.broadcast(&|_| {
                        c.fetch_add(1, Ordering::SeqCst);
                    });
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(count.load(Ordering::SeqCst), 4 * 50 * 2);
    }

    #[test]
    fn row_bands_disjoint_views() {
        let mut plane = vec![0f32; 6 * 4];
        let bands = RowBands::new(&mut plane, 6, 4);
        let (b0, b1) = unsafe { (bands.band(0, 3), bands.band(3, 6)) };
        b0.fill(1.0);
        b1.fill(2.0);
        drop(bands);
        assert!(plane[..12].iter().all(|&v| v == 1.0));
        assert!(plane[12..].iter().all(|&v| v == 2.0));
    }

    #[test]
    fn tile_cells_disjoint_segments() {
        let mut plane = vec![0f32; 4 * 6];
        {
            let cells = TileCells::new(&mut plane, 4, 6);
            // two disjoint tiles: rows [0,4) × cols [0,3) and [3,6)
            for i in 0..4 {
                let (left, right) = unsafe { (cells.row_seg(i, 0, 3), cells.row_seg(i, 3, 6)) };
                left.fill(1.0);
                right.fill(2.0);
            }
        }
        for i in 0..4 {
            assert!(plane[i * 6..i * 6 + 3].iter().all(|&v| v == 1.0));
            assert!(plane[i * 6 + 3..(i + 1) * 6].iter().all(|&v| v == 2.0));
        }
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = WorkerPool::new(8);
        pool.broadcast(&|_| {});
        drop(pool); // must not hang
    }
}
