//! Tile geometry for 2-D dispatch: the unit of task agglomeration.
//!
//! The paper's central scheduling result (section 6, Fig. 3) is that
//! fusing fine-grained tasks into coarser tiles — *task agglomeration* —
//! is what closes the gap between the task-based and loop-based models.
//! Row-range `dispatch` can only express one granularity axis (rows per
//! task); these types give [`super::ExecutionModel::dispatch2d`] an
//! explicit 2-D tile, so the agglomeration factor becomes a measurable,
//! tunable plan dimension (see [`crate::autotune`]).
//!
//! A [`TileSpec`] is the *requested* tile shape; a [`TileGrid`] is the
//! resolved decomposition of a concrete `rows × cols` grid: tiles are
//! laid out row-major, interior tiles are exactly `spec.rows ×
//! spec.cols`, and edge tiles clamp to the grid (a spec larger than the
//! grid degenerates to one tile covering everything). The grid is the
//! single source of truth for the cover-exactness contract: every cell
//! belongs to exactly one tile.

use crate::util::error::Result;

/// Requested tile shape in grid cells. Dimensions larger than the
/// dispatched grid clamp at decomposition time, so "one tile per row"
/// (`rows = 1`) and "whole image" (`usize::MAX × usize::MAX`) are both
/// expressible without knowing the grid in advance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TileSpec {
    /// grid rows per tile (≥ 1)
    pub rows: usize,
    /// grid columns per tile (≥ 1)
    pub cols: usize,
}

impl TileSpec {
    pub fn new(rows: usize, cols: usize) -> Self {
        Self { rows, cols }
    }

    /// Structured validation — plan builders and request intake funnel
    /// tile parameters through here (a zero dimension is a config error,
    /// not a silent no-op).
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.rows >= 1 && self.cols >= 1,
            "tile dimensions must be >= 1, got {}x{}",
            self.rows,
            self.cols
        );
        Ok(())
    }

    /// Stable hash-map key for plan caches.
    pub fn cache_key(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Human-readable label for tables; `usize::MAX` prints as `full`.
    pub fn label(&self) -> String {
        let dim = |d: usize| {
            if d == usize::MAX {
                "full".to_string()
            } else {
                d.to_string()
            }
        };
        format!("{}x{}", dim(self.rows), dim(self.cols))
    }
}

/// One resolved tile: rows `[r0, r1)` × cols `[c0, c1)` of the grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tile {
    pub r0: usize,
    pub r1: usize,
    pub c0: usize,
    pub c1: usize,
}

impl Tile {
    pub fn rows(&self) -> usize {
        self.r1 - self.r0
    }

    pub fn cols(&self) -> usize {
        self.c1 - self.c0
    }

    /// Cells covered (edge tiles are smaller than the spec).
    pub fn cells(&self) -> usize {
        self.rows() * self.cols()
    }
}

/// The row-major tile decomposition of a `rows × cols` grid under a
/// [`TileSpec`] (clamped to the grid). An empty grid has zero tiles.
#[derive(Debug, Clone, Copy)]
pub struct TileGrid {
    rows: usize,
    cols: usize,
    tile_rows: usize,
    tile_cols: usize,
    down: usize,
    across: usize,
}

impl TileGrid {
    pub fn new(rows: usize, cols: usize, spec: TileSpec) -> Self {
        // clamp the spec to the grid; `.max(1)` keeps the div_ceil sound
        // for degenerate (empty) grids, which resolve to zero tiles
        let tile_rows = spec.rows.min(rows).max(1);
        let tile_cols = spec.cols.min(cols).max(1);
        Self {
            rows,
            cols,
            tile_rows,
            tile_cols,
            down: rows.div_ceil(tile_rows),
            across: cols.div_ceil(tile_cols),
        }
    }

    /// Total number of tiles (the dispatch index space).
    pub fn len(&self) -> usize {
        self.down * self.across
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Tile rows of the decomposition (vertical tile count).
    pub fn tiles_down(&self) -> usize {
        self.down
    }

    /// Tile columns of the decomposition (horizontal tile count).
    pub fn tiles_across(&self) -> usize {
        self.across
    }

    /// The clamped tile shape actually used.
    pub fn tile_shape(&self) -> TileSpec {
        TileSpec::new(self.tile_rows, self.tile_cols)
    }

    /// Tile `index` of the row-major enumeration (`index < len()`).
    pub fn tile(&self, index: usize) -> Tile {
        debug_assert!(index < self.len());
        self.tile_at(index / self.across, index % self.across)
    }

    /// Tile at tile-row `trow`, tile-column `tcol`.
    pub fn tile_at(&self, trow: usize, tcol: usize) -> Tile {
        debug_assert!(trow < self.down && tcol < self.across);
        Tile {
            r0: trow * self.tile_rows,
            r1: ((trow + 1) * self.tile_rows).min(self.rows),
            c0: tcol * self.tile_cols,
            c1: ((tcol + 1) * self.tile_cols).min(self.cols),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_exact_cover(rows: usize, cols: usize, spec: TileSpec) {
        let grid = TileGrid::new(rows, cols, spec);
        let mut hits = vec![0u32; rows * cols];
        for t in 0..grid.len() {
            let tile = grid.tile(t);
            assert!(tile.r0 < tile.r1 && tile.r1 <= rows, "{tile:?}");
            assert!(tile.c0 < tile.c1 && tile.c1 <= cols, "{tile:?}");
            for i in tile.r0..tile.r1 {
                for j in tile.c0..tile.c1 {
                    hits[i * cols + j] += 1;
                }
            }
        }
        assert!(
            hits.iter().all(|&h| h == 1),
            "{rows}x{cols} tiled {} not an exact cover",
            spec.label()
        );
    }

    #[test]
    fn grid_covers_exactly_once() {
        for (rows, cols) in [(1usize, 1usize), (1, 37), (37, 1), (24, 20), (61, 47), (100, 3)] {
            for spec in [
                TileSpec::new(1, 1),
                TileSpec::new(4, 4),
                TileSpec::new(7, 3),
                TileSpec::new(16, 64),
                TileSpec::new(usize::MAX, usize::MAX),
            ] {
                assert_exact_cover(rows, cols, spec);
            }
        }
    }

    #[test]
    fn empty_grid_has_no_tiles() {
        for (rows, cols) in [(0usize, 0usize), (0, 10), (10, 0)] {
            let grid = TileGrid::new(rows, cols, TileSpec::new(4, 4));
            assert_eq!(grid.len(), 0, "{rows}x{cols}");
            assert!(grid.is_empty());
        }
    }

    #[test]
    fn oversized_spec_clamps_to_one_tile() {
        let grid = TileGrid::new(10, 8, TileSpec::new(100, 100));
        assert_eq!(grid.len(), 1);
        assert_eq!(grid.tile(0), Tile { r0: 0, r1: 10, c0: 0, c1: 8 });
        assert_eq!(grid.tile_shape(), TileSpec::new(10, 8));
    }

    #[test]
    fn edge_tiles_clamp() {
        let grid = TileGrid::new(10, 10, TileSpec::new(4, 6));
        assert_eq!((grid.tiles_down(), grid.tiles_across()), (3, 2));
        assert_eq!(grid.tile_at(2, 1), Tile { r0: 8, r1: 10, c0: 6, c1: 10 });
        assert_eq!(grid.tile_at(2, 1).cells(), 2 * 4);
    }

    #[test]
    fn row_major_enumeration() {
        let grid = TileGrid::new(4, 4, TileSpec::new(2, 2));
        assert_eq!(grid.len(), 4);
        assert_eq!(grid.tile(0), Tile { r0: 0, r1: 2, c0: 0, c1: 2 });
        assert_eq!(grid.tile(1), Tile { r0: 0, r1: 2, c0: 2, c1: 4 });
        assert_eq!(grid.tile(2), Tile { r0: 2, r1: 4, c0: 0, c1: 2 });
        assert_eq!(grid.tile(3), Tile { r0: 2, r1: 4, c0: 2, c1: 4 });
    }

    #[test]
    fn spec_validation_and_labels() {
        assert!(TileSpec::new(1, 1).validate().is_ok());
        assert!(TileSpec::new(0, 4).validate().is_err());
        assert!(TileSpec::new(4, 0).validate().is_err());
        assert_eq!(TileSpec::new(16, 64).label(), "16x64");
        assert_eq!(TileSpec::new(16, usize::MAX).label(), "16xfull");
        assert_eq!(TileSpec::new(8, 8).cache_key(), (8, 8));
    }
}
