//! OpenMP-style execution model: fork-join static-chunk `parallel for`.
//!
//! Mirrors `#pragma omp parallel for` with the Intel runtime's default
//! `schedule(static)` on a persistent team (paper Listing 1):
//!
//! * the team (worker pool) persists across parallel regions, like an
//!   OpenMP thread team after the first fork;
//! * each of the `num_threads` workers takes one contiguous chunk
//!   `[n·t/T, n·(t+1)/T)` — no queueing, no stealing;
//! * `dispatch` returns only after every worker finished: the implicit
//!   barrier at the end of `omp parallel for`.
//!
//! The paper's "magic number" is 100 threads on 240 hw contexts; on the
//! host the equivalent saturation point is measured by the thread-sweep
//! harness (`bench-table threads`).

use std::sync::atomic::{AtomicUsize, Ordering};

use super::pool::WorkerPool;
use super::{static_chunk, ExecutionModel, Tile, TileGrid, TileSpec};

/// OpenMP loop schedules (ablation subject — the paper uses the Intel
/// default, `static`; `dynamic`/`guided` are provided to measure what
/// that choice costs/buys on this workload).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// one contiguous chunk per thread (the paper's configuration)
    Static,
    /// fixed-size chunks drained from a shared counter
    Dynamic(usize),
    /// exponentially shrinking chunks: remaining/(2T), floored
    Guided(usize),
}

impl Schedule {
    pub fn label(&self) -> String {
        match self {
            Schedule::Static => "static".into(),
            Schedule::Dynamic(c) => format!("dynamic,{c}"),
            Schedule::Guided(m) => format!("guided,{m}"),
        }
    }
}

pub struct OpenMpModel {
    pool: WorkerPool,
    schedule: Schedule,
}

impl OpenMpModel {
    /// `num_threads` — the OMP_NUM_THREADS of this team; `schedule(static)`.
    pub fn new(num_threads: usize) -> Self {
        Self::with_schedule(num_threads, Schedule::Static)
    }

    pub fn with_schedule(num_threads: usize, schedule: Schedule) -> Self {
        if let Schedule::Dynamic(c) | Schedule::Guided(c) = schedule {
            assert!(c > 0, "chunk must be ≥ 1");
        }
        Self { pool: WorkerPool::new(num_threads), schedule }
    }

    pub fn schedule(&self) -> Schedule {
        self.schedule
    }
}

impl ExecutionModel for OpenMpModel {
    fn name(&self) -> &'static str {
        "OpenMP"
    }

    fn workers(&self) -> usize {
        self.pool.len()
    }

    fn dispatch(&self, n: usize, job: &(dyn Fn(usize, usize) + Sync)) {
        let t_total = self.pool.len();
        match self.schedule {
            Schedule::Static => self.pool.broadcast(&|t| {
                let (r0, r1) = static_chunk(n, t_total, t);
                if r0 < r1 {
                    job(r0, r1);
                }
            }),
            Schedule::Dynamic(chunk) => {
                let cursor = AtomicUsize::new(0);
                self.pool.broadcast(&|_t| loop {
                    let r0 = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if r0 >= n {
                        break;
                    }
                    job(r0, (r0 + chunk).min(n));
                });
            }
            Schedule::Guided(min_chunk) => {
                // OpenMP guided: each grab takes ~remaining/(2T), never
                // below min_chunk. A mutex keeps remaining+cursor atomic
                // as a pair (contention is amortised by the large grabs).
                let state = std::sync::Mutex::new(0usize); // next row
                self.pool.broadcast(&|_t| loop {
                    let (r0, r1) = {
                        let mut next = state.lock().unwrap();
                        if *next >= n {
                            break;
                        }
                        let remaining = n - *next;
                        let take = (remaining / (2 * t_total)).max(min_chunk).min(remaining);
                        let r0 = *next;
                        *next += take;
                        (r0, r0 + take)
                    };
                    job(r0, r1);
                });
            }
        }
    }

    fn dispatch2d(&self, rows: usize, cols: usize, tile: TileSpec, job: &(dyn Fn(Tile) + Sync)) {
        let grid = TileGrid::new(rows, cols, tile);
        if grid.is_empty() {
            return;
        }
        let t_total = self.pool.len();
        match self.schedule {
            // `#pragma omp parallel for` over the *outer* tiled loop:
            // contiguous stripes of tile-rows per thread, so each worker
            // touches a contiguous slab of the image (cache-friendly,
            // like the 1-D static chunks)
            Schedule::Static => self.pool.broadcast(&|t| {
                let (d0, d1) = static_chunk(grid.tiles_down(), t_total, t);
                for trow in d0..d1 {
                    for tcol in 0..grid.tiles_across() {
                        job(grid.tile_at(trow, tcol));
                    }
                }
            }),
            // dynamic/guided drain the row-major tile index space from a
            // shared cursor, exactly like their 1-D row schedules
            Schedule::Dynamic(chunk) => {
                let n = grid.len();
                let cursor = AtomicUsize::new(0);
                self.pool.broadcast(&|_t| loop {
                    let t0 = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if t0 >= n {
                        break;
                    }
                    for t in t0..(t0 + chunk).min(n) {
                        job(grid.tile(t));
                    }
                });
            }
            Schedule::Guided(min_chunk) => {
                let n = grid.len();
                let state = std::sync::Mutex::new(0usize); // next tile
                self.pool.broadcast(&|_t| loop {
                    let (t0, t1) = {
                        let mut next = state.lock().unwrap();
                        if *next >= n {
                            break;
                        }
                        let remaining = n - *next;
                        let take = (remaining / (2 * t_total)).max(min_chunk).min(remaining);
                        let t0 = *next;
                        *next += take;
                        (t0, t0 + take)
                    };
                    for t in t0..t1 {
                        job(grid.tile(t));
                    }
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn covers_rows_exactly_once() {
        let m = OpenMpModel::new(7);
        let hits = Mutex::new(vec![0u32; 100]);
        m.dispatch(100, &|a, b| {
            let mut h = hits.lock().unwrap();
            for i in a..b {
                h[i] += 1;
            }
        });
        assert!(hits.lock().unwrap().iter().all(|&h| h == 1));
    }

    #[test]
    fn more_threads_than_rows() {
        let m = OpenMpModel::new(16);
        let hits = Mutex::new(vec![0u32; 5]);
        m.dispatch(5, &|a, b| {
            let mut h = hits.lock().unwrap();
            for i in a..b {
                h[i] += 1;
            }
        });
        assert!(hits.lock().unwrap().iter().all(|&h| h == 1));
    }

    #[test]
    fn zero_rows_is_noop() {
        let m = OpenMpModel::new(4);
        m.dispatch(0, &|_, _| panic!("no job expected"));
    }

    #[test]
    fn chunks_are_contiguous_per_worker() {
        // static schedule ⇒ exactly min(T, n) non-empty contiguous chunks
        let m = OpenMpModel::new(4);
        let ranges = Mutex::new(vec![]);
        m.dispatch(40, &|a, b| ranges.lock().unwrap().push((a, b)));
        let mut r = ranges.lock().unwrap().clone();
        r.sort_unstable();
        assert_eq!(r, vec![(0, 10), (10, 20), (20, 30), (30, 40)]);
    }

    #[test]
    fn overhead_probe_runs() {
        let m = OpenMpModel::new(4);
        let s = m.overhead_probe(1000, 5);
        assert_eq!(s.len(), 5);
        assert!(s.min() >= 0.0);
    }

    #[test]
    fn dynamic_schedule_covers_exactly_once() {
        for chunk in [1usize, 3, 16, 200] {
            let m = OpenMpModel::with_schedule(5, Schedule::Dynamic(chunk));
            let hits = Mutex::new(vec![0u32; 103]);
            m.dispatch(103, &|a, b| {
                let mut h = hits.lock().unwrap();
                for i in a..b {
                    h[i] += 1;
                }
            });
            assert!(hits.lock().unwrap().iter().all(|&h| h == 1), "chunk {chunk}");
        }
    }

    #[test]
    fn guided_schedule_covers_exactly_once() {
        for min in [1usize, 4, 50] {
            let m = OpenMpModel::with_schedule(3, Schedule::Guided(min));
            let hits = Mutex::new(vec![0u32; 211]);
            m.dispatch(211, &|a, b| {
                let mut h = hits.lock().unwrap();
                for i in a..b {
                    h[i] += 1;
                }
            });
            assert!(hits.lock().unwrap().iter().all(|&h| h == 1), "min {min}");
        }
    }

    #[test]
    fn guided_chunks_shrink() {
        let m = OpenMpModel::with_schedule(2, Schedule::Guided(1));
        let sizes = Mutex::new(vec![]);
        m.dispatch(400, &|a, b| sizes.lock().unwrap().push(b - a));
        let s = sizes.lock().unwrap();
        // first grab is remaining/(2T) = 100; later grabs shrink to 1
        assert!(s.iter().max().unwrap() >= &90);
        assert_eq!(*s.iter().min().unwrap(), 1);
    }

    fn hits2d(m: &OpenMpModel, rows: usize, cols: usize, tile: TileSpec) -> Vec<u32> {
        let hits = Mutex::new(vec![0u32; rows * cols]);
        m.dispatch2d(rows, cols, tile, &|t| {
            let mut h = hits.lock().unwrap();
            for i in t.r0..t.r1 {
                for j in t.c0..t.c1 {
                    h[i * cols + j] += 1;
                }
            }
        });
        hits.into_inner().unwrap()
    }

    #[test]
    fn dispatch2d_covers_exactly_once_all_schedules() {
        for schedule in [Schedule::Static, Schedule::Dynamic(3), Schedule::Guided(1)] {
            let m = OpenMpModel::with_schedule(5, schedule);
            for tile in [TileSpec::new(1, 1), TileSpec::new(4, 7), TileSpec::new(100, 100)] {
                let h = hits2d(&m, 23, 19, tile);
                assert!(
                    h.iter().all(|&c| c == 1),
                    "{:?} tile {}",
                    schedule,
                    tile.label()
                );
            }
        }
    }

    #[test]
    fn dispatch2d_static_stripes_tile_rows() {
        // 4 threads, 8 tile-rows of 2 full-width tiles: each thread gets
        // 2 contiguous tile-rows, so tiles arrive grouped per stripe
        let m = OpenMpModel::new(4);
        let tiles = Mutex::new(vec![]);
        m.dispatch2d(16, 8, TileSpec::new(2, 4), &|t| tiles.lock().unwrap().push(t));
        let mut got = tiles.into_inner().unwrap();
        assert_eq!(got.len(), 8 * 2);
        got.sort_unstable_by_key(|t| (t.r0, t.c0));
        assert_eq!(got[0], Tile { r0: 0, r1: 2, c0: 0, c1: 4 });
        assert_eq!(got[15], Tile { r0: 14, r1: 16, c0: 4, c1: 8 });
    }

    #[test]
    fn dispatch2d_empty_grid_is_noop() {
        let m = OpenMpModel::new(3);
        m.dispatch2d(0, 16, TileSpec::new(4, 4), &|_| panic!("no tile expected"));
        m.dispatch2d(16, 0, TileSpec::new(4, 4), &|_| panic!("no tile expected"));
    }

    #[test]
    fn schedule_labels() {
        assert_eq!(Schedule::Static.label(), "static");
        assert_eq!(Schedule::Dynamic(4).label(), "dynamic,4");
        assert_eq!(Schedule::Guided(2).label(), "guided,2");
    }
}
