//! OpenMP-style execution model: fork-join static-chunk `parallel for`.
//!
//! Mirrors `#pragma omp parallel for` with the Intel runtime's default
//! `schedule(static)` on a persistent team (paper Listing 1):
//!
//! * the team (worker pool) persists across parallel regions, like an
//!   OpenMP thread team after the first fork;
//! * each of the `num_threads` workers takes one contiguous chunk
//!   `[n·t/T, n·(t+1)/T)` — no queueing, no stealing;
//! * `dispatch` returns only after every worker finished: the implicit
//!   barrier at the end of `omp parallel for`.
//!
//! The paper's "magic number" is 100 threads on 240 hw contexts; on the
//! host the equivalent saturation point is measured by the thread-sweep
//! harness (`bench-table threads`).

use std::sync::atomic::{AtomicUsize, Ordering};

use super::pool::WorkerPool;
use super::{static_chunk, ExecutionModel};

/// OpenMP loop schedules (ablation subject — the paper uses the Intel
/// default, `static`; `dynamic`/`guided` are provided to measure what
/// that choice costs/buys on this workload).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// one contiguous chunk per thread (the paper's configuration)
    Static,
    /// fixed-size chunks drained from a shared counter
    Dynamic(usize),
    /// exponentially shrinking chunks: remaining/(2T), floored
    Guided(usize),
}

impl Schedule {
    pub fn label(&self) -> String {
        match self {
            Schedule::Static => "static".into(),
            Schedule::Dynamic(c) => format!("dynamic,{c}"),
            Schedule::Guided(m) => format!("guided,{m}"),
        }
    }
}

pub struct OpenMpModel {
    pool: WorkerPool,
    schedule: Schedule,
}

impl OpenMpModel {
    /// `num_threads` — the OMP_NUM_THREADS of this team; `schedule(static)`.
    pub fn new(num_threads: usize) -> Self {
        Self::with_schedule(num_threads, Schedule::Static)
    }

    pub fn with_schedule(num_threads: usize, schedule: Schedule) -> Self {
        if let Schedule::Dynamic(c) | Schedule::Guided(c) = schedule {
            assert!(c > 0, "chunk must be ≥ 1");
        }
        Self { pool: WorkerPool::new(num_threads), schedule }
    }

    pub fn schedule(&self) -> Schedule {
        self.schedule
    }
}

impl ExecutionModel for OpenMpModel {
    fn name(&self) -> &'static str {
        "OpenMP"
    }

    fn workers(&self) -> usize {
        self.pool.len()
    }

    fn dispatch(&self, n: usize, job: &(dyn Fn(usize, usize) + Sync)) {
        let t_total = self.pool.len();
        match self.schedule {
            Schedule::Static => self.pool.broadcast(&|t| {
                let (r0, r1) = static_chunk(n, t_total, t);
                if r0 < r1 {
                    job(r0, r1);
                }
            }),
            Schedule::Dynamic(chunk) => {
                let cursor = AtomicUsize::new(0);
                self.pool.broadcast(&|_t| loop {
                    let r0 = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if r0 >= n {
                        break;
                    }
                    job(r0, (r0 + chunk).min(n));
                });
            }
            Schedule::Guided(min_chunk) => {
                // OpenMP guided: each grab takes ~remaining/(2T), never
                // below min_chunk. A mutex keeps remaining+cursor atomic
                // as a pair (contention is amortised by the large grabs).
                let state = std::sync::Mutex::new(0usize); // next row
                self.pool.broadcast(&|_t| loop {
                    let (r0, r1) = {
                        let mut next = state.lock().unwrap();
                        if *next >= n {
                            break;
                        }
                        let remaining = n - *next;
                        let take = (remaining / (2 * t_total)).max(min_chunk).min(remaining);
                        let r0 = *next;
                        *next += take;
                        (r0, r0 + take)
                    };
                    job(r0, r1);
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn covers_rows_exactly_once() {
        let m = OpenMpModel::new(7);
        let hits = Mutex::new(vec![0u32; 100]);
        m.dispatch(100, &|a, b| {
            let mut h = hits.lock().unwrap();
            for i in a..b {
                h[i] += 1;
            }
        });
        assert!(hits.lock().unwrap().iter().all(|&h| h == 1));
    }

    #[test]
    fn more_threads_than_rows() {
        let m = OpenMpModel::new(16);
        let hits = Mutex::new(vec![0u32; 5]);
        m.dispatch(5, &|a, b| {
            let mut h = hits.lock().unwrap();
            for i in a..b {
                h[i] += 1;
            }
        });
        assert!(hits.lock().unwrap().iter().all(|&h| h == 1));
    }

    #[test]
    fn zero_rows_is_noop() {
        let m = OpenMpModel::new(4);
        m.dispatch(0, &|_, _| panic!("no job expected"));
    }

    #[test]
    fn chunks_are_contiguous_per_worker() {
        // static schedule ⇒ exactly min(T, n) non-empty contiguous chunks
        let m = OpenMpModel::new(4);
        let ranges = Mutex::new(vec![]);
        m.dispatch(40, &|a, b| ranges.lock().unwrap().push((a, b)));
        let mut r = ranges.lock().unwrap().clone();
        r.sort_unstable();
        assert_eq!(r, vec![(0, 10), (10, 20), (20, 30), (30, 40)]);
    }

    #[test]
    fn overhead_probe_runs() {
        let m = OpenMpModel::new(4);
        let s = m.overhead_probe(1000, 5);
        assert_eq!(s.len(), 5);
        assert!(s.min() >= 0.0);
    }

    #[test]
    fn dynamic_schedule_covers_exactly_once() {
        for chunk in [1usize, 3, 16, 200] {
            let m = OpenMpModel::with_schedule(5, Schedule::Dynamic(chunk));
            let hits = Mutex::new(vec![0u32; 103]);
            m.dispatch(103, &|a, b| {
                let mut h = hits.lock().unwrap();
                for i in a..b {
                    h[i] += 1;
                }
            });
            assert!(hits.lock().unwrap().iter().all(|&h| h == 1), "chunk {chunk}");
        }
    }

    #[test]
    fn guided_schedule_covers_exactly_once() {
        for min in [1usize, 4, 50] {
            let m = OpenMpModel::with_schedule(3, Schedule::Guided(min));
            let hits = Mutex::new(vec![0u32; 211]);
            m.dispatch(211, &|a, b| {
                let mut h = hits.lock().unwrap();
                for i in a..b {
                    h[i] += 1;
                }
            });
            assert!(hits.lock().unwrap().iter().all(|&h| h == 1), "min {min}");
        }
    }

    #[test]
    fn guided_chunks_shrink() {
        let m = OpenMpModel::with_schedule(2, Schedule::Guided(1));
        let sizes = Mutex::new(vec![]);
        m.dispatch(400, &|a, b| sizes.lock().unwrap().push(b - a));
        let s = sizes.lock().unwrap();
        // first grab is remaining/(2T) = 100; later grabs shrink to 1
        assert!(s.iter().max().unwrap() >= &90);
        assert_eq!(*s.iter().min().unwrap(), 1);
    }

    #[test]
    fn schedule_labels() {
        assert_eq!(Schedule::Static.label(), "static");
        assert_eq!(Schedule::Dynamic(4).label(), "dynamic,4");
        assert_eq!(Schedule::Guided(2).label(), "guided,2");
    }
}
