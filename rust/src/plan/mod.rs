//! The plan layer: validated, reusable execution plans for convolution
//! requests.
//!
//! The paper's study is a cross-product — algorithm × optimisation rung
//! × execution model × layout — and before this layer every consumer
//! (sequential drivers, parallel driver, coordinator executors, harness,
//! benches) wired that product up with its own `match` block and its own
//! scratch-buffer scheme, each hard-specialised to width-5 Gaussian
//! kernels. A [`ConvPlan`] is built **once** per configuration through a
//! validating [`PlanBuilder`], resolves to a concrete pipeline of passes
//! ([`PassKind`]), and executes against a reusable [`ScratchArena`]:
//!
//! ```text
//! ConvPlan::builder()                 // defaults: two-pass SIMD, RxC
//!     .algorithm(Algorithm::TwoPass)
//!     .variant(Variant::Simd)
//!     .layout(Layout::PerPlane)
//!     .kernel(KernelSpec::new(5, 1.0))   // or .kernel_taps(vec![...])
//!     .shape(planes, rows, cols)
//!     .build()?                       // rejects silently-wrong combos
//!     .execute(&img, &mut arena)?     // or .execute_on(&model, ...)
//! ```
//!
//! **Validation.** `build()` rejects the combinations the old ad-hoc
//! dispatch either mis-served or punted on: even kernel widths, taps of
//! the wrong length, naive+two-pass (the paper's naive rung is
//! single-pass only), non-positive sigma and empty shapes. The
//! zero-filled `[0.0; 5]` dummy-kernel fallback that previously made
//! non-5 widths *silently compute garbage* under the unrolled variants
//! is gone: every width is either served correctly or refused with a
//! structured error at build time.
//!
//! **Fast-path selection.** Width-5 kernels (the paper's) automatically
//! use the hand-unrolled band primitives; any other odd width runs the
//! generic-width engines of the same scalar/simd shape. The choice is
//! observable via [`ConvPlan::is_fast_path`] and can be overridden with
//! [`PlanBuilder::force_generic`] (bench/test comparisons).
//!
//! **Scratch discipline.** Execution leases the A/B working planes from
//! the caller's [`ScratchArena`] and returns them after the run, so a
//! serving executor performs zero scratch allocations after its first
//! request at a given shape (property-tested) — tiled plans included:
//! tiles carve disjoint views out of the same leased A/B planes rather
//! than allocating per tile. Only the response image itself is freshly
//! allocated.
//!
//! **Tiling.** [`PlanBuilder::tile`] switches every pass from row-band
//! dispatch to an explicit 2-D tile decomposition ([`TileSpec`],
//! validated at build): parallel passes go through
//! [`crate::models::ExecutionModel::dispatch2d`], where the tile is the
//! unit of task agglomeration (paper Fig. 3), and sequential passes walk
//! the same grid. Pixels stay equivalent to the untiled plan (≤ 1e-6;
//! differential suite in `tests/tiling.rs`); [`crate::autotune`] sweeps
//! tile shapes and agglomeration factors to pick the fastest
//! decomposition per (model, shape, kernel).
//!
//! **Fusion.** [`PlanBuilder::fuse`] collapses the two separable passes
//! into one rolling row-ring pass: instead of writing a full-plane
//! horizontal intermediate and re-reading it vertically (the image
//! crosses memory twice), each worker keeps a `width`-deep ring of
//! horizontally filtered rows in cache and emits every output row
//! immediately. Scratch shrinks to O(width × cols) per worker
//! ([`ConvPlan::ring_footprint`], leased from the arena with zero
//! steady-state allocations), traffic halves
//! ([`ConvPlan::traffic_estimate`]), and pixels stay equivalent ≤ 1e-6
//! across models, widths, layouts and tiled/untiled dispatch
//! (`tests/fused.rs`). Composes with tiling; [`crate::autotune`] sweeps
//! fused candidates alongside tiled ones.
//!
//! **Graphs.** [`FilterGraph`] lifts single plans into builder-validated
//! multi-stage DAGs whose streamed edges hand rows between stages
//! through cascaded rings ([`graph`] module docs) — a k-stage chain
//! crosses memory twice, not 2k times.
//!
//! **Kernel classes.** [`KernelClass`] is a first-class plan dimension:
//! `Separable` is the paper's two-pass/single-pass ladder (Gaussian or
//! rank-1 taps only — a non-separable [`Kernel2d`] is *refused* with a
//! structured [`ErrorKind::InvalidKernel`]), `Direct2d` convolves any
//! odd×odd tap matrix with the banded/tiled direct engines
//! ([`crate::conv::direct2d`]), and `Fft` routes through the in-tree
//! radix-2 transform convolver ([`crate::conv::fft`]) whose
//! `O(n log n)` arithmetic wins past a measured kernel-width crossover
//! (`phi-conv crossover`). When a request pins no class, the cost model
//! picks one per (shape, kernel extent) — [`crate::costmodel`].

use crate::util::error::{Error, ErrorKind, Result};

use crate::conv::fft::FftPlan;
use crate::conv::{Algorithm, Variant};
use crate::image::{gaussian_kernel, gaussian_kernel2d, PlanarImage};
use crate::models::{ExecutionModel, Layout};

pub use crate::models::tile::TileSpec;

pub mod arena;
pub mod graph;
mod pipeline;

pub use arena::{RingLease, RingSlot, ScratchArena};
pub use graph::{EdgePolicy, FilterGraph, GraphBuilder, GraphStage, GraphTraffic, StageTraffic};
pub use pipeline::PassKind;

use pipeline::{Exec, ResultHome};

/// A kernel described by construction parameters (width + Gaussian
/// sigma) rather than explicit taps — what a serving request carries.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelSpec {
    /// odd tap count (the paper uses 5)
    pub width: usize,
    /// Gaussian sigma (the paper uses 1.0)
    pub sigma: f64,
}

impl KernelSpec {
    pub fn new(width: usize, sigma: f64) -> Self {
        Self { width, sigma }
    }

    /// Structured validation — every public entry point (CLI, coordinator
    /// request intake, graph stage validation, harness) funnels kernel
    /// parameters through here. Failures carry
    /// [`ErrorKind::InvalidKernel`] so callers can dispatch on the
    /// refusal (vs. execution errors, which stay [`ErrorKind::Other`]).
    pub fn validate(&self) -> Result<()> {
        if self.width % 2 != 1 {
            return Err(Error::with_kind(
                ErrorKind::InvalidKernel,
                format!("kernel width must be odd, got {}", self.width),
            ));
        }
        if !(self.sigma > 0.0) {
            return Err(Error::with_kind(
                ErrorKind::InvalidKernel,
                format!("kernel sigma must be positive, got {}", self.sigma),
            ));
        }
        Ok(())
    }

    /// Materialise the normalised 1-D taps.
    pub fn taps(&self) -> Result<Vec<f32>> {
        self.validate()?;
        Ok(gaussian_kernel(self.width, self.sigma))
    }

    /// Materialise the full 2-D tap matrix (the outer product of the 1-D
    /// taps) — what the direct-2D and FFT classes consume.
    pub fn taps2d(&self) -> Result<Kernel2d> {
        let taps = self.taps()?;
        Kernel2d::from_separable(&taps)
    }

    /// Stable hash-map key for plan caches (`f64` is not `Eq`/`Hash`;
    /// the bit pattern is).
    pub fn cache_key(&self) -> (usize, u64) {
        (self.width, self.sigma.to_bits())
    }
}

impl Default for KernelSpec {
    /// The paper's kernel: width 5, sigma 1.
    fn default() -> Self {
        Self { width: 5, sigma: 1.0 }
    }
}

/// Which convolver family a plan executes with — a first-class plan
/// dimension, swept by the autotuner and predicted by the cost model
/// when a request does not pin it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KernelClass {
    /// The paper's separable ladder (two-pass or single-pass over the
    /// outer-product kernel). Requires rank-1 taps; `O(n·w)` per pixel.
    #[default]
    Separable,
    /// Direct 2-D accumulation of an arbitrary odd×odd tap matrix
    /// ([`crate::conv::direct2d`]); `O(n·w²)` per pixel, wins small
    /// kernels.
    Direct2d,
    /// Radix-2 transform convolution ([`crate::conv::fft`]);
    /// `O(n log n)` regardless of kernel extent, wins past the measured
    /// crossover width.
    Fft,
}

impl KernelClass {
    /// Every class, in sweep order.
    pub const ALL: [KernelClass; 3] = [KernelClass::Separable, KernelClass::Direct2d, KernelClass::Fft];

    /// Stable lowercase label (CLI values, JSON artifacts, cost-model
    /// grouping keys).
    pub fn label(&self) -> &'static str {
        match self {
            KernelClass::Separable => "separable",
            KernelClass::Direct2d => "direct2d",
            KernelClass::Fft => "fft",
        }
    }

    /// Parse a [`KernelClass::label`] (CLI / config / JSON).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "separable" => Ok(KernelClass::Separable),
            "direct2d" | "direct" => Ok(KernelClass::Direct2d),
            "fft" => Ok(KernelClass::Fft),
            other => Err(Error::with_kind(
                ErrorKind::InvalidKernel,
                format!("unknown kernel class {other:?} (expected separable, direct2d or fft)"),
            )),
        }
    }
}

/// An explicit 2-D tap matrix with validated odd extents — the kernel
/// form the `Direct2d` and `Fft` classes consume, and the input to the
/// separability (rank-1) check that gates the `Separable` class.
#[derive(Debug, Clone, PartialEq)]
pub struct Kernel2d {
    taps: Vec<f32>,
    krows: usize,
    kcols: usize,
}

impl Kernel2d {
    /// Validate and wrap a row-major `krows × kcols` tap matrix. Even or
    /// zero extents, a tap count that disagrees with them, and
    /// non-finite taps are refused with [`ErrorKind::InvalidKernel`].
    pub fn new(taps: Vec<f32>, krows: usize, kcols: usize) -> Result<Self> {
        let invalid = |msg: String| Err(Error::with_kind(ErrorKind::InvalidKernel, msg));
        if krows % 2 != 1 || kcols % 2 != 1 {
            return invalid(format!("kernel extents must be odd and non-zero, got {krows}x{kcols}"));
        }
        if taps.len() != krows * kcols {
            return invalid(format!(
                "kernel taps length {} does not match extents {krows}x{kcols}",
                taps.len()
            ));
        }
        if let Some(bad) = taps.iter().find(|t| !t.is_finite()) {
            return invalid(format!("kernel taps must be finite, got {bad}"));
        }
        Ok(Self { taps, krows, kcols })
    }

    /// The outer product of a separable tap vector with itself (odd
    /// length enforced).
    pub fn from_separable(taps: &[f32]) -> Result<Self> {
        if taps.is_empty() || taps.len() % 2 != 1 {
            return Err(Error::with_kind(
                ErrorKind::InvalidKernel,
                format!("kernel width must be odd, got {}", taps.len()),
            ));
        }
        let w = taps.len();
        Self::new(gaussian_kernel2d(taps), w, w)
    }

    pub fn krows(&self) -> usize {
        self.krows
    }

    pub fn kcols(&self) -> usize {
        self.kcols
    }

    /// Row-major taps, `krows × kcols`.
    pub fn taps(&self) -> &[f32] {
        &self.taps
    }

    /// Rank-1 (separability) check: if the matrix is the outer product
    /// of some vector `f` with itself — the only form the crate's
    /// two-pass pipeline can serve, which applies one tap vector on both
    /// axes — return `f`. Tolerance is relative to the largest tap
    /// magnitude. Non-square matrices are never separable here.
    pub fn separable_factors(&self, tol: f32) -> Option<Vec<f32>> {
        if self.krows != self.kcols {
            return None;
        }
        let w = self.kcols;
        // pivot on the largest diagonal element: k = f⊗f makes every
        // diagonal k[j][j] = f[j]² ≥ 0, with at least one positive
        // unless the kernel is all-zero
        let j = (0..w).max_by(|&a, &b| {
            self.taps[a * w + a].abs().partial_cmp(&self.taps[b * w + b].abs()).unwrap()
        })?;
        let pivot = self.taps[j * w + j];
        if pivot <= 0.0 {
            return None;
        }
        let root = pivot.sqrt();
        let f: Vec<f32> = (0..w).map(|u| self.taps[u * w + j] / root).collect();
        let scale = self.taps.iter().fold(1f32, |m, t| m.max(t.abs()));
        for u in 0..w {
            for v in 0..w {
                if (self.taps[u * w + v] - f[u] * f[v]).abs() > tol * scale {
                    return None;
                }
            }
        }
        Some(f)
    }

    /// Stable content digest (FNV-1a over extents and tap bits) — the
    /// plan-cache / batching key component for explicit 2-D kernels.
    pub fn digest(&self) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        let mut mix = |v: u64| {
            for byte in v.to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        mix(self.krows as u64);
        mix(self.kcols as u64);
        for t in &self.taps {
            mix(t.to_bits() as u64);
        }
        h
    }
}

enum KernelSource {
    Spec(KernelSpec),
    Taps(Vec<f32>),
    Taps2d(Kernel2d),
}

/// Validating builder for [`ConvPlan`] — see the module docs for the
/// rejection rules.
pub struct PlanBuilder {
    algorithm: Algorithm,
    variant: Variant,
    layout: Layout,
    kernel: KernelSource,
    class: Option<KernelClass>,
    shape: Option<(usize, usize, usize)>,
    force_generic: bool,
    tile: Option<TileSpec>,
    fuse: bool,
}

impl PlanBuilder {
    fn new() -> Self {
        Self {
            algorithm: Algorithm::TwoPass,
            variant: Variant::Simd,
            layout: Layout::PerPlane,
            kernel: KernelSource::Spec(KernelSpec::default()),
            class: None,
            shape: None,
            force_generic: false,
            tile: None,
            fuse: false,
        }
    }

    pub fn algorithm(mut self, a: Algorithm) -> Self {
        self.algorithm = a;
        self
    }

    pub fn variant(mut self, v: Variant) -> Self {
        self.variant = v;
        self
    }

    pub fn layout(mut self, l: Layout) -> Self {
        self.layout = l;
        self
    }

    /// Kernel by construction parameters (Gaussian width + sigma).
    pub fn kernel(mut self, spec: KernelSpec) -> Self {
        self.kernel = KernelSource::Spec(spec);
        self
    }

    /// Kernel by explicit separable taps (length = width, must be odd).
    pub fn kernel_taps(mut self, taps: Vec<f32>) -> Self {
        self.kernel = KernelSource::Taps(taps);
        self
    }

    /// Kernel by explicit 2-D tap matrix (validated [`Kernel2d`]). With
    /// no explicit class this selects [`KernelClass::Direct2d`]; the
    /// `Separable` class additionally requires the matrix to pass the
    /// rank-1 check ([`Kernel2d::separable_factors`]) and refuses
    /// otherwise with [`ErrorKind::InvalidKernel`].
    pub fn kernel2d(mut self, k: Kernel2d) -> Self {
        self.kernel = KernelSource::Taps2d(k);
        self
    }

    /// Pin the convolver class ([`KernelClass`]). Defaults to
    /// `Separable` for 1-D kernel sources and `Direct2d` for explicit
    /// 2-D matrices. `Fft` rejects tiling and fusion; `Direct2d` rejects
    /// fusion; `Separable` rejects non-rank-1 taps — all at `build()`.
    pub fn kernel_class(mut self, c: KernelClass) -> Self {
        self.class = Some(c);
        self
    }

    /// Image shape the plan serves: `planes` × `rows` × `cols`.
    pub fn shape(mut self, planes: usize, rows: usize, cols: usize) -> Self {
        self.shape = Some((planes, rows, cols));
        self
    }

    /// Disable the width-5 unrolled fast path even when eligible (for
    /// measuring fast-path gain and cross-checking the generic engines).
    pub fn force_generic(mut self, yes: bool) -> Self {
        self.force_generic = yes;
        self
    }

    /// Run every pass over an explicit 2-D tile decomposition instead of
    /// row bands: parallel passes go through the execution model's
    /// `dispatch2d` (tiles are the agglomeration unit — paper Fig. 3),
    /// sequential passes iterate the same tile grid. Tile dimensions
    /// larger than the image clamp; pixels are equivalent to the untiled
    /// plan (≤ 1e-6, property-tested).
    pub fn tile(mut self, spec: TileSpec) -> Self {
        self.tile = Some(spec);
        self
    }

    /// [`PlanBuilder::tile`] with an optional spec — convenience for
    /// config plumbing (`None` keeps the untiled row-band dispatch).
    pub fn tile_opt(mut self, spec: Option<TileSpec>) -> Self {
        self.tile = spec;
        self
    }

    /// Fuse the two-pass pipeline into one rolling row-ring pass: instead
    /// of a horizontal pass that writes a full-plane intermediate and a
    /// vertical pass that re-reads it, each worker keeps a `width`-deep
    /// ring of horizontally filtered rows and emits every output row
    /// immediately. The intermediate stays in cache (scratch shrinks to
    /// O(width × cols) per worker, see [`ConvPlan::ring_footprint`]) and
    /// the image crosses memory once instead of twice — the decisive cost
    /// on bandwidth-bound hardware ([`ConvPlan::traffic_estimate`]).
    /// Pixels are equivalent to the unfused plan (≤ 1e-6; differential
    /// suite in `tests/fused.rs`). Two-pass algorithm only: `build()`
    /// rejects fused single-pass plans.
    pub fn fuse(mut self, yes: bool) -> Self {
        self.fuse = yes;
        self
    }

    /// Validate the full combination and resolve the pass pipeline.
    pub fn build(self) -> Result<ConvPlan> {
        let (planes, rows, cols) = self
            .shape
            .ok_or_else(|| err!("plan needs a shape: call .shape(planes, rows, cols)"))?;
        ensure!(
            planes >= 1 && rows >= 1 && cols >= 1,
            "plan shape must be non-empty, got {planes}x{rows}x{cols}"
        );
        // resolve the kernel source into 1-D taps and/or a 2-D matrix
        let (taps_1d, kernel2d) = match self.kernel {
            KernelSource::Spec(spec) => (Some(spec.taps()?), None),
            KernelSource::Taps(taps) => {
                if taps.is_empty() || taps.len() % 2 != 1 {
                    return Err(Error::with_kind(
                        ErrorKind::InvalidKernel,
                        format!("kernel width must be odd, got {}", taps.len()),
                    ));
                }
                (Some(taps), None)
            }
            KernelSource::Taps2d(k) => (None, Some(k)),
        };
        let class = self.class.unwrap_or(if kernel2d.is_some() {
            KernelClass::Direct2d
        } else {
            KernelClass::Separable
        });
        if let Some(tile) = self.tile {
            tile.validate()?;
        }
        if class == KernelClass::Separable {
            // the paper's ladder — exactly the pre-class behaviour
            let taps = match taps_1d {
                Some(taps) => taps,
                None => {
                    let k = kernel2d.as_ref().expect("2-D source when no 1-D taps");
                    k.separable_factors(1e-5).ok_or_else(|| {
                        Error::with_kind(
                            ErrorKind::InvalidKernel,
                            format!(
                                "{}x{} taps are not separable (rank-1 check failed); \
                                 use kernel class direct2d or fft",
                                k.krows(),
                                k.kcols()
                            ),
                        )
                    })?
                }
            };
            let width = taps.len();
            if self.algorithm == Algorithm::TwoPass && self.variant == Variant::Naive {
                bail!("the paper's naive rung is single-pass only (Opt-0)");
            }
            if self.fuse && self.algorithm != Algorithm::TwoPass {
                bail!(
                    "fusion applies to the separable two-pass algorithm only, got {:?}",
                    self.algorithm
                );
            }
            // tiled pipelines run the generic-width tile primitives, so the
            // fast-path flag is only truthful for untiled plans
            let fast_path = width == 5
                && self.variant != Variant::Naive
                && !self.force_generic
                && self.tile.is_none();
            let passes = match (self.algorithm, self.fuse) {
                (Algorithm::TwoPass, true) => vec![PassKind::Fused],
                (Algorithm::TwoPass, false) => vec![PassKind::Horiz, PassKind::Vert],
                (Algorithm::SinglePassNoCopy, _) => vec![PassKind::SinglePass],
                (Algorithm::SinglePassCopyBack, _) => {
                    vec![PassKind::SinglePass, PassKind::CopyBack]
                }
            };
            // only the direct single-pass engines read the 2-D kernel; the
            // separable passes use the 1-D taps alone
            let k2d = if passes.contains(&PassKind::SinglePass) {
                gaussian_kernel2d(&taps)
            } else {
                Vec::new()
            };
            return Ok(ConvPlan {
                algorithm: self.algorithm,
                variant: self.variant,
                layout: self.layout,
                class,
                planes,
                rows,
                cols,
                taps,
                k2d,
                width,
                krows: width,
                kcols: width,
                passes,
                fast_path,
                tile: self.tile,
                fused: self.fuse,
                fft: None,
            });
        }
        // the direct-2D / FFT classes: arbitrary odd×odd tap matrices,
        // one resolved pass, algorithm knob inert (there is no separable
        // ladder to pick a rung from)
        if self.fuse {
            bail!("fusion applies to the separable class only, got {}", class.label());
        }
        let kernel = match kernel2d {
            Some(k) => k,
            None => Kernel2d::from_separable(&taps_1d.expect("1-D source when no 2-D matrix"))?,
        };
        let (krows, kcols) = (kernel.krows(), kernel.kcols());
        let (passes, fft) = match class {
            KernelClass::Direct2d => (vec![PassKind::Direct2d], None),
            KernelClass::Fft => {
                if self.tile.is_some() {
                    bail!("the fft class runs whole-plane transforms and cannot be tiled");
                }
                let cols_eff = match self.layout {
                    Layout::PerPlane => cols,
                    Layout::Agglomerated => planes * cols,
                };
                let plan = FftPlan::new(rows, cols_eff, kernel.taps(), krows, kcols);
                (vec![PassKind::Fft], Some(plan))
            }
            KernelClass::Separable => unreachable!("handled above"),
        };
        Ok(ConvPlan {
            algorithm: self.algorithm,
            variant: self.variant,
            layout: self.layout,
            class,
            planes,
            rows,
            cols,
            taps: Vec::new(),
            k2d: kernel.taps,
            width: krows.max(kcols),
            krows,
            kcols,
            passes,
            fast_path: false,
            tile: self.tile,
            fused: false,
            fft,
        })
    }
}

/// A validated, resolved convolution plan: build once, execute many
/// times against a [`ScratchArena`]. See the module docs.
pub struct ConvPlan {
    algorithm: Algorithm,
    variant: Variant,
    layout: Layout,
    class: KernelClass,
    planes: usize,
    rows: usize,
    cols: usize,
    taps: Vec<f32>,
    k2d: Vec<f32>,
    width: usize,
    krows: usize,
    kcols: usize,
    passes: Vec<PassKind>,
    fast_path: bool,
    tile: Option<TileSpec>,
    fused: bool,
    fft: Option<FftPlan>,
}

/// Estimated main-memory traffic of one plan execution — see
/// [`ConvPlan::traffic_estimate`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Traffic {
    /// bytes the pass pipeline reads from plane buffers
    pub read_bytes: usize,
    /// bytes the pass pipeline writes to plane buffers
    pub write_bytes: usize,
}

impl Traffic {
    /// The additive identity — graph accounting folds stage shares
    /// onto it.
    pub const ZERO: Traffic = Traffic { read_bytes: 0, write_bytes: 0 };

    /// Element-wise sum: per-stage estimates fold into whole-graph
    /// totals ([`FilterGraph::traffic_estimate`]).
    pub fn accumulate(&mut self, other: Traffic) {
        self.read_bytes += other.read_bytes;
        self.write_bytes += other.write_bytes;
    }

    pub fn total_bytes(&self) -> usize {
        self.read_bytes + self.write_bytes
    }

    /// Total traffic in MiB (table-friendly).
    pub fn total_mb(&self) -> f64 {
        self.total_bytes() as f64 / (1024.0 * 1024.0)
    }
}

impl ConvPlan {
    pub fn builder() -> PlanBuilder {
        PlanBuilder::new()
    }

    pub fn algorithm(&self) -> Algorithm {
        self.algorithm
    }

    pub fn variant(&self) -> Variant {
        self.variant
    }

    pub fn layout(&self) -> Layout {
        self.layout
    }

    /// `(planes, rows, cols)` the plan was built for.
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.planes, self.rows, self.cols)
    }

    /// Kernel width (odd). For rectangular direct-2D/FFT kernels this is
    /// the larger extent; see [`ConvPlan::kernel_extent`].
    pub fn width(&self) -> usize {
        self.width
    }

    /// The convolver class the plan resolved to.
    pub fn class(&self) -> KernelClass {
        self.class
    }

    /// Kernel extents `(krows, kcols)` — equal to `(width, width)` for
    /// separable plans.
    pub fn kernel_extent(&self) -> (usize, usize) {
        (self.krows, self.kcols)
    }

    /// Kernel halo: `max(krows, kcols) / 2` — the border-ring depth the
    /// pass-through contract preserves (equals `width / 2` for
    /// separable plans).
    pub fn halo(&self) -> usize {
        self.width / 2
    }

    /// The separable taps the plan convolves with.
    pub fn taps(&self) -> &[f32] {
        &self.taps
    }

    /// The resolved pass pipeline.
    pub fn passes(&self) -> &[PassKind] {
        &self.passes
    }

    /// True when the width-5 unrolled band primitives were selected.
    pub fn is_fast_path(&self) -> bool {
        self.fast_path
    }

    /// The 2-D tile decomposition the plan dispatches with (`None` =
    /// untiled row bands).
    pub fn tile(&self) -> Option<TileSpec> {
        self.tile
    }

    /// True when the two passes are fused into one rolling row-ring
    /// pass ([`PlanBuilder::fuse`]).
    pub fn fused(&self) -> bool {
        self.fused
    }

    /// Ring elements per worker for a fused pass dispatched over `cols`
    /// columns (tile-clamped for tiled plans).
    fn ring_slot_len(&self, cols: usize) -> usize {
        let interior = cols.saturating_sub(2 * self.halo());
        let cap = match self.tile {
            Some(spec) => interior.min(spec.cols),
            None => interior,
        };
        self.width * cap
    }

    /// Per-worker fused scratch footprint in `f32` elements — the whole
    /// point of fusion: O(width × cols) per worker instead of the
    /// O(rows × cols) intermediate plane the unfused two-pass writes.
    /// 0 for unfused plans (their intermediate is a full B plane).
    pub fn ring_footprint(&self) -> usize {
        if !self.fused {
            return 0;
        }
        let cols_eff = match self.layout {
            Layout::PerPlane => self.cols,
            Layout::Agglomerated => self.planes * self.cols,
        };
        self.ring_slot_len(cols_eff)
    }

    /// Estimated main-memory traffic of one execution: per pass, one
    /// full read of the source plane plus one write of the interior
    /// (copy-back reads and writes whole planes). The fused pipeline is
    /// a single pass, so it moves half of what the unfused two-pass
    /// moves; its row-ring is excluded because it stays resident in
    /// L1/L2 (the fusion argument — Hofmann et al., PAPERS.md). The
    /// initial image→scratch copy is identical for every plan and is
    /// not counted.
    pub fn traffic_estimate(&self) -> Traffic {
        const F32: usize = std::mem::size_of::<f32>();
        let (planes_eff, rows, cols) = match self.layout {
            Layout::PerPlane => (self.planes, self.rows, self.cols),
            Layout::Agglomerated => (1, self.rows, self.planes * self.cols),
        };
        let h = self.halo();
        let plane = rows * cols * F32;
        let interior = rows.saturating_sub(2 * h) * cols.saturating_sub(2 * h) * F32;
        let (mut read, mut written) = (0usize, 0usize);
        for &pass in &self.passes {
            let (r, w) = match pass {
                PassKind::Horiz | PassKind::Vert | PassKind::SinglePass | PassKind::Fused => {
                    (plane, interior)
                }
                PassKind::Direct2d => (plane, interior),
                PassKind::Fft => {
                    // the padded complex plane (two f64 halves) crosses
                    // memory once per transform stage: forward,
                    // pointwise spectrum multiply, inverse — kernel-size
                    // independent, which is the whole crossover argument
                    let (nr, nc) = self.fft.as_ref().map(FftPlan::padded).unwrap_or((rows, cols));
                    let padded = nr * nc * std::mem::size_of::<f64>() * 2;
                    (3 * padded, 3 * padded)
                }
                PassKind::CopyBack => (plane, plane),
            };
            read += r;
            written += w;
        }
        Traffic { read_bytes: planes_eff * read, write_bytes: planes_eff * written }
    }

    /// Human-readable one-stop description of what the plan resolved to:
    /// class, engine rung, layout, kernel extent, pass pipeline, tiling
    /// and fusion state, and the traffic estimate. The CLI's plan
    /// provenance line and the crossover exhibit print this.
    pub fn explain(&self) -> String {
        let mut s = format!(
            "class={} algorithm={:?} variant={:?} layout={:?} kernel={}x{} shape={}x{}x{}",
            self.class.label(),
            self.algorithm,
            self.variant,
            self.layout,
            self.krows,
            self.kcols,
            self.planes,
            self.rows,
            self.cols,
        );
        let passes: Vec<String> = self.passes.iter().map(|p| format!("{p:?}")).collect();
        s.push_str(&format!(" passes=[{}]", passes.join(",")));
        if let Some(t) = self.tile {
            s.push_str(&format!(" tile={}", t.label()));
        }
        if self.fused {
            s.push_str(" fused");
        }
        if self.fast_path {
            s.push_str(" fast-path");
        }
        if let Some(fft) = &self.fft {
            let (nr, nc) = fft.padded();
            s.push_str(&format!(" padded={nr}x{nc}"));
        }
        s.push_str(&format!(" traffic={:.2}MiB", self.traffic_estimate().total_mb()));
        s
    }

    // -- whole-image execution -------------------------------------------

    /// Convolve sequentially (no execution model). Scratch comes from
    /// `arena`; only the returned image is freshly allocated.
    pub fn execute(&self, img: &PlanarImage, arena: &mut ScratchArena) -> Result<PlanarImage> {
        self.execute_image(Exec::Seq, img, arena)
    }

    /// Convolve with each pass banded across `model`'s workers.
    pub fn execute_on(
        &self,
        model: &dyn ExecutionModel,
        img: &PlanarImage,
        arena: &mut ScratchArena,
    ) -> Result<PlanarImage> {
        self.execute_image(Exec::Par(model), img, arena)
    }

    /// Convolve a batch of images under one plan (all must match the
    /// plan's shape). `model: None` runs sequentially.
    ///
    /// Every member's shape is checked **up front**, so a mismatched
    /// image refuses the whole batch before any pixels are produced —
    /// the coordinator's batched serve path relies on all-or-nothing
    /// semantics rather than a half-convolved batch. Accepts any
    /// iterable of image refs (slices, `Vec<&_>`, job iterators).
    pub fn execute_batch<'a>(
        &self,
        model: Option<&dyn ExecutionModel>,
        imgs: impl IntoIterator<Item = &'a PlanarImage>,
        arena: &mut ScratchArena,
    ) -> Result<Vec<PlanarImage>> {
        let imgs: Vec<&PlanarImage> = imgs.into_iter().collect();
        for (i, img) in imgs.iter().enumerate() {
            ensure!(
                (img.planes, img.rows, img.cols) == (self.planes, self.rows, self.cols),
                "batch member {}: image {}x{}x{} does not match plan shape {}x{}x{}",
                i,
                img.planes,
                img.rows,
                img.cols,
                self.planes,
                self.rows,
                self.cols
            );
        }
        let exec = match model {
            Some(m) => Exec::Par(m),
            None => Exec::Seq,
        };
        imgs.into_iter().map(|img| self.execute_image(exec, img, arena)).collect()
    }

    /// Convolve into a caller-owned output buffer — plane-major
    /// `(P,R,C)` for [`Layout::PerPlane`], wide `(R, P·C)` for
    /// [`Layout::Agglomerated`]. After the first call neither `out` nor
    /// the arena re-allocates.
    pub fn execute_into(
        &self,
        model: Option<&dyn ExecutionModel>,
        img: &PlanarImage,
        arena: &mut ScratchArena,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let exec = match model {
            Some(m) => Exec::Par(m),
            None => Exec::Seq,
        };
        self.execute_core(exec, img, arena, Sink::Buffer(out))
    }

    /// Convolve and discard the result: the timing-loop shape (no output
    /// copy at all — scratch in, scratch out).
    pub fn execute_discard(
        &self,
        model: Option<&dyn ExecutionModel>,
        img: &PlanarImage,
        arena: &mut ScratchArena,
    ) -> Result<()> {
        let exec = match model {
            Some(m) => Exec::Par(m),
            None => Exec::Seq,
        };
        self.execute_core(exec, img, arena, Sink::None)
    }

    fn execute_image(
        &self,
        exec: Exec<'_>,
        img: &PlanarImage,
        arena: &mut ScratchArena,
    ) -> Result<PlanarImage> {
        // the image is built straight from the scratch buffer (one copy),
        // not via an intermediate layout buffer
        let mut slot = None;
        self.execute_core(exec, img, arena, Sink::Image(&mut slot))?;
        Ok(slot.expect("image sink filled on success"))
    }

    fn execute_core(
        &self,
        exec: Exec<'_>,
        img: &PlanarImage,
        arena: &mut ScratchArena,
        sink: Sink<'_>,
    ) -> Result<()> {
        ensure!(
            (img.planes, img.rows, img.cols) == (self.planes, self.rows, self.cols),
            "image shape {}x{}x{} does not match plan shape {}x{}x{}",
            img.planes,
            img.rows,
            img.cols,
            self.planes,
            self.rows,
            self.cols
        );
        let n = self.planes * self.rows * self.cols;
        let mut a = arena.take(n);
        let mut b = arena.take(n);
        match self.layout {
            Layout::PerPlane => {
                a.copy_from_slice(&img.data);
                // B nominally "starts as a copy of A", but only its
                // border ring is ever read before being written (the
                // vertical pass reads B's top/bottom halo rows; the
                // single-pass result's pass-through pixels are B's
                // border) — so only the ring is copied.
                load_border_ring(&mut b, img, self.halo());
                let plane_len = self.rows * self.cols;
                for p in 0..self.planes {
                    let ap = &mut a[p * plane_len..(p + 1) * plane_len];
                    let bp = &mut b[p * plane_len..(p + 1) * plane_len];
                    self.run_passes(exec, ap, bp, self.rows, self.cols, Some(&mut *arena));
                }
            }
            Layout::Agglomerated => {
                // fold planes into the 3R×C wide layout without allocating
                let (rows, cols, wc) = (self.rows, self.cols, self.planes * self.cols);
                for i in 0..rows {
                    for p in 0..self.planes {
                        let plane = img.plane(p);
                        a[i * wc + p * cols..i * wc + (p + 1) * cols]
                            .copy_from_slice(&plane[i * cols..(i + 1) * cols]);
                    }
                }
                b.copy_from_slice(&a);
                self.run_passes(exec, &mut a, &mut b, rows, wc, Some(&mut *arena));
            }
        }
        let result: &[f32] = match self.result_home() {
            ResultHome::A => &a,
            ResultHome::B => &b,
        };
        let sunk = match sink {
            Sink::None => Ok(()),
            Sink::Buffer(out) => {
                out.clear();
                out.extend_from_slice(result);
                Ok(())
            }
            Sink::Image(slot) => {
                let image = match self.layout {
                    Layout::PerPlane => PlanarImage::from_vec(
                        self.planes,
                        self.rows,
                        self.cols,
                        result.to_vec(),
                    ),
                    Layout::Agglomerated => PlanarImage::from_agglomerated(
                        self.planes,
                        self.rows,
                        self.cols,
                        result,
                    ),
                };
                image.map(|im| *slot = Some(im))
            }
        };
        arena.put(a);
        arena.put(b);
        sunk
    }

    fn result_home(&self) -> ResultHome {
        // the fused pipeline is a single A→B pass, so like no-copy its
        // result lives in B (whose border ring carries the pass-through);
        // direct-2D and FFT plans are likewise single A→B passes
        if self.fused || self.class != KernelClass::Separable {
            return ResultHome::B;
        }
        match self.algorithm {
            Algorithm::SinglePassNoCopy => ResultHome::B,
            _ => ResultHome::A,
        }
    }

    // -- plane-level execution (expert API for caller-owned buffers) -----

    /// Run the pipeline over one caller-owned plane pair, sequentially.
    ///
    /// `a` is the source (and, except for no-copy and fused plans, the
    /// result); `b` is scratch that must start as a copy of `a` at least
    /// on its border ring. Requires a single-plane plan
    /// (`shape(1, rows, cols)`); the dispatch width is the plan's `cols`
    /// (pass the widened column count for agglomerated planes). Fused
    /// plans allocate their row-ring per call on this arena-less expert
    /// path — use `execute*` with a [`ScratchArena`] for zero-alloc
    /// serving.
    pub fn run_plane(&self, a: &mut [f32], b: &mut [f32]) -> Result<()> {
        self.run_plane_exec(Exec::Seq, a, b)
    }

    /// [`Self::run_plane`], banded across an execution model.
    pub fn run_plane_on(
        &self,
        model: &dyn ExecutionModel,
        a: &mut [f32],
        b: &mut [f32],
    ) -> Result<()> {
        self.run_plane_exec(Exec::Par(model), a, b)
    }

    fn run_plane_exec(&self, exec: Exec<'_>, a: &mut [f32], b: &mut [f32]) -> Result<()> {
        ensure!(
            self.planes == 1,
            "run_plane requires a single-plane plan (this one has {} planes); use execute()",
            self.planes
        );
        let n = self.rows * self.cols;
        ensure!(
            a.len() == n && b.len() == n,
            "plane buffers must be rows*cols = {n}, got a={} b={}",
            a.len(),
            b.len()
        );
        self.run_passes(exec, a, b, self.rows, self.cols, None);
        Ok(())
    }
}

/// Where an execution's result goes: nowhere (timing loops), a raw
/// layout buffer, or a freshly built [`PlanarImage`] (one copy straight
/// from the scratch plane in every case).
enum Sink<'o> {
    None,
    Buffer(&'o mut Vec<f32>),
    Image(&'o mut Option<PlanarImage>),
}

/// Copy only the halo-wide border ring of each plane of `img` into `b`
/// (everything the pipeline may read of B before writing it). Planes too
/// small to have an interior are copied whole.
fn load_border_ring(b: &mut [f32], img: &PlanarImage, h: usize) {
    let (rows, cols) = (img.rows, img.cols);
    if rows <= 2 * h || cols <= 2 * h {
        b.copy_from_slice(&img.data);
        return;
    }
    let plane_len = rows * cols;
    for p in 0..img.planes {
        let src = &img.data[p * plane_len..(p + 1) * plane_len];
        let dst = &mut b[p * plane_len..(p + 1) * plane_len];
        // top and bottom h rows
        dst[..h * cols].copy_from_slice(&src[..h * cols]);
        dst[(rows - h) * cols..].copy_from_slice(&src[(rows - h) * cols..]);
        // left and right h columns of the interior rows
        for i in h..rows - h {
            dst[i * cols..i * cols + h].copy_from_slice(&src[i * cols..i * cols + h]);
            dst[(i + 1) * cols - h..(i + 1) * cols]
                .copy_from_slice(&src[(i + 1) * cols - h..(i + 1) * cols]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::{synth_image, Pattern};
    use crate::models::OpenMpModel;

    fn img(planes: usize, rows: usize, cols: usize) -> PlanarImage {
        synth_image(planes, rows, cols, Pattern::Noise, 42)
    }

    fn base_plan(alg: Algorithm, variant: Variant) -> ConvPlan {
        ConvPlan::builder()
            .algorithm(alg)
            .variant(variant)
            .shape(3, 24, 20)
            .build()
            .unwrap()
    }

    #[test]
    fn builder_defaults_and_accessors() {
        let p = ConvPlan::builder().shape(3, 24, 20).build().unwrap();
        assert_eq!(p.algorithm(), Algorithm::TwoPass);
        assert_eq!(p.variant(), Variant::Simd);
        assert_eq!(p.layout(), Layout::PerPlane);
        assert_eq!(p.shape(), (3, 24, 20));
        assert_eq!(p.width(), 5);
        assert_eq!(p.halo(), 2);
        assert!(p.is_fast_path());
        assert_eq!(p.passes(), &[PassKind::Horiz, PassKind::Vert]);
    }

    #[test]
    fn pipeline_resolution_per_algorithm() {
        let p = base_plan(Algorithm::SinglePassNoCopy, Variant::Simd);
        assert_eq!(p.passes(), &[PassKind::SinglePass]);
        let p = base_plan(Algorithm::SinglePassCopyBack, Variant::Scalar);
        assert_eq!(p.passes(), &[PassKind::SinglePass, PassKind::CopyBack]);
    }

    #[test]
    fn build_rejects_silently_wrong_combos() {
        // naive + two-pass
        let e = ConvPlan::builder()
            .algorithm(Algorithm::TwoPass)
            .variant(Variant::Naive)
            .shape(1, 16, 16)
            .build();
        assert!(e.is_err());
        // even kernel width (spec and taps)
        assert!(ConvPlan::builder()
            .kernel(KernelSpec::new(4, 1.0))
            .shape(1, 16, 16)
            .build()
            .is_err());
        assert!(ConvPlan::builder()
            .kernel_taps(vec![0.25; 4])
            .shape(1, 16, 16)
            .build()
            .is_err());
        // empty taps, bad sigma, missing/empty shape
        assert!(ConvPlan::builder().kernel_taps(vec![]).shape(1, 16, 16).build().is_err());
        assert!(ConvPlan::builder()
            .kernel(KernelSpec::new(5, 0.0))
            .shape(1, 16, 16)
            .build()
            .is_err());
        assert!(ConvPlan::builder().build().is_err());
        assert!(ConvPlan::builder().shape(0, 16, 16).build().is_err());
    }

    #[test]
    fn fast_path_selection_rules() {
        // width 5 + unrolled variant → fast
        assert!(base_plan(Algorithm::TwoPass, Variant::Simd).is_fast_path());
        // naive is the generic engine by definition
        assert!(!base_plan(Algorithm::SinglePassCopyBack, Variant::Naive).is_fast_path());
        // non-5 widths → generic
        let p = ConvPlan::builder()
            .kernel(KernelSpec::new(7, 1.0))
            .shape(1, 24, 24)
            .build()
            .unwrap();
        assert!(!p.is_fast_path());
        // forced generic at width 5
        let p = ConvPlan::builder().force_generic(true).shape(1, 24, 24).build().unwrap();
        assert!(!p.is_fast_path());
    }

    #[test]
    fn execute_matches_legacy_sequential_driver() {
        let image = img(3, 24, 20);
        let k = gaussian_kernel(5, 1.0);
        let mut arena = ScratchArena::new();
        for alg in [Algorithm::TwoPass, Algorithm::SinglePassCopyBack, Algorithm::SinglePassNoCopy]
        {
            for variant in [Variant::Scalar, Variant::Simd] {
                let want =
                    crate::conv::convolve_image(image.clone(), &k, alg, variant).unwrap();
                let plan = ConvPlan::builder()
                    .algorithm(alg)
                    .variant(variant)
                    .shape(3, 24, 20)
                    .build()
                    .unwrap();
                let got = plan.execute(&image, &mut arena).unwrap();
                assert_eq!(got, want, "{alg:?} {variant:?}");
            }
        }
    }

    #[test]
    fn execute_on_matches_sequential() {
        let image = img(3, 30, 26);
        let model = OpenMpModel::new(4);
        let mut arena = ScratchArena::new();
        for layout in [Layout::PerPlane, Layout::Agglomerated] {
            let plan = ConvPlan::builder().layout(layout).shape(3, 30, 26).build().unwrap();
            let seq = plan.execute(&image, &mut arena).unwrap();
            let par = plan.execute_on(&model, &image, &mut arena).unwrap();
            assert_eq!(seq, par, "{layout:?}");
        }
    }

    #[test]
    fn execute_rejects_shape_mismatch() {
        let plan = ConvPlan::builder().shape(3, 24, 20).build().unwrap();
        let mut arena = ScratchArena::new();
        assert!(plan.execute(&img(3, 20, 24), &mut arena).is_err());
        assert!(plan.execute(&img(1, 24, 20), &mut arena).is_err());
    }

    #[test]
    fn execute_into_layout_contracts() {
        let image = img(3, 24, 20);
        let mut arena = ScratchArena::new();
        let mut out = Vec::new();
        let plan = ConvPlan::builder().shape(3, 24, 20).build().unwrap();
        plan.execute_into(None, &image, &mut arena, &mut out).unwrap();
        let want = plan.execute(&image, &mut arena).unwrap();
        assert_eq!(out, want.data, "PerPlane: plane-major buffer");

        let plan =
            ConvPlan::builder().layout(Layout::Agglomerated).shape(3, 24, 20).build().unwrap();
        plan.execute_into(None, &image, &mut arena, &mut out).unwrap();
        let want = plan.execute(&image, &mut arena).unwrap();
        assert_eq!(out, want.agglomerate(), "Agglomerated: wide buffer");
    }

    #[test]
    fn execute_batch_matches_singles() {
        let imgs: Vec<PlanarImage> =
            (0..3).map(|s| synth_image(2, 20, 18, Pattern::Noise, s)).collect();
        let plan = ConvPlan::builder().shape(2, 20, 18).build().unwrap();
        let model = OpenMpModel::new(2);
        let mut arena = ScratchArena::new();
        let batch = plan.execute_batch(Some(&model), &imgs, &mut arena).unwrap();
        assert_eq!(batch.len(), 3);
        for (one, image) in batch.iter().zip(&imgs) {
            let single = plan.execute(image, &mut arena).unwrap();
            assert_eq!(*one, single);
        }
    }

    #[test]
    fn execute_batch_rejects_shape_mismatch_up_front() {
        let good = img(2, 20, 18);
        let bad = img(2, 18, 20);
        let plan = ConvPlan::builder().shape(2, 20, 18).build().unwrap();
        let mut arena = ScratchArena::new();
        let e = plan.execute_batch(None, [&good, &bad], &mut arena).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("batch member 1"), "names the offender: {msg}");
        // the good member alone still serves
        assert!(plan.execute_batch(None, [&good], &mut arena).is_ok());
    }

    #[test]
    fn arena_stops_allocating_after_warmup() {
        let image = img(3, 32, 28);
        let plan = ConvPlan::builder().shape(3, 32, 28).build().unwrap();
        let mut arena = ScratchArena::new();
        plan.execute(&image, &mut arena).unwrap();
        let warm = arena.allocations();
        for _ in 0..10 {
            plan.execute(&image, &mut arena).unwrap();
        }
        assert_eq!(arena.allocations(), warm, "steady state must not allocate scratch");
    }

    #[test]
    fn generic_width_plans_execute() {
        let image = img(1, 26, 26);
        let mut arena = ScratchArena::new();
        for width in [3usize, 7, 9] {
            let plan = ConvPlan::builder()
                .kernel(KernelSpec::new(width, 1.0))
                .shape(1, 26, 26)
                .build()
                .unwrap();
            let out = plan.execute(&image, &mut arena).unwrap();
            // border ring passes through untouched
            for j in 0..26 {
                assert_eq!(out.get(0, 0, j), image.get(0, 0, j), "width {width}");
            }
        }
    }

    #[test]
    fn degenerate_shapes_pass_through_without_panic() {
        // planes narrower than the kernel have no interior: every
        // algorithm/variant must return the input unchanged (never
        // panic), including the width-5 fast path on 1–3 column images
        let mut arena = ScratchArena::new();
        for (rows, cols) in [(1usize, 1usize), (3, 1), (1, 3), (3, 3), (16, 2), (2, 16), (4, 4)] {
            let image = synth_image(2, rows, cols, Pattern::Noise, 7);
            for variant in [Variant::Naive, Variant::Scalar, Variant::Simd] {
                for alg in [Algorithm::SinglePassCopyBack, Algorithm::SinglePassNoCopy] {
                    let plan = ConvPlan::builder()
                        .algorithm(alg)
                        .variant(variant)
                        .shape(2, rows, cols)
                        .build()
                        .unwrap();
                    let out = plan.execute(&image, &mut arena).unwrap();
                    assert_eq!(out, image, "{rows}x{cols} {alg:?} {variant:?}");
                }
            }
            let plan = ConvPlan::builder().shape(2, rows, cols).build().unwrap();
            let out = plan.execute(&image, &mut arena).unwrap();
            assert_eq!(out, image, "{rows}x{cols} two-pass");
        }
    }

    #[test]
    fn run_plane_requires_single_plane_plan() {
        let plan = ConvPlan::builder().shape(3, 16, 16).build().unwrap();
        let mut a = vec![0f32; 3 * 16 * 16];
        let mut b = a.clone();
        assert!(plan.run_plane(&mut a, &mut b).is_err());
        let plan = ConvPlan::builder().shape(1, 16, 16).build().unwrap();
        let mut a = vec![0f32; 16 * 16];
        let mut b = a.clone();
        assert!(plan.run_plane(&mut a, &mut b).is_ok());
        assert!(plan.run_plane(&mut a[..100].to_vec(), &mut b).is_err());
    }

    #[test]
    fn tiled_builder_contract() {
        // zero tile dimensions are structured errors
        assert!(ConvPlan::builder()
            .tile(TileSpec::new(0, 4))
            .shape(1, 16, 16)
            .build()
            .is_err());
        // a tiled plan reports its spec and opts out of the W=5 fast path
        let p = ConvPlan::builder()
            .tile(TileSpec::new(8, 8))
            .shape(1, 24, 24)
            .build()
            .unwrap();
        assert_eq!(p.tile(), Some(TileSpec::new(8, 8)));
        assert!(!p.is_fast_path(), "tiled plans run the generic tile engines");
        // tile_opt(None) keeps untiled row-band dispatch
        let p = ConvPlan::builder().tile_opt(None).shape(1, 24, 24).build().unwrap();
        assert_eq!(p.tile(), None);
        assert!(p.is_fast_path());
    }

    #[test]
    fn tiled_execution_matches_untiled() {
        let image = img(3, 30, 26);
        let model = OpenMpModel::new(4);
        let mut arena = ScratchArena::new();
        for alg in [Algorithm::TwoPass, Algorithm::SinglePassCopyBack, Algorithm::SinglePassNoCopy]
        {
            for variant in [Variant::Scalar, Variant::Simd] {
                for layout in [Layout::PerPlane, Layout::Agglomerated] {
                    let untiled = ConvPlan::builder()
                        .algorithm(alg)
                        .variant(variant)
                        .layout(layout)
                        .shape(3, 30, 26)
                        .build()
                        .unwrap();
                    let tiled = ConvPlan::builder()
                        .algorithm(alg)
                        .variant(variant)
                        .layout(layout)
                        .tile(TileSpec::new(7, 9))
                        .shape(3, 30, 26)
                        .build()
                        .unwrap();
                    let want = untiled.execute(&image, &mut arena).unwrap();
                    let seq = tiled.execute(&image, &mut arena).unwrap();
                    let par = tiled.execute_on(&model, &image, &mut arena).unwrap();
                    assert!(
                        seq.max_abs_diff(&want) <= 1e-6,
                        "{alg:?} {variant:?} {layout:?} seq-tiled"
                    );
                    assert!(
                        par.max_abs_diff(&want) <= 1e-6,
                        "{alg:?} {variant:?} {layout:?} par-tiled"
                    );
                }
            }
        }
    }

    #[test]
    fn tile_larger_than_image_degenerates_to_untiled_cover() {
        let image = img(2, 20, 18);
        let mut arena = ScratchArena::new();
        let untiled = ConvPlan::builder().shape(2, 20, 18).build().unwrap();
        let tiled = ConvPlan::builder()
            .tile(TileSpec::new(usize::MAX, usize::MAX))
            .shape(2, 20, 18)
            .build()
            .unwrap();
        let want = untiled.execute(&image, &mut arena).unwrap();
        let got = tiled.execute(&image, &mut arena).unwrap();
        assert!(got.max_abs_diff(&want) <= 1e-6);
    }

    #[test]
    fn fused_builder_contract() {
        // fused two-pass resolves to the single fused pass, result in B
        let p = ConvPlan::builder().fuse(true).shape(1, 24, 24).build().unwrap();
        assert!(p.fused());
        assert_eq!(p.passes(), &[PassKind::Fused]);
        assert!(p.is_fast_path(), "W=5 untiled fused keeps the unrolled fast path");
        assert_eq!(p.ring_footprint(), 5 * (24 - 4));
        // fuse(false) is the unfused default
        let p = ConvPlan::builder().fuse(false).shape(1, 24, 24).build().unwrap();
        assert!(!p.fused());
        assert_eq!(p.ring_footprint(), 0, "unfused plans lease no ring");
        // fusion is a two-pass-only knob
        for alg in [Algorithm::SinglePassCopyBack, Algorithm::SinglePassNoCopy] {
            assert!(
                ConvPlan::builder().algorithm(alg).fuse(true).shape(1, 24, 24).build().is_err(),
                "{alg:?}"
            );
        }
        // tiled fused: ring is clamped to the tile width
        let p = ConvPlan::builder()
            .fuse(true)
            .tile(TileSpec::new(8, 6))
            .shape(1, 24, 24)
            .build()
            .unwrap();
        assert_eq!(p.ring_footprint(), 5 * 6);
        // agglomerated: the ring spans the widened plane
        let p = ConvPlan::builder()
            .fuse(true)
            .layout(Layout::Agglomerated)
            .shape(3, 24, 24)
            .build()
            .unwrap();
        assert_eq!(p.ring_footprint(), 5 * (3 * 24 - 4));
    }

    #[test]
    fn fused_execution_matches_unfused() {
        let image = img(3, 30, 26);
        let model = OpenMpModel::new(4);
        let mut arena = ScratchArena::new();
        for variant in [Variant::Scalar, Variant::Simd] {
            for layout in [Layout::PerPlane, Layout::Agglomerated] {
                let unfused = ConvPlan::builder()
                    .variant(variant)
                    .layout(layout)
                    .shape(3, 30, 26)
                    .build()
                    .unwrap();
                let fused = ConvPlan::builder()
                    .variant(variant)
                    .layout(layout)
                    .fuse(true)
                    .shape(3, 30, 26)
                    .build()
                    .unwrap();
                let want = unfused.execute(&image, &mut arena).unwrap();
                let seq = fused.execute(&image, &mut arena).unwrap();
                let par = fused.execute_on(&model, &image, &mut arena).unwrap();
                assert_eq!(seq, want, "{variant:?} {layout:?} seq: same tap order ⇒ bitwise");
                assert_eq!(par, want, "{variant:?} {layout:?} par");
            }
        }
    }

    #[test]
    fn fused_arena_stops_allocating_after_warmup() {
        let image = img(3, 32, 28);
        let plan = ConvPlan::builder().fuse(true).shape(3, 32, 28).build().unwrap();
        let model = OpenMpModel::new(4);
        let mut arena = ScratchArena::new();
        plan.execute_on(&model, &image, &mut arena).unwrap();
        let warm = arena.allocations();
        for _ in 0..10 {
            plan.execute_on(&model, &image, &mut arena).unwrap();
        }
        assert_eq!(arena.allocations(), warm, "ring leases must recycle");
    }

    #[test]
    fn fused_degenerate_shapes_pass_through() {
        let mut arena = ScratchArena::new();
        for (rows, cols) in [(1usize, 1usize), (3, 1), (1, 3), (3, 3), (16, 2), (2, 16), (4, 4)] {
            let image = synth_image(2, rows, cols, Pattern::Noise, 8);
            let plan = ConvPlan::builder().fuse(true).shape(2, rows, cols).build().unwrap();
            let out = plan.execute(&image, &mut arena).unwrap();
            assert_eq!(out, image, "{rows}x{cols} fused two-pass");
        }
    }

    #[test]
    fn traffic_estimate_shows_the_fusion_halving() {
        let unfused = ConvPlan::builder().shape(3, 256, 256).build().unwrap();
        let fused = ConvPlan::builder().fuse(true).shape(3, 256, 256).build().unwrap();
        let (tu, tf) = (unfused.traffic_estimate(), fused.traffic_estimate());
        assert_eq!(tu.read_bytes, 2 * tf.read_bytes);
        assert_eq!(tu.write_bytes, 2 * tf.write_bytes);
        assert_eq!(tu.total_bytes(), 2 * tf.total_bytes());
        assert!(tf.total_mb() > 0.0);
        // copy-back moves more than no-copy at the same shape
        let cb = ConvPlan::builder()
            .algorithm(Algorithm::SinglePassCopyBack)
            .shape(3, 256, 256)
            .build()
            .unwrap();
        let nc = ConvPlan::builder()
            .algorithm(Algorithm::SinglePassNoCopy)
            .shape(3, 256, 256)
            .build()
            .unwrap();
        assert!(cb.traffic_estimate().total_bytes() > nc.traffic_estimate().total_bytes());
    }

    #[test]
    fn kernel_spec_validation_and_key() {
        assert!(KernelSpec::new(5, 1.0).validate().is_ok());
        assert!(KernelSpec::new(2, 1.0).validate().is_err());
        assert!(KernelSpec::new(5, -1.0).validate().is_err());
        assert_eq!(KernelSpec::default(), KernelSpec::new(5, 1.0));
        assert_eq!(KernelSpec::new(5, 1.0).cache_key(), KernelSpec::default().cache_key());
        assert_ne!(KernelSpec::new(5, 2.0).cache_key(), KernelSpec::default().cache_key());
    }

    #[test]
    fn kernel_refusals_carry_invalid_kernel_kind() {
        use crate::util::error::ErrorKind;
        // every structural kernel refusal is machine-matchable
        assert_eq!(KernelSpec::new(4, 1.0).validate().unwrap_err().kind(), ErrorKind::InvalidKernel);
        assert_eq!(KernelSpec::new(0, 1.0).validate().unwrap_err().kind(), ErrorKind::InvalidKernel);
        assert_eq!(KernelSpec::new(5, 0.0).validate().unwrap_err().kind(), ErrorKind::InvalidKernel);
        let e = KernelSpec::new(4, 1.0).validate().unwrap_err();
        assert!(format!("{e:#}").contains("odd"), "message still names the rule: {e:#}");
        // 2-D extents: even, zero, length mismatch, non-finite taps
        for (taps, kr, kc) in [
            (vec![0.0; 6], 2usize, 3usize),
            (vec![0.0; 3], 3, 0),
            (vec![0.0; 8], 3, 3),
            (vec![f32::NAN; 9], 3, 3),
        ] {
            let e = Kernel2d::new(taps, kr, kc).unwrap_err();
            assert_eq!(e.kind(), ErrorKind::InvalidKernel, "{kr}x{kc}");
        }
        // builder entry points propagate the kind
        let e = ConvPlan::builder().kernel_taps(vec![0.5; 4]).shape(1, 16, 16).build().unwrap_err();
        assert_eq!(e.kind(), ErrorKind::InvalidKernel);
        let e = ConvPlan::builder()
            .kernel(KernelSpec::new(6, 1.0))
            .shape(1, 16, 16)
            .build()
            .unwrap_err();
        assert_eq!(e.kind(), ErrorKind::InvalidKernel);
    }

    #[test]
    fn kernel_class_labels_parse_round_trip() {
        for c in KernelClass::ALL {
            assert_eq!(KernelClass::parse(c.label()).unwrap(), c);
        }
        assert_eq!(KernelClass::parse("direct").unwrap(), KernelClass::Direct2d);
        let e = KernelClass::parse("wavelet").unwrap_err();
        assert_eq!(e.kind(), crate::util::error::ErrorKind::InvalidKernel);
        assert_eq!(KernelClass::default(), KernelClass::Separable);
    }

    #[test]
    fn separability_check_accepts_rank_one_rejects_others() {
        // a Gaussian outer product factors back into (±) its taps
        let taps = gaussian_kernel(7, 1.3);
        let k = Kernel2d::from_separable(&taps).unwrap();
        let f = k.separable_factors(1e-5).expect("gaussian outer product is rank-1");
        for (a, b) in f.iter().zip(&taps) {
            assert!((a.abs() - b.abs()).abs() <= 1e-5, "{a} vs {b}");
        }
        // the discrete Laplacian is the canonical non-separable kernel
        let lap = Kernel2d::new(vec![0.0, 1.0, 0.0, 1.0, -4.0, 1.0, 0.0, 1.0, 0.0], 3, 3).unwrap();
        assert!(lap.separable_factors(1e-4).is_none());
        // rectangular matrices are never separable for this pipeline
        let rect = Kernel2d::new(vec![1.0; 15], 3, 5).unwrap();
        assert!(rect.separable_factors(1e-4).is_none());
        // digest distinguishes contents and extents
        assert_ne!(lap.digest(), rect.digest());
        assert_eq!(lap.digest(), lap.clone().digest());
    }

    #[test]
    fn class_builder_contract() {
        let lap = Kernel2d::new(vec![0.0, 1.0, 0.0, 1.0, -4.0, 1.0, 0.0, 1.0, 0.0], 3, 3).unwrap();
        // explicit 2-D taps default to the direct-2D class
        let p = ConvPlan::builder().kernel2d(lap.clone()).shape(1, 24, 24).build().unwrap();
        assert_eq!(p.class(), KernelClass::Direct2d);
        assert_eq!(p.kernel_extent(), (3, 3));
        assert_eq!(p.passes(), &[PassKind::Direct2d]);
        assert!(!p.is_fast_path());
        // separable class refuses non-rank-1 taps with the structured kind
        let e = ConvPlan::builder()
            .kernel2d(lap.clone())
            .kernel_class(KernelClass::Separable)
            .shape(1, 24, 24)
            .build()
            .unwrap_err();
        assert_eq!(e.kind(), crate::util::error::ErrorKind::InvalidKernel);
        // ...but accepts a rank-1 matrix and runs the ordinary ladder
        let g = KernelSpec::new(5, 1.0).taps2d().unwrap();
        let p = ConvPlan::builder()
            .kernel2d(g)
            .kernel_class(KernelClass::Separable)
            .shape(1, 24, 24)
            .build()
            .unwrap();
        assert_eq!(p.class(), KernelClass::Separable);
        assert_eq!(p.passes(), &[PassKind::Horiz, PassKind::Vert]);
        // fft rejects tiling; non-separable classes reject fusion
        assert!(ConvPlan::builder()
            .kernel_class(KernelClass::Fft)
            .tile(TileSpec::new(8, 8))
            .shape(1, 24, 24)
            .build()
            .is_err());
        for class in [KernelClass::Direct2d, KernelClass::Fft] {
            assert!(
                ConvPlan::builder().kernel_class(class).fuse(true).shape(1, 24, 24).build().is_err(),
                "{class:?} must reject fusion"
            );
        }
        // direct2d composes with tiling
        let p = ConvPlan::builder()
            .kernel_class(KernelClass::Direct2d)
            .tile(TileSpec::new(8, 8))
            .shape(1, 24, 24)
            .build()
            .unwrap();
        assert_eq!(p.tile(), Some(TileSpec::new(8, 8)));
        // a Gaussian spec under fft resolves the transform pass
        let p = ConvPlan::builder()
            .kernel(KernelSpec::new(9, 2.0))
            .kernel_class(KernelClass::Fft)
            .shape(1, 32, 32)
            .build()
            .unwrap();
        assert_eq!(p.passes(), &[PassKind::Fft]);
        assert!(p.explain().contains("class=fft"), "{}", p.explain());
        assert!(p.explain().contains("padded="), "{}", p.explain());
    }

    #[test]
    fn direct2d_plan_matches_separable_ladder() {
        let image = img(3, 30, 26);
        let model = OpenMpModel::new(4);
        let mut arena = ScratchArena::new();
        for layout in [Layout::PerPlane, Layout::Agglomerated] {
            let sep = ConvPlan::builder()
                .kernel(KernelSpec::new(7, 1.2))
                .layout(layout)
                .shape(3, 30, 26)
                .build()
                .unwrap();
            let d2 = ConvPlan::builder()
                .kernel(KernelSpec::new(7, 1.2))
                .kernel_class(KernelClass::Direct2d)
                .layout(layout)
                .shape(3, 30, 26)
                .build()
                .unwrap();
            let want = sep.execute(&image, &mut arena).unwrap();
            let seq = d2.execute(&image, &mut arena).unwrap();
            let par = d2.execute_on(&model, &image, &mut arena).unwrap();
            assert!(seq.max_abs_diff(&want) <= 1e-6, "{layout:?} seq");
            assert!(par.max_abs_diff(&want) <= 1e-6, "{layout:?} par");
        }
    }

    #[test]
    fn fft_plan_matches_direct_within_tolerance() {
        let image = img(3, 30, 26);
        let mut arena = ScratchArena::new();
        let lap = Kernel2d::new(vec![0.0, 1.0, 0.0, 1.0, -4.0, 1.0, 0.0, 1.0, 0.0], 3, 3).unwrap();
        for layout in [Layout::PerPlane, Layout::Agglomerated] {
            let d2 = ConvPlan::builder()
                .kernel2d(lap.clone())
                .layout(layout)
                .shape(3, 30, 26)
                .build()
                .unwrap();
            let fft = ConvPlan::builder()
                .kernel2d(lap.clone())
                .kernel_class(KernelClass::Fft)
                .layout(layout)
                .shape(3, 30, 26)
                .build()
                .unwrap();
            let want = d2.execute(&image, &mut arena).unwrap();
            let got = fft.execute(&image, &mut arena).unwrap();
            assert!(got.max_abs_diff(&want) <= 1e-4, "{layout:?}");
        }
    }

    #[test]
    fn fft_arena_stops_allocating_after_warmup() {
        let image = img(3, 32, 28);
        let plan = ConvPlan::builder()
            .kernel(KernelSpec::new(9, 2.0))
            .kernel_class(KernelClass::Fft)
            .shape(3, 32, 28)
            .build()
            .unwrap();
        let mut arena = ScratchArena::new();
        plan.execute(&image, &mut arena).unwrap();
        let warm = arena.allocations();
        for _ in 0..10 {
            plan.execute(&image, &mut arena).unwrap();
        }
        assert_eq!(arena.allocations(), warm, "fft f64 leases must recycle");
    }

    #[test]
    fn nonseparable_degenerate_shapes_pass_through() {
        let mut arena = ScratchArena::new();
        let lap = Kernel2d::new(vec![0.0, 1.0, 0.0, 1.0, -4.0, 1.0, 0.0, 1.0, 0.0], 3, 3).unwrap();
        for (rows, cols) in [(1usize, 1usize), (3, 1), (1, 3), (2, 16)] {
            let image = synth_image(2, rows, cols, Pattern::Noise, 9);
            for class in [KernelClass::Direct2d, KernelClass::Fft] {
                let plan = ConvPlan::builder()
                    .kernel2d(lap.clone())
                    .kernel_class(class)
                    .shape(2, rows, cols)
                    .build()
                    .unwrap();
                let out = plan.execute(&image, &mut arena).unwrap();
                assert_eq!(out, image, "{rows}x{cols} {class:?}");
            }
        }
    }

    #[test]
    fn traffic_estimate_covers_new_classes() {
        let d2 = ConvPlan::builder()
            .kernel(KernelSpec::new(9, 2.0))
            .kernel_class(KernelClass::Direct2d)
            .shape(1, 256, 256)
            .build()
            .unwrap();
        let fft = ConvPlan::builder()
            .kernel(KernelSpec::new(9, 2.0))
            .kernel_class(KernelClass::Fft)
            .shape(1, 256, 256)
            .build()
            .unwrap();
        assert!(d2.traffic_estimate().total_bytes() > 0);
        // the padded complex f64 planes make the transform route move
        // strictly more bytes than one direct pass at this shape
        assert!(fft.traffic_estimate().total_bytes() > d2.traffic_estimate().total_bytes());
    }
}
