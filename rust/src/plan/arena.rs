//! `ScratchArena`: a reusable pool of scratch planes, keyed by buffer
//! length.
//!
//! Every [`super::ConvPlan`] execution needs two working buffers (the
//! paper's A and B arrays) sized to the request's plane layout. Before
//! the plan layer, each consumer owned its own ad-hoc reuse scheme
//! (the since-deleted `conv::Workspace`) or allocated per request; the
//! arena centralises
//! that: executors hold one arena each, `take`/`put` recycle buffers,
//! and after the first request at a given size the steady state performs
//! **zero scratch allocations** (asserted by the reuse property test).
//!
//! The arena is deliberately not thread-safe — each executor / bench
//! loop owns its own (`&mut` discipline), which keeps `take`/`put` at
//! hash-map-lookup cost with no locking on the serving path.

use std::collections::HashMap;

/// Pool of `Vec<f32>` scratch buffers keyed by exact length.
#[derive(Debug, Default)]
pub struct ScratchArena {
    /// length → stack of free buffers of exactly that length
    pools: HashMap<usize, Vec<Vec<f32>>>,
    /// total fresh allocations performed (monotone; growth after warm-up
    /// means a leak or a shape churn — the reuse tests watch this)
    allocations: usize,
}

impl ScratchArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Borrow a buffer of exactly `len` elements: recycled if one is
    /// pooled, freshly allocated (zero-filled) otherwise. Contents of a
    /// recycled buffer are unspecified — plan passes overwrite or ignore
    /// every cell they read.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        if let Some(buf) = self.pools.get_mut(&len).and_then(|pool| pool.pop()) {
            return buf;
        }
        self.allocations += 1;
        vec![0.0; len]
    }

    /// Return a buffer to the pool for reuse.
    pub fn put(&mut self, buf: Vec<f32>) {
        self.pools.entry(buf.len()).or_default().push(buf);
    }

    /// Fresh allocations performed so far (never decreases).
    pub fn allocations(&self) -> usize {
        self.allocations
    }

    /// Buffers currently pooled (across all sizes).
    pub fn pooled(&self) -> usize {
        self.pools.values().map(Vec::len).sum()
    }

    /// Drop every pooled buffer (e.g. after a shape-mix change).
    pub fn clear(&mut self) {
        self.pools.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_recycles() {
        let mut a = ScratchArena::new();
        let b1 = a.take(64);
        assert_eq!(b1.len(), 64);
        assert_eq!(a.allocations(), 1);
        a.put(b1);
        assert_eq!(a.pooled(), 1);
        let b2 = a.take(64);
        assert_eq!(a.allocations(), 1, "recycled, not re-allocated");
        assert_eq!(a.pooled(), 0);
        a.put(b2);
    }

    #[test]
    fn distinct_sizes_pool_separately() {
        let mut a = ScratchArena::new();
        let x = a.take(16);
        let y = a.take(32);
        a.put(x);
        a.put(y);
        assert_eq!(a.allocations(), 2);
        let _ = a.take(16);
        let _ = a.take(32);
        assert_eq!(a.allocations(), 2);
        // a third size allocates fresh
        let _ = a.take(64);
        assert_eq!(a.allocations(), 3);
    }

    #[test]
    fn steady_state_is_allocation_free() {
        let mut a = ScratchArena::new();
        for _ in 0..100 {
            let x = a.take(128);
            let y = a.take(128);
            a.put(x);
            a.put(y);
        }
        assert_eq!(a.allocations(), 2);
        assert_eq!(a.pooled(), 2);
    }

    #[test]
    fn clear_drops_buffers() {
        let mut a = ScratchArena::new();
        let x = a.take(8);
        a.put(x);
        a.clear();
        assert_eq!(a.pooled(), 0);
        let _ = a.take(8);
        assert_eq!(a.allocations(), 2);
    }
}
