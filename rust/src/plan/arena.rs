//! `ScratchArena`: a reusable pool of scratch planes, keyed by buffer
//! length.
//!
//! Every [`super::ConvPlan`] execution needs two working buffers (the
//! paper's A and B arrays) sized to the request's plane layout. Before
//! the plan layer, each consumer owned its own ad-hoc reuse scheme
//! (the since-deleted `conv::Workspace`) or allocated per request; the
//! arena centralises
//! that: executors hold one arena each, `take`/`put` recycle buffers,
//! and after the first request at a given size the steady state performs
//! **zero scratch allocations** (asserted by the reuse property test).
//!
//! The arena is deliberately not thread-safe — each executor / bench
//! loop owns its own (`&mut` discipline), which keeps `take`/`put` at
//! hash-map-lookup cost with no locking on the serving path.

use std::collections::HashMap;
use std::sync::{Mutex, PoisonError};

/// Pool of `Vec<f32>` scratch buffers keyed by exact length.
#[derive(Debug, Default)]
pub struct ScratchArena {
    /// length → stack of free buffers of exactly that length
    pools: HashMap<usize, Vec<Vec<f32>>>,
    /// length → stack of free f64 buffers (the FFT convolver's padded
    /// spectral scratch; kept apart from the f32 planes so neither pool
    /// pollutes the other's size classes)
    pools_f64: HashMap<usize, Vec<Vec<f64>>>,
    /// recycled free-slot index stores for [`RingLease`]s, so fused
    /// executions allocate nothing after warm-up (the `Vec<f32>` data
    /// itself recycles through `pools`)
    ring_indices: Vec<Vec<usize>>,
    /// total fresh allocations performed (monotone; growth after warm-up
    /// means a leak or a shape churn — the reuse tests watch this)
    allocations: usize,
}

impl ScratchArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Borrow a buffer of exactly `len` elements: recycled if one is
    /// pooled, freshly allocated (zero-filled) otherwise. Contents of a
    /// recycled buffer are unspecified — plan passes overwrite or ignore
    /// every cell they read.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        if let Some(buf) = self.pools.get_mut(&len).and_then(|pool| pool.pop()) {
            return buf;
        }
        self.allocations += 1;
        vec![0.0; len]
    }

    /// Return a buffer to the pool for reuse.
    pub fn put(&mut self, buf: Vec<f32>) {
        self.pools.entry(buf.len()).or_default().push(buf);
    }

    /// Borrow an `f64` buffer of exactly `len` elements — the FFT
    /// convolver's spectral scratch lease. Same discipline as
    /// [`ScratchArena::take`]: recycled when pooled, fresh (and counted
    /// in [`ScratchArena::allocations`]) otherwise, contents
    /// unspecified.
    pub fn take_f64(&mut self, len: usize) -> Vec<f64> {
        if let Some(buf) = self.pools_f64.get_mut(&len).and_then(|pool| pool.pop()) {
            return buf;
        }
        self.allocations += 1;
        vec![0.0; len]
    }

    /// Return a buffer taken with [`ScratchArena::take_f64`].
    pub fn put_f64(&mut self, buf: Vec<f64>) {
        self.pools_f64.entry(buf.len()).or_default().push(buf);
    }

    /// Fresh allocations performed so far (never decreases).
    pub fn allocations(&self) -> usize {
        self.allocations
    }

    /// Buffers currently pooled (across all sizes, f32 and f64).
    pub fn pooled(&self) -> usize {
        self.pools.values().map(Vec::len).sum::<usize>()
            + self.pools_f64.values().map(Vec::len).sum::<usize>()
    }

    /// Drop every pooled buffer (e.g. after a shape-mix change).
    pub fn clear(&mut self) {
        self.pools.clear();
        self.pools_f64.clear();
        self.ring_indices.clear();
    }

    /// Lease a [`RingLease`] of `slots` disjoint ring buffers of
    /// `slot_len` elements each — the per-worker rolling row-rings of a
    /// fused pass (O(width × cols) per worker). The backing `Vec<f32>`
    /// comes from the same pools as the A/B planes, so steady-state
    /// fused serving performs zero scratch allocations.
    pub fn take_rings(&mut self, slots: usize, slot_len: usize) -> RingLease {
        let data = self.take(slots * slot_len);
        let free = self.ring_indices.pop().unwrap_or_default();
        RingLease::assemble(data, slots, slot_len, free)
    }

    /// Return a lease taken with [`ScratchArena::take_rings`]; both the
    /// data buffer and the slot index store recycle.
    pub fn put_rings(&mut self, lease: RingLease) {
        let (data, free) = lease.into_parts();
        self.put(data);
        self.ring_indices.push(free);
    }

    /// Graph-scoped ring lease for a `FilterGraph` execution: one slot
    /// per concurrent band job, each sized for the *cascade* scratch of
    /// the graph's longest streamed segment
    /// (`conv::chain::chain_scratch_len`) rather than one pass's ring.
    /// Identical pooling to [`ScratchArena::take_rings`] — the alias
    /// exists so graph executions read as what they are and the
    /// no-growth tests can name the lease they police. Return with
    /// [`ScratchArena::put_rings`].
    pub fn take_graph_rings(&mut self, slots: usize, slot_len: usize) -> RingLease {
        self.take_rings(slots, slot_len)
    }
}

/// A pool of `slots` disjoint per-worker ring buffers carved out of one
/// arena-leased `Vec<f32>`, handed out to concurrently running band/tile
/// jobs via [`RingLease::acquire`].
///
/// Soundness: a free-list of slot indices guarantees two outstanding
/// [`RingSlot`]s never alias (each index is held by at most one guard;
/// `Drop` returns it). The execution models invoke at most `workers()`
/// jobs concurrently, so leases sized to `workers()` never overflow; if
/// a foreign [`crate::models::ExecutionModel`] exceeds that, `acquire`
/// stays correct by handing out a freshly allocated overflow buffer
/// instead of panicking.
#[derive(Debug)]
pub struct RingLease {
    /// owns the slot storage; accessed only through `ptr`
    data: Vec<f32>,
    slots: usize,
    slot_len: usize,
    ptr: *mut f32,
    free: Mutex<Vec<usize>>,
}

// SAFETY: all shared-access discipline is the free-list above — a slot's
// `&mut` view exists only while its index is checked out.
unsafe impl Send for RingLease {}
unsafe impl Sync for RingLease {}

impl RingLease {
    fn assemble(mut data: Vec<f32>, slots: usize, slot_len: usize, mut free: Vec<usize>) -> Self {
        debug_assert!(data.len() >= slots * slot_len);
        free.clear();
        free.extend(0..slots);
        let ptr = data.as_mut_ptr();
        Self { data, slots, slot_len, ptr, free: Mutex::new(free) }
    }

    /// Arena-less construction for the expert `run_plane` path (one
    /// fresh allocation; serving goes through [`ScratchArena::take_rings`]).
    pub fn fresh(slots: usize, slot_len: usize) -> Self {
        Self::assemble(vec![0.0; slots * slot_len], slots, slot_len, Vec::new())
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Elements per slot (`width · interior-cols` for a fused plan).
    pub fn slot_len(&self) -> usize {
        self.slot_len
    }

    /// Check out one ring buffer; returned to the free list when the
    /// guard drops.
    pub fn acquire(&self) -> RingSlot<'_> {
        let idx = self.free.lock().unwrap_or_else(PoisonError::into_inner).pop();
        match idx {
            Some(i) => RingSlot { lease: self, idx: Some(i), overflow: Vec::new() },
            // more concurrent jobs than advertised workers: stay
            // correct at the cost of one allocation
            None => RingSlot { lease: self, idx: None, overflow: vec![0.0; self.slot_len] },
        }
    }

    fn into_parts(self) -> (Vec<f32>, Vec<usize>) {
        let free = self.free.into_inner().unwrap_or_else(PoisonError::into_inner);
        (self.data, free)
    }
}

/// Checked-out view of one ring buffer (see [`RingLease::acquire`]).
pub struct RingSlot<'a> {
    lease: &'a RingLease,
    idx: Option<usize>,
    overflow: Vec<f32>,
}

impl RingSlot<'_> {
    /// The slot's buffer (`slot_len` elements).
    pub fn buf(&mut self) -> &mut [f32] {
        match self.idx {
            // SAFETY: `idx` is checked out to this guard alone (free-list
            // discipline), so the view aliases no other slot.
            Some(i) => unsafe {
                std::slice::from_raw_parts_mut(
                    self.lease.ptr.add(i * self.lease.slot_len),
                    self.lease.slot_len,
                )
            },
            None => &mut self.overflow,
        }
    }
}

impl Drop for RingSlot<'_> {
    fn drop(&mut self) {
        if let Some(i) = self.idx {
            self.lease.free.lock().unwrap_or_else(PoisonError::into_inner).push(i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_recycles() {
        let mut a = ScratchArena::new();
        let b1 = a.take(64);
        assert_eq!(b1.len(), 64);
        assert_eq!(a.allocations(), 1);
        a.put(b1);
        assert_eq!(a.pooled(), 1);
        let b2 = a.take(64);
        assert_eq!(a.allocations(), 1, "recycled, not re-allocated");
        assert_eq!(a.pooled(), 0);
        a.put(b2);
    }

    #[test]
    fn distinct_sizes_pool_separately() {
        let mut a = ScratchArena::new();
        let x = a.take(16);
        let y = a.take(32);
        a.put(x);
        a.put(y);
        assert_eq!(a.allocations(), 2);
        let _ = a.take(16);
        let _ = a.take(32);
        assert_eq!(a.allocations(), 2);
        // a third size allocates fresh
        let _ = a.take(64);
        assert_eq!(a.allocations(), 3);
    }

    #[test]
    fn steady_state_is_allocation_free() {
        let mut a = ScratchArena::new();
        for _ in 0..100 {
            let x = a.take(128);
            let y = a.take(128);
            a.put(x);
            a.put(y);
        }
        assert_eq!(a.allocations(), 2);
        assert_eq!(a.pooled(), 2);
    }

    #[test]
    fn ring_lease_recycles_without_allocating() {
        let mut a = ScratchArena::new();
        let lease = a.take_rings(4, 32);
        assert_eq!((lease.slots(), lease.slot_len()), (4, 32));
        assert_eq!(a.allocations(), 1, "one backing buffer");
        a.put_rings(lease);
        for _ in 0..20 {
            let lease = a.take_rings(4, 32);
            a.put_rings(lease);
        }
        assert_eq!(a.allocations(), 1, "steady state leases without allocating");
    }

    #[test]
    fn graph_ring_lease_recycles_without_allocating() {
        // graph-scoped leases (cascade-sized slots) pool exactly like
        // single-pass ring leases: one backing allocation, ever
        let mut a = ScratchArena::new();
        let lease = a.take_graph_rings(3, 96);
        assert_eq!(a.allocations(), 1);
        a.put_rings(lease);
        for _ in 0..20 {
            let lease = a.take_graph_rings(3, 96);
            a.put_rings(lease);
        }
        assert_eq!(a.allocations(), 1, "graph leases recycle through the same pools");
    }

    #[test]
    fn ring_slots_are_disjoint_and_returned() {
        let lease = RingLease::fresh(3, 8);
        {
            let mut s0 = lease.acquire();
            let mut s1 = lease.acquire();
            let mut s2 = lease.acquire();
            s0.buf().fill(1.0);
            s1.buf().fill(2.0);
            s2.buf().fill(3.0);
            assert!(s0.buf().iter().all(|&v| v == 1.0), "no cross-slot clobbering");
            // all slots checked out: the overflow fallback still works
            let mut s3 = lease.acquire();
            assert_eq!(s3.buf().len(), 8);
            s3.buf().fill(9.0);
            assert!(s1.buf().iter().all(|&v| v == 2.0));
        }
        // guards dropped: all three pooled slots are available again
        let mut again = lease.acquire();
        assert_eq!(again.buf().len(), 8);
    }

    #[test]
    fn ring_slots_usable_across_threads() {
        let lease = RingLease::fresh(2, 16);
        std::thread::scope(|s| {
            for t in 0..2 {
                let lease = &lease;
                s.spawn(move || {
                    for _ in 0..100 {
                        let mut slot = lease.acquire();
                        slot.buf().fill(t as f32);
                        let v = slot.buf()[0];
                        assert_eq!(v, t as f32, "slot is private while held");
                    }
                });
            }
        });
    }

    #[test]
    fn zero_sized_ring_lease_is_fine() {
        // a fused plan on a plane with no interior leases a zero-length
        // ring; the engines never touch it
        let mut a = ScratchArena::new();
        let lease = a.take_rings(2, 0);
        let mut slot = lease.acquire();
        assert!(slot.buf().is_empty());
        drop(slot);
        a.put_rings(lease);
    }

    #[test]
    fn f64_pool_recycles_without_allocating() {
        // the FFT spectral-scratch lease type: same no-growth contract
        // as the f32 planes, pooled separately
        let mut a = ScratchArena::new();
        let re = a.take_f64(256);
        let im = a.take_f64(256);
        assert_eq!((re.len(), im.len()), (256, 256));
        assert_eq!(a.allocations(), 2);
        a.put_f64(re);
        a.put_f64(im);
        assert_eq!(a.pooled(), 2, "f64 buffers count as pooled");
        for _ in 0..50 {
            let re = a.take_f64(256);
            let im = a.take_f64(256);
            a.put_f64(re);
            a.put_f64(im);
        }
        assert_eq!(a.allocations(), 2, "steady state is allocation-free");
        // f32 and f64 pools are disjoint even at equal lengths
        let _ = a.take(256);
        assert_eq!(a.allocations(), 3, "an f32 take never raids the f64 pool");
        a.clear();
        assert_eq!(a.pooled(), 0);
        let _ = a.take_f64(256);
        assert_eq!(a.allocations(), 4, "clear() drops the f64 pool too");
    }

    #[test]
    fn clear_drops_buffers() {
        let mut a = ScratchArena::new();
        let x = a.take(8);
        a.put(x);
        a.clear();
        assert_eq!(a.pooled(), 0);
        let _ = a.take(8);
        assert_eq!(a.allocations(), 2);
    }
}
