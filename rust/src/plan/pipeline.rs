//! Pipeline resolution and execution: the single dispatch point that
//! replaced the five per-layer `match (algorithm, variant, layout)`
//! blocks.
//!
//! A built [`ConvPlan`] owns a resolved sequence of [`PassKind`]s; this
//! module maps each pass onto the right [`crate::conv::band`] primitive
//! — width-5 unrolled fast path or generic odd-width engine — and runs
//! it either sequentially or banded across an [`ExecutionModel`] (the
//! row-band parallel sweep formerly private to `models::convolve`).

use crate::conv::Variant;
use crate::conv::{band, direct2d, tile};
use crate::models::pool::{RowBands, TileCells};
use crate::models::{ExecutionModel, Tile, TileGrid, TileSpec};

use super::arena::RingLease;
use super::{ConvPlan, ScratchArena};

/// One resolved pass of a convolution pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PassKind {
    /// separable horizontal sweep (two-pass, first phase)
    Horiz,
    /// separable vertical sweep (two-pass, second phase)
    Vert,
    /// both separable sweeps in one rolling row-ring pass (`--fuse`):
    /// the intermediate stays in a per-worker O(width×cols) ring
    /// instead of crossing memory as a full plane
    Fused,
    /// direct 2-D convolution (single-pass algorithms)
    SinglePass,
    /// copy B back over A (the paper's copy-back epilogue)
    CopyBack,
    /// direct 2-D accumulation of an arbitrary odd×odd tap matrix
    /// (`KernelClass::Direct2d` — [`crate::conv::direct2d`])
    Direct2d,
    /// radix-2 transform convolution (`KernelClass::Fft` —
    /// [`crate::conv::fft`]); runs whole-plane, outside the banded
    /// dispatch
    Fft,
}

/// Where the pipeline's result lands (the paper's A/B buffer discipline).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) enum ResultHome {
    A,
    B,
}

/// How passes run: inline on the caller's thread, or banded over an
/// execution model (one disjoint row band per worker, implicit barrier
/// between passes — the paper's `#pragma omp parallel for` regions).
#[derive(Clone, Copy)]
pub(super) enum Exec<'m> {
    Seq,
    Par(&'m dyn ExecutionModel),
}

/// Run one pass over `[0, rows)`: whole-plane for [`Exec::Seq`], a
/// disjoint row-band cover for [`Exec::Par`].
fn run_banded(
    exec: Exec<'_>,
    rows: usize,
    cols: usize,
    src: &[f32],
    dst: &mut [f32],
    pass: &(dyn Fn(&[f32], &mut [f32], usize, usize) + Sync),
) {
    match exec {
        Exec::Seq => pass(src, dst, 0, rows),
        Exec::Par(model) => {
            let bands = RowBands::new(dst, rows, cols);
            model.dispatch(rows, &|r0, r1| {
                // SAFETY: execution models dispatch disjoint covers of
                // [0, rows) (property-tested), so bands never overlap.
                let band = unsafe { bands.band(r0, r1) };
                pass(src, band, r0, r1);
            });
        }
    }
}

/// [`run_banded`] for the fused pass: every job invocation additionally
/// checks a rolling row-ring out of the lease (disjoint per band, at
/// most `workers()` outstanding — see [`RingLease`]).
fn run_banded_fused(
    exec: Exec<'_>,
    rows: usize,
    cols: usize,
    src: &[f32],
    dst: &mut [f32],
    rings: &RingLease,
    pass: &(dyn Fn(&[f32], &mut [f32], &mut [f32], usize, usize) + Sync),
) {
    match exec {
        Exec::Seq => {
            let mut slot = rings.acquire();
            pass(src, dst, slot.buf(), 0, rows);
        }
        Exec::Par(model) => {
            let bands = RowBands::new(dst, rows, cols);
            model.dispatch(rows, &|r0, r1| {
                // SAFETY: execution models dispatch disjoint covers of
                // [0, rows) (property-tested), so bands never overlap.
                let band = unsafe { bands.band(r0, r1) };
                let mut slot = rings.acquire();
                pass(src, band, slot.buf(), r0, r1);
            });
        }
    }
}

/// [`run_tiled`] for the fused pass: one ring checkout per tile job
/// (sequential grids reuse a single slot).
fn run_tiled_fused(
    exec: Exec<'_>,
    rows: usize,
    cols: usize,
    spec: TileSpec,
    rings: &RingLease,
    pass: &(dyn Fn(Tile, &mut [f32]) + Sync),
) {
    match exec {
        Exec::Seq => {
            let grid = TileGrid::new(rows, cols, spec);
            let mut slot = rings.acquire();
            for t in 0..grid.len() {
                pass(grid.tile(t), slot.buf());
            }
        }
        Exec::Par(model) => model.dispatch2d(rows, cols, spec, &|t| {
            let mut slot = rings.acquire();
            pass(t, slot.buf());
        }),
    }
}

/// Run one tiled pass over the grid: every tile once for [`Exec::Seq`],
/// a disjoint tile cover via `dispatch2d` for [`Exec::Par`] (the
/// agglomeration-aware path — each model schedules tiles its own way).
fn run_tiled(
    exec: Exec<'_>,
    rows: usize,
    cols: usize,
    spec: TileSpec,
    pass: &(dyn Fn(Tile) + Sync),
) {
    match exec {
        Exec::Seq => {
            let grid = TileGrid::new(rows, cols, spec);
            for t in 0..grid.len() {
                pass(grid.tile(t));
            }
        }
        Exec::Par(model) => model.dispatch2d(rows, cols, spec, pass),
    }
}

impl ConvPlan {
    /// Run the whole resolved pipeline over one plane: even passes read
    /// A and write B, odd passes read B and write A (the fixed A↔B
    /// ping-pong every algorithm in the paper follows).
    ///
    /// Fused plans have exactly one pass (A → B) and additionally lease
    /// per-worker row-rings: from `arena` when the caller has one (the
    /// serving path — zero allocations after warm-up), freshly otherwise
    /// (the arena-less `run_plane` expert path).
    pub(super) fn run_passes(
        &self,
        exec: Exec<'_>,
        a: &mut [f32],
        b: &mut [f32],
        rows: usize,
        cols: usize,
        arena: Option<&mut ScratchArena>,
    ) {
        if let Some(fft) = &self.fft {
            // the transform route runs whole-plane (its parallelism unit
            // is the transform itself, not a row band), so `exec` is
            // deliberately unused here; scratch is the two f64 planes,
            // leased from the arena's f64 pool on the serving path and
            // allocated fresh on the arena-less expert path
            let _ = exec;
            let len = fft.scratch_len();
            match arena {
                Some(arena) => {
                    let mut re = arena.take_f64(len);
                    let mut im = arena.take_f64(len);
                    fft.convolve_into(a, b, &mut re, &mut im);
                    arena.put_f64(re);
                    arena.put_f64(im);
                }
                None => {
                    let mut re = vec![0f64; len];
                    let mut im = vec![0f64; len];
                    fft.convolve_into(a, b, &mut re, &mut im);
                }
            }
            return;
        }
        if self.fused {
            let slots = match exec {
                Exec::Seq => 1,
                Exec::Par(model) => model.workers(),
            };
            let slot_len = self.ring_slot_len(cols);
            match arena {
                Some(arena) => {
                    let lease = arena.take_rings(slots, slot_len);
                    self.run_pass_fused(exec, a, b, rows, cols, &lease);
                    arena.put_rings(lease);
                }
                None => {
                    let lease = RingLease::fresh(slots, slot_len);
                    self.run_pass_fused(exec, a, b, rows, cols, &lease);
                }
            }
            return;
        }
        for (i, &kind) in self.passes.iter().enumerate() {
            if i % 2 == 0 {
                self.run_pass(exec, kind, a, b, rows, cols);
            } else {
                self.run_pass(exec, kind, b, a, rows, cols);
            }
        }
    }

    /// Dispatch the fused pass: W=5 unrolled engines on the fast path,
    /// generic odd-width twins otherwise, fused tile primitives when the
    /// plan carries a [`TileSpec`] (tiling and the unrolled fast path
    /// are mutually exclusive, as for the unfused passes).
    #[allow(clippy::too_many_arguments)]
    fn run_pass_fused(
        &self,
        exec: Exec<'_>,
        src: &[f32],
        dst: &mut [f32],
        rows: usize,
        cols: usize,
        rings: &RingLease,
    ) {
        if let Some(spec) = self.tile {
            let cells = TileCells::new(dst, rows, cols);
            match self.variant {
                Variant::Naive => unreachable!("naive+twopass rejected at build"),
                Variant::Scalar => run_tiled_fused(exec, rows, cols, spec, rings, &|t, ring| {
                    tile::fused_tile_scalar(src, &cells, rows, cols, &self.taps, ring, t)
                }),
                Variant::Simd => run_tiled_fused(exec, rows, cols, spec, rings, &|t, ring| {
                    tile::fused_tile_simd(src, &cells, rows, cols, &self.taps, ring, t)
                }),
            }
            return;
        }
        match (self.variant, self.fast_path) {
            (Variant::Naive, _) => unreachable!("naive+twopass rejected at build"),
            (Variant::Scalar, true) => {
                let k5: &[f32; 5] = self.taps.as_slice().try_into().expect("width-5 kernel");
                run_banded_fused(exec, rows, cols, src, dst, rings, &|s, d, ring, r0, r1| {
                    band::fused_band_scalar(s, d, rows, cols, k5, ring, r0, r1)
                });
            }
            (Variant::Scalar, false) => {
                run_banded_fused(exec, rows, cols, src, dst, rings, &|s, d, ring, r0, r1| {
                    band::fused_band_scalar_w(s, d, rows, cols, &self.taps, ring, r0, r1)
                });
            }
            (Variant::Simd, true) => {
                let k5: &[f32; 5] = self.taps.as_slice().try_into().expect("width-5 kernel");
                run_banded_fused(exec, rows, cols, src, dst, rings, &|s, d, ring, r0, r1| {
                    band::fused_band_simd(s, d, rows, cols, k5, ring, r0, r1)
                });
            }
            (Variant::Simd, false) => {
                run_banded_fused(exec, rows, cols, src, dst, rings, &|s, d, ring, r0, r1| {
                    band::fused_band_simd_w(s, d, rows, cols, &self.taps, ring, r0, r1)
                });
            }
        }
    }

    /// Dispatch one pass to the band primitive the plan selected:
    /// width-5 unrolled when `fast_path`, generic odd-width otherwise —
    /// or to the tile primitives when the plan carries a [`TileSpec`].
    fn run_pass(
        &self,
        exec: Exec<'_>,
        kind: PassKind,
        src: &[f32],
        dst: &mut [f32],
        rows: usize,
        cols: usize,
    ) {
        if let Some(spec) = self.tile {
            self.run_pass_tiled(exec, kind, src, dst, rows, cols, spec);
            return;
        }
        let w = self.width;
        let (kr, kc) = (self.krows, self.kcols);
        match kind {
            PassKind::Fused => unreachable!("fused plans run through run_pass_fused"),
            PassKind::Fft => unreachable!("fft plans run through the transform path"),
            PassKind::Direct2d => match self.variant {
                Variant::Naive => run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                    direct2d::direct2d_band_naive(s, d, rows, cols, &self.k2d, kr, kc, r0, r1)
                }),
                Variant::Scalar => run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                    direct2d::direct2d_band_scalar(s, d, rows, cols, &self.k2d, kr, kc, r0, r1)
                }),
                Variant::Simd => run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                    direct2d::direct2d_band_simd(s, d, rows, cols, &self.k2d, kr, kc, r0, r1)
                }),
            },
            PassKind::SinglePass => match (self.variant, self.fast_path) {
                (Variant::Naive, _) => {
                    run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                        band::singlepass_naive_band(s, d, rows, cols, &self.k2d, w, r0, r1)
                    });
                }
                (Variant::Scalar, true) => {
                    let k25: &[f32; 25] = self.k2d.as_slice().try_into().expect("5x5 kernel");
                    run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                        band::singlepass_band_scalar(s, d, rows, cols, k25, r0, r1)
                    });
                }
                (Variant::Scalar, false) => {
                    run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                        band::singlepass_band_scalar_w(s, d, rows, cols, &self.k2d, w, r0, r1)
                    });
                }
                (Variant::Simd, true) => {
                    let k25: &[f32; 25] = self.k2d.as_slice().try_into().expect("5x5 kernel");
                    run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                        band::singlepass_band_simd(s, d, rows, cols, k25, r0, r1)
                    });
                }
                (Variant::Simd, false) => {
                    run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                        band::singlepass_band_simd_w(s, d, rows, cols, &self.k2d, w, r0, r1)
                    });
                }
            },
            PassKind::Horiz => match (self.variant, self.fast_path) {
                (Variant::Naive, _) => unreachable!("naive+twopass rejected at build"),
                (Variant::Scalar, true) => {
                    let k5: &[f32; 5] = self.taps.as_slice().try_into().expect("width-5 kernel");
                    run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                        band::horiz_band_scalar(s, d, rows, cols, k5, r0, r1)
                    });
                }
                (Variant::Scalar, false) => {
                    run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                        band::horiz_band_scalar_w(s, d, rows, cols, &self.taps, r0, r1)
                    });
                }
                (Variant::Simd, true) => {
                    let k5: &[f32; 5] = self.taps.as_slice().try_into().expect("width-5 kernel");
                    run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                        band::horiz_band_simd(s, d, rows, cols, k5, r0, r1)
                    });
                }
                (Variant::Simd, false) => {
                    run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                        band::horiz_band_simd_w(s, d, rows, cols, &self.taps, r0, r1)
                    });
                }
            },
            PassKind::Vert => match (self.variant, self.fast_path) {
                (Variant::Naive, _) => unreachable!("naive+twopass rejected at build"),
                (Variant::Scalar, true) => {
                    let k5: &[f32; 5] = self.taps.as_slice().try_into().expect("width-5 kernel");
                    run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                        band::vert_band_scalar(s, d, rows, cols, k5, r0, r1)
                    });
                }
                (Variant::Scalar, false) => {
                    run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                        band::vert_band_scalar_w(s, d, rows, cols, &self.taps, r0, r1)
                    });
                }
                (Variant::Simd, true) => {
                    let k5: &[f32; 5] = self.taps.as_slice().try_into().expect("width-5 kernel");
                    run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                        band::vert_band_simd(s, d, rows, cols, k5, r0, r1)
                    });
                }
                (Variant::Simd, false) => {
                    run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                        band::vert_band_simd_w(s, d, rows, cols, &self.taps, r0, r1)
                    });
                }
            },
            PassKind::CopyBack => match self.variant {
                // parallelised + vectorised copy-back (paper Par-2)
                Variant::Simd => run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                    band::copy_back_band_simd(s, d, cols, r0, r1)
                }),
                _ => run_banded(exec, rows, cols, src, dst, &|s, d, r0, r1| {
                    band::copy_back_band_scalar(s, d, cols, r0, r1)
                }),
            },
        }
    }

    /// The tiled twin of `run_pass`: the same pass pipeline over a 2-D
    /// tile decomposition, writing through a [`TileCells`] accessor.
    /// Tile primitives are generic-width (tiling and the unrolled W=5
    /// fast path are mutually exclusive — `build()` clears `fast_path`);
    /// accumulation order matches the banded engines so tiled and
    /// untiled plans stay bitwise comparable.
    #[allow(clippy::too_many_arguments)]
    fn run_pass_tiled(
        &self,
        exec: Exec<'_>,
        kind: PassKind,
        src: &[f32],
        dst: &mut [f32],
        rows: usize,
        cols: usize,
        spec: TileSpec,
    ) {
        let w = self.width;
        let (kr, kc) = (self.krows, self.kcols);
        let cells = TileCells::new(dst, rows, cols);
        match kind {
            PassKind::Fused => unreachable!("fused plans run through run_pass_fused"),
            PassKind::Fft => unreachable!("fft plans are untiled (rejected at build)"),
            PassKind::Direct2d => match self.variant {
                Variant::Naive => run_tiled(exec, rows, cols, spec, &|t| {
                    direct2d::direct2d_tile_naive(src, &cells, rows, cols, &self.k2d, kr, kc, t)
                }),
                Variant::Scalar => run_tiled(exec, rows, cols, spec, &|t| {
                    direct2d::direct2d_tile_scalar(src, &cells, rows, cols, &self.k2d, kr, kc, t)
                }),
                Variant::Simd => run_tiled(exec, rows, cols, spec, &|t| {
                    direct2d::direct2d_tile_simd(src, &cells, rows, cols, &self.k2d, kr, kc, t)
                }),
            },
            PassKind::SinglePass => match self.variant {
                Variant::Naive => run_tiled(exec, rows, cols, spec, &|t| {
                    tile::singlepass_tile_naive(src, &cells, rows, cols, &self.k2d, w, t)
                }),
                Variant::Scalar => run_tiled(exec, rows, cols, spec, &|t| {
                    tile::singlepass_tile_scalar(src, &cells, rows, cols, &self.k2d, w, t)
                }),
                Variant::Simd => run_tiled(exec, rows, cols, spec, &|t| {
                    tile::singlepass_tile_simd(src, &cells, rows, cols, &self.k2d, w, t)
                }),
            },
            PassKind::Horiz => match self.variant {
                Variant::Naive => unreachable!("naive+twopass rejected at build"),
                Variant::Scalar => run_tiled(exec, rows, cols, spec, &|t| {
                    tile::horiz_tile_scalar(src, &cells, rows, cols, &self.taps, t)
                }),
                Variant::Simd => run_tiled(exec, rows, cols, spec, &|t| {
                    tile::horiz_tile_simd(src, &cells, rows, cols, &self.taps, t)
                }),
            },
            PassKind::Vert => match self.variant {
                Variant::Naive => unreachable!("naive+twopass rejected at build"),
                Variant::Scalar => run_tiled(exec, rows, cols, spec, &|t| {
                    tile::vert_tile_scalar(src, &cells, rows, cols, &self.taps, t)
                }),
                Variant::Simd => run_tiled(exec, rows, cols, spec, &|t| {
                    tile::vert_tile_simd(src, &cells, rows, cols, &self.taps, t)
                }),
            },
            PassKind::CopyBack => run_tiled(exec, rows, cols, spec, &|t| {
                tile::copy_back_tile(src, &cells, cols, t)
            }),
        }
    }
}
