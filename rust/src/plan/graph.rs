//! `FilterGraph`: builder-validated DAGs of convolution stages with
//! per-edge buffer policies.
//!
//! Real image services run *chains* — blur → sharpen → edge — not
//! single convolutions. A [`FilterGraph`] is a DAG of two-pass
//! [`ConvPlan`] stages wired by name through a [`GraphBuilder`], with
//! one buffer-policy decision per inter-stage edge:
//!
//! * [`EdgePolicy::Streamed`] — the consumer ingests rows as the
//!   producer retires them through the N-stage row-ring cascade
//!   ([`crate::conv::chain`]); the intermediate plane never exists, so
//!   a k-stage chain crosses memory twice instead of 2k times.
//! * [`EdgePolicy::Materialized`] — the producer writes a full
//!   intermediate plane first (fan-out join points and graph outputs
//!   require this; the builder demotes their edges automatically).
//!
//! `build()` rejects empty graphs, duplicate or reserved stage names,
//! unknown inputs, cycles (each stage reads one input, so a cycle is a
//! leftover in Kahn's ordering), shape-mismatched edges (stages may
//! pin the shape they expect with [`GraphBuilder::expect_shape`]), and
//! every kernel/variant combination the [`ConvPlan`] builder refuses —
//! streamed stages are separable two-pass by construction. Validation
//! also resolves the graph into maximal streamed *segments*; execution
//! runs each segment through [`crate::conv::chain::chain_band`] with a
//! graph-scoped ring lease ([`ScratchArena::take_graph_rings`]) whose
//! slot is sized for the longest segment.
//!
//! Differential oracle: [`FilterGraph::execute_materialized`] runs the
//! same stages one plan at a time through full intermediate planes.
//! Streamed and materialised execution agree bitwise for generic-width
//! chains and within 1e-6 when width-5 stages take the unrolled fast
//! path (`tests/graph.rs`, `tests/proptests.rs`).

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};

use crate::util::error::{Context, Result};

use crate::conv::chain::{chain_band, chain_scratch_len, ChainStage};
use crate::conv::{Algorithm, Variant};
use crate::image::PlanarImage;
use crate::metrics::Table;
use crate::models::pool::RowBands;
use crate::models::{ExecutionModel, Layout};

use super::arena::RingLease;
use super::pipeline::Exec;
use super::{ConvPlan, KernelSpec, ScratchArena, Traffic};

/// The reserved input name: a stage reading `"source"` consumes the
/// image the graph is executed on.
pub const SOURCE: &str = "source";

/// Buffer policy of one inter-stage edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EdgePolicy {
    /// consume rows as the producer retires them (row-ring cascade)
    Streamed,
    /// materialise the producer's full plane first
    Materialized,
}

impl EdgePolicy {
    pub fn label(&self) -> &'static str {
        match self {
            EdgePolicy::Streamed => "streamed",
            EdgePolicy::Materialized => "materialized",
        }
    }
}

enum TapsSource {
    Spec(KernelSpec),
    Taps(Vec<f32>),
}

struct StageDecl {
    name: String,
    /// `None` = the graph source
    input: Option<String>,
    policy: EdgePolicy,
    kernel: TapsSource,
    variant: Variant,
    expect_shape: Option<(usize, usize, usize)>,
}

/// Validating builder for [`FilterGraph`] — see the module docs for the
/// rejection rules. Stages chain linearly by default (each new stage
/// reads the previous one, the first reads the source); [`after`]
/// rewires the last-added stage to any named producer, which is how
/// fan-out graphs (difference-of-Gaussians) are declared.
///
/// [`after`]: GraphBuilder::after
pub struct GraphBuilder {
    shape: Option<(usize, usize, usize)>,
    layout: Layout,
    variant: Variant,
    stages: Vec<StageDecl>,
    outputs: Vec<String>,
    /// first misuse of a last-stage modifier with no stages yet,
    /// surfaced at `build()` (builder methods cannot fail early)
    defer: Option<String>,
}

impl GraphBuilder {
    fn new() -> Self {
        Self {
            shape: None,
            layout: Layout::PerPlane,
            variant: Variant::Simd,
            stages: Vec::new(),
            outputs: Vec::new(),
            defer: None,
        }
    }

    /// Image shape every edge of the graph carries.
    pub fn shape(mut self, planes: usize, rows: usize, cols: usize) -> Self {
        self.shape = Some((planes, rows, cols));
        self
    }

    pub fn layout(mut self, layout: Layout) -> Self {
        self.layout = layout;
        self
    }

    /// Expression variant for subsequently added stages (default SIMD).
    pub fn variant(mut self, v: Variant) -> Self {
        self.variant = v;
        self
    }

    /// Add a stage with a Gaussian kernel spec. Its input defaults to
    /// the previously added stage (the source for the first one) and
    /// its incoming edge to [`EdgePolicy::Streamed`].
    pub fn stage(self, name: &str, spec: KernelSpec) -> Self {
        self.push_stage(name, TapsSource::Spec(spec))
    }

    /// Add a stage with explicit separable taps (odd length, validated
    /// at `build()`).
    pub fn stage_taps(self, name: &str, taps: Vec<f32>) -> Self {
        self.push_stage(name, TapsSource::Taps(taps))
    }

    fn push_stage(mut self, name: &str, kernel: TapsSource) -> Self {
        let input = self.stages.last().map(|s| s.name.clone());
        self.stages.push(StageDecl {
            name: name.to_string(),
            input,
            policy: EdgePolicy::Streamed,
            kernel,
            variant: self.variant,
            expect_shape: None,
        });
        self
    }

    fn last_stage(&mut self, what: &str) -> Option<&mut StageDecl> {
        if self.stages.is_empty() {
            if self.defer.is_none() {
                self.defer = Some(format!("{what} called before any stage was added"));
            }
            return None;
        }
        self.stages.last_mut()
    }

    /// Rewire the last-added stage to read `input` — another stage's
    /// name, or [`SOURCE`]. Forward references resolve at `build()`.
    pub fn after(mut self, input: &str) -> Self {
        if let Some(s) = self.last_stage("after()") {
            s.input = (input != SOURCE).then(|| input.to_string());
        }
        self
    }

    /// Buffer policy of the last-added stage's incoming edge.
    pub fn policy(mut self, policy: EdgePolicy) -> Self {
        if let Some(s) = self.last_stage("policy()") {
            s.policy = policy;
        }
        self
    }

    /// Shorthand for `.policy(EdgePolicy::Materialized)`.
    pub fn materialized(self) -> Self {
        self.policy(EdgePolicy::Materialized)
    }

    /// Pin the shape the last-added stage expects its input edge to
    /// carry; `build()` rejects the graph when it differs from the
    /// graph shape (every edge carries the graph shape — convolution
    /// stages are shape-preserving).
    pub fn expect_shape(mut self, planes: usize, rows: usize, cols: usize) -> Self {
        if let Some(s) = self.last_stage("expect_shape()") {
            s.expect_shape = Some((planes, rows, cols));
        }
        self
    }

    /// Mark a stage as a graph output (defaults to every sink).
    pub fn output(mut self, name: &str) -> Self {
        self.outputs.push(name.to_string());
        self
    }

    /// Validate the whole graph and resolve its execution structure.
    pub fn build(self) -> Result<FilterGraph> {
        if let Some(msg) = self.defer {
            bail!("{msg}");
        }
        let (planes, rows, cols) = self
            .shape
            .ok_or_else(|| err!("graph needs a shape: call .shape(planes, rows, cols)"))?;
        ensure!(
            planes >= 1 && rows >= 1 && cols >= 1,
            "graph shape must be non-empty, got {planes}x{rows}x{cols}"
        );
        ensure!(!self.stages.is_empty(), "graph must have at least one stage");
        let n = self.stages.len();
        let mut index: HashMap<String, usize> = HashMap::new();
        for (i, s) in self.stages.iter().enumerate() {
            ensure!(!s.name.is_empty(), "stage {i} has an empty name");
            ensure!(
                s.name != SOURCE,
                "{SOURCE:?} names the graph input and cannot name a stage"
            );
            ensure!(
                index.insert(s.name.clone(), i).is_none(),
                "duplicate stage name {:?}",
                s.name
            );
        }
        let mut input_of: Vec<Option<usize>> = Vec::with_capacity(n);
        for (i, s) in self.stages.iter().enumerate() {
            let inp = match &s.input {
                None => None,
                Some(name) => {
                    let &p = index
                        .get(name)
                        .ok_or_else(|| err!("stage {:?} reads unknown input {:?}", s.name, name))?;
                    ensure!(p != i, "stage {:?} reads itself — graphs must be acyclic", s.name);
                    Some(p)
                }
            };
            input_of.push(inp);
        }
        // Kahn's ordering; each stage has exactly one input edge, so
        // any node never reaching in-degree 0 sits on a cycle
        let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, &inp) in input_of.iter().enumerate() {
            if let Some(p) = inp {
                consumers[p].push(i);
            }
        }
        let mut topo = Vec::with_capacity(n);
        let mut ready: Vec<usize> =
            (0..n).rev().filter(|&i| input_of[i].is_none()).collect();
        let mut seen = vec![false; n];
        while let Some(x) = ready.pop() {
            topo.push(x);
            seen[x] = true;
            for &c in consumers[x].iter().rev() {
                ready.push(c);
            }
        }
        if topo.len() != n {
            let stuck = (0..n).find(|&i| !seen[i]).expect("some node is unreached");
            bail!("graph has a cycle through stage {:?}", self.stages[stuck].name);
        }
        // shape-mismatched edges: every edge carries the graph shape
        for s in &self.stages {
            if let Some((ep, er, ec)) = s.expect_shape {
                ensure!(
                    (ep, er, ec) == (planes, rows, cols),
                    "stage {:?} expects shape {ep}x{er}x{ec} on its input edge \
                     but the graph carries {planes}x{rows}x{cols}",
                    s.name
                );
            }
        }
        // outputs: explicit (deduplicated, validated) or every sink
        let mut outputs: Vec<usize> = Vec::new();
        if self.outputs.is_empty() {
            outputs.extend((0..n).filter(|&i| consumers[i].is_empty()));
        } else {
            for name in &self.outputs {
                let &i = index
                    .get(name)
                    .ok_or_else(|| err!("unknown output stage {:?}", name))?;
                if !outputs.contains(&i) {
                    outputs.push(i);
                }
            }
        }
        // build each stage's plan (fused two-pass: the materialised
        // oracle and the per-stage traffic baseline both use it); the
        // plan builder rejects even widths, naive two-pass, etc.
        let mut names = Vec::with_capacity(n);
        let mut plans = Vec::with_capacity(n);
        let mut policies = Vec::with_capacity(n);
        for s in self.stages {
            let builder = ConvPlan::builder()
                .algorithm(Algorithm::TwoPass)
                .variant(s.variant)
                .layout(self.layout)
                .shape(planes, rows, cols)
                .fuse(true);
            let builder = match s.kernel {
                TapsSource::Spec(spec) => builder.kernel(spec),
                TapsSource::Taps(taps) => builder.kernel_taps(taps),
            };
            let plan =
                builder.build().context(format!("building graph stage {:?}", s.name))?;
            names.push(s.name);
            plans.push(plan);
            policies.push(s.policy);
        }
        // demote edges that cannot stream: consumers of fan-out
        // producers and of output stages read a plane that must exist
        // in full anyway
        for p in 0..n {
            if consumers[p].len() >= 2 || (!consumers[p].is_empty() && outputs.contains(&p)) {
                for &c in &consumers[p] {
                    policies[c] = EdgePolicy::Materialized;
                }
            }
        }
        // a stage materialises when its plane is needed in full
        let materialize: Vec<bool> = (0..n)
            .map(|x| {
                outputs.contains(&x)
                    || consumers[x].is_empty()
                    || consumers[x].iter().any(|&c| policies[c] == EdgePolicy::Materialized)
            })
            .collect();
        // maximal streamed segments, in topological order
        let mut segments: Vec<Vec<usize>> = Vec::new();
        let mut visited = vec![false; n];
        for &x in &topo {
            if visited[x] {
                continue;
            }
            let mut seg = vec![x];
            visited[x] = true;
            loop {
                let last = *seg.last().expect("segment is non-empty");
                if materialize[last] {
                    break;
                }
                let c = consumers[last][0];
                seg.push(c);
                visited[c] = true;
            }
            segments.push(seg);
        }
        // resolved per-stage policy: a stage streams exactly when it is
        // a non-head member of a segment
        let mut resolved = vec![EdgePolicy::Materialized; n];
        for seg in &segments {
            for &x in &seg[1..] {
                resolved[x] = EdgePolicy::Streamed;
            }
        }
        let (rows_eff, cols_eff) = match self.layout {
            Layout::PerPlane => (rows, cols),
            Layout::Agglomerated => (rows, planes * cols),
        };
        let mut slot_len = 0usize;
        for seg in &segments {
            let chain: Vec<ChainStage<'_>> =
                seg.iter().map(|&i| ChainStage::new(plans[i].taps(), plans[i].variant())).collect();
            slot_len = slot_len.max(chain_scratch_len(&chain, rows_eff, cols_eff));
        }
        let mut depth = vec![0usize; n];
        for &x in &topo {
            let he = ChainStage::new(plans[x].taps(), plans[x].variant())
                .effective_halo(rows_eff, cols_eff);
            depth[x] = input_of[x].map_or(0, |p| depth[p]) + he;
        }
        let accumulated_halo = depth.iter().copied().max().unwrap_or(0);
        let stages = names
            .into_iter()
            .zip(plans)
            .zip(input_of)
            .zip(resolved)
            .map(|(((name, plan), input), policy)| GraphStage { name, plan, input, policy })
            .collect();
        Ok(FilterGraph {
            planes,
            rows,
            cols,
            layout: self.layout,
            stages,
            topo,
            segments,
            outputs,
            slot_len,
            accumulated_halo,
        })
    }
}

/// One resolved node of a built graph.
pub struct GraphStage {
    name: String,
    plan: ConvPlan,
    input: Option<usize>,
    policy: EdgePolicy,
}

impl GraphStage {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn plan(&self) -> &ConvPlan {
        &self.plan
    }

    /// Index of the producing stage (`None` = the graph source).
    pub fn input(&self) -> Option<usize> {
        self.input
    }

    /// Resolved incoming-edge policy: `Streamed` exactly when this
    /// stage consumes its producer's rows through the cascade (the
    /// builder demotes edges whose producer must materialise anyway).
    pub fn policy(&self) -> EdgePolicy {
        self.policy
    }
}

/// Per-stage and whole-graph traffic under the resolved edge policies,
/// alongside the all-materialised counterpart — the `--explain` view of
/// what streaming saves.
#[derive(Debug, Clone)]
pub struct GraphTraffic {
    pub stages: Vec<StageTraffic>,
    /// whole-graph bytes under the resolved policies
    pub total: Traffic,
    /// whole-graph bytes if every edge materialised
    pub materialized_total: Traffic,
}

#[derive(Debug, Clone)]
pub struct StageTraffic {
    pub name: String,
    pub policy: EdgePolicy,
    /// this stage's share under the resolved policies (a streamed
    /// segment reads one plane at its head and writes one at its tail;
    /// interior handoffs stay ring-resident and count zero)
    pub traffic: Traffic,
    /// what the stage would move if its edges materialised
    pub materialized: Traffic,
}

/// A validated multi-stage convolution DAG — see the module docs.
pub struct FilterGraph {
    planes: usize,
    rows: usize,
    cols: usize,
    layout: Layout,
    stages: Vec<GraphStage>,
    topo: Vec<usize>,
    /// maximal streamed segments, topologically ordered; every stage
    /// appears in exactly one
    segments: Vec<Vec<usize>>,
    outputs: Vec<usize>,
    /// ring-lease slot length: the longest segment's cascade scratch
    slot_len: usize,
    accumulated_halo: usize,
}

impl FilterGraph {
    pub fn builder() -> GraphBuilder {
        GraphBuilder::new()
    }

    /// `(planes, rows, cols)` every edge carries.
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.planes, self.rows, self.cols)
    }

    pub fn layout(&self) -> Layout {
        self.layout
    }

    /// Stages in declaration order.
    pub fn stages(&self) -> &[GraphStage] {
        &self.stages
    }

    /// Output stage indices, in declaration order.
    pub fn outputs(&self) -> &[usize] {
        &self.outputs
    }

    pub fn output_names(&self) -> Vec<&str> {
        self.outputs.iter().map(|&o| self.stages[o].name.as_str()).collect()
    }

    /// Inter-stage edges that stream through the row-ring cascade (the
    /// coordinator's `stages_fused` counter adds this per graph served).
    pub fn streamed_edges(&self) -> usize {
        self.segments.iter().map(|s| s.len() - 1).sum()
    }

    /// How far a final output row depends on source rows: the maximum
    /// over stages of the summed effective halos along their input
    /// path. Also the per-band recompute bound of banded execution.
    pub fn accumulated_halo(&self) -> usize {
        self.accumulated_halo
    }

    /// Elements per graph-scoped ring-lease slot (one slot per
    /// concurrent band job, sized for the longest streamed segment).
    pub fn ring_footprint(&self) -> usize {
        self.slot_len
    }

    /// Stable cache key over everything execution depends on — the
    /// graph-shaped half of the coordinator's `PlanKey`.
    pub fn cache_key(&self) -> u64 {
        let mut h = DefaultHasher::new();
        (self.planes, self.rows, self.cols).hash(&mut h);
        self.layout.hash(&mut h);
        for s in &self.stages {
            s.name.hash(&mut h);
            s.input.hash(&mut h);
            s.policy.hash(&mut h);
            s.plan.variant().hash(&mut h);
            for &t in s.plan.taps() {
                t.to_bits().hash(&mut h);
            }
        }
        self.outputs.hash(&mut h);
        h.finish()
    }

    fn check_shape(&self, img: &PlanarImage) -> Result<()> {
        ensure!(
            (img.planes, img.rows, img.cols) == (self.planes, self.rows, self.cols),
            "image {}x{}x{} does not match graph shape {}x{}x{}",
            img.planes,
            img.rows,
            img.cols,
            self.planes,
            self.rows,
            self.cols
        );
        Ok(())
    }

    /// Execute sequentially; one image per output, in output order.
    pub fn execute(
        &self,
        img: &PlanarImage,
        arena: &mut ScratchArena,
    ) -> Result<Vec<PlanarImage>> {
        self.execute_exec(Exec::Seq, img, arena)
    }

    /// Execute with every segment banded across `model`'s workers.
    pub fn execute_on(
        &self,
        model: &dyn ExecutionModel,
        img: &PlanarImage,
        arena: &mut ScratchArena,
    ) -> Result<Vec<PlanarImage>> {
        self.execute_exec(Exec::Par(model), img, arena)
    }

    /// Execute a single-output graph (the serving path: one request,
    /// one response image).
    pub fn execute_single(
        &self,
        model: Option<&dyn ExecutionModel>,
        img: &PlanarImage,
        arena: &mut ScratchArena,
    ) -> Result<PlanarImage> {
        ensure!(
            self.outputs.len() == 1,
            "graph has {} outputs; execute_single needs exactly one",
            self.outputs.len()
        );
        let mut out = match model {
            Some(m) => self.execute_on(m, img, arena)?,
            None => self.execute(img, arena)?,
        };
        Ok(out.pop().expect("one output"))
    }

    /// The differential oracle: run every stage through its own plan
    /// with full intermediate planes, ignoring the streamed policies.
    pub fn execute_materialized(
        &self,
        model: Option<&dyn ExecutionModel>,
        img: &PlanarImage,
        arena: &mut ScratchArena,
    ) -> Result<Vec<PlanarImage>> {
        self.check_shape(img)?;
        let mut results: Vec<Option<PlanarImage>> = vec![None; self.stages.len()];
        for &x in &self.topo {
            let stage = &self.stages[x];
            let input = match stage.input {
                None => img,
                Some(p) => results[p].as_ref().expect("topo order computed the input"),
            };
            let out = match model {
                Some(m) => stage.plan.execute_on(m, input, arena)?,
                None => stage.plan.execute(input, arena)?,
            };
            results[x] = Some(out);
        }
        Ok(self
            .outputs
            .iter()
            .map(|&o| results[o].take().expect("outputs are computed"))
            .collect())
    }

    fn execute_exec(
        &self,
        exec: Exec<'_>,
        img: &PlanarImage,
        arena: &mut ScratchArena,
    ) -> Result<Vec<PlanarImage>> {
        self.check_shape(img)?;
        let (planes_eff, rows_eff, cols_eff) = match self.layout {
            Layout::PerPlane => (self.planes, self.rows, self.cols),
            Layout::Agglomerated => (1, self.rows, self.planes * self.cols),
        };
        let n = self.planes * self.rows * self.cols;
        let mut src_buf = arena.take(n);
        match self.layout {
            Layout::PerPlane => src_buf.copy_from_slice(&img.data),
            Layout::Agglomerated => {
                // fold planes into the wide (R, P·C) layout in place
                let (p_, r_, c_) = (self.planes, self.rows, self.cols);
                for p in 0..p_ {
                    let plane = img.plane(p);
                    for i in 0..r_ {
                        let off = i * (p_ * c_) + p * c_;
                        src_buf[off..off + c_].copy_from_slice(&plane[i * c_..(i + 1) * c_]);
                    }
                }
            }
        }
        let slots = match exec {
            Exec::Seq => 1,
            Exec::Par(model) => model.workers(),
        };
        let lease = arena.take_graph_rings(slots, self.slot_len);
        let mut bufs: Vec<Option<Vec<f32>>> = vec![None; self.stages.len()];
        for seg in &self.segments {
            let head = seg[0];
            let src: &[f32] = match self.stages[head].input {
                None => &src_buf,
                Some(p) => bufs[p].as_ref().expect("topo order materialised the input"),
            };
            let mut dst = arena.take(n);
            self.run_segment(exec, seg, src, &mut dst, &lease, planes_eff, rows_eff, cols_eff);
            bufs[*seg.last().expect("segment is non-empty")] = Some(dst);
        }
        let mut outs = Vec::with_capacity(self.outputs.len());
        for &o in &self.outputs {
            let buf = bufs[o].as_ref().expect("outputs materialise");
            outs.push(match self.layout {
                Layout::PerPlane => {
                    PlanarImage::from_vec(self.planes, self.rows, self.cols, buf.clone())?
                }
                Layout::Agglomerated => {
                    PlanarImage::from_agglomerated(self.planes, self.rows, self.cols, buf)?
                }
            });
        }
        arena.put(src_buf);
        for buf in bufs.into_iter().flatten() {
            arena.put(buf);
        }
        arena.put_rings(lease);
        Ok(outs)
    }

    /// Run one streamed segment over every plane of the effective
    /// layout: each band job checks a slot out of the graph-scoped ring
    /// lease and drives the whole cascade for its final-row range.
    #[allow(clippy::too_many_arguments)]
    fn run_segment(
        &self,
        exec: Exec<'_>,
        seg: &[usize],
        src: &[f32],
        dst: &mut [f32],
        rings: &RingLease,
        planes_eff: usize,
        rows_eff: usize,
        cols_eff: usize,
    ) {
        let chain: Vec<ChainStage<'_>> = seg
            .iter()
            .map(|&i| ChainStage::new(self.stages[i].plan.taps(), self.stages[i].plan.variant()))
            .collect();
        let plane_len = rows_eff * cols_eff;
        for p in 0..planes_eff {
            let sp = &src[p * plane_len..(p + 1) * plane_len];
            let dp = &mut dst[p * plane_len..(p + 1) * plane_len];
            match exec {
                Exec::Seq => {
                    let mut slot = rings.acquire();
                    chain_band(sp, dp, rows_eff, cols_eff, &chain, slot.buf(), 0, rows_eff);
                }
                Exec::Par(model) => {
                    let bands = RowBands::new(dp, rows_eff, cols_eff);
                    model.dispatch(rows_eff, &|r0, r1| {
                        // SAFETY: execution models dispatch disjoint
                        // covers of [0, rows) (property-tested), so
                        // bands never overlap.
                        let band = unsafe { bands.band(r0, r1) };
                        let mut slot = rings.acquire();
                        chain_band(sp, band, rows_eff, cols_eff, &chain, slot.buf(), r0, r1);
                    });
                }
            }
        }
    }

    /// Per-stage and whole-graph traffic, resolved policies vs the
    /// all-materialised counterpart.
    pub fn traffic_estimate(&self) -> GraphTraffic {
        let n = self.stages.len();
        let mut current = vec![Traffic::ZERO; n];
        for seg in &self.segments {
            let head = seg[0];
            let tail = *seg.last().expect("segment is non-empty");
            let head_est = self.stages[head].plan.traffic_estimate();
            let tail_est = self.stages[tail].plan.traffic_estimate();
            current[head].read_bytes += head_est.read_bytes;
            current[tail].write_bytes += tail_est.write_bytes;
        }
        let mut total = Traffic::ZERO;
        let mut materialized_total = Traffic::ZERO;
        let mut stages = Vec::with_capacity(n);
        for (x, stage) in self.stages.iter().enumerate() {
            let materialized = stage.plan.traffic_estimate();
            total.accumulate(current[x]);
            materialized_total.accumulate(materialized);
            stages.push(StageTraffic {
                name: stage.name.clone(),
                policy: stage.policy,
                traffic: current[x],
                materialized,
            });
        }
        GraphTraffic { stages, total, materialized_total }
    }

    /// The `--explain` table: one row per stage (width, resolved edge
    /// policy, bytes moved under the resolved policies and if
    /// materialised), plus the whole-graph totals.
    pub fn explain(&self) -> Table {
        let (p, r, c) = (self.planes, self.rows, self.cols);
        let traffic = self.traffic_estimate();
        let mut t = Table::new(
            format!(
                "FilterGraph {p}x{r}x{c} ({:?}): {} stages, {} streamed edges, halo {}",
                self.layout,
                self.stages.len(),
                self.streamed_edges(),
                self.accumulated_halo
            ),
            &["Stage", "Width", "Edge", "MiB moved", "MiB if materialized"],
        );
        for (stage, st) in self.stages.iter().zip(&traffic.stages) {
            t.row(vec![
                stage.name.clone(),
                stage.plan.width().to_string(),
                match stage.input {
                    None => format!("{SOURCE} \u{2192} {}", st.policy.label()),
                    Some(i) => format!("{} \u{2192} {}", self.stages[i].name, st.policy.label()),
                },
                format!("{:.2}", st.traffic.total_mb()),
                format!("{:.2}", st.materialized.total_mb()),
            ]);
        }
        t.row(vec![
            "TOTAL".to_string(),
            "-".to_string(),
            "-".to_string(),
            format!("{:.2}", traffic.total.total_mb()),
            format!("{:.2}", traffic.materialized_total.total_mb()),
        ]);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::{gaussian_kernel, synth_image, Pattern};

    fn shaped() -> GraphBuilder {
        FilterGraph::builder().shape(1, 24, 22)
    }

    #[test]
    fn rejects_empty_graph_and_missing_shape() {
        let e = shaped().build().unwrap_err();
        assert!(format!("{e:#}").contains("at least one stage"), "{e:#}");
        let e = FilterGraph::builder().stage("a", KernelSpec::new(3, 1.0)).build().unwrap_err();
        assert!(format!("{e:#}").contains("needs a shape"), "{e:#}");
    }

    #[test]
    fn rejects_bad_names_and_unknown_inputs() {
        let e = shaped()
            .stage("a", KernelSpec::new(3, 1.0))
            .stage("a", KernelSpec::new(3, 1.0))
            .build()
            .unwrap_err();
        assert!(format!("{e:#}").contains("duplicate"), "{e:#}");
        let e = shaped().stage(SOURCE, KernelSpec::new(3, 1.0)).build().unwrap_err();
        assert!(format!("{e:#}").contains("cannot name a stage"), "{e:#}");
        let e = shaped()
            .stage("a", KernelSpec::new(3, 1.0))
            .after("ghost")
            .build()
            .unwrap_err();
        assert!(format!("{e:#}").contains("unknown input"), "{e:#}");
        let e = shaped()
            .stage("a", KernelSpec::new(3, 1.0))
            .output("ghost")
            .build()
            .unwrap_err();
        assert!(format!("{e:#}").contains("unknown output"), "{e:#}");
    }

    #[test]
    fn rejects_cycles() {
        let e = shaped()
            .stage("a", KernelSpec::new(3, 1.0))
            .after("a")
            .build()
            .unwrap_err();
        assert!(format!("{e:#}").contains("reads itself"), "{e:#}");
        let e = shaped()
            .stage("a", KernelSpec::new(3, 1.0))
            .after("b")
            .stage("b", KernelSpec::new(3, 1.0))
            .after("a")
            .build()
            .unwrap_err();
        assert!(format!("{e:#}").contains("cycle"), "{e:#}");
    }

    #[test]
    fn rejects_shape_mismatched_edges_and_bad_kernels() {
        let e = shaped()
            .stage("a", KernelSpec::new(3, 1.0))
            .expect_shape(1, 24, 23)
            .build()
            .unwrap_err();
        assert!(format!("{e:#}").contains("expects shape"), "{e:#}");
        let e = shaped().stage_taps("a", vec![0.25; 4]).build().unwrap_err();
        assert!(format!("{e:#}").contains("odd"), "{e:#}");
        let e = shaped().materialized().build().unwrap_err();
        assert!(format!("{e:#}").contains("before any stage"), "{e:#}");
    }

    #[test]
    fn linear_chain_resolves_to_one_streamed_segment() {
        let g = shaped()
            .stage("a", KernelSpec::new(3, 1.0))
            .stage("b", KernelSpec::new(7, 1.5))
            .stage("c", KernelSpec::new(3, 1.0))
            .build()
            .unwrap();
        assert_eq!(g.streamed_edges(), 2);
        assert_eq!(g.outputs(), &[2]);
        assert_eq!(g.accumulated_halo(), 1 + 3 + 1);
        assert_eq!(g.stages()[0].policy(), EdgePolicy::Materialized, "source edge");
        assert_eq!(g.stages()[1].policy(), EdgePolicy::Streamed);
        assert_eq!(g.stages()[2].policy(), EdgePolicy::Streamed);
        assert!(g.ring_footprint() > 0);
    }

    #[test]
    fn materialized_edge_splits_the_segment() {
        let g = shaped()
            .stage("a", KernelSpec::new(3, 1.0))
            .stage("b", KernelSpec::new(3, 1.0))
            .materialized()
            .stage("c", KernelSpec::new(3, 1.0))
            .build()
            .unwrap();
        assert_eq!(g.streamed_edges(), 1, "only b->c streams");
        assert_eq!(g.stages()[1].policy(), EdgePolicy::Materialized);
        assert_eq!(g.stages()[2].policy(), EdgePolicy::Streamed);
    }

    #[test]
    fn fan_out_edges_demote_to_materialized() {
        let g = shaped()
            .stage("narrow", KernelSpec::new(3, 1.0))
            .after(SOURCE)
            .stage("wide", KernelSpec::new(7, 2.0))
            .after(SOURCE)
            .stage("post", KernelSpec::new(3, 1.0))
            .after("narrow")
            .stage("post2", KernelSpec::new(3, 1.0))
            .after("narrow")
            .build()
            .unwrap();
        // "narrow" fans out to post/post2: both edges demote
        assert_eq!(g.stages()[2].policy(), EdgePolicy::Materialized);
        assert_eq!(g.stages()[3].policy(), EdgePolicy::Materialized);
        assert_eq!(g.streamed_edges(), 0);
        assert_eq!(g.outputs().len(), 3, "wide, post, post2 are sinks");
    }

    #[test]
    fn streamed_execution_matches_materialized_oracle() {
        let img = synth_image(2, 30, 26, Pattern::Noise, 5);
        let g = FilterGraph::builder()
            .shape(2, 30, 26)
            .stage_taps("a", gaussian_kernel(3, 0.8))
            .stage_taps("b", gaussian_kernel(7, 1.5))
            .build()
            .unwrap();
        let mut arena = ScratchArena::new();
        let streamed = g.execute(&img, &mut arena).unwrap();
        let oracle = g.execute_materialized(None, &img, &mut arena).unwrap();
        assert_eq!(streamed.len(), 1);
        assert_eq!(streamed[0], oracle[0], "generic widths are bitwise");
    }

    #[test]
    fn traffic_estimate_shows_the_streaming_saving() {
        let g = shaped()
            .stage("a", KernelSpec::new(3, 1.0))
            .stage("b", KernelSpec::new(3, 1.0))
            .stage("c", KernelSpec::new(3, 1.0))
            .build()
            .unwrap();
        let t = g.traffic_estimate();
        assert_eq!(t.stages.len(), 3);
        assert!(
            t.total.total_bytes() < t.materialized_total.total_bytes(),
            "streamed chain must move fewer bytes: {} vs {}",
            t.total.total_bytes(),
            t.materialized_total.total_bytes()
        );
        // the streamed segment reads once at the head, writes once at
        // the tail, and its interior handoff moves nothing
        assert_eq!(t.stages[0].traffic.write_bytes, 0);
        assert_eq!(t.stages[1].traffic.total_bytes(), 0);
        assert_eq!(t.stages[2].traffic.read_bytes, 0);
        let table = g.explain().to_text();
        assert!(table.contains("TOTAL") && table.contains("streamed"), "{table}");
    }

    #[test]
    fn cache_key_distinguishes_structure() {
        let build = |w: usize, streamed: bool| {
            let b = shaped()
                .stage("a", KernelSpec::new(3, 1.0))
                .stage("b", KernelSpec::new(w, 1.0));
            let b = if streamed { b } else { b.materialized() };
            b.build().unwrap()
        };
        let a = build(7, true);
        assert_eq!(a.cache_key(), build(7, true).cache_key(), "deterministic");
        assert_ne!(a.cache_key(), build(9, true).cache_key(), "taps differ");
        assert_ne!(a.cache_key(), build(7, false).cache_key(), "policy differs");
    }
}
