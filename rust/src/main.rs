//! phi-conv CLI: the L3 leader entrypoint.
//!
//! Subcommands:
//!   simulate     regenerate a paper exhibit from the Xeon Phi cost model
//!   measure      run the same exhibit measured on this host
//!   tune         sweep tile shapes + agglomeration factors per model
//!   validate     cross-check PJRT artifacts vs the native engines
//!   serve        start the coordinator and push a synthetic workload
//!   info         artifact manifest + configuration summary
//!
//! Examples:
//!   phi-conv simulate --exhibit all
//!   phi-conv measure --exhibit table1 --sizes 288,576 --reps 5
//!   phi-conv measure --exhibit fused --format json   # fusion traffic win
//!   phi-conv tune --sizes 288,576 --reps 5
//!   phi-conv tune --sizes 96,192,288 --save BENCH_costmodel.json
//!   phi-conv tune --load BENCH_costmodel.json --predict --sizes 144,432
//!   phi-conv validate
//!   phi-conv serve --requests 40 --executors 2 --tile-rows 16
//!   phi-conv info

use phi_conv::{bail, ensure, Context, Result};

use phi_conv::config::{standard_cli, RunConfig};
use phi_conv::conv::{convolve_image, Algorithm, Variant};
use phi_conv::coordinator::{Backend, ConvRequest, Coordinator, RoutePolicy};
use phi_conv::harness;
use phi_conv::image::synth_image;
use phi_conv::metrics::SampleSet;
use phi_conv::runtime::Manifest;
use phi_conv::util::prng::Prng;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = standard_cli("phi-conv", "2D image convolution under three parallel execution models (Tousimojarad et al. 2017 reproduction)")
        .opt("exhibit", "all", "fig1..fig4|table1|table2|threads|ablations|tiling|fused|all")
        .opt("format", "text", "text|markdown|csv|json")
        .opt("requests", "24", "serve: number of requests")
        .opt("executors", "2", "serve: executor threads")
        .opt("policy", "adaptive", "serve: adaptive|round-robin|openmp|opencl|gprm|pjrt")
        .flag("no-pjrt", "serve: skip the PJRT backend")
        .opt("save", "", "tune: write samples + fitted cost model to this JSON path")
        .opt("load", "", "tune/serve: seed from a saved cost model JSON")
        .flag("predict", "tune: print predicted-vs-measured error for --sizes (needs --load)")
        .parse(args)?;

    let cfg = RunConfig::resolve(&cli)?;
    let command = cli.positionals().first().map(|s| s.as_str()).unwrap_or("help");

    match command {
        "simulate" => {
            for t in harness::simulated(cli.str_of("exhibit")?)? {
                print_table(&t, cli.str_of("format")?);
            }
        }
        "measure" => {
            eprintln!(
                "measuring on host: sizes {:?}, {} threads, {} reps",
                cfg.sizes, cfg.threads, cfg.reps
            );
            for t in harness::run_measured(cli.str_of("exhibit")?, &cfg)? {
                print_table(&t, cli.str_of("format")?);
            }
        }
        "tune" => tune(
            &cfg,
            cli.str_of("format")?,
            cli.str_of("save")?,
            cli.str_of("load")?,
            cli.is_set("predict"),
        )?,
        "validate" => validate(&cfg)?,
        "serve" => serve(
            &cfg,
            cli.usize_of("requests")?,
            cli.usize_of("executors")?,
            cli.str_of("policy")?,
            !cli.is_set("no-pjrt"),
            cli.str_of("load")?,
        )?,
        "info" => info(&cfg)?,
        _ => {
            println!("usage: phi-conv <simulate|measure|tune|validate|serve|info> [options]");
            println!("       phi-conv --help        for the option list");
        }
    }
    Ok(())
}

fn print_table(t: &phi_conv::metrics::Table, format: &str) {
    match format {
        "markdown" => println!("{}", t.to_markdown()),
        "csv" => println!("{}", t.to_csv()),
        "json" => println!("{}", t.to_json()),
        _ => println!("{}", t.to_text()),
    }
}

/// The agglomeration auto-tune: sweep tile shapes (and, for GPRM,
/// tiles-per-task factors) per model at each configured size, print the
/// paper-style sweep tables, fit the cost model over the collected
/// samples, and finish with the tuned-winner + fit summaries.
///
/// `--load` seeds the sample pool from a saved artifact (the new sweep
/// extends it); `--save` persists samples + fitted coefficients;
/// `--predict` skips sweeping entirely and instead reports
/// predicted-vs-measured error for `--sizes` under the loaded model.
fn tune(cfg: &RunConfig, format: &str, save: &str, load: &str, predict: bool) -> Result<()> {
    use phi_conv::costmodel::CostModel;

    let loaded = if load.is_empty() {
        None
    } else {
        let mut cm = CostModel::load(std::path::Path::new(load))?;
        cm.set_r2_min(cfg.r2_min);
        eprintln!(
            "loaded cost model {load}: {} samples, {} of {} groups usable at r2_min {}",
            cm.samples().len(),
            cm.usable_groups(),
            cm.groups().len(),
            cfg.r2_min
        );
        Some(cm)
    };

    if predict {
        let cm = loaded.context("--predict needs --load <path> (a saved cost model)")?;
        print_table(&cm.to_table(), format);
        let t = phi_conv::costmodel::accuracy_table(cfg, &cm, &cfg.sizes)?;
        print_table(&t, format);
        return Ok(());
    }

    eprintln!(
        "tuning tile/agglomeration on host: sizes {:?}, {} threads, {} reps",
        cfg.sizes, cfg.threads, cfg.reps
    );
    let mut samples: Vec<phi_conv::costmodel::Sample> =
        loaded.map(|cm| cm.samples().to_vec()).unwrap_or_default();
    let mut table = phi_conv::autotune::TuningTable::new();
    for &size in &cfg.sizes {
        let t = phi_conv::autotune::sweep_shape_sampled(cfg, size, &mut table, &mut samples)?;
        print_table(&t, format);
    }
    print_table(&table.to_table(), format);

    let model = CostModel::fit(samples, cfg.r2_min);
    print_table(&model.to_table(), format);
    if !save.is_empty() {
        model.save(std::path::Path::new(save))?;
        eprintln!(
            "saved cost model ({} samples, {} groups) to {save}",
            model.samples().len(),
            model.groups().len()
        );
    }
    Ok(())
}

/// Cross-check every full/agg/ablation artifact against the native
/// engines at its own shape.
fn validate(cfg: &RunConfig) -> Result<()> {
    use phi_conv::runtime::EnginePool;

    let pool = EnginePool::open(&cfg.artifacts_dir)?;
    let manifest = pool.manifest().clone();
    let k = phi_conv::image::gaussian_kernel(manifest.kernel_width, manifest.gaussian_sigma);

    // kernel values must match the Python reference bit-for-bit
    for (a, b) in k.iter().zip(&manifest.kernel_values) {
        ensure!((a - b).abs() < 1e-7, "kernel generator mismatch: {a} vs {b}");
    }
    println!("kernel generator matches Python reference ✓");

    let mut checked = 0;
    for entry in manifest.artifacts.iter() {
        let (alg, layout_agg) = match (entry.role.as_str(), entry.algorithm.as_str()) {
            ("full" | "ablation", "twopass") => (Algorithm::TwoPass, false),
            ("full" | "ablation", "singlepass") => (Algorithm::SinglePassNoCopy, false),
            ("agg", "twopass") => (Algorithm::TwoPass, true),
            _ => continue, // tiles & pyramid validated in integration tests
        };
        let rows = entry.meta_usize("rows").context("rows meta")?;
        let cols = entry.meta_usize("cols").context("cols meta")?;
        let planes = entry.meta_usize("planes").context("planes meta")?;
        if rows > 1152 {
            continue; // keep validate fast
        }
        let img = synth_image(planes, rows, cols, cfg.pattern, cfg.seed);
        let engine = pool.engine(&entry.name)?;
        let got = engine.run1(&[&img.data, &k])?;
        let want = if layout_agg {
            let m = phi_conv::models::OpenMpModel::new(cfg.threads);
            phi_conv::models::convolve_parallel(
                &m,
                &img,
                &k,
                alg,
                Variant::Simd,
                phi_conv::models::Layout::Agglomerated,
            )?
        } else {
            convolve_image(img.clone(), &k, alg, Variant::Simd)?
        };
        let max_diff = got
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        ensure!(
            max_diff < 1e-4,
            "{}: PJRT vs native max diff {max_diff}",
            entry.name
        );
        println!("{:32} PJRT == native (max diff {max_diff:.2e}) ✓", entry.name);
        checked += 1;
    }
    println!("validated {checked} artifacts against native engines");
    Ok(())
}

/// Serving demo: synthetic request mix through the coordinator.
fn serve(
    cfg: &RunConfig,
    requests: usize,
    executors: usize,
    policy: &str,
    with_pjrt: bool,
    load: &str,
) -> Result<()> {
    let policy = match policy {
        "adaptive" => RoutePolicy::paper_default(),
        "round-robin" => RoutePolicy::RoundRobin,
        other => match Backend::parse(other) {
            Some(b) => RoutePolicy::Fixed(b),
            None => bail!("unknown policy {other:?}"),
        },
    };
    let mut coord = match Coordinator::new(cfg, policy, executors, with_pjrt) {
        Ok(c) => c,
        Err(e) if with_pjrt && !matches!(policy, RoutePolicy::Fixed(Backend::Pjrt)) => {
            // PJRT is an optional backend (feature-gated, needs artifacts):
            // serve native-only rather than refusing to start.
            eprintln!("PJRT backend unavailable ({e:#}); serving native-only");
            Coordinator::new(cfg, policy, executors, false)?
        }
        Err(e) => return Err(e),
    };
    if !load.is_empty() {
        let mut cm = phi_conv::costmodel::CostModel::load(std::path::Path::new(load))?;
        cm.set_r2_min(cfg.r2_min);
        eprintln!(
            "loaded cost model {load}: {} of {} groups usable at r2_min {}",
            cm.usable_groups(),
            cm.groups().len(),
            cfg.r2_min
        );
        let mut tuning = phi_conv::autotune::TuningTable::new();
        tuning.set_cost_model(cm);
        coord.set_tuning(tuning);
    }
    println!(
        "coordinator up: {} executors, policy {policy:?}, pjrt={}",
        executors,
        coord.has_pjrt()
    );

    let mut rng = Prng::new(cfg.seed);
    let t0 = std::time::Instant::now();
    let mut latencies = SampleSet::new();
    let mut receivers = Vec::with_capacity(requests);
    let mut refused = 0usize;
    for i in 0..requests {
        let size = *rng.pick(&cfg.sizes);
        let img = synth_image(cfg.planes, size, size, cfg.pattern, cfg.seed + i as u64);
        // blocking admission: backpressure (bounded by --queue-capacity
        // / --deadline-ms) rather than unbounded memory growth; a
        // refused admission is tallied like any other refusal, not a
        // run-aborting error
        match coord.submit(ConvRequest::new(i as u64, img)) {
            Ok(rx) => receivers.push(rx),
            Err(e) => {
                refused += 1;
                eprintln!("  request {i} refused at admission: {e:#}");
            }
        }
    }
    for rx in receivers {
        match rx.recv().context("coordinator dropped")? {
            Ok(resp) => latencies.push(resp.latency_ms()),
            Err(e) => {
                refused += 1;
                eprintln!("  request refused: {e:#}");
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = coord.stats();
    println!(
        "served {} requests in {:.2}s ({:.1} req/s)",
        stats.served,
        wall,
        stats.served as f64 / wall
    );
    println!("latency: {}", latencies.summary());
    for (backend, set) in &stats.service_ms {
        println!("  {backend:8} n={:3}  service {}", set.len(), set.summary());
    }
    if stats.pjrt_fallbacks > 0 {
        println!("  ({} requests fell back from PJRT)", stats.pjrt_fallbacks);
    }
    if coord.tuning().is_some() {
        println!(
            "plan decisions: {} predicted · {} swept · {} default",
            stats.plans_predicted, stats.plans_swept, stats.plans_default
        );
    }
    println!(
        "queue: depth peak {} of {} · {} shed · {} expired · {} refused replies",
        stats.depth_peak,
        coord.queue_capacity(),
        stats.shed,
        stats.expired,
        refused
    );
    Ok(())
}

fn info(cfg: &RunConfig) -> Result<()> {
    println!("phi-conv configuration:");
    println!("  sizes      {:?}", cfg.sizes);
    println!("  planes     {}", cfg.planes);
    println!("  kernel     width {} sigma {}", cfg.kernel_width, cfg.sigma);
    println!("  threads    {}", cfg.threads);
    println!("  cutoff     {}", cfg.cutoff);
    println!("  artifacts  {}", cfg.artifacts_dir.display());
    match Manifest::load(&cfg.artifacts_dir) {
        Ok(m) => {
            println!("manifest: {} artifacts", m.artifacts.len());
            for a in &m.artifacts {
                println!(
                    "  {:32} {:9} {:11} in={:?} out={:?}",
                    a.name,
                    a.role,
                    a.variant,
                    a.inputs.iter().map(|s| &s.shape).collect::<Vec<_>>(),
                    a.outputs.iter().map(|s| &s.shape).collect::<Vec<_>>()
                );
            }
        }
        Err(e) => println!("manifest: unavailable ({e}) — run `make artifacts`"),
    }
    Ok(())
}
