//! phi-conv CLI: the L3 leader entrypoint.
//!
//! Subcommands:
//!   simulate     regenerate a paper exhibit from the Xeon Phi cost model
//!   measure      run the same exhibit measured on this host
//!   tune         sweep tile shapes + agglomeration factors per model
//!   graph        run a multi-stage filter chain (streamed vs materialized)
//!   crossover    direct-2D vs FFT width sweep + measured crossover width
//!   validate     cross-check PJRT artifacts vs the native engines
//!   serve        start the coordinator and push a synthetic workload
//!   load         scale-factor load harness: deterministic traffic mix + SLO table
//!   info         artifact manifest + configuration summary
//!
//! Examples:
//!   phi-conv simulate --exhibit all
//!   phi-conv measure --exhibit table1 --sizes 288,576 --reps 5
//!   phi-conv measure --exhibit fused --format json   # fusion traffic win
//!   phi-conv tune --sizes 288,576 --reps 5
//!   phi-conv tune --sizes 96,192,288 --save BENCH_costmodel.json
//!   phi-conv tune --load BENCH_costmodel.json --predict --sizes 144,432
//!   phi-conv graph --stages blur:9,sharpen:5,edge:3 --explain
//!   phi-conv graph --exhibit dog                     # fan-out exhibit
//!   phi-conv graph --stages blur:5,blur:9 --sweep    # per-edge policies
//!   phi-conv crossover --sizes 256 --reps 5            # BENCH_crossover.json
//!   phi-conv crossover --check --sizes 64 --reps 1     # differential smoke
//!   phi-conv validate
//!   phi-conv serve --requests 40 --executors 2 --tile-rows 16
//!   phi-conv load --scale 1,5                        # SLO curve + BENCH_load.json
//!   phi-conv load --scale 2 --mode closed --load BENCH_costmodel.json
//!   phi-conv info

use phi_conv::{bail, ensure, Context, Result};

use phi_conv::config::{standard_cli, RunConfig};
use phi_conv::conv::{convolve_image, Algorithm, Variant};
use phi_conv::coordinator::{Backend, ConvRequest, Coordinator, RoutePolicy};
use phi_conv::harness;
use phi_conv::image::{gaussian_kernel, synth_image, PlanarImage};
use phi_conv::metrics::{time_reps, SampleSet, Table};
use phi_conv::plan::{FilterGraph, KernelSpec, ScratchArena};
use phi_conv::runtime::Manifest;
use phi_conv::util::cli::Cli;
use phi_conv::util::prng::Prng;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = standard_cli("phi-conv", "2D image convolution under three parallel execution models (Tousimojarad et al. 2017 reproduction)")
        .opt(
            "exhibit",
            "all",
            "fig1..fig4|table1|table2|threads|ablations|tiling|fused|all (graph: unsharp|dog)",
        )
        .opt("format", "text", "text|markdown|csv|json")
        .opt("requests", "24", "serve: number of requests")
        .opt("executors", "2", "serve: executor threads")
        .opt("policy", "adaptive", "serve: adaptive|round-robin|openmp|opencl|gprm|pjrt")
        .flag("no-pjrt", "serve: skip the PJRT backend")
        .opt("save", "", "tune: write samples + fitted cost model to this JSON path")
        .opt("load", "", "tune/serve: seed from a saved cost model JSON")
        .flag("predict", "tune: print predicted-vs-measured error for --sizes (needs --load)")
        .opt("stages", "", "graph: kind:width chain, e.g. blur:9,sharpen:5,edge:3")
        .flag("explain", "graph: print the per-stage traffic breakdown")
        .flag(
            "check",
            "graph: fail unless streamed == materialized bitwise; \
             crossover: differential-check fft vs direct at every width",
        )
        .flag("sweep", "graph: sweep per-edge streaming policies (Gaussian stages only)")
        .opt("scale", "1", "load: comma-separated scale factors, e.g. 1,2,5")
        .opt("mode", "both", "load: driver model — open|closed|both")
        .opt("rate", "", "load: open-loop arrival rate per scale unit in req/s (default 200)")
        .opt("per-scale", "", "load: requests issued per scale unit (default 32)")
        .opt(
            "out",
            "",
            "load/crossover: JSON artifact path (default BENCH_load.json / \
             BENCH_crossover.json; pass none to skip the write)",
        )
        .parse(args)?;

    let cfg = RunConfig::resolve(&cli)?;
    let command = cli.positionals().first().map(|s| s.as_str()).unwrap_or("help");

    match command {
        "simulate" => {
            for t in harness::simulated(cli.str_of("exhibit")?)? {
                print_table(&t, cli.str_of("format")?);
            }
        }
        "measure" => {
            eprintln!(
                "measuring on host: sizes {:?}, {} threads, {} reps",
                cfg.sizes, cfg.threads, cfg.reps
            );
            for t in harness::run_measured(cli.str_of("exhibit")?, &cfg)? {
                print_table(&t, cli.str_of("format")?);
            }
        }
        "tune" => tune(
            &cfg,
            cli.str_of("format")?,
            cli.str_of("save")?,
            cli.str_of("load")?,
            cli.is_set("predict"),
        )?,
        "graph" => graph_cmd(
            &cfg,
            cli.str_of("stages")?,
            cli.str_of("exhibit")?,
            cli.str_of("format")?,
            cli.is_set("explain"),
            cli.is_set("check"),
            cli.is_set("sweep"),
        )?,
        "crossover" => crossover_cmd(&cfg, &cli)?,
        "validate" => validate(&cfg)?,
        "serve" => serve(
            &cfg,
            cli.usize_of("requests")?,
            cli.usize_of("executors")?,
            cli.str_of("policy")?,
            !cli.is_set("no-pjrt"),
            cli.str_of("load")?,
        )?,
        "load" => load_cmd(&cfg, &cli)?,
        "info" => info(&cfg)?,
        _ => {
            println!(
                "usage: phi-conv <simulate|measure|tune|graph|crossover|validate|serve|load|info> [options]"
            );
            println!("       phi-conv --help        for the option list");
        }
    }
    Ok(())
}

/// Resolve `--out`: empty = the command's default artifact, the
/// literal `none` = skip the write.
fn artifact_out(raw: &str, default: &str) -> Option<String> {
    match raw {
        "" => Some(default.to_string()),
        "none" => None,
        other => Some(other.to_string()),
    }
}

fn print_table(t: &phi_conv::metrics::Table, format: &str) {
    match format {
        "markdown" => println!("{}", t.to_markdown()),
        "csv" => println!("{}", t.to_csv()),
        "json" => println!("{}", t.to_json()),
        _ => println!("{}", t.to_text()),
    }
}

/// The agglomeration auto-tune: sweep tile shapes (and, for GPRM,
/// tiles-per-task factors) per model at each configured size, print the
/// paper-style sweep tables, fit the cost model over the collected
/// samples, and finish with the tuned-winner + fit summaries.
///
/// `--load` seeds the sample pool from a saved artifact (the new sweep
/// extends it); `--save` persists samples + fitted coefficients;
/// `--predict` skips sweeping entirely and instead reports
/// predicted-vs-measured error for `--sizes` under the loaded model.
fn tune(cfg: &RunConfig, format: &str, save: &str, load: &str, predict: bool) -> Result<()> {
    use phi_conv::costmodel::CostModel;

    let loaded = if load.is_empty() {
        None
    } else {
        let cm = CostModel::load_with_gate(std::path::Path::new(load), cfg.r2_min)?;
        eprintln!(
            "loaded cost model {load}: {} samples, {} of {} groups usable at r2_min {}",
            cm.samples().len(),
            cm.usable_groups(),
            cm.groups().len(),
            cfg.r2_min
        );
        Some(cm)
    };

    if predict {
        let cm = loaded.context("--predict needs --load <path> (a saved cost model)")?;
        print_table(&cm.to_table(), format);
        let t = phi_conv::costmodel::accuracy_table(cfg, &cm, &cfg.sizes)?;
        print_table(&t, format);
        return Ok(());
    }

    eprintln!(
        "tuning tile/agglomeration on host: sizes {:?}, {} threads, {} reps",
        cfg.sizes, cfg.threads, cfg.reps
    );
    let mut samples: Vec<phi_conv::costmodel::Sample> =
        loaded.map(|cm| cm.samples().to_vec()).unwrap_or_default();
    let mut table = phi_conv::autotune::TuningTable::new();
    for &size in &cfg.sizes {
        let t = phi_conv::autotune::sweep_shape_sampled(cfg, size, &mut table, &mut samples)?;
        print_table(&t, format);
    }
    print_table(&table.to_table(), format);

    let model = CostModel::fit(samples, cfg.r2_min);
    print_table(&model.to_table(), format);
    if !save.is_empty() {
        model.save(std::path::Path::new(save))?;
        eprintln!(
            "saved cost model ({} samples, {} groups) to {save}",
            model.samples().len(),
            model.groups().len()
        );
    }
    Ok(())
}

/// Multi-stage filter chains ([`FilterGraph`]): build the requested
/// `--stages` chain (or a canned `--exhibit`), run it with every
/// eligible edge streamed and again with every edge materialised, and
/// report median times + estimated memory traffic for both. `--check`
/// turns any streamed-vs-materialised mismatch into a hard error (the
/// verify.sh smoke), `--explain` adds the per-stage traffic table, and
/// `--sweep` measures every per-edge policy candidate instead.
fn graph_cmd(
    cfg: &RunConfig,
    stages: &str,
    exhibit: &str,
    format: &str,
    explain: bool,
    check: bool,
    sweep: bool,
) -> Result<()> {
    if !stages.is_empty() {
        let parsed = parse_stages(stages)?;
        if sweep {
            return sweep_stages(cfg, &parsed, format);
        }
        for &size in &cfg.sizes {
            let streamed = build_chain(cfg, size, &parsed, true)?;
            let twin = build_chain(cfg, size, &parsed, false)?;
            run_graph_pair(cfg, stages, &streamed, &twin, format, explain, check)?;
        }
        return Ok(());
    }
    ensure!(!sweep, "--sweep needs --stages (a Gaussian chain to sweep)");
    let which: &[&str] = match exhibit {
        "all" => &["unsharp", "dog"],
        "unsharp" => &["unsharp"],
        "dog" => &["dog"],
        other => bail!("unknown graph exhibit {other:?} (unsharp|dog|all; or pass --stages)"),
    };
    for name in which {
        graph_exhibit(cfg, name, format, explain, check)?;
    }
    Ok(())
}

/// `--stages blur:9,sharpen:5,edge:3` → (kind, width) pairs.
fn parse_stages(s: &str) -> Result<Vec<(String, usize)>> {
    let mut out = Vec::new();
    for part in s.split(',').filter(|p| !p.trim().is_empty()) {
        let (kind, width) = part.split_once(':').context(format!(
            "stage {part:?} is not kind:width (e.g. blur:9)"
        ))?;
        let width: usize = width
            .trim()
            .parse()
            .ok()
            .context(format!("stage {part:?} has a non-numeric width"))?;
        out.push((kind.trim().to_string(), width));
    }
    ensure!(!out.is_empty(), "--stages is empty");
    Ok(out)
}

/// Default Gaussian scale for a named stage: the kernel covers ±2.5σ.
fn stage_sigma(width: usize) -> f64 {
    (width as f64 / 5.0).max(0.5)
}

/// Separable taps for a named stage kind at the given odd width.
fn stage_taps(kind: &str, width: usize) -> Result<Vec<f32>> {
    ensure!(
        width % 2 == 1 && width >= 3,
        "stage width must be odd and >= 3, got {width}"
    );
    let g = gaussian_kernel(width, stage_sigma(width));
    let c = width / 2;
    Ok(match kind {
        "blur" | "gauss" => g,
        "sharpen" => {
            // 2·identity − blur: boosts what the blur removes
            let mut t: Vec<f32> = g.iter().map(|v| -v).collect();
            t[c] += 2.0;
            t
        }
        "edge" => {
            // derivative-of-Gaussian, normalised to Σ|t| = 1
            let mut t: Vec<f32> =
                g.iter().enumerate().map(|(i, v)| (i as f32 - c as f32) * v).collect();
            let norm: f32 = t.iter().map(|v| v.abs()).sum();
            ensure!(norm > 0.0, "degenerate edge stage at width {width}");
            for v in &mut t {
                *v /= norm;
            }
            t
        }
        other => bail!("unknown stage kind {other:?} (blur|gauss|sharpen|edge)"),
    })
}

/// A linear chain over the configured planes at `size`×`size`, every
/// eligible edge streamed or every edge materialised.
fn build_chain(
    cfg: &RunConfig,
    size: usize,
    stages: &[(String, usize)],
    streamed: bool,
) -> Result<FilterGraph> {
    let mut b = FilterGraph::builder().shape(cfg.planes, size, size);
    for (i, (kind, width)) in stages.iter().enumerate() {
        b = b.stage_taps(&format!("{kind}{i}"), stage_taps(kind, *width)?);
        if !streamed {
            b = b.materialized();
        }
    }
    b.build()
}

/// `--sweep`: measure every per-edge policy candidate. Needs Gaussian
/// stages — the policy cost depends on stage widths (halos), which
/// blur/gauss stages cover.
fn sweep_stages(cfg: &RunConfig, stages: &[(String, usize)], format: &str) -> Result<()> {
    let mut specs = Vec::with_capacity(stages.len());
    for (kind, width) in stages {
        ensure!(
            kind == "blur" || kind == "gauss",
            "--sweep accepts Gaussian stages only, got {kind:?}"
        );
        specs.push(KernelSpec::new(*width, stage_sigma(*width)));
    }
    for &size in &cfg.sizes {
        let t = phi_conv::autotune::sweep_chain(cfg, size, &specs)?;
        print_table(&t, format);
    }
    Ok(())
}

/// Time a graph against its all-materialised twin on the synthetic
/// image, differential-check the outputs, and print the comparison.
/// Returns the streamed outputs so exhibits can post-process them.
fn run_graph_pair(
    cfg: &RunConfig,
    title: &str,
    streamed: &FilterGraph,
    twin: &FilterGraph,
    format: &str,
    explain: bool,
    check: bool,
) -> Result<Vec<PlanarImage>> {
    let (planes, rows, cols) = streamed.shape();
    let img = synth_image(planes, rows, cols, cfg.pattern, cfg.seed);
    let model = phi_conv::models::OpenMpModel::new(cfg.threads);
    let mut arena = ScratchArena::new();

    // first runs propagate build/shape errors before timing starts
    let mut got = streamed.execute_on(&model, &img, &mut arena)?;
    let mut want = twin.execute_on(&model, &img, &mut arena)?;
    let t_s = time_reps(
        || got = streamed.execute_on(&model, &img, &mut arena).expect("streamed graph"),
        cfg.warmup,
        cfg.reps,
    )
    .median();
    let t_m = time_reps(
        || want = twin.execute_on(&model, &img, &mut arena).expect("materialized graph"),
        cfg.warmup,
        cfg.reps,
    )
    .median();

    let mut max_diff = 0f32;
    let mut bitwise = true;
    for (a, b) in got.iter().zip(&want) {
        for (x, y) in a.data.iter().zip(&b.data) {
            max_diff = max_diff.max((x - y).abs());
            bitwise &= x.to_bits() == y.to_bits();
        }
    }
    ensure!(
        max_diff < 1e-6,
        "{title}: streamed vs materialized diverged by {max_diff:e}"
    );
    if check {
        ensure!(
            bitwise,
            "{title}: streamed vs materialized not bitwise (max diff {max_diff:e})"
        );
    }

    let traffic = streamed.traffic_estimate();
    let mut t = Table::new(
        format!(
            "FilterGraph {title}: {planes}x{rows}x{cols}, {} stages, {} streamed edges, {} threads",
            streamed.stages().len(),
            streamed.streamed_edges(),
            cfg.threads
        ),
        &["Mode", "ms (median)", "est MiB moved", "agreement"],
    );
    t.row(vec![
        "streamed".to_string(),
        format!("{t_s:.3}"),
        format!("{:.2}", traffic.total.total_mb()),
        if bitwise { "bitwise".to_string() } else { format!("{max_diff:.1e}") },
    ]);
    t.row(vec![
        "materialized".to_string(),
        format!("{t_m:.3}"),
        format!("{:.2}", traffic.materialized_total.total_mb()),
        "baseline".to_string(),
    ]);
    print_table(&t, format);
    if explain {
        print_table(&streamed.explain(), format);
    }
    Ok(got)
}

/// Canned graph exhibits.
///
/// * `unsharp` — two cascaded blurs (effective σ = √(σ1²+σ2²)) feed an
///   unsharp mask applied afterwards: out = img + 0.6·(img − blurred).
/// * `dog` — difference of Gaussians with the wider blur expressed as
///   a cascade over the narrow one; the narrow blur is both consumed
///   and a graph output, so the builder demotes that edge to
///   materialised (visible under --explain).
fn graph_exhibit(
    cfg: &RunConfig,
    which: &str,
    format: &str,
    explain: bool,
    check: bool,
) -> Result<()> {
    let size = *cfg.sizes.last().context("no sizes configured")?;
    let img = synth_image(cfg.planes, size, size, cfg.pattern, cfg.seed);
    match which {
        "unsharp" => {
            let chain = [("blur".to_string(), 5), ("blur".to_string(), 9)];
            let streamed = build_chain(cfg, size, &chain, true)?;
            let twin = build_chain(cfg, size, &chain, false)?;
            let outs =
                run_graph_pair(cfg, "unsharp mask", &streamed, &twin, format, explain, check)?;
            let blurred = outs.last().context("unsharp graph has one output")?;
            let amount = 0.6f32;
            let out: Vec<f32> = img
                .data
                .iter()
                .zip(&blurred.data)
                .map(|(x, b)| x + amount * (x - b))
                .collect();
            let (lo, hi) =
                out.iter().fold((f32::MAX, f32::MIN), |(lo, hi), &v| (lo.min(v), hi.max(v)));
            println!("unsharp mask (amount {amount}): output range [{lo:.3}, {hi:.3}]");
        }
        "dog" => {
            let streamed = FilterGraph::builder()
                .shape(cfg.planes, size, size)
                .stage_taps("narrow", stage_taps("blur", 5)?)
                .stage_taps("widen", stage_taps("blur", 9)?)
                .output("narrow")
                .output("widen")
                .build()?;
            let twin = FilterGraph::builder()
                .shape(cfg.planes, size, size)
                .stage_taps("narrow", stage_taps("blur", 5)?)
                .materialized()
                .stage_taps("widen", stage_taps("blur", 9)?)
                .materialized()
                .output("narrow")
                .output("widen")
                .build()?;
            let outs = run_graph_pair(
                cfg,
                "difference of Gaussians",
                &streamed,
                &twin,
                format,
                explain,
                check,
            )?;
            let dog: f64 = outs[0]
                .data
                .iter()
                .zip(&outs[1].data)
                .map(|(a, b)| (a - b).abs() as f64)
                .sum::<f64>()
                / outs[0].data.len() as f64;
            println!("difference of Gaussians: mean band-pass energy {dog:.4}");
        }
        other => bail!("unknown graph exhibit {other:?}"),
    }
    Ok(())
}

/// The kernel-class crossover exhibit: sweep odd kernel widths on the
/// largest configured size, timing the banded direct 2-D engine against
/// the FFT convolver, and report the first width where FFT wins — the
/// measured crossover the cost model is expected to learn. Ends with a
/// 3-image RGB batch through the FFT plan (`execute_batch`). `--check`
/// differential-checks every width (fft vs direct ≤ 1e-4, direct vs
/// separable two-pass ≤ 1e-6) — the verify.sh smoke. Writes
/// `BENCH_crossover.json` unless `--out none`.
fn crossover_cmd(cfg: &RunConfig, cli: &Cli) -> Result<()> {
    use phi_conv::plan::{ConvPlan, KernelClass};
    use phi_conv::util::json::Json;

    let format = cli.str_of("format")?;
    let check = cli.is_set("check");
    let size = *cfg.sizes.last().context("no sizes configured")?;
    let img = synth_image(cfg.planes, size, size, cfg.pattern, cfg.seed);
    let model = phi_conv::models::OpenMpModel::new(cfg.threads);
    let mut arena = ScratchArena::new();

    let build = |width: usize, class: KernelClass| {
        ConvPlan::builder()
            .variant(Variant::Simd)
            .kernel(KernelSpec::new(width, stage_sigma(width)))
            .kernel_class(class)
            .shape(cfg.planes, size, size)
            .build()
    };

    let mut t = Table::new(
        format!(
            "kernel-class crossover: {}x{size}x{size}, {} threads, median of {} reps",
            cfg.planes, cfg.threads, cfg.reps
        ),
        &["Width", "direct2d ms", "fft ms", "winner", "fft speedup"],
    );
    let mut sweep = Vec::new();
    let mut crossover: Option<usize> = None;
    let mut last_width = 0usize;
    for width in (3..=63usize).step_by(4) {
        if width >= size {
            eprintln!("  (sweep clipped at width {last_width}: size {size} is too small)");
            break;
        }
        last_width = width;
        let direct = build(width, KernelClass::Direct2d)?;
        let fft = build(width, KernelClass::Fft)?;
        let mut got_d = direct.execute_on(&model, &img, &mut arena)?;
        let mut got_f = fft.execute_on(&model, &img, &mut arena)?;
        if check {
            let sep = ConvPlan::builder()
                .variant(Variant::Simd)
                .kernel(KernelSpec::new(width, stage_sigma(width)))
                .shape(cfg.planes, size, size)
                .build()?;
            let want = sep.execute(&img, &mut arena)?;
            let d = got_d.max_abs_diff(&want);
            ensure!(d < 1e-6, "width {width}: direct2d vs separable two-pass diff {d:e}");
            let f = got_f.max_abs_diff(&got_d);
            ensure!(f < 1e-4, "width {width}: fft vs direct2d diff {f:e}");
        }
        let t_d = time_reps(
            || got_d = direct.execute_on(&model, &img, &mut arena).expect("direct2d plan"),
            cfg.warmup,
            cfg.reps,
        )
        .median();
        let t_f = time_reps(
            || got_f = fft.execute_on(&model, &img, &mut arena).expect("fft plan"),
            cfg.warmup,
            cfg.reps,
        )
        .median();
        if crossover.is_none() && t_f < t_d {
            crossover = Some(width);
        }
        t.row(vec![
            width.to_string(),
            format!("{t_d:.3}"),
            format!("{t_f:.3}"),
            if t_f < t_d { "fft" } else { "direct2d" }.to_string(),
            format!("{:.2}x", t_d / t_f),
        ]);
        let mut row = std::collections::BTreeMap::new();
        row.insert("width".to_string(), Json::Num(width as f64));
        row.insert("direct_ms".to_string(), Json::Num(t_d));
        row.insert("fft_ms".to_string(), Json::Num(t_f));
        sweep.push(Json::Obj(row));
    }
    print_table(&t, format);
    match crossover {
        Some(w) => println!("measured crossover width: {w} (FFT wins at and beyond)"),
        None => println!("measured crossover width: none within the sweep (direct2d wins)"),
    }

    // the RGB leg: three channel-planes batched through one FFT plan —
    // the multi-image entry point the coordinator's batching uses
    ensure!(last_width >= 3, "size {size} leaves no width to sweep (need > 3)");
    let fft = build(last_width, KernelClass::Fft)?;
    let batch: Vec<PlanarImage> = (0..3u64)
        .map(|c| synth_image(cfg.planes, size, size, cfg.pattern, cfg.seed + 100 + c))
        .collect();
    let mut outs = Vec::new();
    let t_b = time_reps(
        || outs = fft.execute_batch(Some(&model), &batch, &mut arena).expect("rgb batch"),
        cfg.warmup,
        cfg.reps,
    )
    .median();
    ensure!(outs.len() == 3, "RGB batch must return one image per channel");
    println!("RGB batch (3 images, width {last_width}, fft): {t_b:.3} ms");

    if let Some(out) = artifact_out(cli.str_of("out")?, "BENCH_crossover.json") {
        let mut root = std::collections::BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("crossover".to_string()));
        root.insert("provenance".to_string(), Json::Str("measured".to_string()));
        root.insert("threads".to_string(), Json::Num(cfg.threads as f64));
        root.insert("planes".to_string(), Json::Num(cfg.planes as f64));
        root.insert("size".to_string(), Json::Num(size as f64));
        root.insert("reps".to_string(), Json::Num(cfg.reps as f64));
        root.insert("warmup".to_string(), Json::Num(cfg.warmup as f64));
        root.insert("seed".to_string(), Json::Num(cfg.seed as f64));
        root.insert(
            "crossover_width".to_string(),
            crossover.map(|w| Json::Num(w as f64)).unwrap_or(Json::Null),
        );
        root.insert("rgb_batch_ms".to_string(), Json::Num(t_b));
        root.insert("sweep".to_string(), Json::Arr(sweep));
        let json = Json::Obj(root);
        std::fs::write(&out, format!("{json}\n")).with_context(|| format!("writing {out}"))?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

/// Cross-check every full/agg/ablation artifact against the native
/// engines at its own shape.
fn validate(cfg: &RunConfig) -> Result<()> {
    use phi_conv::runtime::EnginePool;

    let pool = EnginePool::open(&cfg.artifacts_dir)?;
    let manifest = pool.manifest().clone();
    let k = phi_conv::image::gaussian_kernel(manifest.kernel_width, manifest.gaussian_sigma);

    // kernel values must match the Python reference bit-for-bit
    for (a, b) in k.iter().zip(&manifest.kernel_values) {
        ensure!((a - b).abs() < 1e-7, "kernel generator mismatch: {a} vs {b}");
    }
    println!("kernel generator matches Python reference ✓");

    let mut checked = 0;
    for entry in manifest.artifacts.iter() {
        let (alg, layout_agg) = match (entry.role.as_str(), entry.algorithm.as_str()) {
            ("full" | "ablation", "twopass") => (Algorithm::TwoPass, false),
            ("full" | "ablation", "singlepass") => (Algorithm::SinglePassNoCopy, false),
            ("agg", "twopass") => (Algorithm::TwoPass, true),
            _ => continue, // tiles & pyramid validated in integration tests
        };
        let rows = entry.meta_usize("rows").context("rows meta")?;
        let cols = entry.meta_usize("cols").context("cols meta")?;
        let planes = entry.meta_usize("planes").context("planes meta")?;
        if rows > 1152 {
            continue; // keep validate fast
        }
        let img = synth_image(planes, rows, cols, cfg.pattern, cfg.seed);
        let engine = pool.engine(&entry.name)?;
        let got = engine.run1(&[&img.data, &k])?;
        let want = if layout_agg {
            let m = phi_conv::models::OpenMpModel::new(cfg.threads);
            phi_conv::models::convolve_parallel(
                &m,
                &img,
                &k,
                alg,
                Variant::Simd,
                phi_conv::models::Layout::Agglomerated,
            )?
        } else {
            convolve_image(img.clone(), &k, alg, Variant::Simd)?
        };
        let max_diff = got
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        ensure!(
            max_diff < 1e-4,
            "{}: PJRT vs native max diff {max_diff}",
            entry.name
        );
        println!("{:32} PJRT == native (max diff {max_diff:.2e}) ✓", entry.name);
        checked += 1;
    }
    println!("validated {checked} artifacts against native engines");
    Ok(())
}

/// Serving demo: synthetic request mix through the coordinator.
fn serve(
    cfg: &RunConfig,
    requests: usize,
    executors: usize,
    policy: &str,
    with_pjrt: bool,
    load: &str,
) -> Result<()> {
    let policy = match policy {
        "adaptive" => RoutePolicy::paper_default(),
        "round-robin" => RoutePolicy::RoundRobin,
        other => match Backend::parse(other) {
            Some(b) => RoutePolicy::Fixed(b),
            None => bail!("unknown policy {other:?}"),
        },
    };
    let mut coord = match Coordinator::new(cfg, policy, executors, with_pjrt) {
        Ok(c) => c,
        Err(e) if with_pjrt && !matches!(policy, RoutePolicy::Fixed(Backend::Pjrt)) => {
            // PJRT is an optional backend (feature-gated, needs artifacts):
            // serve native-only rather than refusing to start.
            eprintln!("PJRT backend unavailable ({e:#}); serving native-only");
            Coordinator::new(cfg, policy, executors, false)?
        }
        Err(e) => return Err(e),
    };
    if !load.is_empty() {
        let cm =
            phi_conv::costmodel::CostModel::load_with_gate(std::path::Path::new(load), cfg.r2_min)?;
        eprintln!(
            "loaded cost model {load}: {} of {} groups usable at r2_min {}",
            cm.usable_groups(),
            cm.groups().len(),
            cfg.r2_min
        );
        coord.set_tuning(phi_conv::autotune::TuningTable::from_cost_model(cm));
    }
    println!(
        "coordinator up: {} executors, policy {policy:?}, pjrt={}",
        executors,
        coord.has_pjrt()
    );

    let mut rng = Prng::new(cfg.seed);
    let t0 = std::time::Instant::now();
    let mut latencies = SampleSet::new();
    let mut receivers = Vec::with_capacity(requests);
    let mut refused = 0usize;
    for i in 0..requests {
        let size = *rng.pick(&cfg.sizes);
        let img = synth_image(cfg.planes, size, size, cfg.pattern, cfg.seed + i as u64);
        // blocking admission: backpressure (bounded by --queue-capacity
        // / --deadline-ms) rather than unbounded memory growth; a
        // refused admission is tallied like any other refusal, not a
        // run-aborting error
        match coord.submit(ConvRequest::new(i as u64, img)) {
            Ok(rx) => receivers.push(rx),
            Err(e) => {
                refused += 1;
                eprintln!("  request {i} refused at admission: {e:#}");
            }
        }
    }
    for rx in receivers {
        match rx.recv().context("coordinator dropped")? {
            Ok(resp) => latencies.push(resp.latency_ms()),
            Err(e) => {
                refused += 1;
                eprintln!("  request refused: {e:#}");
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = coord.stats();
    println!(
        "served {} requests in {:.2}s ({:.1} req/s)",
        stats.served,
        wall,
        stats.served as f64 / wall
    );
    println!("latency: {}", latencies.summary());
    for (backend, set) in &stats.service_ms {
        println!("  {backend:8} n={:3}  service {}", set.len(), set.summary());
    }
    if stats.pjrt_fallbacks > 0 {
        println!("  ({} requests fell back from PJRT)", stats.pjrt_fallbacks);
    }
    if coord.tuning().is_some() {
        println!(
            "plan decisions: {} predicted · {} swept · {} default",
            stats.plans_predicted, stats.plans_swept, stats.plans_default
        );
    }
    println!(
        "queue: depth peak {} of {} · {} shed · {} expired · {} refused replies",
        stats.depth_peak,
        coord.queue_capacity(),
        stats.shed,
        stats.expired,
        refused
    );
    Ok(())
}

/// The scale-factor load harness: deterministic traffic mixes against
/// a fresh coordinator per (scale, mode), reported as the per-scale
/// SLO table (p50/p95/p99, served/shed/expired, depth peak, batch and
/// plan-decision mixes) plus the `BENCH_load.json` document.
fn load_cmd(cfg: &RunConfig, cli: &Cli) -> Result<()> {
    use phi_conv::loadgen::{report_table, results_json, run_scales, MixConfig, Mode};

    let scales = cli.usize_list_of("scale")?;
    let modes = Mode::parse(cli.str_of("mode")?)?;
    let executors = cli.usize_of("executors")?;

    // the harness exists to exercise plan-keyed batching: unless the
    // operator pinned --batch-max, coalesce up to 8 jobs per dispatch
    let mut cfg = cfg.clone();
    if cli.get("batch-max").unwrap_or("").is_empty() {
        cfg.batch_max = 8;
    }

    let mut mix = MixConfig { seed: cfg.seed, planes: cfg.planes, ..MixConfig::default() };
    if cfg.deadline_ms > 0 {
        mix.deadline_ms = cfg.deadline_ms;
    }
    if let Some(v) = cli.get("rate") {
        if !v.is_empty() {
            mix.rate_per_s = v.parse()?;
        }
    }
    if let Some(v) = cli.get("per-scale") {
        if !v.is_empty() {
            mix.requests_per_scale = v.parse()?;
        }
    }

    let load = cli.str_of("load")?;
    let cm = if load.is_empty() {
        None
    } else {
        let cm =
            phi_conv::costmodel::CostModel::load_with_gate(std::path::Path::new(load), cfg.r2_min)?;
        eprintln!(
            "loaded cost model {load}: {} of {} groups usable at r2_min {}",
            cm.usable_groups(),
            cm.groups().len(),
            cfg.r2_min
        );
        Some(cm)
    };

    eprintln!(
        "load harness: scales {scales:?}, {} requests + {} req/s per scale unit, \
         {executors} executors, batch_max {}, deadline {} ms, seed {}",
        mix.requests_per_scale, mix.rate_per_s, cfg.batch_max, mix.deadline_ms, mix.seed
    );
    let results = run_scales(&cfg, &mix, &scales, &modes, executors, cm.as_ref())?;
    print_table(&report_table(&results), cli.str_of("format")?);

    if let Some(out) = artifact_out(cli.str_of("out")?, "BENCH_load.json") {
        let json = results_json(&mix, &cfg, executors, &results);
        std::fs::write(&out, format!("{json}\n")).with_context(|| format!("writing {out}"))?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

fn info(cfg: &RunConfig) -> Result<()> {
    println!("phi-conv configuration:");
    println!("  sizes      {:?}", cfg.sizes);
    println!("  planes     {}", cfg.planes);
    println!("  kernel     width {} sigma {}", cfg.kernel_width, cfg.sigma);
    println!("  threads    {}", cfg.threads);
    println!("  cutoff     {}", cfg.cutoff);
    println!("  artifacts  {}", cfg.artifacts_dir.display());
    match Manifest::load(&cfg.artifacts_dir) {
        Ok(m) => {
            println!("manifest: {} artifacts", m.artifacts.len());
            for a in &m.artifacts {
                println!(
                    "  {:32} {:9} {:11} in={:?} out={:?}",
                    a.name,
                    a.role,
                    a.variant,
                    a.inputs.iter().map(|s| &s.shape).collect::<Vec<_>>(),
                    a.outputs.iter().map(|s| &s.shape).collect::<Vec<_>>()
                );
            }
        }
        Err(e) => println!("manifest: unavailable ({e}) — run `make artifacts`"),
    }
    Ok(())
}
