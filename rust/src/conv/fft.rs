//! Zero-dependency radix-2 FFT convolver — the large-kernel class.
//!
//! Direct 2-D convolution costs `O(rows·cols·krows·kcols)`; past a
//! machine-dependent kernel width the `O(n log n)` transform route wins
//! (Kepner's fast-convolver crossover, PAPERS.md). This module supplies
//! that route without touching crates.io: an iterative radix-2
//! Cooley–Tukey transform over in-tree `f64` buffers, run row-wise then
//! column-wise (strided, no transpose) over a zero-padded
//! next-power-of-two plane.
//!
//! [`FftPlan`] is the plan-cached half: built once per
//! `(rows, cols, kernel)` it holds the per-axis twiddle tables and the
//! forward spectrum of the *reversed* kernel. The engines here compute
//! correlation (like every direct engine in this crate:
//! `out[i,j] = Σ k[u,v]·src[i+u−hr, j+v−hc]`), and correlation by `k`
//! is circular convolution by the both-axes-reversed kernel, shifted by
//! the halo: `corr[i,j] = circ[i+hr, j+hc]`. Padding each axis to
//! `next_pow2(n + k − 1)` leaves the wraparound outside the region we
//! read back, so edge semantics match the direct reference on the
//! interior `[hr, rows−hr) × [hc, cols−hc)` (differentially asserted
//! ≤ 1e-4; in practice f64 transforms land within f32 rounding).
//!
//! Execution scratch is two `f64` planes of [`FftPlan::scratch_len`]
//! elements (real + imaginary), leased from the plan arena's `f64` pool
//! by the pipeline — this module itself has no arena dependency.

/// Smallest power of two ≥ `n` (and ≥ 1).
pub fn next_pow2(n: usize) -> usize {
    n.max(1).next_power_of_two()
}

/// Forward twiddle table for transform length `n`: `n/2` roots
/// `w_k = e^{−2πik/n}` as `(cos, −sin)` pairs.
fn twiddles(n: usize) -> (Vec<f64>, Vec<f64>) {
    let half = n / 2;
    let mut re = Vec::with_capacity(half);
    let mut im = Vec::with_capacity(half);
    for k in 0..half {
        let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
        re.push(ang.cos());
        im.push(ang.sin());
    }
    (re, im)
}

/// In-place iterative radix-2 transform of the length-`n` sequence at
/// `off, off+stride, …` in `(re, im)`. `inverse` conjugates the
/// twiddles; no scaling is applied (the caller folds the single
/// `1/(nr·nc)` factor into the read-back).
#[allow(clippy::too_many_arguments)]
fn fft_strided(
    re: &mut [f64],
    im: &mut [f64],
    off: usize,
    stride: usize,
    n: usize,
    twr: &[f64],
    twi: &[f64],
    inverse: bool,
) {
    debug_assert!(n.is_power_of_two());
    debug_assert_eq!(twr.len(), n / 2);
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 0..n {
        if i < j {
            re.swap(off + i * stride, off + j * stride);
            im.swap(off + i * stride, off + j * stride);
        }
        let mut bit = n >> 1;
        while bit > 0 && j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
    }
    // butterflies
    let mut len = 2;
    while len <= n {
        let half = len / 2;
        let step = n / len; // twiddle stride for this stage
        let mut start = 0;
        while start < n {
            for k in 0..half {
                let wr = twr[k * step];
                let wi = if inverse { -twi[k * step] } else { twi[k * step] };
                let ia = off + (start + k) * stride;
                let ib = off + (start + k + half) * stride;
                let tr = re[ib] * wr - im[ib] * wi;
                let ti = re[ib] * wi + im[ib] * wr;
                re[ib] = re[ia] - tr;
                im[ib] = im[ia] - ti;
                re[ia] += tr;
                im[ia] += ti;
            }
            start += len;
        }
        len <<= 1;
    }
}

/// 2-D transform of the `nr × nc` complex plane in `(re, im)`:
/// row transforms (unit stride) then column transforms (stride `nc`).
#[allow(clippy::too_many_arguments)]
fn fft2d(
    re: &mut [f64],
    im: &mut [f64],
    nr: usize,
    nc: usize,
    twr_c: &[f64],
    twi_c: &[f64],
    twr_r: &[f64],
    twi_r: &[f64],
    inverse: bool,
) {
    for r in 0..nr {
        fft_strided(re, im, r * nc, 1, nc, twr_c, twi_c, inverse);
    }
    for c in 0..nc {
        fft_strided(re, im, c, nc, nr, twr_r, twi_r, inverse);
    }
}

/// Plan-cached state for one `(rows, cols, kernel)` FFT convolution:
/// padded extents, per-axis twiddle tables, and the forward spectrum of
/// the reversed kernel. Build once, call
/// [`FftPlan::convolve_into`] per plane.
#[derive(Debug, Clone)]
pub struct FftPlan {
    rows: usize,
    cols: usize,
    krows: usize,
    kcols: usize,
    /// Padded extents: `next_pow2(rows + krows − 1)` × `next_pow2(cols + kcols − 1)`.
    nr: usize,
    nc: usize,
    /// Forward twiddles for the column-length (`nc`) and row-length
    /// (`nr`) transforms.
    twr_c: Vec<f64>,
    twi_c: Vec<f64>,
    twr_r: Vec<f64>,
    twi_r: Vec<f64>,
    /// Forward spectrum of the both-axes-reversed, zero-padded kernel.
    kre: Vec<f64>,
    kim: Vec<f64>,
}

impl FftPlan {
    /// Build the plan for an `rows × cols` plane and a `krows × kcols`
    /// tap matrix (row-major, odd extents enforced upstream by
    /// `KernelSpec`/`Kernel2d` validation).
    pub fn new(rows: usize, cols: usize, k2d: &[f32], krows: usize, kcols: usize) -> Self {
        debug_assert_eq!(k2d.len(), krows * kcols);
        let nr = next_pow2(rows + krows - 1);
        let nc = next_pow2(cols + kcols - 1);
        let (twr_c, twi_c) = twiddles(nc);
        let (twr_r, twi_r) = twiddles(nr);
        // correlation by k == circular convolution by the reversed
        // kernel; pad it at the origin and take its forward spectrum
        let mut kre = vec![0f64; nr * nc];
        let mut kim = vec![0f64; nr * nc];
        for u in 0..krows {
            for v in 0..kcols {
                kre[u * nc + v] = k2d[(krows - 1 - u) * kcols + (kcols - 1 - v)] as f64;
            }
        }
        fft2d(&mut kre, &mut kim, nr, nc, &twr_c, &twi_c, &twr_r, &twi_r, false);
        Self { rows, cols, krows, kcols, nr, nc, twr_c, twi_c, twr_r, twi_r, kre, kim }
    }

    /// Length of each of the two `f64` scratch planes (real and
    /// imaginary) that [`FftPlan::convolve_into`] requires.
    pub fn scratch_len(&self) -> usize {
        self.nr * self.nc
    }

    /// Padded extents `(nr, nc)` — exposed for traffic estimation.
    pub fn padded(&self) -> (usize, usize) {
        (self.nr, self.nc)
    }

    /// Convolve one plane: `dst[i,j] = Σ k[u,v]·src[i+u−hr, j+v−hc]`
    /// over the interior `[hr, rows−hr) × [hc, cols−hc)`; border cells
    /// of `dst` are left untouched (the caller pre-loads them, exactly
    /// as for the direct engines). `re`/`im` are caller-leased scratch
    /// of [`FftPlan::scratch_len`] elements each. A kernel taller or
    /// wider than the plane writes nothing.
    pub fn convolve_into(&self, src: &[f32], dst: &mut [f32], re: &mut [f64], im: &mut [f64]) {
        let (rows, cols) = (self.rows, self.cols);
        debug_assert_eq!(src.len(), rows * cols);
        debug_assert_eq!(dst.len(), rows * cols);
        assert_eq!(re.len(), self.scratch_len(), "real scratch length");
        assert_eq!(im.len(), self.scratch_len(), "imaginary scratch length");
        let (hr, hc) = (self.krows / 2, self.kcols / 2);
        if 2 * hr >= rows || 2 * hc >= cols {
            return;
        }
        let (nr, nc) = (self.nr, self.nc);
        re.fill(0.0);
        im.fill(0.0);
        for i in 0..rows {
            for (pad, &s) in re[i * nc..i * nc + cols].iter_mut().zip(&src[i * cols..]) {
                *pad = s as f64;
            }
        }
        fft2d(re, im, nr, nc, &self.twr_c, &self.twi_c, &self.twr_r, &self.twi_r, false);
        for ((r, i), (kr, ki)) in
            re.iter_mut().zip(im.iter_mut()).zip(self.kre.iter().zip(&self.kim))
        {
            let (a, b) = (*r, *i);
            *r = a * kr - b * ki;
            *i = a * ki + b * kr;
        }
        fft2d(re, im, nr, nc, &self.twr_c, &self.twi_c, &self.twr_r, &self.twi_r, true);
        // corr[i,j] = circ[i+hr, j+hc]; one global inverse scale
        let scale = 1.0 / (nr * nc) as f64;
        for i in hr..rows - hr {
            let circ = &re[(i + hr) * nc + hc..];
            for (d, c) in dst[i * cols + hc..i * cols + cols - hc].iter_mut().zip(circ) {
                *d = (c * scale) as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::direct2d::direct2d_band_naive;
    use crate::image::{gaussian_kernel, gaussian_kernel2d};
    use crate::util::prng::Prng;

    const R: usize = 26;
    const C: usize = 22;

    fn noise(seed: u64, n: usize) -> Vec<f32> {
        let mut p = Prng::new(seed);
        (0..n).map(|_| p.normal()).collect()
    }

    fn run_fft(src: &[f32], rows: usize, cols: usize, k: &[f32], kr: usize, kc: usize) -> Vec<f32> {
        let plan = FftPlan::new(rows, cols, k, kr, kc);
        let mut re = vec![0f64; plan.scratch_len()];
        let mut im = vec![0f64; plan.scratch_len()];
        let mut dst = src.to_vec();
        plan.convolve_into(src, &mut dst, &mut re, &mut im);
        dst
    }

    #[test]
    fn next_pow2_rounds_up() {
        assert_eq!(next_pow2(0), 1);
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(2), 2);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(33), 64);
        assert_eq!(next_pow2(64), 64);
    }

    #[test]
    fn matches_direct_reference_on_random_kernels() {
        let src = noise(1, R * C);
        for (kr, kc) in [(1usize, 1usize), (3, 3), (5, 7), (7, 3), (9, 9), (1, 5)] {
            let mut p = Prng::new(40 + (kr * 10 + kc) as u64);
            let k: Vec<f32> = (0..kr * kc).map(|_| p.normal()).collect();
            let mut want = src.clone();
            direct2d_band_naive(&src, &mut want, R, C, &k, kr, kc, 0, R);
            let got = run_fft(&src, R, C, &k, kr, kc);
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                assert!((w - g).abs() <= 1e-4, "{kr}x{kc} cell {i}: {w} vs {g}");
            }
        }
    }

    #[test]
    fn matches_direct_on_separable_gaussian() {
        let src = noise(2, R * C);
        for width in [3usize, 7, 13] {
            let k2 = gaussian_kernel2d(&gaussian_kernel(width, 1.5));
            let mut want = src.clone();
            direct2d_band_naive(&src, &mut want, R, C, &k2, width, width, 0, R);
            let got = run_fft(&src, R, C, &k2, width, width);
            for (w, g) in want.iter().zip(&got) {
                assert!((w - g).abs() <= 1e-4, "w{width}: {w} vs {g}");
            }
        }
    }

    #[test]
    fn borders_are_left_untouched() {
        let src = noise(3, R * C);
        let k = gaussian_kernel2d(&gaussian_kernel(5, 1.0));
        let plan = FftPlan::new(R, C, &k, 5, 5);
        let mut re = vec![0f64; plan.scratch_len()];
        let mut im = vec![0f64; plan.scratch_len()];
        let mut dst = vec![7f32; R * C];
        plan.convolve_into(&src, &mut dst, &mut re, &mut im);
        let h = 2;
        for i in 0..R {
            for j in 0..C {
                let border = i < h || i >= R - h || j < h || j >= C - h;
                if border {
                    assert_eq!(dst[i * C + j], 7.0, "border cell ({i},{j}) written");
                }
            }
        }
    }

    #[test]
    fn plan_is_reusable_across_planes() {
        let a = noise(4, R * C);
        let b = noise(5, R * C);
        let k = noise(6, 7 * 7);
        let plan = FftPlan::new(R, C, &k, 7, 7);
        let mut re = vec![0f64; plan.scratch_len()];
        let mut im = vec![0f64; plan.scratch_len()];
        let mut got_a = a.clone();
        plan.convolve_into(&a, &mut got_a, &mut re, &mut im);
        let mut got_b = b.clone();
        plan.convolve_into(&b, &mut got_b, &mut re, &mut im);
        // scratch reuse must not leak plane A into plane B
        let fresh_b = run_fft(&b, R, C, &k, 7, 7);
        assert_eq!(got_b, fresh_b);
        // and a second pass over A reproduces the first exactly
        let mut again_a = a.clone();
        plan.convolve_into(&a, &mut again_a, &mut re, &mut im);
        assert_eq!(got_a, again_a);
    }

    #[test]
    fn degenerate_plane_is_a_noop() {
        let src = noise(7, 8 * 7);
        let k = noise(8, 9 * 9);
        let plan = FftPlan::new(8, 7, &k, 9, 9);
        let mut re = vec![0f64; plan.scratch_len()];
        let mut im = vec![0f64; plan.scratch_len()];
        let mut dst = vec![5f32; 8 * 7];
        plan.convolve_into(&src, &mut dst, &mut re, &mut im);
        assert!(dst.iter().all(|&v| v == 5.0));
    }

    #[test]
    fn padding_covers_linear_extent() {
        let plan = FftPlan::new(100, 60, &[1.0; 9], 3, 3);
        let (nr, nc) = plan.padded();
        assert!(nr >= 100 + 3 - 1 && nr.is_power_of_two());
        assert!(nc >= 60 + 3 - 1 && nc.is_power_of_two());
        assert_eq!(plan.scratch_len(), nr * nc);
    }
}
