//! Native convolution engines: the paper's optimisation ladder.
//!
//! The paper walks a naive single-pass convolution through loop
//! unrolling, SIMD vectorisation and an algorithmic switch to the
//! separable two-pass form (section 5.2, Figure 1/4). These engines
//! mirror each rung in Rust:
//!
//! | rung  | paper                              | here                          |
//! |-------|------------------------------------|-------------------------------|
//! | Opt-0 | naive 4-loop, `-no-vec`            | [`band::singlepass_naive_band`] |
//! | Opt-1 | unrolled 25-term, `-no-vec`        | [`band::singlepass_band`] (scalar) |
//! | Opt-2 | + `#pragma simd`                   | [`band::singlepass_band`] (simd) |
//! | Opt-3 | two-pass unrolled, `-no-vec`       | [`band::horiz_band`]/[`band::vert_band`] (scalar) |
//! | Opt-4 | + `#pragma simd`                   | same (simd)                   |
//!
//! *Vectorisation analogue.* `-no-vec` vs `#pragma simd` on the Xeon Phi
//! toggles use of the 512-bit VPU. Here the split is structural: `scalar`
//! variants compute one pixel at a time through index arithmetic (the
//! compiler is told nothing about independence), while `simd` variants
//! express each output row as five shifted whole-row slice operations —
//! the shape LLVM reliably auto-vectorises (and exactly the shape of the
//! Pallas kernels, which keeps Rust↔PJRT numerics aligned). The measured
//! scalar/simd ratio on the host plays the role of the paper's
//! no-vec/SIMD columns in Table 1.
//!
//! All engines work on *row bands* `[r0, r1)` so the execution models in
//! [`crate::models`] can parallelise the outer loop exactly like
//! `#pragma omp parallel for` / GPRM's `par_cont_for` / OpenCL NDRange
//! partitioning do in the paper. The [`tile`] module carries the 2-D
//! siblings of the band primitives (rectangular tiles instead of full
//! rows) that back the tiled `dispatch2d` plans.
//!
//! Every rung exists in two widths: the paper's hand-unrolled W=5
//! primitives (the fast path) and generic odd-width `*_w` twins of the
//! same scalar/simd shape. Selection between them — and all
//! algorithm/variant/layout dispatch — lives in [`crate::plan`]; the
//! drivers here are sequential conveniences over it.
//!
//! Beyond the paper's ladder, the two-pass rung also exists **fused**
//! (`band::fused_band_*`, `tile::fused_tile_*`): one rolling row-ring
//! pass that keeps the horizontal intermediate in an O(width×cols)
//! per-worker ring instead of a full plane, halving memory traffic on
//! the bandwidth-bound shapes that dominate at scale (enabled per plan
//! via `PlanBuilder::fuse`, per run via `--fuse`). The [`chain`] module
//! generalises that ring to N stages: a whole filter chain streams
//! row-by-row through cascaded rings, crossing memory twice instead of
//! 2k times (driven by `plan::FilterGraph`).

//! Beyond the separable ladder entirely, two further *kernel classes*
//! serve workloads the paper's scope excludes: [`direct2d`] convolves
//! arbitrary (non-separable) odd×odd tap matrices directly, with the
//! same band/tile contracts and scalar/simd shapes as the single-pass
//! engines, and [`fft`] carries an in-tree radix-2 transform convolver
//! for the large kernels where `O(n log n)` beats direct arithmetic
//! (Kepner's crossover). Class selection is a plan dimension
//! (`plan::KernelClass`), picked by the cost model when not pinned.

pub mod band;
pub mod chain;
pub mod direct2d;
pub mod fft;
pub mod plane;
pub mod tile;

pub use plane::{convolve_image, convolve_plane, Algorithm, Variant};

/// Halo of the paper's 5-wide kernel.
pub const HALO: usize = 2;

/// Kernel width of the unrolled fast-path engines (the paper hand-unrolls
/// W=5; the generic-width engines accept any odd width).
pub const WIDTH: usize = 5;
