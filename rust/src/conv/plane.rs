//! Whole-plane / whole-image sequential drivers over the band primitives.
//!
//! These are the "sequential code" of the paper's speedup denominators:
//! every parallel execution model must produce pixel-identical output to
//! these drivers (integration tests enforce it).
//!
//! Since the plan refactor, these drivers are thin conveniences over
//! [`crate::plan::ConvPlan`]: the former per-function
//! `match (algorithm, variant)` dispatch lives in the plan's pass
//! pipeline, which also serves the parallel driver, the coordinator and
//! the harness. Any odd kernel width is accepted — width 5 takes the
//! unrolled fast path, everything else the generic-width engines.

use crate::util::error::Result;

use crate::image::PlanarImage;

/// Which algorithm (paper sections 5.1 / 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// naive or unrolled direct convolution, then copy B back over A.
    SinglePassCopyBack,
    /// direct convolution into B, no copy-back (section 7).
    SinglePassNoCopy,
    /// separable horizontal+vertical passes; result lands in A.
    TwoPass,
}

impl Algorithm {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "singlepass" | "singlepass-copyback" => Algorithm::SinglePassCopyBack,
            "singlepass-nocopy" => Algorithm::SinglePassNoCopy,
            "twopass" => Algorithm::TwoPass,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Algorithm::SinglePassCopyBack => "singlepass",
            Algorithm::SinglePassNoCopy => "singlepass-nocopy",
            Algorithm::TwoPass => "twopass",
        }
    }
}

/// Which rung of the ladder (paper section 5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// 4 nested loops, per-pixel (Opt-0 shape). Single-pass only.
    Naive,
    /// unrolled taps, per-pixel indexed arithmetic (`-no-vec` shape).
    Scalar,
    /// unrolled taps, whole-row slice sweeps (`#pragma simd` shape).
    Simd,
}

impl Variant {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "naive" => Variant::Naive,
            "scalar" | "no-vec" => Variant::Scalar,
            "simd" => Variant::Simd,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Variant::Naive => "naive",
            Variant::Scalar => "no-vec",
            Variant::Simd => "simd",
        }
    }
}

/// Convolve one plane `a` (in place, paper semantics) using scratch `b`.
///
/// * `TwoPass`: horizontal a→b, vertical b→a; result in `a`.
/// * `SinglePassNoCopy`: direct a→b; result in `b` (`b` must start as a
///   copy of `a` so its border band carries the pass-through pixels).
/// * `SinglePassCopyBack`: direct a→b then copy b→a; result in `a`.
///
/// One-shot wrapper over [`crate::plan::ConvPlan::run_plane`] — build a
/// plan once instead when convolving repeatedly.
pub fn convolve_plane(
    a: &mut [f32],
    b: &mut [f32],
    rows: usize,
    cols: usize,
    k: &[f32],
    algorithm: Algorithm,
    variant: Variant,
) -> Result<()> {
    let plan = crate::plan::ConvPlan::builder()
        .algorithm(algorithm)
        .variant(variant)
        .kernel_taps(k.to_vec())
        .shape(1, rows, cols)
        .build()?;
    plan.run_plane(a, b)
}

/// Convolve every plane of an image sequentially (the paper's `conv`
/// wrapper, Listing 1). Returns the convolved image; `img` is consumed as
/// the working buffer.
pub fn convolve_image(
    mut img: PlanarImage,
    k: &[f32],
    algorithm: Algorithm,
    variant: Variant,
) -> Result<PlanarImage> {
    let (rows, cols) = (img.rows, img.cols);
    let plan = crate::plan::ConvPlan::builder()
        .algorithm(algorithm)
        .variant(variant)
        .kernel_taps(k.to_vec())
        .shape(1, rows, cols)
        .build()?;
    let mut scratch_img = img.clone(); // B starts as a copy of A (DESIGN.md §4)
    for p in 0..img.planes {
        let a = img.plane_mut(p);
        let b = scratch_img.plane_mut(p);
        plan.run_plane(a, b)?;
    }
    Ok(match algorithm {
        Algorithm::SinglePassNoCopy => scratch_img, // result lives in B
        _ => img,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::{gaussian_kernel, synth_image, Pattern};

    fn setup() -> (PlanarImage, Vec<f32>) {
        (synth_image(3, 24, 20, Pattern::Noise, 11), gaussian_kernel(5, 1.0))
    }

    #[test]
    fn all_singlepass_variants_identical_pixels() {
        let (img, k) = setup();
        let cb = convolve_image(img.clone(), &k, Algorithm::SinglePassCopyBack, Variant::Simd).unwrap();
        let nc = convolve_image(img.clone(), &k, Algorithm::SinglePassNoCopy, Variant::Simd).unwrap();
        let nv = convolve_image(img.clone(), &k, Algorithm::SinglePassCopyBack, Variant::Scalar).unwrap();
        let na = convolve_image(img.clone(), &k, Algorithm::SinglePassCopyBack, Variant::Naive).unwrap();
        assert_eq!(cb, nc, "copy-back only changes where the result lives");
        assert!(cb.max_abs_diff(&nv) < 1e-6);
        assert!(cb.max_abs_diff(&na) < 1e-5);
    }

    #[test]
    fn twopass_variants_identical_pixels() {
        let (img, k) = setup();
        let a = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        let b = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Scalar).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn deep_interior_agreement_between_algorithms() {
        let (img, k) = setup();
        let sp = convolve_image(img.clone(), &k, Algorithm::SinglePassNoCopy, Variant::Simd).unwrap();
        let tp = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        assert!(sp.max_abs_diff_deep(&tp, 2) < 1e-4);
        // ...but they genuinely differ near the border band
        assert!(sp.max_abs_diff(&tp) > 1e-4);
    }

    #[test]
    fn border_passthrough() {
        let (img, k) = setup();
        for alg in [Algorithm::SinglePassCopyBack, Algorithm::SinglePassNoCopy, Algorithm::TwoPass] {
            let out = convolve_image(img.clone(), &k, alg, Variant::Simd).unwrap();
            for p in 0..3 {
                for j in 0..img.cols {
                    assert_eq!(out.get(p, 0, j), img.get(p, 0, j), "{alg:?}");
                    assert_eq!(out.get(p, 1, j), img.get(p, 1, j));
                    assert_eq!(out.get(p, img.rows - 1, j), img.get(p, img.rows - 1, j));
                }
                for i in 0..img.rows {
                    assert_eq!(out.get(p, i, 0), img.get(p, i, 0));
                    assert_eq!(out.get(p, i, img.cols - 1), img.get(p, i, img.cols - 1));
                }
            }
        }
    }

    #[test]
    fn constant_image_fixed_point() {
        let img = synth_image(1, 16, 16, Pattern::Constant, 0);
        let k = gaussian_kernel(5, 1.0);
        for alg in [Algorithm::SinglePassNoCopy, Algorithm::TwoPass] {
            let out = convolve_image(img.clone(), &k, alg, Variant::Simd).unwrap();
            for &v in &out.data {
                assert!((v - 0.5).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn ramp_invariance() {
        // Gaussian of a horizontal ramp = same ramp on the interior.
        let img = synth_image(1, 16, 32, Pattern::RampX, 0);
        let k = gaussian_kernel(5, 1.0);
        let out = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        for i in 2..14 {
            for j in 2..30 {
                assert!((out.get(0, i, j) - j as f32).abs() < 1e-3, "({i},{j})");
            }
        }
    }

    #[test]
    fn naive_twopass_rejected() {
        let (img, k) = setup();
        assert!(convolve_image(img, &k, Algorithm::TwoPass, Variant::Naive).is_err());
    }

    #[test]
    fn generic_widths_served_not_mis_served() {
        // pre-plan, non-5 widths under the unrolled variants were a hard
        // error (and the parallel driver silently used a zero kernel);
        // now they run the generic-width engines correctly.
        let (img, _) = setup();
        let k3 = gaussian_kernel(3, 1.0);
        let naive3 =
            convolve_image(img.clone(), &k3, Algorithm::SinglePassCopyBack, Variant::Naive).unwrap();
        for variant in [Variant::Scalar, Variant::Simd] {
            let sp = convolve_image(img.clone(), &k3, Algorithm::SinglePassCopyBack, variant).unwrap();
            assert!(sp.max_abs_diff(&naive3) < 1e-4, "{variant:?} single-pass w3");
            let tp = convolve_image(img.clone(), &k3, Algorithm::TwoPass, variant).unwrap();
            assert!(tp.max_abs_diff_deep(&naive3, 1) < 1e-4, "{variant:?} two-pass w3");
        }
        // even widths stay structured errors
        let k4 = vec![0.25f32; 4];
        assert!(convolve_image(img, &k4, Algorithm::TwoPass, Variant::Simd).is_err());
    }

    #[test]
    fn parse_labels_roundtrip() {
        for a in [Algorithm::SinglePassCopyBack, Algorithm::SinglePassNoCopy, Algorithm::TwoPass] {
            assert_eq!(Algorithm::parse(a.label()), Some(a));
        }
        for v in [Variant::Naive, Variant::Scalar, Variant::Simd] {
            assert_eq!(Variant::parse(v.label()), Some(v));
        }
    }
}
