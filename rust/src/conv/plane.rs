//! Whole-plane / whole-image sequential drivers over the band primitives.
//!
//! These are the "sequential code" of the paper's speedup denominators:
//! every parallel execution model must produce pixel-identical output to
//! these drivers (integration tests enforce it).

use crate::util::error::Result;

use crate::image::{gaussian_kernel2d, PlanarImage};

use super::band;

/// Which algorithm (paper sections 5.1 / 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// naive or unrolled direct convolution, then copy B back over A.
    SinglePassCopyBack,
    /// direct convolution into B, no copy-back (section 7).
    SinglePassNoCopy,
    /// separable horizontal+vertical passes; result lands in A.
    TwoPass,
}

impl Algorithm {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "singlepass" | "singlepass-copyback" => Algorithm::SinglePassCopyBack,
            "singlepass-nocopy" => Algorithm::SinglePassNoCopy,
            "twopass" => Algorithm::TwoPass,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Algorithm::SinglePassCopyBack => "singlepass",
            Algorithm::SinglePassNoCopy => "singlepass-nocopy",
            Algorithm::TwoPass => "twopass",
        }
    }
}

/// Which rung of the ladder (paper section 5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// 4 nested loops, per-pixel (Opt-0 shape). Single-pass only.
    Naive,
    /// unrolled taps, per-pixel indexed arithmetic (`-no-vec` shape).
    Scalar,
    /// unrolled taps, whole-row slice sweeps (`#pragma simd` shape).
    Simd,
}

impl Variant {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "naive" => Variant::Naive,
            "scalar" | "no-vec" => Variant::Scalar,
            "simd" => Variant::Simd,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Variant::Naive => "naive",
            Variant::Scalar => "no-vec",
            Variant::Simd => "simd",
        }
    }
}

/// Convolve one plane `a` (in place, paper semantics) using scratch `b`.
///
/// * `TwoPass`: horizontal a→b, vertical b→a; result in `a`.
/// * `SinglePassNoCopy`: direct a→b; result in `b` (`b` must start as a
///   copy of `a` so its border band carries the pass-through pixels).
/// * `SinglePassCopyBack`: direct a→b then copy b→a; result in `a`.
pub fn convolve_plane(
    a: &mut [f32],
    b: &mut [f32],
    rows: usize,
    cols: usize,
    k: &[f32],
    algorithm: Algorithm,
    variant: Variant,
) -> Result<()> {
    if k.len() != 5 && variant != Variant::Naive {
        bail!("unrolled engines are specialised to width 5, got {}", k.len());
    }
    if a.len() != rows * cols || b.len() != rows * cols {
        bail!("plane buffers must be rows*cols");
    }
    let k2d = gaussian_kernel2d(k);
    match (algorithm, variant) {
        (Algorithm::TwoPass, Variant::Naive) => {
            bail!("the paper's naive rung is single-pass only (Opt-0)")
        }
        (Algorithm::TwoPass, Variant::Scalar) => {
            band::horiz_band_scalar(a, b, rows, cols, five(k), 0, rows);
            band::vert_band_scalar(b, a, rows, cols, five(k), 0, rows);
        }
        (Algorithm::TwoPass, Variant::Simd) => {
            band::horiz_band_simd(a, b, rows, cols, five(k), 0, rows);
            band::vert_band_simd(b, a, rows, cols, five(k), 0, rows);
        }
        (alg, variant) => {
            match variant {
                Variant::Naive => band::singlepass_naive_band(a, b, rows, cols, &k2d, k.len(), 0, rows),
                Variant::Scalar => {
                    band::singlepass_band_scalar(a, b, rows, cols, k2d25(&k2d), 0, rows)
                }
                Variant::Simd => band::singlepass_band_simd(a, b, rows, cols, k2d25(&k2d), 0, rows),
            }
            if alg == Algorithm::SinglePassCopyBack {
                match variant {
                    Variant::Simd => band::copy_back_band_simd(b, a, cols, 0, rows),
                    _ => band::copy_back_band_scalar(b, a, cols, 0, rows),
                }
            }
        }
    }
    Ok(())
}

fn five(k: &[f32]) -> &[f32; 5] {
    k.try_into().expect("width-5 kernel")
}

fn k2d25(k2d: &[f32]) -> &[f32; 25] {
    k2d.try_into().expect("5x5 kernel")
}

/// Reusable buffers for repeated convolutions (perf pass, EXPERIMENTS.md
/// §Perf iteration 1): a fresh `Vec` per call costs an allocation plus
/// first-touch page faults — ~2.5 ms at 576²×3, more than the convolution
/// itself. The paper's benchmark loop convolves the same arrays 1000
/// times in place; `Workspace` restores that pattern.
#[derive(Debug, Default)]
pub struct Workspace {
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    /// wide buffers for the 3R×C agglomerated layout
    pub wide_a: Vec<f32>,
    pub wide_b: Vec<f32>,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fill `a` and `b` for a convolution, reusing capacity.
    ///
    /// `a` is a full copy. `b` nominally "starts as a copy of A"
    /// (DESIGN.md §4), but only its border band is ever *read* before
    /// being written — the vertical pass reads B's top/bottom `h` rows,
    /// and the single-pass result's pass-through pixels are B's border
    /// ring — so only the border ring is copied (§Perf iteration 3:
    /// ~19 % off the two-pass sequential path at 576²).
    pub fn load(&mut self, img: &PlanarImage) {
        self.a.clear();
        self.a.extend_from_slice(&img.data);
        let n = img.data.len();
        self.b.resize(n, 0.0);
        let h = crate::conv::HALO;
        let (rows, cols) = (img.rows, img.cols);
        if rows <= 2 * h || cols <= 2 * h {
            self.b.copy_from_slice(&img.data);
            return;
        }
        let plane_len = rows * cols;
        for p in 0..img.planes {
            let src = &img.data[p * plane_len..(p + 1) * plane_len];
            let dst = &mut self.b[p * plane_len..(p + 1) * plane_len];
            // top and bottom h rows
            dst[..h * cols].copy_from_slice(&src[..h * cols]);
            dst[(rows - h) * cols..].copy_from_slice(&src[(rows - h) * cols..]);
            // left and right h columns of the interior rows
            for i in h..rows - h {
                dst[i * cols..i * cols + h].copy_from_slice(&src[i * cols..i * cols + h]);
                dst[(i + 1) * cols - h..(i + 1) * cols]
                    .copy_from_slice(&src[(i + 1) * cols - h..(i + 1) * cols]);
            }
        }
    }
}

/// Convolve an image using caller-owned buffers; returns the slice (in
/// the workspace) holding the result. No allocation after the first call
/// at a given size.
pub fn convolve_image_into<'ws>(
    ws: &'ws mut Workspace,
    img: &PlanarImage,
    k: &[f32],
    algorithm: Algorithm,
    variant: Variant,
) -> Result<&'ws [f32]> {
    ws.load(img);
    let (rows, cols) = (img.rows, img.cols);
    let plane_len = rows * cols;
    for p in 0..img.planes {
        let a = &mut ws.a[p * plane_len..(p + 1) * plane_len];
        let b = &mut ws.b[p * plane_len..(p + 1) * plane_len];
        convolve_plane(a, b, rows, cols, k, algorithm, variant)?;
    }
    Ok(match algorithm {
        Algorithm::SinglePassNoCopy => &ws.b,
        _ => &ws.a,
    })
}

/// Convolve every plane of an image sequentially (the paper's `conv`
/// wrapper, Listing 1). Returns the convolved image; `img` is consumed as
/// the working buffer.
pub fn convolve_image(
    mut img: PlanarImage,
    k: &[f32],
    algorithm: Algorithm,
    variant: Variant,
) -> Result<PlanarImage> {
    let (rows, cols) = (img.rows, img.cols);
    let mut scratch_img = img.clone(); // B starts as a copy of A (DESIGN.md §4)
    for p in 0..img.planes {
        let a = img.plane_mut(p);
        let b = scratch_img.plane_mut(p);
        convolve_plane(a, b, rows, cols, k, algorithm, variant)?;
    }
    Ok(match algorithm {
        Algorithm::SinglePassNoCopy => scratch_img, // result lives in B
        _ => img,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::{gaussian_kernel, synth_image, Pattern};

    fn setup() -> (PlanarImage, Vec<f32>) {
        (synth_image(3, 24, 20, Pattern::Noise, 11), gaussian_kernel(5, 1.0))
    }

    #[test]
    fn all_singlepass_variants_identical_pixels() {
        let (img, k) = setup();
        let cb = convolve_image(img.clone(), &k, Algorithm::SinglePassCopyBack, Variant::Simd).unwrap();
        let nc = convolve_image(img.clone(), &k, Algorithm::SinglePassNoCopy, Variant::Simd).unwrap();
        let nv = convolve_image(img.clone(), &k, Algorithm::SinglePassCopyBack, Variant::Scalar).unwrap();
        let na = convolve_image(img.clone(), &k, Algorithm::SinglePassCopyBack, Variant::Naive).unwrap();
        assert_eq!(cb, nc, "copy-back only changes where the result lives");
        assert!(cb.max_abs_diff(&nv) < 1e-6);
        assert!(cb.max_abs_diff(&na) < 1e-5);
    }

    #[test]
    fn twopass_variants_identical_pixels() {
        let (img, k) = setup();
        let a = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        let b = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Scalar).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn deep_interior_agreement_between_algorithms() {
        let (img, k) = setup();
        let sp = convolve_image(img.clone(), &k, Algorithm::SinglePassNoCopy, Variant::Simd).unwrap();
        let tp = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        assert!(sp.max_abs_diff_deep(&tp, 2) < 1e-4);
        // ...but they genuinely differ near the border band
        assert!(sp.max_abs_diff(&tp) > 1e-4);
    }

    #[test]
    fn border_passthrough() {
        let (img, k) = setup();
        for alg in [Algorithm::SinglePassCopyBack, Algorithm::SinglePassNoCopy, Algorithm::TwoPass] {
            let out = convolve_image(img.clone(), &k, alg, Variant::Simd).unwrap();
            for p in 0..3 {
                for j in 0..img.cols {
                    assert_eq!(out.get(p, 0, j), img.get(p, 0, j), "{alg:?}");
                    assert_eq!(out.get(p, 1, j), img.get(p, 1, j));
                    assert_eq!(out.get(p, img.rows - 1, j), img.get(p, img.rows - 1, j));
                }
                for i in 0..img.rows {
                    assert_eq!(out.get(p, i, 0), img.get(p, i, 0));
                    assert_eq!(out.get(p, i, img.cols - 1), img.get(p, i, img.cols - 1));
                }
            }
        }
    }

    #[test]
    fn constant_image_fixed_point() {
        let img = synth_image(1, 16, 16, Pattern::Constant, 0);
        let k = gaussian_kernel(5, 1.0);
        for alg in [Algorithm::SinglePassNoCopy, Algorithm::TwoPass] {
            let out = convolve_image(img.clone(), &k, alg, Variant::Simd).unwrap();
            for &v in &out.data {
                assert!((v - 0.5).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn ramp_invariance() {
        // Gaussian of a horizontal ramp = same ramp on the interior.
        let img = synth_image(1, 16, 32, Pattern::RampX, 0);
        let k = gaussian_kernel(5, 1.0);
        let out = convolve_image(img.clone(), &k, Algorithm::TwoPass, Variant::Simd).unwrap();
        for i in 2..14 {
            for j in 2..30 {
                assert!((out.get(0, i, j) - j as f32).abs() < 1e-3, "({i},{j})");
            }
        }
    }

    #[test]
    fn naive_twopass_rejected() {
        let (img, k) = setup();
        assert!(convolve_image(img, &k, Algorithm::TwoPass, Variant::Naive).is_err());
    }

    #[test]
    fn width5_enforced_for_unrolled() {
        let (img, _) = setup();
        let k3 = gaussian_kernel(3, 1.0);
        assert!(convolve_image(img.clone(), &k3, Algorithm::TwoPass, Variant::Simd).is_err());
        // but the naive generic engine accepts width 3
        assert!(convolve_image(img, &k3, Algorithm::SinglePassCopyBack, Variant::Naive).is_ok());
    }

    #[test]
    fn parse_labels_roundtrip() {
        for a in [Algorithm::SinglePassCopyBack, Algorithm::SinglePassNoCopy, Algorithm::TwoPass] {
            assert_eq!(Algorithm::parse(a.label()), Some(a));
        }
        for v in [Variant::Naive, Variant::Scalar, Variant::Simd] {
            assert_eq!(Variant::parse(v.label()), Some(v));
        }
    }
}
