//! N-stage streamed convolution cascade: the engine under
//! [`crate::plan::FilterGraph`].
//!
//! PR 5's fused engines keep the horizontal intermediate of *one*
//! separable stage in a `width`-deep rolling row-ring. This module
//! generalises the pattern to a chain of stages: stage `i+1` consumes
//! rows as stage `i` retires them, so a k-stage chain reads the source
//! plane once and writes the destination plane once — 2 plane crossings
//! instead of the 2k a materialised chain pays (and the 4k an unfused
//! one would).
//!
//! Each [`StageStream`] is a push-based streamer holding three small
//! per-stage buffers:
//!
//! * `filt` — the PR 5 ring: `width` horizontally-filtered interior
//!   rows (halo rows enter as raw pass-through, exactly like the fused
//!   band engines fill their ring),
//! * `raw`  — the last `halo + 1` input rows, so border rows and border
//!   columns can pass through verbatim (a materialised stage reads them
//!   from its input plane; a streamed stage no longer has one),
//! * `out`  — one assembled output row handed to the next stage.
//!
//! Pushing input row `r` fills ring slot `r % width`; output row `i`
//! retires as soon as row `i + halo` has been pushed (border rows as
//! soon as row `i` itself has). The accumulation order of every fill
//! and emit expression matches the generic-width fused band engines
//! term for term, so a streamed chain is bitwise-comparable to running
//! the same stages back to back through their own plans.
//!
//! **Banded parallelism.** A band `[r0, r1)` of *final* rows is
//! computed by propagating ranges backwards through the chain — stage
//! k's input range is its output range expanded by its effective halo —
//! and running a private cascade over the expanded source range. Bands
//! recompute at most `Σ halo_k` boundary rows each, the multi-stage
//! analogue of the single-stage engines re-reading their 2·halo
//! neighbour rows, and identical expressions make the banded result
//! bitwise equal to the sequential one.
//!
//! **Degenerate stages.** A stage whose kernel doesn't fit the plane
//! (`2·halo >= rows` or `>= cols`) is the identity, matching
//! `load_border_ring`'s whole-plane pass-through for single plans; its
//! effective halo is 0.

use super::band::dotw;
use super::Variant;

/// One stage of a streamed chain: separable odd-width taps plus the
/// scalar/simd expression shape to evaluate them with.
pub struct ChainStage<'k> {
    taps: &'k [f32],
    simd: bool,
}

impl<'k> ChainStage<'k> {
    /// `taps.len()` must be odd (the plan layer validates; the engine
    /// debug-asserts). [`Variant::Naive`] maps to the scalar shape —
    /// the graph builder only admits two-pass-able stages.
    pub fn new(taps: &'k [f32], variant: Variant) -> Self {
        debug_assert!(taps.len() % 2 == 1, "kernel width must be odd");
        Self { taps, simd: variant == Variant::Simd }
    }

    pub fn width(&self) -> usize {
        self.taps.len()
    }

    pub fn halo(&self) -> usize {
        self.taps.len() / 2
    }

    /// True when the kernel doesn't fit the plane: the stage is the
    /// identity (single-stage plans pass the plane through via
    /// `load_border_ring`; the streamer does the same row by row).
    pub fn is_identity(&self, rows: usize, cols: usize) -> bool {
        let h = self.halo();
        2 * h >= rows || 2 * h >= cols
    }

    /// Halo the stage adds to the chain's boundary recomputation: 0 for
    /// identity stages, `width / 2` otherwise.
    pub fn effective_halo(&self, rows: usize, cols: usize) -> usize {
        if self.is_identity(rows, cols) {
            0
        } else {
            self.halo()
        }
    }
}

/// Scratch floats one stage's streamer needs at this plane shape.
fn stage_scratch_len(stage: &ChainStage<'_>, rows: usize, cols: usize) -> usize {
    if stage.is_identity(rows, cols) {
        // raw ring (depth 1) + assembled output row
        2 * cols
    } else {
        let (width, h) = (stage.width(), stage.halo());
        width * (cols - 2 * h) + (h + 1) * cols + cols
    }
}

/// Scratch floats a whole chain needs per concurrent band job — the
/// slot length of the graph-scoped ring lease
/// ([`crate::plan::ScratchArena::take_rings`]).
pub fn chain_scratch_len(stages: &[ChainStage<'_>], rows: usize, cols: usize) -> usize {
    stages.iter().map(|s| stage_scratch_len(s, rows, cols)).sum()
}

/// Accumulated effective halo of the chain: how far a final output row
/// depends on source rows, and the per-band recompute overhead bound.
pub fn chain_halo(stages: &[ChainStage<'_>], rows: usize, cols: usize) -> usize {
    stages.iter().map(|s| s.effective_halo(rows, cols)).sum()
}

/// Push-based streamer for one stage (see module docs). Buffers are
/// carved out of one caller-provided scratch slab, so a chain of
/// streamers is one ring-lease slot, not per-stage allocations.
struct StageStream<'a> {
    taps: &'a [f32],
    simd: bool,
    identity: bool,
    rows: usize,
    cols: usize,
    h: usize,
    /// interior width `cols - 2h` (0 for identity stages)
    w: usize,
    /// rows of `raw` retained (`h + 1`, or 1 for identity stages)
    raw_depth: usize,
    /// next input row index expected by `push`
    next_in: usize,
    /// next output row index `next_ready` will emit
    next_out: usize,
    /// one past the last output row this streamer emits
    out_end: usize,
    filt: &'a mut [f32],
    raw: &'a mut [f32],
    out: &'a mut [f32],
}

impl<'a> StageStream<'a> {
    fn new(
        stage: &ChainStage<'a>,
        rows: usize,
        cols: usize,
        in_start: usize,
        out_range: (usize, usize),
        scratch: &'a mut [f32],
    ) -> Self {
        let identity = stage.is_identity(rows, cols);
        let h = stage.halo();
        let (w, raw_depth) = if identity { (0, 1) } else { (cols - 2 * h, h + 1) };
        let width = stage.taps.len();
        let (filt, rest) = scratch.split_at_mut(if identity { 0 } else { width * w });
        let (raw, rest) = rest.split_at_mut(raw_depth * cols);
        let (out, _) = rest.split_at_mut(cols);
        Self {
            taps: stage.taps,
            simd: stage.simd,
            identity,
            rows,
            cols,
            h,
            w,
            raw_depth,
            next_in: in_start,
            next_out: out_range.0,
            out_end: out_range.1,
            filt,
            raw,
            out,
        }
    }

    /// Accept the next input row (index `self.next_in`): retain it in
    /// the raw ring and, for non-identity stages, fill ring slot
    /// `r % width` — horizontally filtered for interior rows, raw
    /// interior pass-through for halo rows — exactly like the fused
    /// band engines fill theirs.
    fn push(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.cols);
        let r = self.next_in;
        let rslot = (r % self.raw_depth) * self.cols;
        self.raw[rslot..rslot + self.cols].copy_from_slice(row);
        if !self.identity {
            let width = self.taps.len();
            let fslot = (r % width) * self.w;
            let slot = &mut self.filt[fslot..fslot + self.w];
            if r >= self.h && r < self.rows - self.h {
                if self.simd {
                    for (o, win) in slot.iter_mut().zip(row.windows(width)) {
                        *o = dotw(win, self.taps);
                    }
                } else {
                    for j in self.h..self.cols - self.h {
                        let base = j - self.h;
                        let mut s = 0.0f32;
                        for (v, &kv) in self.taps.iter().enumerate() {
                            s += row[base + v] * kv;
                        }
                        slot[j - self.h] = s;
                    }
                }
            } else {
                slot.copy_from_slice(&row[self.h..self.h + self.w]);
            }
        }
        self.next_in = r + 1;
    }

    /// The next output row, if enough input has been pushed: border
    /// rows (and identity stages) pass through verbatim as soon as row
    /// `i` itself arrived; interior row `i` retires once row `i + h`
    /// arrived, combining the ring rows `i-h ..= i+h` in tap order with
    /// the same expressions as the fused band engines' emit step.
    fn next_ready(&mut self) -> Option<(usize, &[f32])> {
        if self.next_out >= self.out_end {
            return None;
        }
        let i = self.next_out;
        let last = self.next_in.checked_sub(1)?;
        let interior = !self.identity && i >= self.h && i < self.rows - self.h;
        let need = if interior { i + self.h } else { i };
        if last < need {
            return None;
        }
        let cols = self.cols;
        let rslot = (i % self.raw_depth) * cols;
        let raw_row = &self.raw[rslot..rslot + cols];
        let out = &mut *self.out;
        if !interior {
            out.copy_from_slice(raw_row);
        } else {
            // border columns pass through from the stage's input row
            out[..self.h].copy_from_slice(&raw_row[..self.h]);
            out[cols - self.h..].copy_from_slice(&raw_row[cols - self.h..]);
            let width = self.taps.len();
            let w = self.w;
            let inner = &mut out[self.h..self.h + w];
            if self.simd {
                let rr0 = ((i - self.h) % width) * w;
                for (o, &s0) in inner.iter_mut().zip(&self.filt[rr0..rr0 + w]) {
                    *o = s0 * self.taps[0];
                }
                for (u, &ku) in self.taps.iter().enumerate().skip(1) {
                    let rru = ((i + u - self.h) % width) * w;
                    for (o, &sv) in inner.iter_mut().zip(&self.filt[rru..rru + w]) {
                        *o += sv * ku;
                    }
                }
            } else {
                for (jj, o) in inner.iter_mut().enumerate() {
                    let mut s = 0.0f32;
                    for (u, &ku) in self.taps.iter().enumerate() {
                        s += self.filt[((i + u - self.h) % width) * w + jj] * ku;
                    }
                    *o = s;
                }
            }
        }
        self.next_out = i + 1;
        Some((i, &*self.out))
    }
}

/// Recursive cascade step: push `row` into the first streamer, then
/// forward every row it retires into the rest of the chain (the last
/// streamer's rows go to `sink`). `split_first_mut` keeps the borrows
/// disjoint, so a retired row can be fed onward while its producer
/// stays mutable for the next iteration.
fn feed(streams: &mut [StageStream<'_>], row: &[f32], sink: &mut dyn FnMut(usize, &[f32])) {
    let Some((first, rest)) = streams.split_first_mut() else {
        return;
    };
    first.push(row);
    if rest.is_empty() {
        while let Some((i, out)) = first.next_ready() {
            sink(i, out);
        }
    } else {
        while let Some((i, out)) = first.next_ready() {
            debug_assert_eq!(i, rest[0].next_in, "stage handoff must be gapless");
            feed(rest, out, sink);
        }
    }
}

/// Run the whole chain for final rows `[r0, r1)` of one plane,
/// writing every row (borders included — they pass through the
/// streamers) into `dst`, which holds exactly `r1 - r0` rows.
///
/// `scratch` must hold at least [`chain_scratch_len`] floats and is the
/// band job's private slab (one ring-lease slot on the parallel path).
/// Sequential execution is the single band `[0, rows)`.
#[allow(clippy::too_many_arguments)]
pub fn chain_band(
    src: &[f32],
    dst: &mut [f32],
    rows: usize,
    cols: usize,
    stages: &[ChainStage<'_>],
    scratch: &mut [f32],
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(src.len(), rows * cols);
    debug_assert!(r1 <= rows && r0 <= r1);
    debug_assert_eq!(dst.len(), (r1 - r0) * cols);
    if r0 >= r1 || stages.is_empty() {
        return;
    }
    // backward range propagation: stage k's input rows are its output
    // rows expanded by its effective halo, and stage k-1 must produce
    // exactly that range
    let m = stages.len();
    let mut out_ranges = vec![(0usize, 0usize); m];
    let (mut lo, mut hi) = (r0, r1);
    for k in (0..m).rev() {
        out_ranges[k] = (lo, hi);
        let he = stages[k].effective_halo(rows, cols);
        lo = lo.saturating_sub(he);
        hi = (hi + he).min(rows);
    }
    // (lo, hi) is now the source row range stage 0 consumes
    let mut streams = Vec::with_capacity(m);
    let mut rest: &mut [f32] = scratch;
    for (k, stage) in stages.iter().enumerate() {
        let len = stage_scratch_len(stage, rows, cols);
        let (slab, tail) = std::mem::take(&mut rest).split_at_mut(len);
        rest = tail;
        let in_start = if k == 0 { lo } else { out_ranges[k - 1].0 };
        streams.push(StageStream::new(stage, rows, cols, in_start, out_ranges[k], slab));
    }
    let mut sink = |i: usize, row: &[f32]| {
        let off = (i - r0) * cols;
        dst[off..off + cols].copy_from_slice(row);
    };
    for r in lo..hi {
        feed(&mut streams, &src[r * cols..(r + 1) * cols], &mut sink);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{convolve_image, Algorithm};
    use crate::image::{gaussian_kernel, synth_image, Pattern, PlanarImage};
    use crate::models::pool::RowBands;
    use crate::models::ExecutionModel;
    use crate::models::OpenMpModel;

    /// Materialised reference: each stage through the existing two-pass
    /// plane driver, intermediates as full planes.
    fn staged_reference(img: &PlanarImage, kernels: &[Vec<f32>], variant: Variant) -> PlanarImage {
        let mut cur = img.clone();
        for k in kernels {
            cur = convolve_image(cur, k, Algorithm::TwoPass, variant).unwrap();
        }
        cur
    }

    fn run_chain_seq(img: &PlanarImage, kernels: &[Vec<f32>], variant: Variant) -> PlanarImage {
        let (rows, cols) = (img.rows, img.cols);
        let stages: Vec<ChainStage<'_>> =
            kernels.iter().map(|k| ChainStage::new(k, variant)).collect();
        let mut scratch = vec![0.0f32; chain_scratch_len(&stages, rows, cols)];
        let mut out = img.clone();
        for p in 0..img.planes {
            let src = img.plane(p).to_vec();
            chain_band(&src, out.plane_mut(p), rows, cols, &stages, &mut scratch, 0, rows);
        }
        out
    }

    /// Generic-width chains (no W=5 fast path on either side) are
    /// bitwise equal to stage-by-stage materialised execution, for 2-,
    /// 3- and 4-stage chains, both variants.
    #[test]
    fn streamed_chain_matches_materialized_bitwise() {
        let chains: [&[usize]; 3] = [&[3, 7], &[7, 3, 9], &[3, 9, 3, 7]];
        for (case, widths) in chains.iter().enumerate() {
            let kernels: Vec<Vec<f32>> =
                widths.iter().map(|&w| gaussian_kernel(w, 0.4 + w as f64 / 4.0)).collect();
            let img = synth_image(2, 46, 41, Pattern::Noise, 900 + case as u64);
            for variant in [Variant::Scalar, Variant::Simd] {
                let want = staged_reference(&img, &kernels, variant);
                let got = run_chain_seq(&img, &kernels, variant);
                assert_eq!(got, want, "case {case} {widths:?} {variant:?}");
            }
        }
    }

    /// Chains containing width-5 stages stay within 1e-6 of the
    /// materialised reference (whose W=5 stages take the unrolled fast
    /// path; the streamer always evaluates the generic expressions).
    #[test]
    fn streamed_chain_matches_width5_fast_path() {
        let kernels =
            vec![gaussian_kernel(5, 1.0), gaussian_kernel(5, 2.0), gaussian_kernel(3, 0.8)];
        let img = synth_image(3, 40, 37, Pattern::Noise, 42);
        for variant in [Variant::Scalar, Variant::Simd] {
            let want = staged_reference(&img, &kernels, variant);
            let got = run_chain_seq(&img, &kernels, variant);
            let d = got.max_abs_diff(&want);
            assert!(d <= 1e-6, "{variant:?}: {d}");
        }
    }

    /// A stage whose kernel doesn't fit the plane is the identity —
    /// matching the single-plan pass-through — and contributes no halo.
    #[test]
    fn degenerate_stage_is_identity_in_chain() {
        let kernels =
            vec![gaussian_kernel(3, 0.8), gaussian_kernel(31, 4.0), gaussian_kernel(3, 0.8)];
        let img = synth_image(1, 12, 14, Pattern::Noise, 7);
        let want = staged_reference(&img, &kernels, Variant::Simd);
        let got = run_chain_seq(&img, &kernels, Variant::Simd);
        assert_eq!(got, want);
        let stages: Vec<ChainStage<'_>> =
            kernels.iter().map(|k| ChainStage::new(k, Variant::Simd)).collect();
        assert_eq!(chain_halo(&stages, 12, 14), 2, "identity stage adds no halo");
    }

    /// Banded parallel execution over an execution model's dispatch is
    /// bitwise equal to the sequential single band.
    #[test]
    fn banded_chain_matches_sequential_bitwise() {
        let kernels =
            vec![gaussian_kernel(9, 1.8), gaussian_kernel(3, 0.8), gaussian_kernel(7, 1.4)];
        let img = synth_image(1, 57, 33, Pattern::Noise, 11);
        let (rows, cols) = (img.rows, img.cols);
        for variant in [Variant::Scalar, Variant::Simd] {
            let want = run_chain_seq(&img, &kernels, variant);
            let stages: Vec<ChainStage<'_>> =
                kernels.iter().map(|k| ChainStage::new(k, variant)).collect();
            let slot_len = chain_scratch_len(&stages, rows, cols);
            for threads in [2usize, 5] {
                let model = OpenMpModel::new(threads);
                let mut out = img.clone();
                let n_slabs = model.workers() + 1;
                let slabs = std::sync::Mutex::new(vec![vec![0.0f32; slot_len]; n_slabs]);
                let bands = RowBands::new(out.plane_mut(0), rows, cols);
                model.dispatch(rows, &|r0, r1| {
                    // SAFETY: dispatch covers [0, rows) disjointly
                    let band = unsafe { bands.band(r0, r1) };
                    let mut slab = slabs.lock().unwrap().pop().expect("enough slabs");
                    chain_band(img.plane(0), band, rows, cols, &stages, &mut slab, r0, r1);
                    slabs.lock().unwrap().push(slab);
                });
                assert_eq!(out, want, "{variant:?} threads {threads}");
            }
        }
    }

    /// Single-stage chains reduce to the fused plan semantics: every
    /// row written, borders passed through.
    #[test]
    fn single_stage_chain_matches_plane_driver() {
        for width in [3usize, 5, 9] {
            let k = gaussian_kernel(width, width as f64 / 4.0);
            let img = synth_image(1, 30, 26, Pattern::Noise, width as u64);
            let want = staged_reference(&img, std::slice::from_ref(&k), Variant::Simd);
            let got = run_chain_seq(&img, std::slice::from_ref(&k), Variant::Simd);
            if width == 5 {
                let d = got.max_abs_diff(&want);
                assert!(d <= 1e-6, "w5: {d}");
            } else {
                assert_eq!(got, want, "w{width}");
            }
        }
    }
}
