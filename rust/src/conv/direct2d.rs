//! Direct 2-D convolution engines for arbitrary (non-separable)
//! odd×odd kernels — the generic-kernel siblings of the single-pass
//! functions in [`super::band`] and [`super::tile`].
//!
//! The separable engines factor a `w×w` kernel into two `w`-tap passes;
//! these engines take the full `krows×kcols` tap matrix and accumulate
//! it directly, so they accept kernels with no rank-1 structure (edge
//! detectors, rotated anisotropic blurs, learned taps). The banding and
//! tiling contracts are identical to the separable engines': band
//! functions compute output rows `[r0, r1) ∩ [hr, rows−hr)` into a
//! `dst_band` of exactly `(r1−r0)·cols` elements, tile functions write
//! through a [`TileCells`] accessor clamped to the interior, and both
//! guard degenerate planes (kernel taller/wider than the plane) by
//! writing nothing.
//!
//! Accumulation orders mirror the separable single-pass engines exactly
//! — 4-nested-loop for naive, per-kernel-row subtotals for scalar,
//! `dotw` window sweeps for simd — so for a *square* kernel the scalar
//! and simd shapes here are bitwise-identical to
//! [`super::band::singlepass_band_scalar_w`] /
//! [`super::band::singlepass_band_simd_w`] with the same taps (asserted
//! below), and tiled sweeps are bitwise-comparable to banded ones.

use super::band::dotw;
use crate::models::pool::TileCells;
use crate::models::Tile;

#[inline]
fn band_range(rows: usize, h: usize, r0: usize, r1: usize) -> (usize, usize) {
    (r0.max(h), r1.min(rows.saturating_sub(h)))
}

/// Clamp a tile to the rectangular-halo interior
/// `[hr, rows−hr) × [hc, cols−hc)`; `None` when nothing survives.
#[inline]
fn interior(
    rows: usize,
    cols: usize,
    hr: usize,
    hc: usize,
    t: Tile,
) -> Option<(usize, usize, usize, usize)> {
    if 2 * hr >= rows || 2 * hc >= cols {
        return None; // no interior (also guards the `- h` arithmetic)
    }
    let (a, b) = (t.r0.max(hr), t.r1.min(rows - hr));
    let (ja, jb) = (t.c0.max(hc), t.c1.min(cols - hc));
    if a >= b || ja >= jb {
        return None;
    }
    Some((a, b, ja, jb))
}

/// Naive direct 2-D band: 2 image loops × 2 kernel loops, indexed
/// loads (the paper's Opt-0 shape, generalised to rectangular kernels).
#[allow(clippy::too_many_arguments)]
pub fn direct2d_band_naive(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k2d: &[f32],
    krows: usize,
    kcols: usize,
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    debug_assert_eq!(k2d.len(), krows * kcols);
    let (hr, hc) = (krows / 2, kcols / 2);
    if 2 * hr >= rows || 2 * hc >= cols {
        return;
    }
    let (a, b) = band_range(rows, hr, r0, r1);
    for i in a..b {
        let out = &mut dst_band[(i - r0) * cols..(i - r0 + 1) * cols];
        for j in hc..cols - hc {
            let mut s = 0.0f32;
            for u in 0..krows {
                for v in 0..kcols {
                    s += src[(i + u - hr) * cols + (j + v - hc)] * k2d[u * kcols + v];
                }
            }
            out[j] = s;
        }
    }
}

/// Direct 2-D band, scalar shape: per-pixel indexed arithmetic with
/// per-kernel-row subtotals (the re-rolled Eq. 3 shape).
#[allow(clippy::too_many_arguments)]
pub fn direct2d_band_scalar(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k2d: &[f32],
    krows: usize,
    kcols: usize,
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    debug_assert_eq!(k2d.len(), krows * kcols);
    let (hr, hc) = (krows / 2, kcols / 2);
    if 2 * hr >= rows || 2 * hc >= cols {
        return;
    }
    let (a, b) = band_range(rows, hr, r0, r1);
    for i in a..b {
        let out = &mut dst_band[(i - r0) * cols..(i - r0 + 1) * cols];
        for j in hc..cols - hc {
            let mut s = 0.0f32;
            for u in 0..krows {
                let base = (i + u - hr) * cols + j - hc;
                let ku = &k2d[u * kcols..(u + 1) * kcols];
                let mut row_s = 0.0f32;
                for (v, &kv) in ku.iter().enumerate() {
                    row_s += src[base + v] * kv;
                }
                s += row_s;
            }
            out[j] = s;
        }
    }
}

/// Direct 2-D band, SIMD shape: per kernel row, sweep a `kcols`-window
/// dot product across the output row and accumulate.
#[allow(clippy::too_many_arguments)]
pub fn direct2d_band_simd(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k2d: &[f32],
    krows: usize,
    kcols: usize,
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    debug_assert_eq!(k2d.len(), krows * kcols);
    let (hr, hc) = (krows / 2, kcols / 2);
    if 2 * hr >= rows || 2 * hc >= cols {
        return;
    }
    let (a, b) = band_range(rows, hr, r0, r1);
    let w = cols - 2 * hc;
    for i in a..b {
        let start = (i - r0) * cols + hc;
        let out = &mut dst_band[start..start + w];
        let row0 = &src[(i - hr) * cols..(i - hr) * cols + cols];
        for (o, win) in out.iter_mut().zip(row0.windows(kcols)) {
            *o = dotw(win, &k2d[0..kcols]);
        }
        for u in 1..krows {
            let row = &src[(i + u - hr) * cols..(i + u - hr) * cols + cols];
            let ku = &k2d[u * kcols..(u + 1) * kcols];
            for (o, win) in out.iter_mut().zip(row.windows(kcols)) {
                *o += dotw(win, ku);
            }
        }
    }
}

/// Naive direct 2-D over one tile.
#[allow(clippy::too_many_arguments)]
pub fn direct2d_tile_naive(
    src: &[f32],
    out: &TileCells,
    rows: usize,
    cols: usize,
    k2d: &[f32],
    krows: usize,
    kcols: usize,
    t: Tile,
) {
    debug_assert_eq!(k2d.len(), krows * kcols);
    let (hr, hc) = (krows / 2, kcols / 2);
    let Some((a, b, ja, jb)) = interior(rows, cols, hr, hc, t) else { return };
    for i in a..b {
        // SAFETY: [ja, jb) ⊆ this tile's columns, i ∈ this tile's rows;
        // dispatch2d covers are disjoint tiles (property-tested).
        let out_row = unsafe { out.row_seg(i, ja, jb) };
        for (o, j) in out_row.iter_mut().zip(ja..jb) {
            let mut s = 0.0f32;
            for u in 0..krows {
                for v in 0..kcols {
                    s += src[(i + u - hr) * cols + (j + v - hc)] * k2d[u * kcols + v];
                }
            }
            *o = s;
        }
    }
}

/// Direct 2-D over one tile, scalar shape (per-kernel-row subtotals).
#[allow(clippy::too_many_arguments)]
pub fn direct2d_tile_scalar(
    src: &[f32],
    out: &TileCells,
    rows: usize,
    cols: usize,
    k2d: &[f32],
    krows: usize,
    kcols: usize,
    t: Tile,
) {
    debug_assert_eq!(k2d.len(), krows * kcols);
    let (hr, hc) = (krows / 2, kcols / 2);
    let Some((a, b, ja, jb)) = interior(rows, cols, hr, hc, t) else { return };
    for i in a..b {
        // SAFETY: segment inside this tile; tiles are disjoint.
        let out_row = unsafe { out.row_seg(i, ja, jb) };
        for (o, j) in out_row.iter_mut().zip(ja..jb) {
            let mut s = 0.0f32;
            for u in 0..krows {
                let base = (i + u - hr) * cols + j - hc;
                let ku = &k2d[u * kcols..(u + 1) * kcols];
                let mut row_s = 0.0f32;
                for (v, &kv) in ku.iter().enumerate() {
                    row_s += src[base + v] * kv;
                }
                s += row_s;
            }
            *o = s;
        }
    }
}

/// Direct 2-D over one tile, SIMD shape: per kernel row, a
/// `kcols`-window dot-product sweep across the tile's columns.
#[allow(clippy::too_many_arguments)]
pub fn direct2d_tile_simd(
    src: &[f32],
    out: &TileCells,
    rows: usize,
    cols: usize,
    k2d: &[f32],
    krows: usize,
    kcols: usize,
    t: Tile,
) {
    debug_assert_eq!(k2d.len(), krows * kcols);
    let (hr, hc) = (krows / 2, kcols / 2);
    let Some((a, b, ja, jb)) = interior(rows, cols, hr, hc, t) else { return };
    for i in a..b {
        // SAFETY: segment inside this tile; tiles are disjoint.
        let out_row = unsafe { out.row_seg(i, ja, jb) };
        let row0 = &src[(i - hr) * cols + ja - hc..(i - hr) * cols + jb + hc];
        for (o, win) in out_row.iter_mut().zip(row0.windows(kcols)) {
            *o = dotw(win, &k2d[0..kcols]);
        }
        for u in 1..krows {
            let row = &src[(i + u - hr) * cols + ja - hc..(i + u - hr) * cols + jb + hc];
            let ku = &k2d[u * kcols..(u + 1) * kcols];
            for (o, win) in out_row.iter_mut().zip(row.windows(kcols)) {
                *o += dotw(win, ku);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::band;
    use crate::image::{gaussian_kernel, gaussian_kernel2d};
    use crate::models::{TileGrid, TileSpec};
    use crate::util::prng::Prng;

    const R: usize = 26;
    const C: usize = 22;

    fn noise(seed: u64) -> Vec<f32> {
        let mut p = Prng::new(seed);
        (0..R * C).map(|_| p.normal()).collect()
    }

    fn random_kernel(seed: u64, krows: usize, kcols: usize) -> Vec<f32> {
        let mut p = Prng::new(seed);
        (0..krows * kcols).map(|_| p.normal()).collect()
    }

    fn sweep_tiles(spec: TileSpec, dst: &mut [f32], f: impl Fn(&TileCells, Tile)) {
        let grid = TileGrid::new(R, C, spec);
        let cells = TileCells::new(dst, R, C);
        for i in 0..grid.len() {
            f(&cells, grid.tile(i));
        }
    }

    #[test]
    fn square_kernel_matches_separable_singlepass_bitwise() {
        // same accumulation orders as the separable single-pass engines
        // means a square direct 2-D kernel is bitwise-identical to them
        let src = noise(1);
        for width in [5usize, 7] {
            let k2 = gaussian_kernel2d(&gaussian_kernel(width, 1.2));
            let mut want = src.clone();
            band::singlepass_band_scalar_w(&src, &mut want, R, C, &k2, width, 0, R);
            let mut got = src.clone();
            direct2d_band_scalar(&src, &mut got, R, C, &k2, width, width, 0, R);
            assert_eq!(want, got, "scalar w{width}");

            let mut want = src.clone();
            band::singlepass_band_simd_w(&src, &mut want, R, C, &k2, width, 0, R);
            let mut got = src.clone();
            direct2d_band_simd(&src, &mut got, R, C, &k2, width, width, 0, R);
            assert_eq!(want, got, "simd w{width}");

            let mut want = src.clone();
            band::singlepass_naive_band(&src, &mut want, R, C, &k2, width, 0, R);
            let mut got = src.clone();
            direct2d_band_naive(&src, &mut got, R, C, &k2, width, width, 0, R);
            assert_eq!(want, got, "naive w{width}");
        }
    }

    #[test]
    fn rectangular_shapes_agree_with_naive_reference() {
        let src = noise(2);
        for (krows, kcols) in [(3usize, 7usize), (7, 3), (5, 9), (1, 5), (5, 1)] {
            let k = random_kernel(10 + krows as u64 * kcols as u64, krows, kcols);
            let mut want = vec![0f32; R * C];
            direct2d_band_naive(&src, &mut want, R, C, &k, krows, kcols, 0, R);
            for simd in [false, true] {
                let mut got = vec![0f32; R * C];
                if simd {
                    direct2d_band_simd(&src, &mut got, R, C, &k, krows, kcols, 0, R);
                } else {
                    direct2d_band_scalar(&src, &mut got, R, C, &k, krows, kcols, 0, R);
                }
                for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                    assert!(
                        (w - g).abs() <= 1e-5,
                        "{krows}x{kcols} simd={simd} cell {i}: {w} vs {g}"
                    );
                }
            }
        }
    }

    #[test]
    fn banded_partition_composes_to_full_sweep() {
        // arbitrary band splits cover exactly the full-plane result
        let src = noise(3);
        let k = random_kernel(77, 5, 7);
        let mut want = vec![0f32; R * C];
        direct2d_band_simd(&src, &mut want, R, C, &k, 5, 7, 0, R);
        let mut got = vec![0f32; R * C];
        for (r0, r1) in [(0usize, 4usize), (4, 9), (9, 20), (20, R)] {
            let mut band = vec![0f32; (r1 - r0) * C];
            // seed the band with the full-plane rows so untouched border
            // cells compare equal
            band.copy_from_slice(&want[r0 * C..r1 * C]);
            direct2d_band_simd(&src, &mut band, R, C, &k, 5, 7, r0, r1);
            got[r0 * C..r1 * C].copy_from_slice(&band);
        }
        assert_eq!(want, got);
    }

    #[test]
    fn tiled_matches_banded() {
        let src = noise(4);
        for (krows, kcols) in [(5usize, 5usize), (3, 7), (7, 3)] {
            let k = random_kernel(5 + krows as u64, krows, kcols);
            for spec in [TileSpec::new(5, 7), TileSpec::new(100, 3), TileSpec::new(4, 100)] {
                let mut want = src.clone();
                direct2d_band_simd(&src, &mut want, R, C, &k, krows, kcols, 0, R);
                let mut got = src.clone();
                sweep_tiles(spec, &mut got, |cells, t| {
                    direct2d_tile_simd(&src, cells, R, C, &k, krows, kcols, t)
                });
                assert_eq!(want, got, "simd {krows}x{kcols} {}", spec.label());

                let mut want = src.clone();
                direct2d_band_scalar(&src, &mut want, R, C, &k, krows, kcols, 0, R);
                let mut got = src.clone();
                sweep_tiles(spec, &mut got, |cells, t| {
                    direct2d_tile_scalar(&src, cells, R, C, &k, krows, kcols, t)
                });
                assert_eq!(want, got, "scalar {krows}x{kcols} {}", spec.label());

                let mut want = src.clone();
                direct2d_band_naive(&src, &mut want, R, C, &k, krows, kcols, 0, R);
                let mut got = src.clone();
                sweep_tiles(spec, &mut got, |cells, t| {
                    direct2d_tile_naive(&src, cells, R, C, &k, krows, kcols, t)
                });
                assert_eq!(want, got, "naive {krows}x{kcols} {}", spec.label());
            }
        }
    }

    #[test]
    fn degenerate_planes_and_border_tiles_are_noops() {
        let src = noise(5);
        let k = random_kernel(6, 9, 9);
        // kernel taller/wider than the plane: nothing written
        let mut dst = vec![5f32; 8 * 7];
        direct2d_band_simd(&src[..56], &mut dst, 8, 7, &k, 9, 9, 0, 8);
        direct2d_band_scalar(&src[..56], &mut dst, 8, 7, &k, 9, 9, 0, 8);
        assert!(dst.iter().all(|&v| v == 5.0));
        // border-only tiles: nothing written
        let k5 = random_kernel(7, 5, 5);
        let mut dst = vec![9f32; R * C];
        {
            let cells = TileCells::new(&mut dst, R, C);
            direct2d_tile_simd(&src, &cells, R, C, &k5, 5, 5, Tile { r0: 0, r1: 2, c0: 0, c1: C });
            direct2d_tile_scalar(&src, &cells, R, C, &k5, 5, 5, Tile { r0: 0, r1: R, c0: 0, c1: 2 });
        }
        assert!(dst.iter().all(|&v| v == 9.0));
    }
}
