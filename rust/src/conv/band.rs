//! Row-band convolution primitives — the inner loops of every rung of the
//! paper's optimisation ladder.
//!
//! Every function computes output rows `[r0, r1) ∩ [h, rows−h)` of one
//! plane. The destination is passed as `dst_band`, a mutable slice
//! covering exactly rows `[r0, r1)` (`(r1−r0)·cols` elements): parallel
//! callers hand each worker a *disjoint* sub-slice of the output plane,
//! which keeps the data-parallel sweep sound without aliased `&mut`.
//! Sequential callers pass the whole plane with `r0=0, r1=rows`.
//!
//! Bands self-clamp to the interior, so callers may pass raw partitions
//! of `[0, rows)`; the execution models' invariant is only "cover
//! `[0, rows)` disjointly", which the property tests check.
//!
//! `scalar` variants are per-pixel indexed arithmetic (the paper's
//! `-no-vec` shape); `simd` variants are whole-row slice/window sweeps
//! (the `#pragma simd` shape — see `conv/mod.rs` for the mapping
//! rationale). Tap summation order matches the Pallas kernels (u outer,
//! v inner) so PJRT and native outputs agree to float-associativity
//! tolerance.

use super::HALO;

#[inline]
fn band_range(rows: usize, h: usize, r0: usize, r1: usize) -> (usize, usize) {
    (r0.max(h), r1.min(rows.saturating_sub(h)))
}

#[inline(always)]
fn dot5(w: &[f32], k: &[f32]) -> f32 {
    w[0] * k[0] + w[1] * k[1] + w[2] * k[2] + w[3] * k[3] + w[4] * k[4]
}

/// Window dot product of arbitrary width (the generic-width analogue of
/// [`dot5`]); the paired `iter().zip()` shape keeps it vectorisable.
/// Shared with the tile primitives in [`super::tile`] so tiled and
/// banded sweeps accumulate in the same order (bitwise-comparable).
#[inline(always)]
pub(crate) fn dotw(w: &[f32], k: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (a, b) in w.iter().zip(k) {
        s += a * b;
    }
    s
}

// ---------------------------------------------------------------------------
// Opt-0: naive single-pass — generic width, 4 nested loops, per-pixel
// ---------------------------------------------------------------------------

/// The paper's naive code: 2 image loops × 2 kernel loops, indexed loads,
/// accumulation in a scalar. Generic over odd kernel width.
pub fn singlepass_naive_band(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k2d: &[f32],
    width: usize,
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    let h = width / 2;
    if 2 * h >= cols || 2 * h >= rows {
        return; // no interior (also guards the `- h` arithmetic)
    }
    let (a, b) = band_range(rows, h, r0, r1);
    for i in a..b {
        let out = &mut dst_band[(i - r0) * cols..(i - r0 + 1) * cols];
        for j in h..cols - h {
            let mut s = 0.0f32;
            for u in 0..width {
                for v in 0..width {
                    s += src[(i + u - h) * cols + (j + v - h)] * k2d[u * width + v];
                }
            }
            out[j] = s;
        }
    }
}

// ---------------------------------------------------------------------------
// Opt-1/2: unrolled single-pass (W=5), scalar and simd shapes
// ---------------------------------------------------------------------------

/// Opt-1: hand-unrolled 25-term expression per pixel, indexed loads (the
/// paper's Eq. 3), one pixel at a time.
pub fn singlepass_band_scalar(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k2d: &[f32; 25],
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    let h = HALO;
    if 2 * h >= cols || 2 * h >= rows {
        return; // no interior (also guards the `- h` arithmetic)
    }
    let (a, b) = band_range(rows, h, r0, r1);
    for i in a..b {
        let out = &mut dst_band[(i - r0) * cols..(i - r0 + 1) * cols];
        for j in h..cols - h {
            let mut s = 0.0f32;
            // u-outer / v-inner, all 25 terms written out via the 5-term
            // row sub-expressions (paper Eq. 3 shape).
            for u in 0..5usize {
                let base = (i + u - h) * cols + j - h;
                s += src[base] * k2d[u * 5]
                    + src[base + 1] * k2d[u * 5 + 1]
                    + src[base + 2] * k2d[u * 5 + 2]
                    + src[base + 3] * k2d[u * 5 + 3]
                    + src[base + 4] * k2d[u * 5 + 4];
            }
            out[j] = s;
        }
    }
}

/// Opt-2: the SIMD shape — for each of the 5 source rows, sweep a
/// 5-window dot product across the whole output row (vectorisable), and
/// accumulate rows into the destination slice.
pub fn singlepass_band_simd(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k2d: &[f32; 25],
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    let h = HALO;
    if 2 * h >= cols || 2 * h >= rows {
        return; // no interior (also guards the `- h` arithmetic)
    }
    let (a, b) = band_range(rows, h, r0, r1);
    let w = cols - 2 * h;
    for i in a..b {
        let start = (i - r0) * cols + h;
        let out = &mut dst_band[start..start + w];
        // u = 0 initialises, u = 1..5 accumulate (tap order = Pallas).
        let row0 = &src[(i - h) * cols..(i - h) * cols + cols];
        for (o, win) in out.iter_mut().zip(row0.windows(5)) {
            *o = dot5(win, &k2d[0..5]);
        }
        for u in 1..5usize {
            let row = &src[(i + u - h) * cols..(i + u - h) * cols + cols];
            let ku = &k2d[u * 5..u * 5 + 5];
            for (o, win) in out.iter_mut().zip(row.windows(5)) {
                *o += dot5(win, ku);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Opt-3/4: two-pass (W=5), scalar and simd shapes
// ---------------------------------------------------------------------------

/// Horizontal pass, scalar shape: `dst[i][j] = Σ_v src[i][j−2+v]·k[v]`
/// for interior i, j (paper Listing 1, first loop nest).
pub fn horiz_band_scalar(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k: &[f32; 5],
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    let h = HALO;
    if 2 * h >= cols || 2 * h >= rows {
        return; // no interior (also guards the `- h` arithmetic)
    }
    let (a, b) = band_range(rows, h, r0, r1);
    for i in a..b {
        let out = &mut dst_band[(i - r0) * cols..(i - r0 + 1) * cols];
        for j in h..cols - h {
            let base = i * cols + j - h;
            out[j] = src[base] * k[0]
                + src[base + 1] * k[1]
                + src[base + 2] * k[2]
                + src[base + 3] * k[3]
                + src[base + 4] * k[4];
        }
    }
}

/// Horizontal pass, SIMD shape: one 5-window sweep per row.
pub fn horiz_band_simd(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k: &[f32; 5],
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    let h = HALO;
    if 2 * h >= cols || 2 * h >= rows {
        return; // no interior (also guards the `- h` arithmetic)
    }
    let (a, b) = band_range(rows, h, r0, r1);
    let w = cols - 2 * h;
    for i in a..b {
        let row = &src[i * cols..(i + 1) * cols];
        let start = (i - r0) * cols + h;
        let out = &mut dst_band[start..start + w];
        for (o, win) in out.iter_mut().zip(row.windows(5)) {
            *o = dot5(win, k);
        }
    }
}

/// Vertical pass, scalar shape: `dst[i][j] = Σ_u src[i−2+u][j]·k[u]`
/// for interior i, j (paper Listing 1, second loop nest).
pub fn vert_band_scalar(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k: &[f32; 5],
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    let h = HALO;
    if 2 * h >= cols || 2 * h >= rows {
        return; // no interior (also guards the `- h` arithmetic)
    }
    let (a, b) = band_range(rows, h, r0, r1);
    for i in a..b {
        let out = &mut dst_band[(i - r0) * cols..(i - r0 + 1) * cols];
        for j in h..cols - h {
            out[j] = src[(i - 2) * cols + j] * k[0]
                + src[(i - 1) * cols + j] * k[1]
                + src[i * cols + j] * k[2]
                + src[(i + 1) * cols + j] * k[3]
                + src[(i + 2) * cols + j] * k[4];
        }
    }
}

/// Vertical pass, SIMD shape: five aligned row-slice FMAs per output row —
/// columns are contiguous so this vectorises trivially. The inner loop
/// is a zipped sweep over the five row slices (like the `windows`-based
/// horizontal engines) rather than an indexed `jj` loop, so every
/// bounds check is elided; `cargo bench --bench vectorisation` is where
/// the before/after shows up.
pub fn vert_band_simd(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k: &[f32; 5],
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    let h = HALO;
    if 2 * h >= cols || 2 * h >= rows {
        return; // no interior (also guards the `- h` arithmetic)
    }
    let (a, b) = band_range(rows, h, r0, r1);
    let w = cols - 2 * h;
    for i in a..b {
        let (s0, s1, s2, s3, s4) = (
            &src[(i - 2) * cols + h..(i - 2) * cols + h + w],
            &src[(i - 1) * cols + h..(i - 1) * cols + h + w],
            &src[i * cols + h..i * cols + h + w],
            &src[(i + 1) * cols + h..(i + 1) * cols + h + w],
            &src[(i + 2) * cols + h..(i + 2) * cols + h + w],
        );
        let start = (i - r0) * cols + h;
        let out = &mut dst_band[start..start + w];
        for (((((o, &a0), &a1), &a2), &a3), &a4) in
            out.iter_mut().zip(s0).zip(s1).zip(s2).zip(s3).zip(s4)
        {
            *o = a0 * k[0] + a1 * k[1] + a2 * k[2] + a3 * k[3] + a4 * k[4];
        }
    }
}

// ---------------------------------------------------------------------------
// Generic odd-width engines: the same scalar/simd shapes as the W=5
// unrolled rungs above, parameterised over any odd kernel width. The
// plan layer (`crate::plan`) selects the W=5 unrolled functions as a
// fast path and falls back to these for every other width, replacing
// the old zero-filled `[0.0; 5]` dummy-kernel behaviour.
// ---------------------------------------------------------------------------

/// Single-pass, scalar shape, generic width: per-pixel indexed
/// arithmetic with per-source-row subtotals (the unrolled Eq. 3 shape,
/// re-rolled over `width`).
pub fn singlepass_band_scalar_w(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k2d: &[f32],
    width: usize,
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    debug_assert_eq!(k2d.len(), width * width);
    let h = width / 2;
    if 2 * h >= cols || 2 * h >= rows {
        return;
    }
    let (a, b) = band_range(rows, h, r0, r1);
    for i in a..b {
        let out = &mut dst_band[(i - r0) * cols..(i - r0 + 1) * cols];
        for j in h..cols - h {
            let mut s = 0.0f32;
            for u in 0..width {
                let base = (i + u - h) * cols + j - h;
                let ku = &k2d[u * width..(u + 1) * width];
                let mut row_s = 0.0f32;
                for (v, &kv) in ku.iter().enumerate() {
                    row_s += src[base + v] * kv;
                }
                s += row_s;
            }
            out[j] = s;
        }
    }
}

/// Single-pass, SIMD shape, generic width: per source row, sweep a
/// `width`-window dot product across the output row and accumulate.
pub fn singlepass_band_simd_w(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k2d: &[f32],
    width: usize,
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    debug_assert_eq!(k2d.len(), width * width);
    let h = width / 2;
    if 2 * h >= cols || 2 * h >= rows {
        return;
    }
    let (a, b) = band_range(rows, h, r0, r1);
    let w = cols - 2 * h;
    for i in a..b {
        let start = (i - r0) * cols + h;
        let out = &mut dst_band[start..start + w];
        let row0 = &src[(i - h) * cols..(i - h) * cols + cols];
        for (o, win) in out.iter_mut().zip(row0.windows(width)) {
            *o = dotw(win, &k2d[0..width]);
        }
        for u in 1..width {
            let row = &src[(i + u - h) * cols..(i + u - h) * cols + cols];
            let ku = &k2d[u * width..(u + 1) * width];
            for (o, win) in out.iter_mut().zip(row.windows(width)) {
                *o += dotw(win, ku);
            }
        }
    }
}

/// Horizontal pass, scalar shape, generic width.
pub fn horiz_band_scalar_w(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k: &[f32],
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    let width = k.len();
    let h = width / 2;
    if 2 * h >= cols || 2 * h >= rows {
        return;
    }
    let (a, b) = band_range(rows, h, r0, r1);
    for i in a..b {
        let out = &mut dst_band[(i - r0) * cols..(i - r0 + 1) * cols];
        for j in h..cols - h {
            let base = i * cols + j - h;
            let mut s = 0.0f32;
            for (v, &kv) in k.iter().enumerate() {
                s += src[base + v] * kv;
            }
            out[j] = s;
        }
    }
}

/// Horizontal pass, SIMD shape, generic width: one `width`-window sweep
/// per row.
pub fn horiz_band_simd_w(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k: &[f32],
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    let width = k.len();
    let h = width / 2;
    if 2 * h >= cols || 2 * h >= rows {
        return;
    }
    let (a, b) = band_range(rows, h, r0, r1);
    let w = cols - 2 * h;
    for i in a..b {
        let row = &src[i * cols..(i + 1) * cols];
        let start = (i - r0) * cols + h;
        let out = &mut dst_band[start..start + w];
        for (o, win) in out.iter_mut().zip(row.windows(width)) {
            *o = dotw(win, k);
        }
    }
}

/// Vertical pass, scalar shape, generic width.
pub fn vert_band_scalar_w(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k: &[f32],
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    let width = k.len();
    let h = width / 2;
    if 2 * h >= cols || 2 * h >= rows {
        return;
    }
    let (a, b) = band_range(rows, h, r0, r1);
    for i in a..b {
        let out = &mut dst_band[(i - r0) * cols..(i - r0 + 1) * cols];
        for j in h..cols - h {
            let mut s = 0.0f32;
            for (u, &ku) in k.iter().enumerate() {
                s += src[(i + u - h) * cols + j] * ku;
            }
            out[j] = s;
        }
    }
}

/// Vertical pass, SIMD shape, generic width: `width` aligned row-slice
/// FMAs per output row.
pub fn vert_band_simd_w(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k: &[f32],
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    let width = k.len();
    let h = width / 2;
    if 2 * h >= cols || 2 * h >= rows {
        return;
    }
    let (a, b) = band_range(rows, h, r0, r1);
    let w = cols - 2 * h;
    for i in a..b {
        let start = (i - r0) * cols + h;
        let out = &mut dst_band[start..start + w];
        let row0 = &src[(i - h) * cols + h..(i - h) * cols + h + w];
        for (o, &s0) in out.iter_mut().zip(row0) {
            *o = s0 * k[0];
        }
        for u in 1..width {
            let row = &src[(i + u - h) * cols + h..(i + u - h) * cols + h + w];
            let ku = k[u];
            for (o, &sv) in out.iter_mut().zip(row) {
                *o += sv * ku;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Fused two-pass: rolling row-ring execution. The unfused separable
// pipeline writes a full-plane horizontal intermediate and then re-reads
// it for the vertical pass — the whole image crosses memory twice. The
// fused engines instead keep a `width`-deep ring of horizontally
// filtered row buffers per band: for each output row they filter only
// the one source row the ring has not seen yet and emit the vertical
// combination immediately, so the intermediate never leaves cache and
// scratch shrinks from O(rows×cols) per plane to O(width×cols) per
// worker (the bandwidth-bound argument of Hofmann et al., PAPERS.md).
//
// Equivalence contract: a ring row holds exactly the value the unfused
// pipeline would have placed in the intermediate plane B — the same
// horizontal tap order for interior rows, the raw image pixels for the
// halo rows B passes through via its border ring — and the emit step
// accumulates in exactly the vertical engines' tap order, so fused
// output is bitwise equal to unfused (the differential suite in
// tests/fused.rs asserts ≤ 1e-6; the unit tests below assert equality).
//
// Each band primes its ring from its own halo rows, so banded parallel
// dispatch is unchanged: workers recompute at most 2·halo boundary rows
// that their neighbour also computes. `ring` must hold at least
// `width · (cols − 2·halo)` elements; only that prefix is touched.
// ---------------------------------------------------------------------------

/// Fused two-pass, scalar shape, W=5 unrolled: per-pixel indexed
/// arithmetic with the [`horiz_band_scalar`] fill and
/// [`vert_band_scalar`] emit expressions.
pub fn fused_band_scalar(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k: &[f32; 5],
    ring: &mut [f32],
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    let h = HALO;
    if 2 * h >= cols || 2 * h >= rows {
        return; // no interior (also guards the `- h` arithmetic)
    }
    let (a, b) = band_range(rows, h, r0, r1);
    if a >= b {
        return; // band entirely inside the border: ring never needed
    }
    let w = cols - 2 * h;
    debug_assert!(ring.len() >= 5 * w);
    for r in (a - h)..(b + h) {
        // fill: source row r into its ring slot — horiz_band_scalar's
        // 5-term expression for interior rows, the raw image for the
        // halo rows the unfused pipeline passes through in B
        let rr = (r % 5) * w;
        let slot = &mut ring[rr..rr + w];
        if r >= h && r < rows - h {
            for j in h..cols - h {
                let base = r * cols + j - h;
                slot[j - h] = src[base] * k[0]
                    + src[base + 1] * k[1]
                    + src[base + 2] * k[2]
                    + src[base + 3] * k[3]
                    + src[base + 4] * k[4];
            }
        } else {
            for (jj, o) in slot.iter_mut().enumerate() {
                *o = src[r * cols + h + jj];
            }
        }
        if r < a + h {
            continue; // ring not yet primed for the first output row
        }
        // emit output row i = r − h: vert_band_scalar's 5-term
        // expression over the ring instead of the intermediate plane
        let i = r - h;
        let out = &mut dst_band[(i - r0) * cols..(i - r0 + 1) * cols];
        for j in h..cols - h {
            let jj = j - h;
            out[j] = ring[((i - 2) % 5) * w + jj] * k[0]
                + ring[((i - 1) % 5) * w + jj] * k[1]
                + ring[(i % 5) * w + jj] * k[2]
                + ring[((i + 1) % 5) * w + jj] * k[3]
                + ring[((i + 2) % 5) * w + jj] * k[4];
        }
    }
}

/// Fused two-pass, SIMD shape, W=5 unrolled: [`horiz_band_simd`]'s
/// window sweep fills the ring, [`vert_band_simd`]'s five-slice zipped
/// sweep emits.
pub fn fused_band_simd(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k: &[f32; 5],
    ring: &mut [f32],
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    let h = HALO;
    if 2 * h >= cols || 2 * h >= rows {
        return; // no interior (also guards the `- h` arithmetic)
    }
    let (a, b) = band_range(rows, h, r0, r1);
    if a >= b {
        return; // band entirely inside the border: ring never needed
    }
    let w = cols - 2 * h;
    debug_assert!(ring.len() >= 5 * w);
    for r in (a - h)..(b + h) {
        let rr = (r % 5) * w;
        let slot = &mut ring[rr..rr + w];
        if r >= h && r < rows - h {
            let row = &src[r * cols..(r + 1) * cols];
            for (o, win) in slot.iter_mut().zip(row.windows(5)) {
                *o = dot5(win, k);
            }
        } else {
            slot.copy_from_slice(&src[r * cols + h..r * cols + h + w]);
        }
        if r < a + h {
            continue; // ring not yet primed for the first output row
        }
        let i = r - h;
        let (s0, s1, s2, s3, s4) = (
            &ring[((i - 2) % 5) * w..((i - 2) % 5) * w + w],
            &ring[((i - 1) % 5) * w..((i - 1) % 5) * w + w],
            &ring[(i % 5) * w..(i % 5) * w + w],
            &ring[((i + 1) % 5) * w..((i + 1) % 5) * w + w],
            &ring[((i + 2) % 5) * w..((i + 2) % 5) * w + w],
        );
        let start = (i - r0) * cols + h;
        let out = &mut dst_band[start..start + w];
        for (((((o, &a0), &a1), &a2), &a3), &a4) in
            out.iter_mut().zip(s0).zip(s1).zip(s2).zip(s3).zip(s4)
        {
            *o = a0 * k[0] + a1 * k[1] + a2 * k[2] + a3 * k[3] + a4 * k[4];
        }
    }
}

/// Fused two-pass, scalar shape, generic odd width: the
/// [`horiz_band_scalar_w`] fill and [`vert_band_scalar_w`] emit orders.
pub fn fused_band_scalar_w(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k: &[f32],
    ring: &mut [f32],
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    let width = k.len();
    let h = width / 2;
    if 2 * h >= cols || 2 * h >= rows {
        return;
    }
    let (a, b) = band_range(rows, h, r0, r1);
    if a >= b {
        return;
    }
    let w = cols - 2 * h;
    debug_assert!(ring.len() >= width * w);
    for r in (a - h)..(b + h) {
        let rr = (r % width) * w;
        let slot = &mut ring[rr..rr + w];
        if r >= h && r < rows - h {
            for j in h..cols - h {
                let base = r * cols + j - h;
                let mut s = 0.0f32;
                for (v, &kv) in k.iter().enumerate() {
                    s += src[base + v] * kv;
                }
                slot[j - h] = s;
            }
        } else {
            for (jj, o) in slot.iter_mut().enumerate() {
                *o = src[r * cols + h + jj];
            }
        }
        if r < a + h {
            continue;
        }
        let i = r - h;
        let out = &mut dst_band[(i - r0) * cols..(i - r0 + 1) * cols];
        for j in h..cols - h {
            let jj = j - h;
            let mut s = 0.0f32;
            for (u, &ku) in k.iter().enumerate() {
                s += ring[((i + u - h) % width) * w + jj] * ku;
            }
            out[j] = s;
        }
    }
}

/// Fused two-pass, SIMD shape, generic odd width: the
/// [`horiz_band_simd_w`] window sweep fills the ring, the
/// [`vert_band_simd_w`] accumulation order emits.
pub fn fused_band_simd_w(
    src: &[f32],
    dst_band: &mut [f32],
    rows: usize,
    cols: usize,
    k: &[f32],
    ring: &mut [f32],
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    let width = k.len();
    let h = width / 2;
    if 2 * h >= cols || 2 * h >= rows {
        return;
    }
    let (a, b) = band_range(rows, h, r0, r1);
    if a >= b {
        return;
    }
    let w = cols - 2 * h;
    debug_assert!(ring.len() >= width * w);
    for r in (a - h)..(b + h) {
        let rr = (r % width) * w;
        let slot = &mut ring[rr..rr + w];
        if r >= h && r < rows - h {
            let row = &src[r * cols..(r + 1) * cols];
            for (o, win) in slot.iter_mut().zip(row.windows(width)) {
                *o = dotw(win, k);
            }
        } else {
            slot.copy_from_slice(&src[r * cols + h..r * cols + h + w]);
        }
        if r < a + h {
            continue;
        }
        let i = r - h;
        let start = (i - r0) * cols + h;
        let out = &mut dst_band[start..start + w];
        let rr0 = ((i - h) % width) * w;
        let row0 = &ring[rr0..rr0 + w];
        for (o, &s0) in out.iter_mut().zip(row0) {
            *o = s0 * k[0];
        }
        for u in 1..width {
            let rru = ((i + u - h) % width) * w;
            let rowu = &ring[rru..rru + w];
            let ku = k[u];
            for (o, &sv) in out.iter_mut().zip(rowu) {
                *o += sv * ku;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// copy-back (the single-pass algorithm's extra pass, paper section 7)
// ---------------------------------------------------------------------------

/// Scalar copy-back: per-pixel indexed assignment of rows `[r0, r1)`.
pub fn copy_back_band_scalar(src: &[f32], dst_band: &mut [f32], cols: usize, r0: usize, r1: usize) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    for i in r0..r1 {
        for j in 0..cols {
            dst_band[(i - r0) * cols + j] = src[i * cols + j];
        }
    }
}

/// SIMD copy-back: one block `copy_from_slice` (memcpy).
pub fn copy_back_band_simd(src: &[f32], dst_band: &mut [f32], cols: usize, r0: usize, r1: usize) {
    debug_assert_eq!(dst_band.len(), (r1 - r0) * cols);
    dst_band.copy_from_slice(&src[r0 * cols..r1 * cols]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::{gaussian_kernel, gaussian_kernel2d};
    use crate::util::prng::Prng;

    const R: usize = 24;
    const C: usize = 20;

    fn noise(seed: u64) -> Vec<f32> {
        let mut p = Prng::new(seed);
        (0..R * C).map(|_| p.normal()).collect()
    }

    fn k5() -> ([f32; 5], [f32; 25]) {
        let k = gaussian_kernel(5, 1.0);
        let k2 = gaussian_kernel2d(&k);
        (k.try_into().unwrap(), k2.try_into().unwrap())
    }

    /// brute-force oracle for single-pass interior
    fn oracle_singlepass(src: &[f32], k2d: &[f32; 25]) -> Vec<f32> {
        let mut out = src.to_vec();
        for i in 2..R - 2 {
            for j in 2..C - 2 {
                let mut s = 0.0;
                for u in 0..5 {
                    for v in 0..5 {
                        s += src[(i + u - 2) * C + j + v - 2] * k2d[u * 5 + v];
                    }
                }
                out[i * C + j] = s;
            }
        }
        out
    }

    #[test]
    fn scalar_simd_naive_all_agree() {
        let src = noise(1);
        let (_k, k2) = k5();
        let want = oracle_singlepass(&src, &k2);

        let mut d1 = src.clone();
        singlepass_naive_band(&src, &mut d1, R, C, &k2, 5, 0, R);
        let mut d2 = src.clone();
        singlepass_band_scalar(&src, &mut d2, R, C, &k2, 0, R);
        let mut d3 = src.clone();
        singlepass_band_simd(&src, &mut d3, R, C, &k2, 0, R);

        for (name, d) in [("naive", &d1), ("scalar", &d2), ("simd", &d3)] {
            for (g, w) in d.iter().zip(&want) {
                assert!((g - w).abs() < 1e-5, "{name}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn horiz_scalar_simd_agree() {
        let src = noise(2);
        let (k, _) = k5();
        let mut a = src.clone();
        horiz_band_scalar(&src, &mut a, R, C, &k, 0, R);
        let mut b = src.clone();
        horiz_band_simd(&src, &mut b, R, C, &k, 0, R);
        assert_eq!(a, b, "identical tap order ⇒ bitwise equal");
    }

    #[test]
    fn vert_scalar_simd_agree() {
        let src = noise(3);
        let (k, _) = k5();
        let mut a = src.clone();
        vert_band_scalar(&src, &mut a, R, C, &k, 0, R);
        let mut b = src.clone();
        vert_band_simd(&src, &mut b, R, C, &k, 0, R);
        assert_eq!(a, b);
    }

    #[test]
    fn bands_clamp_to_interior() {
        let src = noise(4);
        let (k, _) = k5();
        let mut d = src.clone();
        horiz_band_simd(&src, &mut d, R, C, &k, 0, R);
        // rows 0..2 and R-2..R untouched
        for j in 0..C {
            assert_eq!(d[j], src[j]);
            assert_eq!(d[(R - 1) * C + j], src[(R - 1) * C + j]);
        }
        // border columns untouched too
        for i in 0..R {
            assert_eq!(d[i * C], src[i * C]);
            assert_eq!(d[i * C + C - 1], src[i * C + C - 1]);
        }
    }

    #[test]
    fn banded_partition_equals_full_sweep() {
        let src = noise(5);
        let (_, k2) = k5();
        let mut full = src.clone();
        singlepass_band_simd(&src, &mut full, R, C, &k2, 0, R);
        // disjoint banded sub-slices, exactly how the models call it
        let mut parts = src.clone();
        {
            let (b0, rest) = parts.split_at_mut(7 * C);
            let (b1, b2) = rest.split_at_mut((15 - 7) * C);
            singlepass_band_simd(&src, b0, R, C, &k2, 0, 7);
            singlepass_band_simd(&src, b1, R, C, &k2, 7, 15);
            singlepass_band_simd(&src, b2, R, C, &k2, 15, R);
        }
        assert_eq!(full, parts);
    }

    #[test]
    fn empty_band_is_noop() {
        let src = noise(6);
        let (k, _) = k5();
        let mut d: Vec<f32> = vec![];
        horiz_band_simd(&src, &mut d, R, C, &k, 10, 10);
        // band entirely inside the top border: values untouched
        let mut d2 = vec![9f32; 2 * C];
        vert_band_scalar(&src, &mut d2, R, C, &k, 0, 2);
        assert!(d2.iter().all(|&v| v == 9.0));
    }

    #[test]
    fn copy_back_variants_agree() {
        let src = noise(7);
        let mut a = vec![0f32; (17 - 3) * C];
        let mut b = vec![0f32; (17 - 3) * C];
        copy_back_band_scalar(&src, &mut a, C, 3, 17);
        copy_back_band_simd(&src, &mut b, C, 3, 17);
        assert_eq!(a, b);
        assert_eq!(a[0], src[3 * C]);
    }

    #[test]
    fn generic_width5_matches_unrolled_fast_path() {
        let src = noise(10);
        let (k, k2) = k5();

        let mut fast = src.clone();
        singlepass_band_simd(&src, &mut fast, R, C, &k2, 0, R);
        let mut generic = src.clone();
        singlepass_band_simd_w(&src, &mut generic, R, C, &k2, 5, 0, R);
        for (f, g) in fast.iter().zip(&generic) {
            assert!((f - g).abs() < 1e-6, "simd: {f} vs {g}");
        }

        let mut fast = src.clone();
        singlepass_band_scalar(&src, &mut fast, R, C, &k2, 0, R);
        let mut generic = src.clone();
        singlepass_band_scalar_w(&src, &mut generic, R, C, &k2, 5, 0, R);
        for (f, g) in fast.iter().zip(&generic) {
            assert!((f - g).abs() < 1e-6, "scalar: {f} vs {g}");
        }

        let mut fast = src.clone();
        horiz_band_simd(&src, &mut fast, R, C, &k, 0, R);
        let mut generic = src.clone();
        horiz_band_simd_w(&src, &mut generic, R, C, &k, 0, R);
        assert_eq!(fast, generic, "horiz: identical tap order ⇒ bitwise equal");

        let mut fast = src.clone();
        vert_band_simd(&src, &mut fast, R, C, &k, 0, R);
        let mut generic = src.clone();
        vert_band_simd_w(&src, &mut generic, R, C, &k, 0, R);
        assert_eq!(fast, generic, "vert: identical tap order ⇒ bitwise equal");
    }

    #[test]
    fn generic_scalar_simd_agree_at_width7() {
        let src = noise(11);
        let k = gaussian_kernel(7, 1.5);
        let k2 = gaussian_kernel2d(&k);

        let mut a = src.clone();
        singlepass_band_scalar_w(&src, &mut a, R, C, &k2, 7, 0, R);
        let mut b = src.clone();
        singlepass_band_simd_w(&src, &mut b, R, C, &k2, 7, 0, R);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5, "singlepass w7: {x} vs {y}");
        }

        let mut a = src.clone();
        horiz_band_scalar_w(&src, &mut a, R, C, &k, 0, R);
        let mut b = src.clone();
        horiz_band_simd_w(&src, &mut b, R, C, &k, 0, R);
        assert_eq!(a, b, "horiz w7");

        let mut a = src.clone();
        vert_band_scalar_w(&src, &mut a, R, C, &k, 0, R);
        let mut b = src.clone();
        vert_band_simd_w(&src, &mut b, R, C, &k, 0, R);
        assert_eq!(a, b, "vert w7");
    }

    #[test]
    fn generic_singlepass_matches_naive_at_width3() {
        let src = noise(12);
        let k = gaussian_kernel(3, 1.0);
        let k2 = gaussian_kernel2d(&k);
        let mut want = src.clone();
        singlepass_naive_band(&src, &mut want, R, C, &k2, 3, 0, R);
        for f in [singlepass_band_scalar_w, singlepass_band_simd_w] {
            let mut got = src.clone();
            f(&src, &mut got, R, C, &k2, 3, 0, R);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-5, "{g} vs {w}");
            }
        }
    }

    #[test]
    fn generic_fns_noop_when_kernel_exceeds_plane() {
        // width 9 on a 7-column plane: no interior, everything untouched
        let src = noise(13);
        let k = gaussian_kernel(9, 2.0);
        let k2 = gaussian_kernel2d(&k);
        let mut d = vec![5f32; 10 * 7];
        singlepass_band_scalar_w(&src[..70], &mut d, 10, 7, &k2, 9, 0, 10);
        horiz_band_simd_w(&src[..70], &mut d, 10, 7, &k, 0, 10);
        vert_band_scalar_w(&src[..70], &mut d, 10, 7, &k, 0, 10);
        assert!(d.iter().all(|&v| v == 5.0));
    }

    /// Unfused two-pass reference: horizontal into a copy of src (halo
    /// rows stay raw, exactly the plan's intermediate plane B), then
    /// vertical into a second copy — the values the fused engines must
    /// reproduce bitwise.
    fn twopass_reference(
        src: &[f32],
        horiz: impl Fn(&[f32], &mut [f32]),
        vert: impl Fn(&[f32], &mut [f32]),
    ) -> Vec<f32> {
        let mut b = src.to_vec();
        horiz(src, &mut b);
        let mut out = src.to_vec();
        vert(&b, &mut out);
        out
    }

    #[test]
    fn fused_w5_bitwise_equals_unfused_composition() {
        let src = noise(20);
        let (k, _) = k5();
        let w = C - 4;

        let want = twopass_reference(
            &src,
            |s, d| horiz_band_simd(s, d, R, C, &k, 0, R),
            |s, d| vert_band_simd(s, d, R, C, &k, 0, R),
        );
        let mut got = src.clone();
        let mut ring = vec![0f32; 5 * w];
        fused_band_simd(&src, &mut got, R, C, &k, &mut ring, 0, R);
        assert_eq!(got, want, "simd: same tap order ⇒ bitwise equal");

        let want = twopass_reference(
            &src,
            |s, d| horiz_band_scalar(s, d, R, C, &k, 0, R),
            |s, d| vert_band_scalar(s, d, R, C, &k, 0, R),
        );
        let mut got = src.clone();
        let mut ring = vec![0f32; 5 * w];
        fused_band_scalar(&src, &mut got, R, C, &k, &mut ring, 0, R);
        assert_eq!(got, want, "scalar");
    }

    #[test]
    fn fused_generic_bitwise_equals_unfused_composition() {
        let src = noise(21);
        for width in [3usize, 5, 7, 9] {
            let k = gaussian_kernel(width, 1.3);
            let w = C - 2 * (width / 2);
            let want = twopass_reference(
                &src,
                |s, d| horiz_band_simd_w(s, d, R, C, &k, 0, R),
                |s, d| vert_band_simd_w(s, d, R, C, &k, 0, R),
            );
            let mut got = src.clone();
            let mut ring = vec![0f32; width * w];
            fused_band_simd_w(&src, &mut got, R, C, &k, &mut ring, 0, R);
            assert_eq!(got, want, "simd w{width}");

            let want = twopass_reference(
                &src,
                |s, d| horiz_band_scalar_w(s, d, R, C, &k, 0, R),
                |s, d| vert_band_scalar_w(s, d, R, C, &k, 0, R),
            );
            let mut got = src.clone();
            let mut ring = vec![0f32; width * w];
            fused_band_scalar_w(&src, &mut got, R, C, &k, &mut ring, 0, R);
            assert_eq!(got, want, "scalar w{width}");
        }
    }

    #[test]
    fn fused_banded_partition_equals_full_sweep() {
        // ring-wrap edge cases: r0 = 0 prime, bands shorter than the
        // kernel height (1-row bands), and the r1 = rows tail — every
        // band primes its own ring, so any disjoint cover agrees with
        // the whole-plane sweep bitwise
        let src = noise(22);
        let (k, _) = k5();
        let w = C - 4;
        let mut full = src.clone();
        let mut ring = vec![0f32; 5 * w];
        fused_band_simd(&src, &mut full, R, C, &k, &mut ring, 0, R);

        let cuts = [0usize, 1, 3, 4, 9, 10, R];
        let mut parts = src.clone();
        {
            let mut rest = &mut parts[..];
            let mut taken = 0;
            for pair in cuts.windows(2) {
                let (band, tail) = rest.split_at_mut((pair[1] - pair[0]) * C);
                let mut ring = vec![1e9f32; 5 * w]; // poisoned: primes must overwrite
                fused_band_simd(&src, band, R, C, &k, &mut ring, pair[0], pair[1]);
                rest = tail;
                taken += band.len();
            }
            assert_eq!(taken, R * C);
        }
        assert_eq!(full, parts);

        // generic engine, width 7, same cover
        let k7 = gaussian_kernel(7, 1.5);
        let w7 = C - 6;
        let mut full = src.clone();
        let mut ring = vec![0f32; 7 * w7];
        fused_band_simd_w(&src, &mut full, R, C, &k7, &mut ring, 0, R);
        let mut parts = src.clone();
        {
            let mut rest = &mut parts[..];
            for pair in cuts.windows(2) {
                let (band, tail) = rest.split_at_mut((pair[1] - pair[0]) * C);
                let mut ring = vec![1e9f32; 7 * w7];
                fused_band_simd_w(&src, band, R, C, &k7, &mut ring, pair[0], pair[1]);
                rest = tail;
            }
        }
        assert_eq!(full, parts, "w7");
    }

    #[test]
    fn fused_noop_on_degenerate_shapes() {
        // rows or cols shorter than the kernel: untouched, no panic —
        // and the ring is never read (zero-length ring is accepted)
        let src = noise(23);
        let (k, _) = k5();
        let mut ring: Vec<f32> = vec![];
        for (rows, cols) in [(3usize, 10usize), (10, 3), (1, 10), (10, 1), (4, 4)] {
            let mut d = vec![7f32; rows * cols];
            fused_band_simd(&src[..rows * cols], &mut d, rows, cols, &k, &mut ring, 0, rows);
            fused_band_scalar(&src[..rows * cols], &mut d, rows, cols, &k, &mut ring, 0, rows);
            assert!(d.iter().all(|&v| v == 7.0), "{rows}x{cols}");
        }
        // band entirely inside the border ring: no output rows
        let mut d = vec![7f32; 2 * C];
        fused_band_simd(&src, &mut d, R, C, &k, &mut ring, 0, 2);
        assert!(d.iter().all(|&v| v == 7.0));
    }

    #[test]
    fn existing_engines_noop_when_kernel_taller_than_plane() {
        // the degenerate-shape guard symmetry: rows < kernel height is
        // an explicit no-op for every engine, like cols already was
        let src = noise(24);
        let (k, k2) = k5();
        let mut d = vec![9f32; 3 * C];
        singlepass_band_scalar(&src[..3 * C], &mut d, 3, C, &k2, 0, 3);
        singlepass_band_simd(&src[..3 * C], &mut d, 3, C, &k2, 0, 3);
        singlepass_naive_band(&src[..3 * C], &mut d, 3, C, &k2, 5, 0, 3);
        horiz_band_scalar(&src[..3 * C], &mut d, 3, C, &k, 0, 3);
        horiz_band_simd(&src[..3 * C], &mut d, 3, C, &k, 0, 3);
        vert_band_scalar(&src[..3 * C], &mut d, 3, C, &k, 0, 3);
        vert_band_simd(&src[..3 * C], &mut d, 3, C, &k, 0, 3);
        singlepass_band_scalar_w(&src[..3 * C], &mut d, 3, C, &k2, 5, 0, 3);
        singlepass_band_simd_w(&src[..3 * C], &mut d, 3, C, &k2, 5, 0, 3);
        horiz_band_scalar_w(&src[..3 * C], &mut d, 3, C, &k, 0, 3);
        horiz_band_simd_w(&src[..3 * C], &mut d, 3, C, &k, 0, 3);
        vert_band_scalar_w(&src[..3 * C], &mut d, 3, C, &k, 0, 3);
        vert_band_simd_w(&src[..3 * C], &mut d, 3, C, &k, 0, 3);
        assert!(d.iter().all(|&v| v == 9.0));
    }

    #[test]
    fn naive_generic_width3() {
        // width-3 box kernel sanity: interior = local mean of ones = 1
        let src = vec![1.0f32; R * C];
        let k2 = vec![1.0 / 9.0; 9];
        let mut d = src.clone();
        singlepass_naive_band(&src, &mut d, R, C, &k2, 3, 0, R);
        for i in 1..R - 1 {
            for j in 1..C - 1 {
                assert!((d[i * C + j] - 1.0).abs() < 1e-6);
            }
        }
    }
}
