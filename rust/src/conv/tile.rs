//! Tile convolution primitives — the 2-D siblings of the row-band
//! functions in [`super::band`].
//!
//! Every function computes the cells of one [`Tile`] clamped to the
//! plane interior (`[h, rows−h) × [h, cols−h)` for a halo-`h` kernel;
//! copy-back covers the whole tile). Output goes through a
//! [`TileCells`] accessor instead of a `dst_band` slice: tiles in the
//! same row range own different column segments, so the disjointness
//! that made row bands expressible as safe sub-slices lives at
//! row-segment granularity here (see `TileCells` for the contract — the
//! execution models' `dispatch2d` covers are disjoint by construction,
//! property-tested in `tests/tiling.rs`).
//!
//! All primitives are generic over odd kernel width and accumulate in
//! exactly the same order as the generic-width band engines (`dotw`
//! windows for simd shapes, row subtotals for scalar, the 4-nested-loop
//! order for naive), so a tiled sweep is bitwise comparable to an
//! untiled one — the property the differential equivalence suite
//! asserts.

use super::band::dotw;
use crate::models::pool::TileCells;
use crate::models::Tile;

/// Clamp a tile to the interior `[h, rows−h) × [h, cols−h)`; returns
/// `None` when nothing of the tile survives (border-only tiles, or a
/// kernel wider than the plane).
#[inline]
fn interior(rows: usize, cols: usize, h: usize, t: Tile) -> Option<(usize, usize, usize, usize)> {
    if 2 * h >= cols || 2 * h >= rows {
        return None; // no interior (also guards the `- h` arithmetic)
    }
    let (a, b) = (t.r0.max(h), t.r1.min(rows - h));
    let (ja, jb) = (t.c0.max(h), t.c1.min(cols - h));
    if a >= b || ja >= jb {
        return None;
    }
    Some((a, b, ja, jb))
}

/// Naive single-pass over one tile (4 nested loops, the Opt-0 shape).
pub fn singlepass_tile_naive(
    src: &[f32],
    out: &TileCells,
    rows: usize,
    cols: usize,
    k2d: &[f32],
    width: usize,
    t: Tile,
) {
    debug_assert_eq!(k2d.len(), width * width);
    let h = width / 2;
    let Some((a, b, ja, jb)) = interior(rows, cols, h, t) else { return };
    for i in a..b {
        // SAFETY: [ja, jb) ⊆ this tile's columns, i ∈ this tile's rows;
        // dispatch2d covers are disjoint tiles (property-tested).
        let out_row = unsafe { out.row_seg(i, ja, jb) };
        for (o, j) in out_row.iter_mut().zip(ja..jb) {
            let mut s = 0.0f32;
            for u in 0..width {
                for v in 0..width {
                    s += src[(i + u - h) * cols + (j + v - h)] * k2d[u * width + v];
                }
            }
            *o = s;
        }
    }
}

/// Single-pass, scalar shape, over one tile (per-pixel indexed
/// arithmetic with per-source-row subtotals, like
/// [`super::band::singlepass_band_scalar_w`]).
pub fn singlepass_tile_scalar(
    src: &[f32],
    out: &TileCells,
    rows: usize,
    cols: usize,
    k2d: &[f32],
    width: usize,
    t: Tile,
) {
    debug_assert_eq!(k2d.len(), width * width);
    let h = width / 2;
    let Some((a, b, ja, jb)) = interior(rows, cols, h, t) else { return };
    for i in a..b {
        // SAFETY: segment inside this tile; tiles are disjoint.
        let out_row = unsafe { out.row_seg(i, ja, jb) };
        for (o, j) in out_row.iter_mut().zip(ja..jb) {
            let mut s = 0.0f32;
            for u in 0..width {
                let base = (i + u - h) * cols + j - h;
                let ku = &k2d[u * width..(u + 1) * width];
                let mut row_s = 0.0f32;
                for (v, &kv) in ku.iter().enumerate() {
                    row_s += src[base + v] * kv;
                }
                s += row_s;
            }
            *o = s;
        }
    }
}

/// Single-pass, SIMD shape, over one tile: per source row, a
/// `width`-window dot-product sweep across the tile's columns.
pub fn singlepass_tile_simd(
    src: &[f32],
    out: &TileCells,
    rows: usize,
    cols: usize,
    k2d: &[f32],
    width: usize,
    t: Tile,
) {
    debug_assert_eq!(k2d.len(), width * width);
    let h = width / 2;
    let Some((a, b, ja, jb)) = interior(rows, cols, h, t) else { return };
    for i in a..b {
        // SAFETY: segment inside this tile; tiles are disjoint.
        let out_row = unsafe { out.row_seg(i, ja, jb) };
        let row0 = &src[(i - h) * cols + ja - h..(i - h) * cols + jb + h];
        for (o, win) in out_row.iter_mut().zip(row0.windows(width)) {
            *o = dotw(win, &k2d[0..width]);
        }
        for u in 1..width {
            let row = &src[(i + u - h) * cols + ja - h..(i + u - h) * cols + jb + h];
            let ku = &k2d[u * width..(u + 1) * width];
            for (o, win) in out_row.iter_mut().zip(row.windows(width)) {
                *o += dotw(win, ku);
            }
        }
    }
}

/// Horizontal pass, scalar shape, over one tile.
pub fn horiz_tile_scalar(
    src: &[f32],
    out: &TileCells,
    rows: usize,
    cols: usize,
    k: &[f32],
    t: Tile,
) {
    let width = k.len();
    let h = width / 2;
    let Some((a, b, ja, jb)) = interior(rows, cols, h, t) else { return };
    for i in a..b {
        // SAFETY: segment inside this tile; tiles are disjoint.
        let out_row = unsafe { out.row_seg(i, ja, jb) };
        for (o, j) in out_row.iter_mut().zip(ja..jb) {
            let base = i * cols + j - h;
            let mut s = 0.0f32;
            for (v, &kv) in k.iter().enumerate() {
                s += src[base + v] * kv;
            }
            *o = s;
        }
    }
}

/// Horizontal pass, SIMD shape, over one tile: one `width`-window sweep
/// across the tile's columns per row.
pub fn horiz_tile_simd(
    src: &[f32],
    out: &TileCells,
    rows: usize,
    cols: usize,
    k: &[f32],
    t: Tile,
) {
    let width = k.len();
    let h = width / 2;
    let Some((a, b, ja, jb)) = interior(rows, cols, h, t) else { return };
    for i in a..b {
        // SAFETY: segment inside this tile; tiles are disjoint.
        let out_row = unsafe { out.row_seg(i, ja, jb) };
        let row = &src[i * cols + ja - h..i * cols + jb + h];
        for (o, win) in out_row.iter_mut().zip(row.windows(width)) {
            *o = dotw(win, k);
        }
    }
}

/// Vertical pass, scalar shape, over one tile.
pub fn vert_tile_scalar(
    src: &[f32],
    out: &TileCells,
    rows: usize,
    cols: usize,
    k: &[f32],
    t: Tile,
) {
    let width = k.len();
    let h = width / 2;
    let Some((a, b, ja, jb)) = interior(rows, cols, h, t) else { return };
    for i in a..b {
        // SAFETY: segment inside this tile; tiles are disjoint.
        let out_row = unsafe { out.row_seg(i, ja, jb) };
        for (o, j) in out_row.iter_mut().zip(ja..jb) {
            let mut s = 0.0f32;
            for (u, &ku) in k.iter().enumerate() {
                s += src[(i + u - h) * cols + j] * ku;
            }
            *o = s;
        }
    }
}

/// Vertical pass, SIMD shape, over one tile: `width` aligned row-slice
/// FMAs per tile row.
pub fn vert_tile_simd(src: &[f32], out: &TileCells, rows: usize, cols: usize, k: &[f32], t: Tile) {
    let width = k.len();
    let h = width / 2;
    let Some((a, b, ja, jb)) = interior(rows, cols, h, t) else { return };
    let w = jb - ja;
    for i in a..b {
        // SAFETY: segment inside this tile; tiles are disjoint.
        let out_row = unsafe { out.row_seg(i, ja, jb) };
        let row0 = &src[(i - h) * cols + ja..(i - h) * cols + ja + w];
        for (o, &s0) in out_row.iter_mut().zip(row0) {
            *o = s0 * k[0];
        }
        for u in 1..width {
            let row = &src[(i + u - h) * cols + ja..(i + u - h) * cols + ja + w];
            let ku = k[u];
            for (o, &sv) in out_row.iter_mut().zip(row) {
                *o += sv * ku;
            }
        }
    }
}

/// Fused two-pass over one tile, scalar shape: the 2-D sibling of
/// [`super::band::fused_band_scalar_w`]. A `width`-deep ring of
/// horizontally filtered row segments (the tile's columns only) rolls
/// down the tile; each output row is emitted as soon as its window is
/// resident. Fill matches [`horiz_tile_scalar`]'s accumulation order
/// (raw image for the halo rows the unfused pipeline passes through in
/// B), emit matches [`vert_tile_scalar`]'s, so fused tiled output is
/// bitwise equal to the unfused tiled pipeline. `ring` needs
/// `width · tile_width` elements; only that prefix is touched.
pub fn fused_tile_scalar(
    src: &[f32],
    out: &TileCells,
    rows: usize,
    cols: usize,
    k: &[f32],
    ring: &mut [f32],
    t: Tile,
) {
    let width = k.len();
    let h = width / 2;
    let Some((a, b, ja, jb)) = interior(rows, cols, h, t) else { return };
    let tw = jb - ja;
    debug_assert!(ring.len() >= width * tw);
    for r in (a - h)..(b + h) {
        let rr = (r % width) * tw;
        let slot = &mut ring[rr..rr + tw];
        if r >= h && r < rows - h {
            for (o, j) in slot.iter_mut().zip(ja..jb) {
                let base = r * cols + j - h;
                let mut s = 0.0f32;
                for (v, &kv) in k.iter().enumerate() {
                    s += src[base + v] * kv;
                }
                *o = s;
            }
        } else {
            for (jj, o) in slot.iter_mut().enumerate() {
                *o = src[r * cols + ja + jj];
            }
        }
        if r < a + h {
            continue; // ring not yet primed for the first output row
        }
        let i = r - h;
        // SAFETY: segment inside this tile; tiles are disjoint.
        let out_row = unsafe { out.row_seg(i, ja, jb) };
        for (o, j) in out_row.iter_mut().zip(ja..jb) {
            let jj = j - ja;
            let mut s = 0.0f32;
            for (u, &ku) in k.iter().enumerate() {
                s += ring[((i + u - h) % width) * tw + jj] * ku;
            }
            *o = s;
        }
    }
}

/// Fused two-pass over one tile, SIMD shape: [`horiz_tile_simd`]'s
/// window sweep fills the ring, [`vert_tile_simd`]'s accumulation order
/// emits (see [`fused_tile_scalar`] for the ring discipline).
pub fn fused_tile_simd(
    src: &[f32],
    out: &TileCells,
    rows: usize,
    cols: usize,
    k: &[f32],
    ring: &mut [f32],
    t: Tile,
) {
    let width = k.len();
    let h = width / 2;
    let Some((a, b, ja, jb)) = interior(rows, cols, h, t) else { return };
    let tw = jb - ja;
    debug_assert!(ring.len() >= width * tw);
    for r in (a - h)..(b + h) {
        let rr = (r % width) * tw;
        let slot = &mut ring[rr..rr + tw];
        if r >= h && r < rows - h {
            let row = &src[r * cols + ja - h..r * cols + jb + h];
            for (o, win) in slot.iter_mut().zip(row.windows(width)) {
                *o = dotw(win, k);
            }
        } else {
            slot.copy_from_slice(&src[r * cols + ja..r * cols + jb]);
        }
        if r < a + h {
            continue; // ring not yet primed for the first output row
        }
        let i = r - h;
        // SAFETY: segment inside this tile; tiles are disjoint.
        let out_row = unsafe { out.row_seg(i, ja, jb) };
        let rr0 = ((i - h) % width) * tw;
        let row0 = &ring[rr0..rr0 + tw];
        for (o, &s0) in out_row.iter_mut().zip(row0) {
            *o = s0 * k[0];
        }
        for u in 1..width {
            let rru = ((i + u - h) % width) * tw;
            let rowu = &ring[rru..rru + tw];
            let ku = k[u];
            for (o, &sv) in out_row.iter_mut().zip(rowu) {
                *o += sv * ku;
            }
        }
    }
}

/// Copy-back over one tile (covers the whole tile — the copy-back pass
/// has no interior clamp).
pub fn copy_back_tile(src: &[f32], out: &TileCells, cols: usize, t: Tile) {
    for i in t.r0..t.r1 {
        // SAFETY: segment is exactly this tile's columns; tiles are
        // disjoint.
        let out_row = unsafe { out.row_seg(i, t.c0, t.c1) };
        out_row.copy_from_slice(&src[i * cols + t.c0..i * cols + t.c1]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::band;
    use crate::image::{gaussian_kernel, gaussian_kernel2d};
    use crate::models::{TileGrid, TileSpec};
    use crate::util::prng::Prng;

    const R: usize = 26;
    const C: usize = 22;

    fn noise(seed: u64) -> Vec<f32> {
        let mut p = Prng::new(seed);
        (0..R * C).map(|_| p.normal()).collect()
    }

    /// Run a tile primitive over every tile of a grid, sequentially.
    fn sweep_tiles(spec: TileSpec, dst: &mut [f32], f: impl Fn(&TileCells, Tile)) {
        let grid = TileGrid::new(R, C, spec);
        let cells = TileCells::new(dst, R, C);
        for i in 0..grid.len() {
            f(&cells, grid.tile(i));
        }
    }

    #[test]
    fn tiled_matches_banded_all_passes_width5() {
        let src = noise(1);
        let k = gaussian_kernel(5, 1.0);
        let k2 = gaussian_kernel2d(&k);
        let spec = TileSpec::new(5, 7); // ragged against 26x22
        // (banded reference fn, tiled fn) pairs — generic width twins
        let mut want = src.clone();
        band::horiz_band_simd_w(&src, &mut want, R, C, &k, 0, R);
        let mut got = src.clone();
        sweep_tiles(spec, &mut got, |cells, t| horiz_tile_simd(&src, cells, R, C, &k, t));
        assert_eq!(want, got, "horiz simd");

        let mut want = src.clone();
        band::horiz_band_scalar_w(&src, &mut want, R, C, &k, 0, R);
        let mut got = src.clone();
        sweep_tiles(spec, &mut got, |cells, t| horiz_tile_scalar(&src, cells, R, C, &k, t));
        assert_eq!(want, got, "horiz scalar");

        let mut want = src.clone();
        band::vert_band_simd_w(&src, &mut want, R, C, &k, 0, R);
        let mut got = src.clone();
        sweep_tiles(spec, &mut got, |cells, t| vert_tile_simd(&src, cells, R, C, &k, t));
        assert_eq!(want, got, "vert simd");

        let mut want = src.clone();
        band::vert_band_scalar_w(&src, &mut want, R, C, &k, 0, R);
        let mut got = src.clone();
        sweep_tiles(spec, &mut got, |cells, t| vert_tile_scalar(&src, cells, R, C, &k, t));
        assert_eq!(want, got, "vert scalar");

        let mut want = src.clone();
        band::singlepass_band_simd_w(&src, &mut want, R, C, &k2, 5, 0, R);
        let mut got = src.clone();
        sweep_tiles(spec, &mut got, |cells, t| {
            singlepass_tile_simd(&src, cells, R, C, &k2, 5, t)
        });
        assert_eq!(want, got, "singlepass simd");

        let mut want = src.clone();
        band::singlepass_band_scalar_w(&src, &mut want, R, C, &k2, 5, 0, R);
        let mut got = src.clone();
        sweep_tiles(spec, &mut got, |cells, t| {
            singlepass_tile_scalar(&src, cells, R, C, &k2, 5, t)
        });
        assert_eq!(want, got, "singlepass scalar");

        let mut want = src.clone();
        band::singlepass_naive_band(&src, &mut want, R, C, &k2, 5, 0, R);
        let mut got = src.clone();
        sweep_tiles(spec, &mut got, |cells, t| {
            singlepass_tile_naive(&src, cells, R, C, &k2, 5, t)
        });
        assert_eq!(want, got, "singlepass naive");
    }

    #[test]
    fn tiled_matches_banded_width7() {
        let src = noise(2);
        let k = gaussian_kernel(7, 1.5);
        let k2 = gaussian_kernel2d(&k);
        for spec in [TileSpec::new(1, 1), TileSpec::new(4, 4), TileSpec::new(100, 3)] {
            let mut want = src.clone();
            band::horiz_band_simd_w(&src, &mut want, R, C, &k, 0, R);
            let mut got = src.clone();
            sweep_tiles(spec, &mut got, |cells, t| horiz_tile_simd(&src, cells, R, C, &k, t));
            assert_eq!(want, got, "horiz {}", spec.label());

            let mut want = src.clone();
            band::singlepass_band_simd_w(&src, &mut want, R, C, &k2, 7, 0, R);
            let mut got = src.clone();
            sweep_tiles(spec, &mut got, |cells, t| {
                singlepass_tile_simd(&src, cells, R, C, &k2, 7, t)
            });
            assert_eq!(want, got, "singlepass {}", spec.label());
        }
    }

    #[test]
    fn border_tiles_are_noops() {
        let src = noise(3);
        let k = gaussian_kernel(5, 1.0);
        let mut dst = vec![9f32; R * C];
        {
            let cells = TileCells::new(&mut dst, R, C);
            // tiles entirely inside the halo ring: nothing written
            horiz_tile_simd(&src, &cells, R, C, &k, Tile { r0: 0, r1: 2, c0: 0, c1: C });
            vert_tile_scalar(&src, &cells, R, C, &k, Tile { r0: 0, r1: R, c0: 0, c1: 2 });
            singlepass_tile_scalar(
                &src,
                &cells,
                R,
                C,
                &gaussian_kernel2d(&k),
                5,
                Tile { r0: R - 2, r1: R, c0: 0, c1: C },
            );
        }
        assert!(dst.iter().all(|&v| v == 9.0));
    }

    #[test]
    fn kernel_wider_than_plane_is_noop() {
        let src = noise(4);
        let k = gaussian_kernel(9, 2.0);
        let mut dst = vec![5f32; 10 * 7];
        {
            let cells = TileCells::new(&mut dst, 10, 7);
            horiz_tile_simd(&src[..70], &cells, 10, 7, &k, Tile { r0: 0, r1: 10, c0: 0, c1: 7 });
            vert_tile_simd(&src[..70], &cells, 10, 7, &k, Tile { r0: 0, r1: 10, c0: 0, c1: 7 });
        }
        assert!(dst.iter().all(|&v| v == 5.0));
    }

    #[test]
    fn fused_tiles_match_unfused_tile_composition() {
        // fused tiled ≡ horiz-tiles-then-vert-tiles, bitwise, across
        // ragged grids and widths — the tiled twin of the band-level
        // fused equivalence tests
        let src = noise(6);
        for width in [3usize, 5, 7] {
            let k = gaussian_kernel(width, 1.2);
            for spec in [TileSpec::new(5, 7), TileSpec::new(100, 3), TileSpec::new(4, 100)] {
                for simd in [false, true] {
                    let mut b = src.clone();
                    sweep_tiles(spec, &mut b, |cells, t| {
                        if simd {
                            horiz_tile_simd(&src, cells, R, C, &k, t);
                        } else {
                            horiz_tile_scalar(&src, cells, R, C, &k, t);
                        }
                    });
                    let mut want = src.clone();
                    sweep_tiles(spec, &mut want, |cells, t| {
                        if simd {
                            vert_tile_simd(&b, cells, R, C, &k, t);
                        } else {
                            vert_tile_scalar(&b, cells, R, C, &k, t);
                        }
                    });
                    let mut got = src.clone();
                    let mut ring = vec![1e9f32; width * C];
                    sweep_tiles(spec, &mut got, |cells, t| {
                        if simd {
                            fused_tile_simd(&src, cells, R, C, &k, &mut ring.clone(), t);
                        } else {
                            fused_tile_scalar(&src, cells, R, C, &k, &mut ring.clone(), t);
                        }
                    });
                    assert_eq!(want, got, "w{width} {} simd={simd}", spec.label());
                }
            }
        }
    }

    #[test]
    fn fused_border_tiles_and_degenerate_planes_are_noops() {
        let src = noise(7);
        let k = gaussian_kernel(5, 1.0);
        let mut ring = vec![0f32; 5 * C];
        let mut dst = vec![9f32; R * C];
        {
            let cells = TileCells::new(&mut dst, R, C);
            let top = Tile { r0: 0, r1: 2, c0: 0, c1: C };
            fused_tile_simd(&src, &cells, R, C, &k, &mut ring, top);
            let left = Tile { r0: 0, r1: R, c0: 0, c1: 2 };
            fused_tile_scalar(&src, &cells, R, C, &k, &mut ring, left);
        }
        assert!(dst.iter().all(|&v| v == 9.0));
        // kernel taller/wider than the plane
        let k9 = gaussian_kernel(9, 2.0);
        let mut d = vec![5f32; 10 * 7];
        {
            let cells = TileCells::new(&mut d, 10, 7);
            let whole = Tile { r0: 0, r1: 10, c0: 0, c1: 7 };
            fused_tile_simd(&src[..70], &cells, 10, 7, &k9, &mut ring, whole);
        }
        assert!(d.iter().all(|&v| v == 5.0));
    }

    #[test]
    fn copy_back_tile_covers_whole_tile() {
        let src = noise(5);
        let mut dst = vec![0f32; R * C];
        sweep_tiles(TileSpec::new(6, 5), &mut dst, |cells, t| {
            copy_back_tile(&src, cells, C, t)
        });
        assert_eq!(dst, src);
    }
}
