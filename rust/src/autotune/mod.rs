//! Auto-tuning of tile granularity and task agglomeration.
//!
//! The paper's agglomeration experiment (section 6, Fig. 2 vs Fig. 3)
//! shows that the *granularity* handed to the scheduler — not the
//! scheduler itself — decides whether the task-based model competes with
//! the loop-based ones; Kepner's multi-threaded fast convolver
//! (astro-ph/0107084) reports the same tile-size trade-off for
//! dynamically parallel image filtering in general. This module makes
//! that trade-off a measured, queryable quantity:
//!
//! * [`default_candidates`] enumerates tile decompositions for a shape —
//!   always starting from the **untiled, unfused row-partition
//!   baseline**, so the tuned winner can only beat or equal it — plus
//!   fused two-pass twins (the rolling row-ring pipeline, `--fuse`) and,
//!   for GPRM, agglomerated variants where several tiles fuse into one
//!   task instance (the paper's cutoff knob re-expressed per tile).
//! * [`sweep_shape`] measures every candidate under all three execution
//!   models at one image shape (total ms via plan execution, fixed
//!   overhead via the empty-`dispatch2d` probe — the paper's Table-2
//!   methodology) and renders the sweep as a harness table mirroring the
//!   paper's agglomeration exhibit.
//! * [`TuningTable`] persists the per-(model, shape, kernel) winners in
//!   memory, for lookups by serving code and for the `phi-conv tune`
//!   subcommand's summary. On a lookup miss it consults an optional
//!   predictive tier — a fitted [`crate::costmodel::CostModel`] — via
//!   [`TuningTable::choose`], so never-swept shapes still get a
//!   tile/fusion decision (R²-gated: a poor fit falls back to `None`,
//!   i.e. empirical sweeping).
//! * [`sweep_shape_sampled`] additionally records every (model,
//!   candidate) measurement as a self-describing
//!   [`crate::costmodel::Sample`] (repeats, warmup, worker count ride
//!   along) — the training data the cost model is fitted from. Warmup
//!   for both the timed runs and the overhead probes comes from
//!   `cfg.warmup`, which `RunConfig::from_bench_env` funnels through
//!   `models::overhead_warmup()` — so `PHI_BENCH_WARMUP` means the same
//!   thing to the sweep, the probes, and the recorded samples.
//!
//! Reproduce with `phi-conv tune` (sizes/reps/threads from the standard
//! config) or `cargo bench --bench tiling`; fit + persist with
//! `phi-conv tune --save` / `cargo bench --bench costmodel`.

use std::collections::HashMap;

use crate::util::error::Result;

use crate::config::RunConfig;
use crate::costmodel::{dispatch_units, CostModel, Prediction, Sample};
use crate::image::synth_image;
use crate::metrics::{time_reps, Table};
use crate::models::{ExecutionModel, GprmModel, OpenClModel, OpenMpModel, TileSpec};
use crate::plan::{ConvPlan, EdgePolicy, FilterGraph, KernelClass, KernelSpec, ScratchArena};

/// One execution configuration the tuner evaluates: a kernel class
/// (separable ladder, direct 2-D, or FFT), a tile decomposition (or
/// untiled row bands), a GPRM agglomeration factor, and whether the
/// two-pass pipeline is fused (`--fuse`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Candidate {
    /// Which convolver family executes the plan.
    pub class: KernelClass,
    /// `None` = the untiled row-partition baseline.
    pub tile: Option<TileSpec>,
    /// Tiles fused per task instance (GPRM only; 1 elsewhere).
    pub agglomeration: usize,
    /// Fused rolling row-ring two-pass instead of separate passes.
    pub fused: bool,
}

impl Candidate {
    /// The untiled, unfused, separable row-partition baseline every
    /// sweep starts from.
    pub fn untiled() -> Self {
        Self { class: KernelClass::Separable, tile: None, agglomeration: 1, fused: false }
    }

    /// The fused twin of a candidate.
    pub fn fused_twin(self) -> Self {
        Self { fused: true, ..self }
    }

    /// The same candidate under a different kernel class.
    pub fn with_class(self, class: KernelClass) -> Self {
        Self { class, ..self }
    }

    pub fn label(&self) -> String {
        let mut s = match self.tile {
            None => "rows (untiled)".to_string(),
            Some(t) if self.agglomeration > 1 => {
                format!("{} agg={}", t.label(), self.agglomeration)
            }
            Some(t) => t.label(),
        };
        if self.fused {
            s.push_str(" fused");
        }
        if self.class != KernelClass::Separable {
            s.push_str(&format!(" [{}]", self.class.label()));
        }
        s
    }
}

/// Default candidate set for a `rows`-tall image: the untiled-unfused
/// separable baseline, its fused twin, the direct-2D and FFT
/// kernel-class alternatives, full-width stripes (fused and unfused),
/// squares, and (when `gprm`) agglomerated variants of the finer
/// decompositions. Shapes that don't fit the image are dropped rather
/// than clamped so the sweep never measures duplicates. The baseline is
/// always index 0, so the tuned winner beats or equals it by
/// construction.
pub fn default_candidates(rows: usize, gprm: bool) -> Vec<Candidate> {
    let mut out = vec![Candidate::untiled(), Candidate::untiled().fused_twin()];
    // kernel-class alternatives, swept untiled: the direct-2D engines
    // (which also serve as the separable classes' small-kernel rival)
    // and the transform route (which tiling cannot apply to). The cost
    // model fits each class separately, so these rows are what teach it
    // where the crossover sits.
    out.push(Candidate::untiled().with_class(KernelClass::Direct2d));
    out.push(Candidate::untiled().with_class(KernelClass::Fft));
    let tiled = |rows: usize, cols: usize, agg: usize| Candidate {
        class: KernelClass::Separable,
        tile: Some(TileSpec::new(rows, cols)),
        agglomeration: agg,
        fused: false,
    };
    for r in [16usize, 64] {
        if r < rows {
            let stripe = tiled(r, usize::MAX, 1); // full-width stripes
            out.push(stripe);
            out.push(stripe.fused_twin());
        }
    }
    for s in [32usize, 128] {
        if s < rows {
            out.push(tiled(s, s, 1)); // squares
        }
    }
    if gprm {
        // the paper's knob: same tiles, coarser task instances
        for agg in [4usize, 16] {
            if 16 < rows {
                out.push(tiled(16, usize::MAX, agg));
            }
            if 32 < rows {
                out.push(tiled(32, 32, agg));
            }
        }
    }
    out
}

/// Per-edge buffer-policy candidates for a `stages`-long linear chain
/// (`stages - 1` inter-stage edges): the **all-materialised baseline
/// first** (every sweep's reference, by the same invariant as
/// [`default_candidates`]), then the fully streamed chain, then — for
/// chains with several edges — one split per edge (all streamed except
/// that edge). Per-edge fuse decisions are thus swept exactly like tile
/// shapes are.
pub fn chain_policy_candidates(stages: usize) -> Vec<Vec<EdgePolicy>> {
    let edges = stages.saturating_sub(1);
    let mut out = vec![vec![EdgePolicy::Materialized; edges]];
    if edges == 0 {
        return out;
    }
    out.push(vec![EdgePolicy::Streamed; edges]);
    if edges >= 2 {
        for i in 0..edges {
            let mut cand = vec![EdgePolicy::Streamed; edges];
            cand[i] = EdgePolicy::Materialized;
            out.push(cand);
        }
    }
    out
}

/// Compact label for a chain-policy candidate: one letter per
/// inter-stage edge (`S` streamed, `M` materialised).
pub fn chain_policy_label(policies: &[EdgePolicy]) -> String {
    if policies.is_empty() {
        return "single stage".to_string();
    }
    policies
        .iter()
        .map(|p| match p {
            EdgePolicy::Streamed => "S",
            EdgePolicy::Materialized => "M",
        })
        .collect::<Vec<_>>()
        .join("\u{00b7}")
}

/// A linear chain graph with explicit per-edge policies (`policies[i]`
/// is the edge into stage `i + 1`; the source edge materialises by
/// construction).
fn chain_graph(
    planes: usize,
    rows: usize,
    cols: usize,
    specs: &[KernelSpec],
    policies: &[EdgePolicy],
) -> Result<FilterGraph> {
    let mut b = FilterGraph::builder().shape(planes, rows, cols);
    for (i, spec) in specs.iter().enumerate() {
        b = b.stage(&format!("s{i}"), *spec);
        if i >= 1 {
            b = b.policy(policies[i - 1]);
        }
    }
    b.build()
}

/// Sweep every per-edge policy candidate of a chain under OpenMP at one
/// square size: measured ms plus the traffic estimate per candidate,
/// winner marked, the all-materialised baseline always row 0 (`phi-conv
/// graph --tune`).
pub fn sweep_chain(cfg: &RunConfig, size: usize, specs: &[KernelSpec]) -> Result<Table> {
    cfg.validate()?;
    ensure!(!specs.is_empty(), "chain sweep needs at least one stage");
    let img = synth_image(cfg.planes, size, size, cfg.pattern, cfg.seed);
    let model = OpenMpModel::new(cfg.threads);
    let mut arena = ScratchArena::new();
    let mut out = Table::new(
        format!(
            "Chain edge-policy sweep: {} stages on {size}x{size}x{} planes, {} threads",
            specs.len(),
            cfg.planes,
            cfg.threads
        ),
        &["Edge policies", "total ms", "est MiB moved", "vs materialized", ""],
    );
    let mut measured: Vec<(Vec<EdgePolicy>, f64, f64)> = Vec::new();
    for cand in chain_policy_candidates(specs.len()) {
        let graph = chain_graph(cfg.planes, size, size, specs, &cand)?;
        let ms = time_reps(
            || {
                graph.execute_on(&model, &img, &mut arena).expect("chain sweep execution");
            },
            cfg.warmup,
            cfg.reps,
        )
        .median();
        let mb = graph.traffic_estimate().total.total_mb();
        measured.push((cand, ms, mb));
    }
    let baseline_ms = measured[0].1;
    let best = measured
        .iter()
        .enumerate()
        .min_by(|a, b| a.1 .1.total_cmp(&b.1 .1))
        .map(|(i, _)| i)
        .unwrap_or(0);
    for (i, (cand, ms, mb)) in measured.iter().enumerate() {
        out.row(vec![
            chain_policy_label(cand),
            format!("{ms:.3}"),
            format!("{mb:.2}"),
            format!("{:.2}x", if *ms > 0.0 { baseline_ms / ms } else { 1.0 }),
            if i == best { "\u{25c0} tuned".to_string() } else { String::new() },
        ]);
    }
    Ok(out)
}

/// What a winner was tuned for.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TuneKey {
    /// execution-model name ("OpenMP" / "OpenCL" / "GPRM")
    pub model: String,
    pub planes: usize,
    pub rows: usize,
    pub cols: usize,
    pub kernel_width: usize,
}

/// A tuned winner plus the baseline it displaced.
#[derive(Debug, Clone)]
pub struct Tuned {
    pub candidate: Candidate,
    /// median ms of the winning configuration
    pub ms: f64,
    /// median ms of the untiled row-partition baseline
    pub baseline_ms: f64,
}

impl Tuned {
    /// ≥ 1.0 by construction: the baseline is always a candidate, so the
    /// winner beats or equals it (modulo its own measurement).
    pub fn speedup(&self) -> f64 {
        if self.ms > 0.0 {
            self.baseline_ms / self.ms
        } else {
            1.0
        }
    }
}

/// How a plan decision was reached: an exact swept winner from this
/// table, or a cost-model prediction for a never-swept shape.
#[derive(Debug, Clone, Copy)]
pub enum PlanDecision<'a> {
    /// Exact hit: this (model, shape, kernel) was empirically swept.
    Swept(&'a Tuned),
    /// Lookup miss, but the fitted cost model predicts a winner.
    Predicted(Prediction),
}

/// Small in-memory table of tuned winners, keyed by
/// (model, planes, rows, cols, kernel width), with an optional
/// cost-model predictive tier for lookup misses.
#[derive(Debug, Default)]
pub struct TuningTable {
    entries: HashMap<TuneKey, Tuned>,
    cost_model: Option<CostModel>,
}

impl TuningTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Record a winner (later sweeps at the same key overwrite).
    pub fn record(&mut self, key: TuneKey, tuned: Tuned) {
        self.entries.insert(key, tuned);
    }

    /// Install (or replace) the predictive tier consulted on lookup
    /// misses.
    pub fn set_cost_model(&mut self, cm: CostModel) {
        self.cost_model = Some(cm);
    }

    /// A table whose only tier is the given predictive model (no swept
    /// entries yet) — what `phi-conv serve --load` and the load
    /// harness install at coordinator start.
    pub fn from_cost_model(cm: CostModel) -> Self {
        let mut t = Self::new();
        t.set_cost_model(cm);
        t
    }

    pub fn cost_model(&self) -> Option<&CostModel> {
        self.cost_model.as_ref()
    }

    /// Tiered plan decision: an exact swept winner if this
    /// configuration was measured, else the cost model's predicted
    /// winner, else `None` — which means "sweep empirically" (no cost
    /// model installed, or its fit for this model's groups failed the
    /// R² gate).
    pub fn choose(
        &self,
        model: &str,
        planes: usize,
        rows: usize,
        cols: usize,
        kernel_width: usize,
        workers: usize,
    ) -> Option<PlanDecision<'_>> {
        if let Some(tuned) = self.lookup(model, planes, rows, cols, kernel_width) {
            return Some(PlanDecision::Swept(tuned));
        }
        let cm = self.cost_model.as_ref()?;
        cm.choose(model, planes, rows, cols, kernel_width, workers).map(PlanDecision::Predicted)
    }

    /// The tuned winner for a configuration, if one was swept.
    pub fn lookup(
        &self,
        model: &str,
        planes: usize,
        rows: usize,
        cols: usize,
        kernel_width: usize,
    ) -> Option<&Tuned> {
        self.entries.get(&TuneKey {
            model: model.to_string(),
            planes,
            rows,
            cols,
            kernel_width,
        })
    }

    /// Whether the tuned winner for a configuration is fused (`None` =
    /// never swept).
    pub fn fused_for(
        &self,
        model: &str,
        planes: usize,
        rows: usize,
        cols: usize,
        kernel_width: usize,
    ) -> Option<bool> {
        self.lookup(model, planes, rows, cols, kernel_width).map(|t| t.candidate.fused)
    }

    /// The tuned tile decomposition for a configuration (`Some(None)` =
    /// "tuned, and untiled won").
    pub fn tile_for(
        &self,
        model: &str,
        planes: usize,
        rows: usize,
        cols: usize,
        kernel_width: usize,
    ) -> Option<Option<TileSpec>> {
        self.lookup(model, planes, rows, cols, kernel_width).map(|t| t.candidate.tile)
    }

    /// Render the winners as a harness table (rows sorted for
    /// deterministic output).
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            "Tuning table: per-(model, shape, kernel) winners vs untiled row partition",
            &["Model", "Shape", "Kernel", "Tuned config", "ms", "Speedup vs untiled"],
        );
        let mut keys: Vec<&TuneKey> = self.entries.keys().collect();
        keys.sort_by_key(|k| (k.rows, k.cols, k.planes, k.kernel_width, k.model.clone()));
        for key in keys {
            let tuned = &self.entries[key];
            t.row(vec![
                key.model.clone(),
                format!("{}x{}x{}", key.planes, key.rows, key.cols),
                format!("w{}", key.kernel_width),
                tuned.candidate.label(),
                format!("{:.3}", tuned.ms),
                format!("{:.2}x", tuned.speedup()),
            ]);
        }
        t
    }
}

/// Sweep every candidate under all three models at one square image
/// size, render the paper-style agglomeration table, and record each
/// model's winner in `table`.
pub fn sweep_shape(cfg: &RunConfig, size: usize, table: &mut TuningTable) -> Result<Table> {
    sweep_shape_sampled(cfg, size, table, &mut Vec::new())
}

/// [`sweep_shape`], additionally appending one self-describing
/// [`Sample`] per (model, candidate) measurement to `samples` — the
/// training set [`CostModel::fit`](crate::costmodel::CostModel::fit)
/// consumes. Each sample carries the repeats, warmup, and worker count
/// it was measured under, so persisted sample sets can be audited or
/// re-fit without the config that produced them.
pub fn sweep_shape_sampled(
    cfg: &RunConfig,
    size: usize,
    table: &mut TuningTable,
    samples: &mut Vec<Sample>,
) -> Result<Table> {
    cfg.validate()?;
    let img = synth_image(cfg.planes, size, size, cfg.pattern, cfg.seed);
    let kernel = cfg.kernel_spec();
    let mut out = Table::new(
        format!(
            "Agglomeration sweep (measured): {size}x{size}x{} planes, {} threads, w{} kernel",
            cfg.planes, cfg.threads, cfg.kernel_width
        ),
        &["Model", "Config", "total ms", "empty-dispatch ms", "vs untiled", ""],
    );

    let openmp = OpenMpModel::new(cfg.threads);
    let opencl = OpenClModel::new(cfg.threads, 16);
    let gprm = GprmModel::new(cfg.threads, cfg.cutoff).with_agglomeration(cfg.agglomeration.max(1));
    // GPRM agglomeration is a model parameter, so agglomerated
    // candidates need their own instance; built lazily, one per factor
    let mut gprm_variants: HashMap<usize, GprmModel> = HashMap::new();

    for model_ix in 0..3usize {
        let base: &dyn ExecutionModel = match model_ix {
            0 => &openmp,
            1 => &opencl,
            _ => &gprm,
        };
        let is_gprm = model_ix == 2;
        let candidates = default_candidates(size, is_gprm);
        let mut arena = ScratchArena::new();
        let mut measured: Vec<(Candidate, f64, f64)> = Vec::with_capacity(candidates.len());
        for cand in candidates {
            let model: &dyn ExecutionModel = if is_gprm && cand.agglomeration > 1 {
                &*gprm_variants
                    .entry(cand.agglomeration)
                    .or_insert_with(|| gprm.respawn_with_agglomeration(cand.agglomeration))
            } else {
                base
            };
            let plan = ConvPlan::builder()
                .kernel(kernel)
                .kernel_class(cand.class)
                .tile_opt(cand.tile)
                .fuse(cand.fused)
                .shape(cfg.planes, size, size)
                .build()?;
            let ms = time_reps(
                || plan.execute_discard(Some(model), &img, &mut arena).expect("sweep execution"),
                cfg.warmup,
                cfg.reps,
            )
            .median();
            // the paper's empty-task probe at this candidate's granularity
            let overhead = match cand.tile {
                Some(tile) => {
                    model.overhead_probe2d(size, size, tile, cfg.warmup, cfg.reps).median()
                }
                None => model.overhead_probe_with(size, cfg.warmup, cfg.reps).median(),
            };
            samples.push(Sample {
                model: base.name().to_string(),
                planes: cfg.planes,
                rows: size,
                cols: size,
                kernel_width: cfg.kernel_width,
                class: cand.class.label().to_string(),
                tile: cand.tile,
                fused: cand.fused,
                agglomeration: cand.agglomeration,
                units: dispatch_units(size, size, cand.tile, model.workers()),
                workers: model.workers(),
                ms,
                reps: cfg.reps,
                warmup: cfg.warmup,
            });
            measured.push((cand, ms, overhead));
        }
        // baseline is always index 0 (untiled); winner = min total ms
        let baseline_ms = measured[0].1;
        let best = measured
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .1.total_cmp(&b.1 .1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        for (i, (cand, ms, overhead)) in measured.iter().enumerate() {
            out.row(vec![
                base.name().to_string(),
                cand.label(),
                format!("{ms:.3}"),
                format!("{overhead:.4}"),
                format!("{:.2}x", if *ms > 0.0 { baseline_ms / ms } else { 1.0 }),
                if i == best { "◀ tuned".to_string() } else { String::new() },
            ]);
        }
        let (cand, ms, _) = measured[best];
        table.record(
            TuneKey {
                model: base.name().to_string(),
                planes: cfg.planes,
                rows: size,
                cols: size,
                kernel_width: cfg.kernel_width,
            },
            Tuned { candidate: cand, ms, baseline_ms },
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> RunConfig {
        RunConfig { sizes: vec![40], reps: 1, warmup: 0, threads: 2, ..Default::default() }
    }

    #[test]
    fn candidates_start_from_untiled_baseline() {
        for gprm in [false, true] {
            let c = default_candidates(288, gprm);
            assert_eq!(c[0], Candidate::untiled(), "gprm={gprm}");
            assert!(c.len() >= 4);
            let has_agglomerated = c.iter().any(|x| x.agglomeration > 1);
            assert_eq!(has_agglomerated, gprm, "agglomeration is the GPRM knob");
            assert!(c.iter().any(|x| x.fused && x.tile.is_none()), "fused row bands swept");
            assert!(c.iter().any(|x| x.fused && x.tile.is_some()), "fused stripes swept");
            assert!(
                c.iter().any(|x| x.class == KernelClass::Direct2d),
                "direct-2D class swept"
            );
            assert!(c.iter().any(|x| x.class == KernelClass::Fft), "fft class swept");
        }
        // tiny images keep only the shapes that fit (plus the fused twin
        // of the baseline and the class alternatives, which fit whenever
        // the baseline does)
        let c = default_candidates(8, true);
        assert_eq!(
            c,
            vec![
                Candidate::untiled(),
                Candidate::untiled().fused_twin(),
                Candidate::untiled().with_class(KernelClass::Direct2d),
                Candidate::untiled().with_class(KernelClass::Fft),
            ]
        );
    }

    #[test]
    fn chain_policy_candidates_start_from_materialized_baseline() {
        // the baseline-first invariant extends to per-edge fuse sweeps
        assert_eq!(chain_policy_candidates(1), vec![Vec::<EdgePolicy>::new()]);
        let two = chain_policy_candidates(2);
        assert_eq!(two[0], vec![EdgePolicy::Materialized], "baseline first");
        assert_eq!(two, vec![vec![EdgePolicy::Materialized], vec![EdgePolicy::Streamed]]);
        let three = chain_policy_candidates(3);
        assert_eq!(three[0], vec![EdgePolicy::Materialized; 2]);
        assert_eq!(three[1], vec![EdgePolicy::Streamed; 2]);
        assert_eq!(three.len(), 4, "baseline + all-streamed + one split per edge");
        assert_eq!(chain_policy_label(&three[2]), "M\u{00b7}S");
        assert_eq!(chain_policy_label(&[]), "single stage");
    }

    #[test]
    fn chain_sweep_measures_every_candidate() {
        let cfg = tiny_cfg();
        let specs = [KernelSpec::new(3, 0.8), KernelSpec::new(5, 1.0), KernelSpec::new(7, 1.4)];
        let rendered = sweep_chain(&cfg, 40, &specs).unwrap();
        assert_eq!(rendered.n_rows(), 4, "one row per policy candidate");
        let text = rendered.to_text();
        assert!(text.contains("tuned"), "{text}");
        assert!(text.contains("S\u{00b7}S"), "{text}");
        assert!(sweep_chain(&cfg, 40, &[]).is_err());
    }

    #[test]
    fn candidate_labels() {
        assert_eq!(Candidate::untiled().label(), "rows (untiled)");
        assert_eq!(Candidate::untiled().fused_twin().label(), "rows (untiled) fused");
        let c = Candidate {
            class: KernelClass::Separable,
            tile: Some(TileSpec::new(16, usize::MAX)),
            agglomeration: 1,
            fused: false,
        };
        assert_eq!(c.label(), "16xfull");
        assert_eq!(c.fused_twin().label(), "16xfull fused");
        let c = Candidate {
            class: KernelClass::Separable,
            tile: Some(TileSpec::new(32, 32)),
            agglomeration: 4,
            fused: false,
        };
        assert_eq!(c.label(), "32x32 agg=4");
        assert_eq!(
            Candidate::untiled().with_class(KernelClass::Fft).label(),
            "rows (untiled) [fft]"
        );
        assert_eq!(
            Candidate::untiled().with_class(KernelClass::Direct2d).label(),
            "rows (untiled) [direct2d]"
        );
    }

    #[test]
    fn sweep_records_winners_no_worse_than_baseline() {
        let cfg = tiny_cfg();
        let mut table = TuningTable::new();
        let rendered = sweep_shape(&cfg, 40, &mut table).unwrap();
        assert!(rendered.n_rows() >= 3, "at least the three baselines");
        assert_eq!(table.len(), 3, "one winner per model");
        for model in ["OpenMP", "OpenCL", "GPRM"] {
            let tuned = table.lookup(model, 3, 40, 40, 5).unwrap_or_else(|| {
                panic!("missing winner for {model}")
            });
            assert!(
                tuned.ms <= tuned.baseline_ms,
                "{model}: winner {} ms vs baseline {} ms",
                tuned.ms,
                tuned.baseline_ms
            );
            assert!(tuned.speedup() >= 1.0);
        }
        assert!(table.tile_for("OpenMP", 3, 40, 40, 5).is_some());
        assert!(table.fused_for("OpenMP", 3, 40, 40, 5).is_some());
        assert!(table.lookup("OpenMP", 3, 41, 41, 5).is_none());
        assert!(table.fused_for("OpenMP", 3, 41, 41, 5).is_none());
        let summary = table.to_table();
        assert_eq!(summary.n_rows(), 3);
        assert!(summary.to_text().contains("GPRM"));
    }

    #[test]
    fn sweep_rejects_invalid_config() {
        let cfg = RunConfig { kernel_width: 4, ..tiny_cfg() };
        assert!(sweep_shape(&cfg, 40, &mut TuningTable::new()).is_err());
    }

    #[test]
    fn sweep_samples_are_self_describing() {
        let cfg = tiny_cfg();
        let mut table = TuningTable::new();
        let mut samples = Vec::new();
        let rendered = sweep_shape_sampled(&cfg, 40, &mut table, &mut samples).unwrap();
        assert_eq!(samples.len(), rendered.n_rows(), "one sample per measured row");
        for s in &samples {
            assert!(
                matches!(s.model.as_str(), "OpenMP" | "OpenCL" | "GPRM"),
                "unknown model {:?}",
                s.model
            );
            assert_eq!((s.planes, s.rows, s.cols), (cfg.planes, 40, 40));
            assert_eq!(s.kernel_width, cfg.kernel_width);
            assert_eq!(s.reps, cfg.reps, "samples carry the repeats they were measured under");
            assert_eq!(s.warmup, cfg.warmup, "samples carry the warmup they were measured under");
            assert_eq!(s.workers, cfg.threads);
            assert!(s.units >= 1);
            assert!(s.ms.is_finite() && s.ms >= 0.0);
            if s.tile.is_none() {
                assert_eq!(s.units, s.workers.max(1), "untiled units = one band per worker");
            }
        }
        // the untiled baseline sample exists for every model
        for model in ["OpenMP", "OpenCL", "GPRM"] {
            assert!(samples.iter().any(|s| s.model == model && s.tile.is_none() && !s.fused));
        }
        // every kernel class gets measured, so the fitted cost model can
        // place the direct-vs-fft crossover
        for class in ["separable", "direct2d", "fft"] {
            assert!(samples.iter().any(|s| s.class == class), "class {class} sampled");
        }
    }

    #[test]
    fn bench_env_warmup_matches_probe_warmup() {
        // `PHI_BENCH_WARMUP` must mean the same thing to the sweep's
        // timed runs (cfg.warmup) and to the overhead probes — both
        // funnel through `models::overhead_warmup()`. No env mutation
        // here: both sides read the same live environment.
        assert_eq!(RunConfig::from_bench_env().warmup, crate::models::overhead_warmup());
    }

    /// Noise-free linear samples for one model so choose() has a fitted
    /// predictive tier: fused+tiled is constructed 4x cheaper than the
    /// untiled baseline.
    fn synthetic_samples(model: &str) -> Vec<Sample> {
        let mut out = Vec::new();
        let tiles = [None, Some(TileSpec::new(16, usize::MAX)), Some(TileSpec::new(32, 32))];
        for (rows, cols) in [(64, 64), (80, 96), (96, 128), (128, 128), (160, 96), (192, 192)] {
            for width in [3usize, 5, 7] {
                for tile in tiles {
                    for fused in [false, true] {
                        let units = dispatch_units(rows, cols, tile, 4);
                        let pixels = (3 * rows * cols) as f64;
                        let base = 0.2 + 1.5e-6 * pixels + 2.0e-7 * pixels * width as f64
                            + 1e-3 * units as f64;
                        let mult = match (fused, tile.is_some()) {
                            (false, false) => 4.0,
                            (true, false) => 3.0,
                            (false, true) => 2.0,
                            (true, true) => 1.0,
                        };
                        out.push(Sample {
                            model: model.to_string(),
                            planes: 3,
                            rows,
                            cols,
                            kernel_width: width,
                            class: "separable".to_string(),
                            tile,
                            fused,
                            agglomeration: 1,
                            units,
                            workers: 4,
                            ms: base * mult,
                            reps: 3,
                            warmup: 1,
                        });
                    }
                }
            }
        }
        out
    }

    #[test]
    fn choose_tiers_swept_then_predicted_then_sweep_fallback() {
        let mut table = TuningTable::new();
        // tier 3: nothing installed → None → caller sweeps
        assert!(table.choose("OpenMP", 3, 100, 100, 5, 4).is_none());

        // tier 2: cost model predicts for the lookup miss
        table.set_cost_model(CostModel::fit(synthetic_samples("OpenMP"), 0.8));
        assert!(table.cost_model().is_some());
        match table.choose("OpenMP", 3, 100, 100, 5, 4) {
            Some(PlanDecision::Predicted(p)) => {
                assert!(p.candidate.fused && p.candidate.tile.is_some());
                assert!(p.ms <= p.baseline_ms);
            }
            other => panic!("expected Predicted, got {other:?}"),
        }
        // a model the fit never saw still falls back to sweeping
        assert!(table.choose("GPRM", 3, 100, 100, 5, 4).is_none());

        // tier 1: an exact swept entry takes precedence over prediction
        let key = TuneKey {
            model: "OpenMP".into(),
            planes: 3,
            rows: 100,
            cols: 100,
            kernel_width: 5,
        };
        let tuned = Tuned { candidate: Candidate::untiled(), ms: 9.0, baseline_ms: 9.0 };
        table.record(key, tuned);
        match table.choose("OpenMP", 3, 100, 100, 5, 4) {
            Some(PlanDecision::Swept(t)) => assert_eq!(t.candidate, Candidate::untiled()),
            other => panic!("expected Swept, got {other:?}"),
        }
    }
}
