//! Auto-tuning of tile granularity and task agglomeration.
//!
//! The paper's agglomeration experiment (section 6, Fig. 2 vs Fig. 3)
//! shows that the *granularity* handed to the scheduler — not the
//! scheduler itself — decides whether the task-based model competes with
//! the loop-based ones; Kepner's multi-threaded fast convolver
//! (astro-ph/0107084) reports the same tile-size trade-off for
//! dynamically parallel image filtering in general. This module makes
//! that trade-off a measured, queryable quantity:
//!
//! * [`default_candidates`] enumerates tile decompositions for a shape —
//!   always starting from the **untiled, unfused row-partition
//!   baseline**, so the tuned winner can only beat or equal it — plus
//!   fused two-pass twins (the rolling row-ring pipeline, `--fuse`) and,
//!   for GPRM, agglomerated variants where several tiles fuse into one
//!   task instance (the paper's cutoff knob re-expressed per tile).
//! * [`sweep_shape`] measures every candidate under all three execution
//!   models at one image shape (total ms via plan execution, fixed
//!   overhead via the empty-`dispatch2d` probe — the paper's Table-2
//!   methodology) and renders the sweep as a harness table mirroring the
//!   paper's agglomeration exhibit.
//! * [`TuningTable`] persists the per-(model, shape, kernel) winners in
//!   memory, for lookups by serving code and for the `phi-conv tune`
//!   subcommand's summary.
//!
//! Reproduce with `phi-conv tune` (sizes/reps/threads from the standard
//! config) or `cargo bench --bench tiling`.

use std::collections::HashMap;

use crate::util::error::Result;

use crate::config::RunConfig;
use crate::image::synth_image;
use crate::metrics::{time_reps, Table};
use crate::models::{ExecutionModel, GprmModel, OpenClModel, OpenMpModel, TileSpec};
use crate::plan::{ConvPlan, ScratchArena};

/// One execution configuration the tuner evaluates: a tile
/// decomposition (or untiled row bands), a GPRM agglomeration factor,
/// and whether the two-pass pipeline is fused (`--fuse`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Candidate {
    /// `None` = the untiled row-partition baseline.
    pub tile: Option<TileSpec>,
    /// Tiles fused per task instance (GPRM only; 1 elsewhere).
    pub agglomeration: usize,
    /// Fused rolling row-ring two-pass instead of separate passes.
    pub fused: bool,
}

impl Candidate {
    /// The untiled, unfused row-partition baseline every sweep starts
    /// from.
    pub fn untiled() -> Self {
        Self { tile: None, agglomeration: 1, fused: false }
    }

    /// The fused twin of a candidate.
    pub fn fused_twin(self) -> Self {
        Self { fused: true, ..self }
    }

    pub fn label(&self) -> String {
        let mut s = match self.tile {
            None => "rows (untiled)".to_string(),
            Some(t) if self.agglomeration > 1 => {
                format!("{} agg={}", t.label(), self.agglomeration)
            }
            Some(t) => t.label(),
        };
        if self.fused {
            s.push_str(" fused");
        }
        s
    }
}

/// Default candidate set for a `rows`-tall image: the untiled-unfused
/// baseline, its fused twin, full-width stripes (fused and unfused),
/// squares, and (when `gprm`) agglomerated variants of the finer
/// decompositions. Shapes that don't fit the image are dropped rather
/// than clamped so the sweep never measures duplicates. The baseline is
/// always index 0, so the tuned winner beats or equals it by
/// construction.
pub fn default_candidates(rows: usize, gprm: bool) -> Vec<Candidate> {
    let mut out = vec![Candidate::untiled(), Candidate::untiled().fused_twin()];
    let tiled = |rows: usize, cols: usize, agg: usize| Candidate {
        tile: Some(TileSpec::new(rows, cols)),
        agglomeration: agg,
        fused: false,
    };
    for r in [16usize, 64] {
        if r < rows {
            let stripe = tiled(r, usize::MAX, 1); // full-width stripes
            out.push(stripe);
            out.push(stripe.fused_twin());
        }
    }
    for s in [32usize, 128] {
        if s < rows {
            out.push(tiled(s, s, 1)); // squares
        }
    }
    if gprm {
        // the paper's knob: same tiles, coarser task instances
        for agg in [4usize, 16] {
            if 16 < rows {
                out.push(tiled(16, usize::MAX, agg));
            }
            if 32 < rows {
                out.push(tiled(32, 32, agg));
            }
        }
    }
    out
}

/// What a winner was tuned for.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TuneKey {
    /// execution-model name ("OpenMP" / "OpenCL" / "GPRM")
    pub model: String,
    pub planes: usize,
    pub rows: usize,
    pub cols: usize,
    pub kernel_width: usize,
}

/// A tuned winner plus the baseline it displaced.
#[derive(Debug, Clone)]
pub struct Tuned {
    pub candidate: Candidate,
    /// median ms of the winning configuration
    pub ms: f64,
    /// median ms of the untiled row-partition baseline
    pub baseline_ms: f64,
}

impl Tuned {
    /// ≥ 1.0 by construction: the baseline is always a candidate, so the
    /// winner beats or equals it (modulo its own measurement).
    pub fn speedup(&self) -> f64 {
        if self.ms > 0.0 {
            self.baseline_ms / self.ms
        } else {
            1.0
        }
    }
}

/// Small in-memory table of tuned winners, keyed by
/// (model, planes, rows, cols, kernel width).
#[derive(Debug, Default)]
pub struct TuningTable {
    entries: HashMap<TuneKey, Tuned>,
}

impl TuningTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Record a winner (later sweeps at the same key overwrite).
    pub fn record(&mut self, key: TuneKey, tuned: Tuned) {
        self.entries.insert(key, tuned);
    }

    /// The tuned winner for a configuration, if one was swept.
    pub fn lookup(
        &self,
        model: &str,
        planes: usize,
        rows: usize,
        cols: usize,
        kernel_width: usize,
    ) -> Option<&Tuned> {
        self.entries.get(&TuneKey {
            model: model.to_string(),
            planes,
            rows,
            cols,
            kernel_width,
        })
    }

    /// Whether the tuned winner for a configuration is fused (`None` =
    /// never swept).
    pub fn fused_for(
        &self,
        model: &str,
        planes: usize,
        rows: usize,
        cols: usize,
        kernel_width: usize,
    ) -> Option<bool> {
        self.lookup(model, planes, rows, cols, kernel_width).map(|t| t.candidate.fused)
    }

    /// The tuned tile decomposition for a configuration (`Some(None)` =
    /// "tuned, and untiled won").
    pub fn tile_for(
        &self,
        model: &str,
        planes: usize,
        rows: usize,
        cols: usize,
        kernel_width: usize,
    ) -> Option<Option<TileSpec>> {
        self.lookup(model, planes, rows, cols, kernel_width).map(|t| t.candidate.tile)
    }

    /// Render the winners as a harness table (rows sorted for
    /// deterministic output).
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            "Tuning table: per-(model, shape, kernel) winners vs untiled row partition",
            &["Model", "Shape", "Kernel", "Tuned config", "ms", "Speedup vs untiled"],
        );
        let mut keys: Vec<&TuneKey> = self.entries.keys().collect();
        keys.sort_by_key(|k| (k.rows, k.cols, k.planes, k.kernel_width, k.model.clone()));
        for key in keys {
            let tuned = &self.entries[key];
            t.row(vec![
                key.model.clone(),
                format!("{}x{}x{}", key.planes, key.rows, key.cols),
                format!("w{}", key.kernel_width),
                tuned.candidate.label(),
                format!("{:.3}", tuned.ms),
                format!("{:.2}x", tuned.speedup()),
            ]);
        }
        t
    }
}

/// Sweep every candidate under all three models at one square image
/// size, render the paper-style agglomeration table, and record each
/// model's winner in `table`.
pub fn sweep_shape(cfg: &RunConfig, size: usize, table: &mut TuningTable) -> Result<Table> {
    cfg.validate()?;
    let img = synth_image(cfg.planes, size, size, cfg.pattern, cfg.seed);
    let kernel = cfg.kernel_spec();
    let mut out = Table::new(
        format!(
            "Agglomeration sweep (measured): {size}x{size}x{} planes, {} threads, w{} kernel",
            cfg.planes, cfg.threads, cfg.kernel_width
        ),
        &["Model", "Config", "total ms", "empty-dispatch ms", "vs untiled", ""],
    );

    let openmp = OpenMpModel::new(cfg.threads);
    let opencl = OpenClModel::new(cfg.threads, 16);
    let gprm = GprmModel::new(cfg.threads, cfg.cutoff).with_agglomeration(cfg.agglomeration.max(1));
    // GPRM agglomeration is a model parameter, so agglomerated
    // candidates need their own instance; built lazily, one per factor
    let mut gprm_variants: HashMap<usize, GprmModel> = HashMap::new();

    for model_ix in 0..3usize {
        let base: &dyn ExecutionModel = match model_ix {
            0 => &openmp,
            1 => &opencl,
            _ => &gprm,
        };
        let is_gprm = model_ix == 2;
        let candidates = default_candidates(size, is_gprm);
        let mut arena = ScratchArena::new();
        let mut measured: Vec<(Candidate, f64, f64)> = Vec::with_capacity(candidates.len());
        for cand in candidates {
            let model: &dyn ExecutionModel = if is_gprm && cand.agglomeration > 1 {
                &*gprm_variants
                    .entry(cand.agglomeration)
                    .or_insert_with(|| gprm.respawn_with_agglomeration(cand.agglomeration))
            } else {
                base
            };
            let plan = ConvPlan::builder()
                .kernel(kernel)
                .tile_opt(cand.tile)
                .fuse(cand.fused)
                .shape(cfg.planes, size, size)
                .build()?;
            let ms = time_reps(
                || plan.execute_discard(Some(model), &img, &mut arena).expect("sweep execution"),
                cfg.warmup,
                cfg.reps,
            )
            .median();
            // the paper's empty-task probe at this candidate's granularity
            let overhead = match cand.tile {
                Some(tile) => {
                    model.overhead_probe2d(size, size, tile, cfg.warmup, cfg.reps).median()
                }
                None => model.overhead_probe_with(size, cfg.warmup, cfg.reps).median(),
            };
            measured.push((cand, ms, overhead));
        }
        // baseline is always index 0 (untiled); winner = min total ms
        let baseline_ms = measured[0].1;
        let best = measured
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .1.total_cmp(&b.1 .1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        for (i, (cand, ms, overhead)) in measured.iter().enumerate() {
            out.row(vec![
                base.name().to_string(),
                cand.label(),
                format!("{ms:.3}"),
                format!("{overhead:.4}"),
                format!("{:.2}x", if *ms > 0.0 { baseline_ms / ms } else { 1.0 }),
                if i == best { "◀ tuned".to_string() } else { String::new() },
            ]);
        }
        let (cand, ms, _) = measured[best];
        table.record(
            TuneKey {
                model: base.name().to_string(),
                planes: cfg.planes,
                rows: size,
                cols: size,
                kernel_width: cfg.kernel_width,
            },
            Tuned { candidate: cand, ms, baseline_ms },
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> RunConfig {
        RunConfig { sizes: vec![40], reps: 1, warmup: 0, threads: 2, ..Default::default() }
    }

    #[test]
    fn candidates_start_from_untiled_baseline() {
        for gprm in [false, true] {
            let c = default_candidates(288, gprm);
            assert_eq!(c[0], Candidate::untiled(), "gprm={gprm}");
            assert!(c.len() >= 4);
            let has_agglomerated = c.iter().any(|x| x.agglomeration > 1);
            assert_eq!(has_agglomerated, gprm, "agglomeration is the GPRM knob");
            assert!(c.iter().any(|x| x.fused && x.tile.is_none()), "fused row bands swept");
            assert!(c.iter().any(|x| x.fused && x.tile.is_some()), "fused stripes swept");
        }
        // tiny images keep only the shapes that fit (plus the fused twin
        // of the baseline, which fits whenever the baseline does)
        let c = default_candidates(8, true);
        assert_eq!(c, vec![Candidate::untiled(), Candidate::untiled().fused_twin()]);
    }

    #[test]
    fn candidate_labels() {
        assert_eq!(Candidate::untiled().label(), "rows (untiled)");
        assert_eq!(Candidate::untiled().fused_twin().label(), "rows (untiled) fused");
        let c = Candidate {
            tile: Some(TileSpec::new(16, usize::MAX)),
            agglomeration: 1,
            fused: false,
        };
        assert_eq!(c.label(), "16xfull");
        assert_eq!(c.fused_twin().label(), "16xfull fused");
        let c = Candidate { tile: Some(TileSpec::new(32, 32)), agglomeration: 4, fused: false };
        assert_eq!(c.label(), "32x32 agg=4");
    }

    #[test]
    fn sweep_records_winners_no_worse_than_baseline() {
        let cfg = tiny_cfg();
        let mut table = TuningTable::new();
        let rendered = sweep_shape(&cfg, 40, &mut table).unwrap();
        assert!(rendered.n_rows() >= 3, "at least the three baselines");
        assert_eq!(table.len(), 3, "one winner per model");
        for model in ["OpenMP", "OpenCL", "GPRM"] {
            let tuned = table.lookup(model, 3, 40, 40, 5).unwrap_or_else(|| {
                panic!("missing winner for {model}")
            });
            assert!(
                tuned.ms <= tuned.baseline_ms,
                "{model}: winner {} ms vs baseline {} ms",
                tuned.ms,
                tuned.baseline_ms
            );
            assert!(tuned.speedup() >= 1.0);
        }
        assert!(table.tile_for("OpenMP", 3, 40, 40, 5).is_some());
        assert!(table.fused_for("OpenMP", 3, 40, 40, 5).is_some());
        assert!(table.lookup("OpenMP", 3, 41, 41, 5).is_none());
        assert!(table.fused_for("OpenMP", 3, 41, 41, 5).is_none());
        let summary = table.to_table();
        assert_eq!(summary.n_rows(), 3);
        assert!(summary.to_text().contains("GPRM"));
    }

    #[test]
    fn sweep_rejects_invalid_config() {
        let cfg = RunConfig { kernel_width: 4, ..tiny_cfg() };
        assert!(sweep_shape(&cfg, 40, &mut TuningTable::new()).is_err());
    }
}
