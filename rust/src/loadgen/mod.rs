//! Scale-factor load harness: deterministic traffic mixes driving the
//! real coordinator end-to-end, with latency-SLO reporting.
//!
//! The paper measures kernels in isolation; a serving system earns its
//! claims under traffic. This module turns a single integer — the
//! *scale factor* — into a reproducible production-shaped workload
//! (clickgraph-style planning: every knob is `scale × constant`):
//!
//! * [`MixConfig`] — the traffic model: a Zipf-skewed shape set,
//!   kernel-width distribution, graph-request fraction, deadlines,
//!   arrival rate. All deterministic from a seed.
//! * [`RequestPlan`] — the materialised schedule for one
//!   `(mix, scale)` pair; [`RequestPlan::digest`] is the regression
//!   handle for "same seed ⇒ same schedule".
//! * [`run_scales`]/[`run_mode`] — drive a fresh [`Coordinator`]
//!   (open-loop Poisson pacing or closed-loop workers), classify every
//!   request as served / shed / expired, and snapshot the
//!   coordinator's queue/batch/plan-decision counters.
//! * [`report_table`]/[`results_json`] — the per-scale p50/p95/p99
//!   table and the `BENCH_load.json` document.
//!
//! Consumers: `phi-conv load`, `benches/loadgen.rs`,
//! `tests/loadgen.rs` (tier-1), and the mixed-traffic leg of
//! `tests/queue_stress.rs`.
//!
//! [`Coordinator`]: crate::coordinator::Coordinator

mod drive;
mod mix;

pub use drive::{report_table, result_json, results_json, run_mode, run_scales, LoadResult, Mode};
pub use mix::{default_sigma, zipf_weights, MixConfig, PlannedRequest, RequestPlan, Shape};
