//! Drivers and reporting: issue a [`RequestPlan`] against a live
//! [`Coordinator`] and account for every request.
//!
//! Two driver models, the standard pair for serving benchmarks:
//!
//! * **open loop** — requests arrive on the plan's virtual timeline
//!   (Poisson inter-arrival, paced against a monotonic clock that is
//!   never reset, so a slow server faces a growing backlog instead of
//!   a conveniently slowed generator). Admission is `try_submit`:
//!   a full queue sheds, exactly as production overload would.
//! * **closed loop** — `plan.workers` clients each keep one request in
//!   flight (blocking `submit`, then wait for the reply), the
//!   think-time-free saturation model.
//!
//! Latency semantics differ deliberately: the open-loop driver records
//! the server-side `queue_ms + service_ms` (client-perceived arrival
//! pacing is virtual), while closed-loop workers record client wall
//! time around submit→reply. Every issued request resolves to exactly
//! one of served / shed / expired / failed, and the suites assert
//! `failed == 0` — refusals must be structured.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::autotune::TuningTable;
use crate::config::RunConfig;
use crate::coordinator::{ConvRequest, Coordinator, CoordinatorStats, RoutePolicy};
use crate::costmodel::CostModel;
use crate::metrics::{Histogram, SampleSet, Table};
use crate::util::error::{ErrorKind, Result};
use crate::util::json::Json;

use super::mix::{MixConfig, RequestPlan};

/// Driver model for one run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Open,
    Closed,
}

impl Mode {
    pub fn label(&self) -> &'static str {
        match self {
            Mode::Open => "open",
            Mode::Closed => "closed",
        }
    }

    /// CLI/env spelling → run list: `open`, `closed` or `both`.
    pub fn parse(s: &str) -> Result<Vec<Mode>> {
        match s {
            "open" => Ok(vec![Mode::Open]),
            "closed" => Ok(vec![Mode::Closed]),
            "both" | "" => Ok(vec![Mode::Open, Mode::Closed]),
            other => bail!("unknown load mode {other:?} (open|closed|both)"),
        }
    }
}

/// Everything measured for one `(scale, mode)` run.
#[derive(Debug)]
pub struct LoadResult {
    pub scale: usize,
    pub mode: Mode,
    pub issued: usize,
    pub served: u64,
    pub shed: u64,
    pub expired: u64,
    /// refusals without a structured QueueFull/DeadlineExceeded kind —
    /// always 0 in a healthy run (asserted by the suites).
    pub failed: u64,
    /// exact per-request latencies (ms).
    pub latency: SampleSet,
    /// the same latencies, histogram-bucketed (what reporting quotes).
    pub hist: Histogram,
    pub wall_ms: f64,
    /// coordinator counters snapshot after the drain.
    pub stats: CoordinatorStats,
    pub plan_digest: u64,
}

impl LoadResult {
    /// served + shed + expired + failed — must equal `issued`.
    pub fn resolved(&self) -> u64 {
        self.served + self.shed + self.expired + self.failed
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.wall_ms > 0.0 {
            self.served as f64 / (self.wall_ms / 1e3)
        } else {
            0.0
        }
    }
}

/// Per-request outcome accumulator shared by both drivers.
#[derive(Default)]
struct Tally {
    served: u64,
    shed: u64,
    expired: u64,
    failed: u64,
    latency: SampleSet,
    hist: Histogram,
}

impl Tally {
    fn refusal(&mut self, kind: ErrorKind) {
        match kind {
            ErrorKind::QueueFull => self.shed += 1,
            ErrorKind::DeadlineExceeded => self.expired += 1,
            _ => self.failed += 1,
        }
    }

    fn served_ms(&mut self, ms: f64) {
        self.served += 1;
        self.latency.push(ms);
        self.hist.record(ms);
    }
}

/// Open loop: pace submissions on the plan's virtual arrival times,
/// shed on overflow, then drain every admitted reply.
fn drive_open(coord: &Coordinator, plan: &RequestPlan, cfg: &RunConfig) -> (Tally, f64) {
    let reqs = plan.realize(cfg.pattern);
    let mut tally = Tally::default();
    let mut pending = Vec::with_capacity(reqs.len());
    let t0 = Instant::now();
    for (req, planned) in reqs.into_iter().zip(&plan.requests) {
        let target = Duration::from_micros(planned.arrival_us);
        let now = t0.elapsed();
        if now < target {
            std::thread::sleep(target - now);
        }
        match coord.try_submit(req) {
            Ok(rx) => pending.push(rx),
            Err(e) => tally.refusal(e.kind()),
        }
    }
    for rx in pending {
        match rx.recv() {
            Ok(Ok(resp)) => tally.served_ms(resp.latency_ms()),
            Ok(Err(e)) => tally.refusal(e.kind()),
            Err(_) => tally.failed += 1,
        }
    }
    (tally, t0.elapsed().as_secs_f64() * 1e3)
}

/// Closed loop: `plan.workers` clients, each submitting its round-robin
/// slice of the plan one request at a time (blocking admission).
fn drive_closed(coord: &Coordinator, plan: &RequestPlan, cfg: &RunConfig) -> (Tally, f64) {
    let reqs = plan.realize(cfg.pattern);
    let workers = plan.workers.max(1);
    // round-robin lanes preserve plan order within each worker
    let mut lanes: Vec<Vec<ConvRequest>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, req) in reqs.into_iter().enumerate() {
        lanes[i % workers].push(req);
    }
    let shared = Mutex::new(Tally::default());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for lane in lanes {
            let shared = &shared;
            s.spawn(move || {
                for req in lane {
                    let t = Instant::now();
                    match coord.submit(req) {
                        Ok(rx) => match rx.recv() {
                            Ok(Ok(_resp)) => {
                                let ms = t.elapsed().as_secs_f64() * 1e3;
                                lock(shared).served_ms(ms);
                            }
                            Ok(Err(e)) => lock(shared).refusal(e.kind()),
                            Err(_) => lock(shared).failed += 1,
                        },
                        Err(e) => lock(shared).refusal(e.kind()),
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64() * 1e3;
    (shared.into_inner().unwrap_or_else(|e| e.into_inner()), wall)
}

fn lock(m: &Mutex<Tally>) -> std::sync::MutexGuard<'_, Tally> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One `(plan, mode)` run against a fresh coordinator.
///
/// The tuning tier is installed unconditionally (with the given cost
/// model when there is one), so the plan-decision counters are always
/// live: an untuned run reports everything as `default`, a model-backed
/// run splits into `predicted`/`default`. Routing is the adaptive
/// paper policy — per-shape deterministic, so batching keys stay
/// coherent (round-robin would scatter equal requests across backends
/// and defeat the coalescing the mix is built to exercise).
pub fn run_mode(
    cfg: &RunConfig,
    plan: &RequestPlan,
    mode: Mode,
    executors: usize,
    cost_model: Option<&CostModel>,
) -> Result<LoadResult> {
    let mut coord = Coordinator::new(cfg, RoutePolicy::paper_default(), executors, false)?;
    let tuning = match cost_model {
        Some(cm) => TuningTable::from_cost_model(cm.clone()),
        None => TuningTable::new(),
    };
    coord.set_tuning(tuning);
    let (tally, wall_ms) = match mode {
        Mode::Open => drive_open(&coord, plan, cfg),
        Mode::Closed => drive_closed(&coord, plan, cfg),
    };
    // every reply was received above, so executor stat shards are final
    let stats = coord.stats();
    Ok(LoadResult {
        scale: plan.scale,
        mode,
        issued: plan.issued(),
        served: tally.served,
        shed: tally.shed,
        expired: tally.expired,
        failed: tally.failed,
        latency: tally.latency,
        hist: tally.hist,
        wall_ms,
        stats,
        plan_digest: plan.digest(),
    })
}

/// The full sweep: one plan per scale factor, one fresh coordinator
/// per `(scale, mode)` run so runs never share queue state.
pub fn run_scales(
    cfg: &RunConfig,
    mix: &MixConfig,
    scales: &[usize],
    modes: &[Mode],
    executors: usize,
    cost_model: Option<&CostModel>,
) -> Result<Vec<LoadResult>> {
    ensure!(!scales.is_empty(), "no scale factors given");
    ensure!(!modes.is_empty(), "no load modes given");
    let mut out = Vec::with_capacity(scales.len() * modes.len());
    for &scale in scales {
        let plan = RequestPlan::generate(mix, scale)?;
        for &mode in modes {
            out.push(run_mode(cfg, &plan, mode, executors, cost_model)?);
        }
    }
    Ok(out)
}

fn fmt_p(v: Option<f64>) -> String {
    match v {
        Some(x) => format!("{x:.3}"),
        None => "-".to_string(),
    }
}

/// The per-scale SLO table (`phi-conv load` output).
pub fn report_table(results: &[LoadResult]) -> Table {
    let mut t = Table::new(
        "Load harness: latency SLOs per scale factor",
        &[
            "scale",
            "mode",
            "issued",
            "served",
            "shed",
            "expired",
            "p50 ms",
            "p95 ms",
            "p99 ms",
            "req/s",
            "depth peak",
            "batch avg/max",
            "plans p/s/d",
        ],
    );
    for r in results {
        let batch_mix = if r.stats.batch_sizes.is_empty() {
            "-".to_string()
        } else {
            format!("{:.2}/{:.0}", r.stats.batch_sizes.mean(), r.stats.batch_sizes.max())
        };
        t.row(vec![
            r.scale.to_string(),
            r.mode.label().to_string(),
            r.issued.to_string(),
            r.served.to_string(),
            r.shed.to_string(),
            r.expired.to_string(),
            fmt_p(r.hist.percentile(50.0)),
            fmt_p(r.hist.percentile(95.0)),
            fmt_p(r.hist.percentile(99.0)),
            format!("{:.0}", r.throughput_rps()),
            r.stats.depth_peak.to_string(),
            batch_mix,
            format!(
                "{}/{}/{}",
                r.stats.plans_predicted, r.stats.plans_swept, r.stats.plans_default
            ),
        ]);
    }
    t
}

fn opt_num(v: Option<f64>) -> Json {
    match v {
        Some(x) if x.is_finite() => Json::Num(x),
        _ => Json::Null,
    }
}

/// One result as JSON (an element of `BENCH_load.json`'s `scales`).
pub fn result_json(r: &LoadResult) -> Json {
    let mut o = std::collections::BTreeMap::new();
    o.insert("scale".to_string(), Json::Num(r.scale as f64));
    o.insert("mode".to_string(), Json::Str(r.mode.label().to_string()));
    o.insert("issued".to_string(), Json::Num(r.issued as f64));
    o.insert("served".to_string(), Json::Num(r.served as f64));
    o.insert("shed".to_string(), Json::Num(r.shed as f64));
    o.insert("expired".to_string(), Json::Num(r.expired as f64));
    o.insert("failed".to_string(), Json::Num(r.failed as f64));
    o.insert("p50_ms".to_string(), opt_num(r.hist.percentile(50.0)));
    o.insert("p95_ms".to_string(), opt_num(r.hist.percentile(95.0)));
    o.insert("p99_ms".to_string(), opt_num(r.hist.percentile(99.0)));
    o.insert("mean_ms".to_string(), opt_num(r.hist.mean()));
    o.insert("max_ms".to_string(), opt_num(r.hist.max()));
    o.insert("wall_ms".to_string(), opt_num(Some(r.wall_ms)));
    o.insert("req_per_s".to_string(), opt_num(Some(r.throughput_rps())));
    // u64 digests exceed 2^53 — a JSON number would round; hex string
    o.insert("plan_digest".to_string(), Json::Str(format!("{:016x}", r.plan_digest)));
    o.insert("stats".to_string(), r.stats.to_json());
    Json::Obj(o)
}

/// The whole run as JSON: the mix block (so a reader can reproduce the
/// schedule) plus one entry per `(scale, mode)` result.
pub fn results_json(
    mix: &MixConfig,
    cfg: &RunConfig,
    executors: usize,
    results: &[LoadResult],
) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert(
        "shapes".to_string(),
        Json::Arr(mix.shapes().iter().map(|s| Json::Str(s.label())).collect()),
    );
    m.insert("zipf_s".to_string(), Json::Num(mix.zipf_s));
    m.insert(
        "widths".to_string(),
        Json::Arr(mix.widths.iter().map(|&w| Json::Num(w as f64)).collect()),
    );
    m.insert(
        "tail_widths".to_string(),
        Json::Arr(mix.tail_widths.iter().map(|&w| Json::Num(w as f64)).collect()),
    );
    m.insert("tail_fraction".to_string(), Json::Num(mix.tail_fraction));
    m.insert("direct2d_fraction".to_string(), Json::Num(mix.direct2d_fraction));
    m.insert("graph_fraction".to_string(), Json::Num(mix.graph_fraction));
    m.insert("deadline_ms".to_string(), Json::Num(mix.deadline_ms as f64));
    m.insert("requests_per_scale".to_string(), Json::Num(mix.requests_per_scale as f64));
    m.insert("rate_per_s".to_string(), Json::Num(mix.rate_per_s));

    let mut root = std::collections::BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("load".to_string()));
    root.insert("seed".to_string(), Json::Num(mix.seed as f64));
    root.insert("threads".to_string(), Json::Num(cfg.threads as f64));
    root.insert("executors".to_string(), Json::Num(executors as f64));
    root.insert("batch_max".to_string(), Json::Num(cfg.batch_max as f64));
    root.insert("queue_capacity".to_string(), Json::Num(cfg.queue_capacity as f64));
    root.insert("mix".to_string(), Json::Obj(m));
    root.insert("scales".to_string(), Json::Arr(results.iter().map(result_json).collect()));
    Json::Obj(root)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_spellings() {
        assert_eq!(Mode::parse("open").unwrap(), vec![Mode::Open]);
        assert_eq!(Mode::parse("closed").unwrap(), vec![Mode::Closed]);
        assert_eq!(Mode::parse("both").unwrap(), vec![Mode::Open, Mode::Closed]);
        assert_eq!(Mode::parse("").unwrap(), vec![Mode::Open, Mode::Closed]);
        assert!(Mode::parse("sideways").is_err());
    }

    #[test]
    fn empty_sweeps_are_rejected() {
        let cfg = RunConfig::default();
        let mix = MixConfig::default();
        assert!(run_scales(&cfg, &mix, &[], &[Mode::Open], 1, None).is_err());
        assert!(run_scales(&cfg, &mix, &[1], &[], 1, None).is_err());
    }
}
