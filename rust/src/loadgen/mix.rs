//! Deterministic traffic-mix generation.
//!
//! A scale factor maps to a request schedule through seeded PRNG draws
//! only — no wall-clock, no host entropy — so the same
//! `(seed, scale)` pair always yields byte-identical plans
//! ([`RequestPlan::digest`] is the regression handle). The mix models
//! the traffic a convolution service actually sees:
//!
//! * a small shape set with Zipf-skewed popularity (shape 0 is the hot
//!   shape), so plan-keyed batching and shard affinity are exercised
//!   rather than defeated by uniform traffic;
//! * a kernel-width distribution over odd widths;
//! * a fraction of multi-stage graph requests;
//! * per-request deadlines and Poisson (exponential inter-arrival)
//!   virtual arrival times for the open-loop driver.

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::time::Duration;

use crate::coordinator::{ConvRequest, GraphSpec};
use crate::image::{synth_image, Pattern, PlanarImage};
use crate::plan::{KernelClass, KernelSpec};
use crate::util::error::Result;
use crate::util::prng::Prng;

/// Knobs of the traffic model. Scale-factor mapping (Snippet-2 style:
/// every formula is `scale × constant`):
///
/// * requests issued  = `requests_per_scale × scale`
/// * open-loop rate   = `rate_per_s × scale` (requests per second)
/// * closed-loop size = `workers_base + scale` workers (capped at 16)
///
/// The shape set itself is derived from `seed` alone, so the same mix
/// serves comparable request populations at every scale factor.
#[derive(Debug, Clone, PartialEq)]
pub struct MixConfig {
    /// PRNG seed for shapes and the request stream.
    pub seed: u64,
    /// planes per image (the paper's exhibits use 3).
    pub planes: usize,
    /// number of distinct shapes; shape 0 is the hot shape.
    pub shape_count: usize,
    /// square-ish shape bounds: rows and cols drawn from [min, max].
    pub min_size: usize,
    pub max_size: usize,
    /// Zipf exponent for shape popularity (0 = uniform; larger =
    /// more skew toward shape 0).
    pub zipf_s: f64,
    /// candidate kernel widths (odd, ≥ 3).
    pub widths: Vec<usize>,
    /// large-kernel tail widths (odd, ≥ 3, < min_size) — drawn instead
    /// of `widths` for `tail_fraction` of requests, so the serving path
    /// exercises the direct-vs-FFT crossover on realistic traffic.
    pub tail_widths: Vec<usize>,
    /// fraction of requests drawing their width from the tail.
    pub tail_fraction: f64,
    /// fraction of single-kernel requests pinned to the direct 2-D
    /// class (exercises the generic-kernel engines under load).
    pub direct2d_fraction: f64,
    /// fraction of requests carrying a 2–3 stage graph chain.
    pub graph_fraction: f64,
    /// per-request deadline (0 = no deadline).
    pub deadline_ms: u64,
    /// requests issued per unit of scale factor.
    pub requests_per_scale: usize,
    /// open-loop arrival rate per unit of scale factor (req/s).
    pub rate_per_s: f64,
    /// closed-loop worker baseline (workers = base + scale).
    pub workers_base: usize,
}

impl Default for MixConfig {
    fn default() -> Self {
        Self {
            seed: 20170710,
            planes: 3,
            shape_count: 5,
            min_size: 48,
            max_size: 160,
            zipf_s: 1.1,
            widths: vec![3, 5, 7, 9],
            tail_widths: vec![11, 17, 25],
            tail_fraction: 0.1,
            direct2d_fraction: 0.1,
            graph_fraction: 0.15,
            deadline_ms: 1000,
            requests_per_scale: 32,
            rate_per_s: 200.0,
            workers_base: 2,
        }
    }
}

impl MixConfig {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.planes >= 1, "mix: planes must be >= 1");
        ensure!(self.shape_count >= 1, "mix: shape_count must be >= 1");
        ensure!(
            self.min_size >= 16 && self.min_size <= self.max_size,
            "mix: need 16 <= min_size <= max_size, got [{}, {}]",
            self.min_size,
            self.max_size
        );
        ensure!(!self.widths.is_empty(), "mix: widths is empty");
        for &w in self.widths.iter().chain(&self.tail_widths) {
            ensure!(w % 2 == 1 && w >= 3, "mix: kernel width {w} must be odd and >= 3");
            ensure!(w < self.min_size, "mix: kernel width {w} exceeds min_size {}", self.min_size);
        }
        ensure!(
            (0.0..=1.0).contains(&self.tail_fraction),
            "mix: tail_fraction must be in [0, 1], got {}",
            self.tail_fraction
        );
        ensure!(
            self.tail_fraction == 0.0 || !self.tail_widths.is_empty(),
            "mix: tail_fraction > 0 needs tail_widths"
        );
        ensure!(
            (0.0..=1.0).contains(&self.direct2d_fraction),
            "mix: direct2d_fraction must be in [0, 1], got {}",
            self.direct2d_fraction
        );
        ensure!(
            self.zipf_s.is_finite() && self.zipf_s >= 0.0,
            "mix: zipf_s must be finite and >= 0"
        );
        ensure!(
            (0.0..=1.0).contains(&self.graph_fraction),
            "mix: graph_fraction must be in [0, 1], got {}",
            self.graph_fraction
        );
        ensure!(self.requests_per_scale >= 1, "mix: requests_per_scale must be >= 1");
        ensure!(
            self.rate_per_s.is_finite() && self.rate_per_s > 0.0,
            "mix: rate_per_s must be finite and > 0"
        );
        ensure!(self.workers_base >= 1, "mix: workers_base must be >= 1");
        Ok(())
    }

    pub fn requests_for(&self, scale: usize) -> usize {
        self.requests_per_scale * scale
    }

    pub fn rate_for(&self, scale: usize) -> f64 {
        self.rate_per_s * scale as f64
    }

    pub fn workers_for(&self, scale: usize) -> usize {
        (self.workers_base + scale).min(16)
    }

    /// The shape set, derived from `seed` alone (stable across scale
    /// factors, so per-scale results compare like for like). Shapes
    /// are drawn distinct where the bounds allow it.
    pub fn shapes(&self) -> Vec<Shape> {
        let mut rng = Prng::new(self.seed ^ 0x5148_4150_4553); // "SHAPES"
        let mut out: Vec<Shape> = Vec::with_capacity(self.shape_count);
        for _ in 0..self.shape_count {
            let mut shape = Shape {
                planes: self.planes,
                rows: rng.range(self.min_size, self.max_size),
                cols: rng.range(self.min_size, self.max_size),
            };
            // bounded dedup: small bound spans may not have
            // shape_count distinct pairs, so give up after 16 tries
            for _ in 0..16 {
                if !out.contains(&shape) {
                    break;
                }
                shape.rows = rng.range(self.min_size, self.max_size);
                shape.cols = rng.range(self.min_size, self.max_size);
            }
            out.push(shape);
        }
        out
    }
}

/// One entry of the mix's shape set.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Shape {
    pub planes: usize,
    pub rows: usize,
    pub cols: usize,
}

impl Shape {
    pub fn pixels(&self) -> usize {
        self.planes * self.rows * self.cols
    }

    pub fn label(&self) -> String {
        format!("{}x{}x{}", self.planes, self.rows, self.cols)
    }
}

/// Normalised Zipf weights over `n` ranks: `w_i ∝ 1/(i+1)^s`.
pub fn zipf_weights(n: usize, s: f64) -> Vec<f64> {
    let raw: Vec<f64> = (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(s)).collect();
    let total: f64 = raw.iter().sum();
    raw.into_iter().map(|w| w / total).collect()
}

/// Default Gaussian scale for a drawn width (the kernel covers ±2.5σ —
/// same rule as the `graph` subcommand's stages).
pub fn default_sigma(width: usize) -> f64 {
    (width as f64 / 5.0).max(0.5)
}

/// One request of the schedule, in plan form (no image data — shapes
/// are indices into the plan's shape set until [`RequestPlan::realize`]).
#[derive(Debug, Clone, PartialEq)]
pub struct PlannedRequest {
    pub id: u64,
    /// index into [`RequestPlan::shapes`].
    pub shape: usize,
    /// single-stage kernel (ignored when `graph` is set).
    pub kernel: KernelSpec,
    /// pinned kernel class for single-stage requests (`None` lets the
    /// coordinator's tuning tier pick the class per shape).
    pub kernel_class: Option<KernelClass>,
    /// multi-stage chain for graph requests.
    pub graph: Option<Vec<KernelSpec>>,
    pub deadline_ms: u64,
    /// virtual arrival offset from the run start (open-loop pacing).
    pub arrival_us: u64,
}

/// The full deterministic schedule for one `(mix, scale)` pair.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestPlan {
    pub scale: usize,
    pub seed: u64,
    pub shapes: Vec<Shape>,
    /// Zipf popularity of each shape (sums to 1; index 0 is hot).
    pub weights: Vec<f64>,
    pub requests: Vec<PlannedRequest>,
    /// open-loop arrival rate for this scale (req/s).
    pub rate_per_s: f64,
    /// closed-loop worker count for this scale.
    pub workers: usize,
}

impl RequestPlan {
    /// Derive the schedule. Deterministic: PRNG draws only, seeded
    /// from `(mix.seed, scale)` — same inputs, same plan, bitwise.
    pub fn generate(mix: &MixConfig, scale: usize) -> Result<RequestPlan> {
        mix.validate()?;
        ensure!(scale >= 1, "scale factor must be >= 1, got {scale}");
        let shapes = mix.shapes();
        let weights = zipf_weights(shapes.len(), mix.zipf_s);
        let cum: Vec<f64> = weights
            .iter()
            .scan(0.0, |acc, w| {
                *acc += w;
                Some(*acc)
            })
            .collect();

        let n = mix.requests_for(scale);
        let mean_gap_us = 1e6 / mix.rate_for(scale);
        let mut rng = Prng::new(mix.seed ^ (scale as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut arrival = 0f64;
        let mut requests = Vec::with_capacity(n);
        for id in 0..n as u64 {
            let u = rng.f32() as f64;
            let shape = cum.iter().position(|&c| u < c).unwrap_or(shapes.len() - 1);
            let tail =
                !mix.tail_widths.is_empty() && (rng.f32() as f64) < mix.tail_fraction;
            let width =
                if tail { *rng.pick(&mix.tail_widths) } else { *rng.pick(&mix.widths) };
            let kernel = KernelSpec::new(width, default_sigma(width));
            let graph = if (rng.f32() as f64) < mix.graph_fraction {
                let stages = rng.range(2, 3);
                Some(
                    (0..stages)
                        .map(|_| {
                            let w = *rng.pick(&mix.widths);
                            KernelSpec::new(w, default_sigma(w))
                        })
                        .collect::<Vec<_>>(),
                )
            } else {
                None
            };
            // class pinning only applies to single-stage requests
            // (graph stages are separable chains by construction); the
            // draw happens unconditionally so skipping it for graph
            // requests does not shift every later request's stream
            let pin = (rng.f32() as f64) < mix.direct2d_fraction;
            let kernel_class =
                if pin && graph.is_none() { Some(KernelClass::Direct2d) } else { None };
            // Poisson arrivals: exponential inter-arrival gaps,
            // −ln(1−u)·mean with u ∈ [0,1) so the log argument is
            // in (0,1] and the gap is finite and ≥ 0
            let u = rng.f32() as f64;
            arrival += -(1.0 - u).ln() * mean_gap_us;
            requests.push(PlannedRequest {
                id,
                shape,
                kernel,
                kernel_class,
                graph,
                deadline_ms: mix.deadline_ms,
                arrival_us: arrival as u64,
            });
        }
        Ok(RequestPlan {
            scale,
            seed: mix.seed,
            shapes,
            weights,
            requests,
            rate_per_s: mix.rate_for(scale),
            workers: mix.workers_for(scale),
        })
    }

    pub fn issued(&self) -> usize {
        self.requests.len()
    }

    /// How many requests target each shape index (skew diagnostics).
    pub fn shape_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.shapes.len()];
        for r in &self.requests {
            counts[r.shape] += 1;
        }
        counts
    }

    /// Requests carrying a graph chain.
    pub fn graph_count(&self) -> usize {
        self.requests.iter().filter(|r| r.graph.is_some()).count()
    }

    /// Requests pinned to the direct 2-D kernel class.
    pub fn direct2d_count(&self) -> usize {
        self.requests.iter().filter(|r| r.kernel_class == Some(KernelClass::Direct2d)).count()
    }

    /// Requests whose width came from the large-kernel tail.
    pub fn tail_count(&self, mix: &MixConfig) -> usize {
        self.requests
            .iter()
            .filter(|r| r.graph.is_none() && mix.tail_widths.contains(&r.kernel.width))
            .count()
    }

    /// Stable identity of the schedule: same `(mix, scale)` ⇒ same
    /// digest, any drift in the generator changes it. (DefaultHasher
    /// uses fixed keys, so this is stable across processes — the same
    /// property `GraphSpec::digest` already relies on.)
    pub fn digest(&self) -> u64 {
        let mut h = DefaultHasher::new();
        self.scale.hash(&mut h);
        self.seed.hash(&mut h);
        for s in &self.shapes {
            s.hash(&mut h);
        }
        for r in &self.requests {
            r.id.hash(&mut h);
            r.shape.hash(&mut h);
            r.kernel.cache_key().hash(&mut h);
            r.kernel_class.map(|c| c.label()).hash(&mut h);
            match &r.graph {
                Some(stages) => {
                    true.hash(&mut h);
                    stages.len().hash(&mut h);
                    for k in stages {
                        k.cache_key().hash(&mut h);
                    }
                }
                None => false.hash(&mut h),
            }
            r.deadline_ms.hash(&mut h);
            r.arrival_us.hash(&mut h);
        }
        h.finish()
    }

    /// Materialise submittable requests: one synthetic image per shape
    /// (cloned per request — the submission loop must stay cheap so
    /// open-loop pacing is honest), builders applied per the plan.
    pub fn realize(&self, pattern: Pattern) -> Vec<ConvRequest> {
        let images: Vec<PlanarImage> = self
            .shapes
            .iter()
            .enumerate()
            .map(|(i, s)| synth_image(s.planes, s.rows, s.cols, pattern, self.seed + i as u64))
            .collect();
        self.requests
            .iter()
            .map(|p| {
                let mut req = ConvRequest::new(p.id, images[p.shape].clone());
                req = match &p.graph {
                    Some(stages) => req.with_graph(GraphSpec::chain(stages.clone())),
                    None => req.with_kernel(p.kernel),
                };
                if let Some(c) = p.kernel_class {
                    req = req.with_kernel_class(c);
                }
                if p.deadline_ms > 0 {
                    req = req.with_deadline(Duration::from_millis(p.deadline_ms));
                }
                req
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_scale_is_bitwise_identical() {
        let mix = MixConfig::default();
        let a = RequestPlan::generate(&mix, 3).unwrap();
        let b = RequestPlan::generate(&mix, 3).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn different_seeds_differ() {
        let a = RequestPlan::generate(&MixConfig::default(), 2).unwrap();
        let mix_b = MixConfig { seed: 99, ..MixConfig::default() };
        let b = RequestPlan::generate(&mix_b, 2).unwrap();
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn scale_maps_linearly_to_volume_and_rate() {
        let mix = MixConfig::default();
        for scale in [1usize, 2, 5] {
            let plan = RequestPlan::generate(&mix, scale).unwrap();
            assert_eq!(plan.issued(), mix.requests_per_scale * scale);
            assert_eq!(plan.rate_per_s, mix.rate_per_s * scale as f64);
            assert_eq!(plan.workers, (mix.workers_base + scale).min(16));
        }
    }

    #[test]
    fn shape_set_is_stable_across_scales() {
        let mix = MixConfig::default();
        let a = RequestPlan::generate(&mix, 1).unwrap();
        let b = RequestPlan::generate(&mix, 5).unwrap();
        assert_eq!(a.shapes, b.shapes);
        assert_eq!(a.weights, b.weights);
    }

    #[test]
    fn zipf_weights_are_a_distribution() {
        for (n, s) in [(5usize, 1.1), (3, 0.0), (8, 2.5), (1, 1.0)] {
            let w = zipf_weights(n, s);
            assert_eq!(w.len(), n);
            assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12, "n={n} s={s}");
            for pair in w.windows(2) {
                assert!(pair[0] >= pair[1] - 1e-15, "weights must be non-increasing");
            }
        }
    }

    #[test]
    fn arrivals_are_nondecreasing() {
        let plan = RequestPlan::generate(&MixConfig::default(), 2).unwrap();
        for pair in plan.requests.windows(2) {
            assert!(pair[0].arrival_us <= pair[1].arrival_us);
        }
    }

    #[test]
    fn validate_rejects_bad_mixes() {
        let even = MixConfig { widths: vec![4], ..MixConfig::default() };
        assert!(even.validate().is_err());
        let inverted = MixConfig { min_size: 100, max_size: 50, ..MixConfig::default() };
        assert!(inverted.validate().is_err());
        let frac = MixConfig { graph_fraction: 1.5, ..MixConfig::default() };
        assert!(frac.validate().is_err());
        let tail_even = MixConfig { tail_widths: vec![12], ..MixConfig::default() };
        assert!(tail_even.validate().is_err(), "tail widths obey the same odd/size rules");
        let tail_huge = MixConfig { tail_widths: vec![49], ..MixConfig::default() };
        assert!(tail_huge.validate().is_err(), "tail widths must fit the smallest shape");
        let tail_frac = MixConfig { tail_fraction: -0.1, ..MixConfig::default() };
        assert!(tail_frac.validate().is_err());
        let tail_empty =
            MixConfig { tail_widths: vec![], tail_fraction: 0.2, ..MixConfig::default() };
        assert!(tail_empty.validate().is_err(), "a nonzero tail fraction needs tail widths");
        let d2d = MixConfig { direct2d_fraction: 2.0, ..MixConfig::default() };
        assert!(d2d.validate().is_err());
        assert!(RequestPlan::generate(&MixConfig::default(), 0).is_err());
    }

    #[test]
    fn realize_carries_the_plan_onto_requests() {
        let mix = MixConfig { requests_per_scale: 16, ..MixConfig::default() };
        let plan = RequestPlan::generate(&mix, 1).unwrap();
        let reqs = plan.realize(Pattern::Noise);
        assert_eq!(reqs.len(), plan.issued());
        for (req, p) in reqs.iter().zip(&plan.requests) {
            assert_eq!(req.id, p.id);
            let shape = plan.shapes[p.shape];
            assert_eq!(
                (req.image.planes, req.image.rows, req.image.cols),
                (shape.planes, shape.rows, shape.cols)
            );
            match &p.graph {
                Some(stages) => {
                    let g = req.graph.as_ref().expect("graph request");
                    assert_eq!(g.stages.len(), stages.len());
                }
                None => assert_eq!(req.kernel, Some(p.kernel)),
            }
            assert_eq!(req.kernel_class, p.kernel_class, "class pins ride the request");
            assert!(req.deadline.is_some(), "default mix sets deadlines");
        }
    }

    #[test]
    fn default_mix_draws_tail_widths_and_class_pins() {
        let mix = MixConfig::default();
        let plan = RequestPlan::generate(&mix, 4).unwrap();
        let n = plan.issued();
        let tails = plan.tail_count(&mix);
        let pins = plan.direct2d_count();
        assert!(tails > 0 && tails < n / 2, "tail draws present but a minority ({tails}/{n})");
        assert!(pins > 0 && pins < n / 2, "class pins present but a minority ({pins}/{n})");
        for r in &plan.requests {
            assert!(
                r.kernel_class.is_none() || r.graph.is_none(),
                "graph requests never pin a kernel class"
            );
        }
        // the new dimensions are part of the schedule's identity
        let flat = MixConfig { tail_fraction: 0.0, direct2d_fraction: 0.0, ..mix.clone() };
        let flat_plan = RequestPlan::generate(&flat, 4).unwrap();
        assert_eq!(flat_plan.direct2d_count(), 0);
        assert_eq!(flat_plan.tail_count(&flat), 0);
        assert_ne!(plan.digest(), flat_plan.digest());
    }
}
