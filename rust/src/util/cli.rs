//! Tiny declarative CLI argument parser (in-tree replacement for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and auto-generated `--help`. Each binary declares its options up front;
//! unknown options are hard errors so typos never silently fall through.

use std::collections::BTreeMap;

use crate::util::error::Result;

/// Declared option.
#[derive(Debug, Clone)]
struct Opt {
    name: &'static str,
    takes_value: bool,
    default: Option<String>,
    help: &'static str,
}

/// Builder + parsed result.
#[derive(Debug, Clone)]
pub struct Cli {
    bin: &'static str,
    about: &'static str,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

impl Cli {
    pub fn new(bin: &'static str, about: &'static str) -> Self {
        Self {
            bin,
            about,
            opts: vec![],
            values: BTreeMap::new(),
            flags: BTreeMap::new(),
            positionals: vec![],
        }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            takes_value: true,
            default: Some(default.to_string()),
            help,
        });
        self
    }

    /// Declare `--name <value>` with no default (optional).
    pub fn opt_req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, takes_value: true, default: None, help });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, takes_value: false, default: None, help });
        self
    }

    fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} [options] [args…]\n\nOPTIONS:\n", self.bin, self.about, self.bin);
        for o in &self.opts {
            let left = if o.takes_value {
                format!("--{} <v>", o.name)
            } else {
                format!("--{}", o.name)
            };
            let dflt = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {left:<22} {}{dflt}\n", o.help));
        }
        s.push_str("  --help                 print this help\n");
        s
    }

    /// Parse; on `--help` prints usage and exits the process.
    pub fn parse(self, args: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut me = self;
        for o in &me.opts {
            if let Some(d) = &o.default {
                me.values.insert(o.name.to_string(), d.clone());
            }
            if !o.takes_value {
                me.flags.insert(o.name.to_string(), false);
            }
        }
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                print!("{}", me.usage());
                std::process::exit(0);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let opt = me
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| err!("unknown option --{name}\n\n{}", me.usage()))?
                    .clone();
                if opt.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| err!("--{name} needs a value"))?,
                    };
                    me.values.insert(name, v);
                } else {
                    if inline.is_some() {
                        bail!("--{name} does not take a value");
                    }
                    me.flags.insert(name, true);
                }
            } else {
                me.positionals.push(a);
            }
        }
        Ok(me)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str_of(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| err!("missing required option --{name}"))
    }

    pub fn usize_of(&self, name: &str) -> Result<usize> {
        Ok(self.str_of(name)?.parse()?)
    }

    pub fn f64_of(&self, name: &str) -> Result<f64> {
        Ok(self.str_of(name)?.parse()?)
    }

    /// Comma-separated usize list, e.g. `--sizes 1152,1728`.
    pub fn usize_list_of(&self, name: &str) -> Result<Vec<usize>> {
        self.str_of(name)?
            .split(',')
            .map(|s| Ok(s.trim().parse()?))
            .collect()
    }

    pub fn is_set(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    fn base() -> Cli {
        Cli::new("t", "test")
            .opt("size", "288", "image size")
            .opt_req("name", "artifact name")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults_and_overrides() {
        let c = base().parse(args(&["--name", "x"])).unwrap();
        assert_eq!(c.usize_of("size").unwrap(), 288);
        assert_eq!(c.str_of("name").unwrap(), "x");
        assert!(!c.is_set("verbose"));

        let c = base()
            .parse(args(&["--size=512", "--name", "y", "--verbose"]))
            .unwrap();
        assert_eq!(c.usize_of("size").unwrap(), 512);
        assert!(c.is_set("verbose"));
    }

    #[test]
    fn positionals_collected() {
        let c = base().parse(args(&["serve", "--name", "x", "extra"])).unwrap();
        assert_eq!(c.positionals(), &["serve".to_string(), "extra".to_string()]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(base().parse(args(&["--bogus"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(base().parse(args(&["--size"])).is_err());
    }

    #[test]
    fn missing_required_is_error_on_access() {
        let c = base().parse(args(&[])).unwrap();
        assert!(c.str_of("name").is_err());
    }

    #[test]
    fn usize_list() {
        let c = Cli::new("t", "t")
            .opt("sizes", "1,2,3", "list")
            .parse(args(&["--sizes", "10, 20,30"]))
            .unwrap();
        assert_eq!(c.usize_list_of("sizes").unwrap(), vec![10, 20, 30]);
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(base().parse(args(&["--verbose=yes", "--name", "x"])).is_err());
    }
}
