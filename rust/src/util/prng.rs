//! Deterministic PRNG: splitmix64 seeding + xoshiro256** core.
//!
//! In-tree replacement for `rand` (offline build). Drives the synthetic
//! image generators, the request workloads and the property-test case
//! generators, so every run is reproducible from a single u64 seed.

/// xoshiro256** (Blackman & Vigna), seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Approximately standard-normal f32 (sum of 12 uniforms − 6; exact
    /// distribution does not matter for convolution workloads).
    pub fn normal(&mut self) -> f32 {
        let mut s = 0.0f32;
        for _ in 0..12 {
            s += self.f32();
        }
        s - 6.0
    }

    /// Uniform usize in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free modulo is fine here: n ≪ 2^64 so bias < 2^-40.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform usize in [lo, hi].
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<u64> = (0..8).map({
            let mut p = Prng::new(7);
            move |_| p.next_u64()
        }).collect();
        let b: Vec<u64> = (0..8).map({
            let mut p = Prng::new(7);
            move |_| p.next_u64()
        }).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn seeds_diverge() {
        let mut p1 = Prng::new(1);
        let mut p2 = Prng::new(2);
        assert_ne!(p1.next_u64(), p2.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut p = Prng::new(42);
        for _ in 0..10_000 {
            let x = p.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut p = Prng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| p.f32() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_mean_and_var() {
        let mut p = Prng::new(4);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_covers_range() {
        let mut p = Prng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[p.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(6);
        let mut v: Vec<usize> = (0..100).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
