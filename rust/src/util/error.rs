//! In-tree error substrate (replaces `anyhow` — offline build).
//!
//! The build environment provides no crates.io access (DESIGN.md §1), so
//! the error-handling conveniences the rest of the crate leans on are
//! implemented here from scratch, in the same spirit as the in-tree
//! [`crate::util::json`] / [`crate::util::toml`] / [`crate::util::cli`]
//! substrates:
//!
//! * [`Error`] — an opaque, `Send + Sync` error value holding a chain of
//!   human-readable context frames (outermost first, root cause last)
//!   plus a machine-matchable [`ErrorKind`] for the serving taxonomy;
//! * [`Result`] — the crate-wide alias `Result<T, Error>`;
//! * [`Context`] — `.context(..)` / `.with_context(|| ..)` on both
//!   `Result` and `Option`, pushing a new outer frame;
//! * [`crate::err!`], [`crate::bail!`], [`crate::ensure!`] — formatted
//!   construction / early-return / assertion macros.
//!
//! Any `E: std::error::Error + Send + Sync + 'static` converts into
//! [`Error`] via `?` (the source chain is flattened into frames), so
//! `std` errors — I/O, UTF-8, parse — thread through unchanged call
//! sites. Like `anyhow::Error`, [`Error`] deliberately does **not**
//! implement `std::error::Error` itself: that keeps the blanket `From`
//! conversion coherent.
//!
//! Display: `{e}` prints the outermost frame only; `{e:#}` prints the
//! whole chain separated by `": "` (the CLI's error format).

use std::fmt;

/// Machine-matchable classification of an [`Error`].
///
/// Most errors are [`ErrorKind::Other`] — a human-readable chain is all
/// a CLI or test needs. The serving path (the coordinator's admission
/// queue) additionally needs callers to *dispatch* on why a request was
/// refused — retry on `QueueFull`, give up on `DeadlineExceeded`, stop
/// on `Shutdown` — which string matching cannot do robustly. Context
/// frames added with `.context(..)` preserve the kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
#[non_exhaustive]
pub enum ErrorKind {
    /// Anything without a more specific classification.
    #[default]
    Other,
    /// The admission queue was at capacity and the request was shed.
    QueueFull,
    /// The request's deadline lapsed (at admission or while queued).
    DeadlineExceeded,
    /// The service is shutting down (or already shut down).
    Shutdown,
    /// A kernel specification was structurally invalid: even or zero
    /// extents, a tap count that disagrees with them, or non-finite
    /// taps. Every kernel entry point (CLI config validation,
    /// coordinator intake, graph stage validation, plan building)
    /// refuses with this kind so callers can dispatch on it.
    InvalidKernel,
}

/// An error: a non-empty chain of context frames, outermost first.
pub struct Error {
    chain: Vec<String>,
    kind: ErrorKind,
}

impl Error {
    /// Build from a single printable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { chain: vec![m.to_string()], kind: ErrorKind::Other }
    }

    /// Build with an explicit [`ErrorKind`] (the serving taxonomy).
    pub fn with_kind(kind: ErrorKind, m: impl fmt::Display) -> Self {
        Self { chain: vec![m.to_string()], kind }
    }

    /// Wrap with an outer context frame (what `.context(..)` does).
    /// The kind is preserved.
    pub fn context(mut self, c: impl fmt::Display) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The machine-matchable classification.
    pub fn kind(&self) -> ErrorKind {
        self.kind
    }

    /// The outermost message.
    pub fn message(&self) -> &str {
        &self.chain[0]
    }

    /// The root cause (innermost frame).
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("chain is non-empty")
    }

    /// All frames, outermost first.
    pub fn frames(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{e:#}`: the full chain, anyhow-style
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.chain[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

/// Every standard error converts via `?`, with its `source()` chain
/// flattened into frames.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain, kind: ErrorKind::Other }
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(|| ..)` on `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or missing value) with an outer context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;

    /// Like [`Context::context`], but the message is built lazily —
    /// use when formatting it is not free.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| {
            let e: Error = e.into();
            e.context(ctx)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let e: Error = e.into();
            e.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string: `err!("bad {x}")`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`]: `bail!("bad {x}")`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Bail unless a condition holds: `ensure!(x > 0, "x must be positive")`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e = Error::msg("root").context("middle").context("top");
        assert_eq!(format!("{e}"), "top");
        assert_eq!(format!("{e:#}"), "top: middle: root");
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("root").context("top");
        let d = format!("{e:?}");
        assert!(d.starts_with("top"));
        assert!(d.contains("Caused by:"));
        assert!(d.contains("root"));
    }

    #[test]
    fn std_errors_convert_via_question_mark() {
        fn f() -> Result<()> {
            let _: usize = "nope".parse()?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(format!("{e}").contains("invalid digit"));
    }

    #[test]
    fn context_on_result() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening manifest").unwrap_err();
        assert_eq!(format!("{e}"), "opening manifest");
        assert!(format!("{e:#}").contains("no such file"));
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u32, std::io::Error> = Ok(7);
        let v = ok
            .with_context(|| -> String { panic!("must not be evaluated on Ok") })
            .unwrap();
        assert_eq!(v, 7);
    }

    #[test]
    fn context_on_option() {
        let some = Some(3).context("missing").unwrap();
        assert_eq!(some, 3);
        let e = None::<u32>.with_context(|| format!("field {:?} absent", "x")).unwrap_err();
        assert_eq!(format!("{e}"), "field \"x\" absent");
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(v: usize) -> Result<usize> {
            ensure!(v < 10, "v too big: {v}");
            if v == 0 {
                bail!("v must be nonzero");
            }
            Ok(v)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(0).unwrap_err()), "v must be nonzero");
        assert_eq!(format!("{}", f(11).unwrap_err()), "v too big: 11");
    }

    #[test]
    fn ensure_without_message_names_the_condition() {
        fn f(v: usize) -> Result<()> {
            ensure!(v % 2 == 0);
            Ok(())
        }
        assert!(f(2).is_ok());
        assert!(format!("{}", f(3).unwrap_err()).contains("v % 2 == 0"));
    }

    #[test]
    fn source_chain_flattens_into_frames() {
        #[derive(Debug)]
        struct Outer(std::io::Error);
        impl fmt::Display for Outer {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "outer failed")
            }
        }
        impl std::error::Error for Outer {
            fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
                Some(&self.0)
            }
        }
        let e: Error = Outer(io_err()).into();
        assert_eq!(e.frames().len(), 2);
        assert_eq!(e.message(), "outer failed");
        assert_eq!(e.root_cause(), "no such file");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }

    #[test]
    fn kinds_default_to_other() {
        assert_eq!(Error::msg("x").kind(), ErrorKind::Other);
        let e: Error = io_err().into();
        assert_eq!(e.kind(), ErrorKind::Other);
        assert_eq!(err!("formatted {}", 1).kind(), ErrorKind::Other);
    }

    #[test]
    fn kind_survives_context_frames() {
        let e = Error::with_kind(ErrorKind::QueueFull, "queue full (capacity 8)");
        assert_eq!(e.kind(), ErrorKind::QueueFull);
        let wrapped = e.context("submitting request 42");
        assert_eq!(wrapped.kind(), ErrorKind::QueueFull);
        assert_eq!(format!("{wrapped:#}"), "submitting request 42: queue full (capacity 8)");

        // and through the Context trait on Result
        let r: Result<()> = Err(Error::with_kind(ErrorKind::Shutdown, "shut down"));
        assert_eq!(r.context("outer").unwrap_err().kind(), ErrorKind::Shutdown);
    }
}
