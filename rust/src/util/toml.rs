//! Pragmatic TOML-subset parser for run configuration files.
//!
//! In-tree replacement for the `toml` crate (offline build). Supports the
//! subset the config system uses: `[section]` / `[a.b]` headers, `key =
//! value` with string / integer / float / bool / homogeneous-scalar-array
//! values, `#` comments and blank lines. Keys flatten to dotted paths
//! (`section.key`).

use std::collections::BTreeMap;

use crate::util::error::{Context, Result};

/// A TOML scalar or scalar array.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().filter(|i| *i >= 0).map(|i| i as usize)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize_arr(&self) -> Option<Vec<usize>> {
        match self {
            TomlValue::Arr(v) => v.iter().map(|x| x.as_usize()).collect(),
            _ => None,
        }
    }
}

/// Flat dotted-key map of a parsed document.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section header", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let v = parse_value(value.trim())
                .with_context(|| format!("line {}: bad value for {full:?}", lineno + 1))?;
            if entries.insert(full.clone(), v).is_some() {
                bail!("line {}: duplicate key {full:?}", lineno + 1);
            }
        }
        Ok(Self { entries })
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .with_context(|| "unterminated string".to_string())?;
        // Minimal escapes (the config never needs more).
        let un = inner.replace("\\\"", "\"").replace("\\\\", "\\").replace("\\n", "\n");
        return Ok(TomlValue::Str(un));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .with_context(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items = inner
            .split(',')
            .map(|x| parse_value(x.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(TomlValue::Arr(items));
    }
    let clean = s.replace('_', "");
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# run configuration
title = "phi-conv"

[workload]
sizes = [1152, 1728, 2592]
planes = 3
reps = 10
scale = 0.5
verbose = true

[models.gprm]
cutoff = 100          # paper's magic number
steal = true
"#;

    #[test]
    fn parses_document() {
        let d = TomlDoc::parse(DOC).unwrap();
        assert_eq!(d.str_or("title", ""), "phi-conv");
        assert_eq!(d.usize_or("workload.planes", 0), 3);
        assert_eq!(d.usize_or("models.gprm.cutoff", 0), 100);
        assert!((d.f64_or("workload.scale", 0.0) - 0.5).abs() < 1e-12);
        assert!(d.bool_or("workload.verbose", false));
        assert_eq!(
            d.get("workload.sizes").unwrap().as_usize_arr().unwrap(),
            vec![1152, 1728, 2592]
        );
    }

    #[test]
    fn defaults_apply() {
        let d = TomlDoc::parse("").unwrap();
        assert_eq!(d.usize_or("nope", 9), 9);
        assert_eq!(d.str_or("nope", "x"), "x");
    }

    #[test]
    fn comments_inside_strings_kept() {
        let d = TomlDoc::parse("k = \"a # not comment\"").unwrap();
        assert_eq!(d.str_or("k", ""), "a # not comment");
    }

    #[test]
    fn int_vs_float() {
        let d = TomlDoc::parse("a = 3\nb = 3.5\nc = 1_000").unwrap();
        assert_eq!(d.get("a").unwrap().as_i64(), Some(3));
        assert_eq!(d.get("b").unwrap().as_i64(), None);
        assert!((d.get("b").unwrap().as_f64().unwrap() - 3.5).abs() < 1e-12);
        assert_eq!(d.get("c").unwrap().as_i64(), Some(1000));
        // ints coerce to f64 on demand
        assert_eq!(d.get("a").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = \"open").is_err());
        assert!(TomlDoc::parse("k = 1\nk = 2").is_err());
    }
}
