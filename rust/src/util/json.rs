//! Minimal strict JSON parser (RFC 8259 subset sufficient for the AOT
//! manifest: objects, arrays, strings with escapes, numbers, bools, null).
//!
//! Written in-tree because the offline build environment has no
//! `serde_json`. The parser is recursive-descent over bytes, rejects
//! trailing garbage, and reports byte offsets in errors.

use std::collections::BTreeMap;
use std::fmt;

use crate::util::error::Result;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for missing keys keeps call sites
    /// terse (`m.get("x").as_usize()` patterns).
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Required-field helpers with contextual errors.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| err!("field {key:?} missing or not a string"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| err!("field {key:?} missing or not an unsigned integer"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| err!("field {key:?} missing or not a number"))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.get(key)
            .as_arr()
            .ok_or_else(|| err!("field {key:?} missing or not an array"))
    }

    pub fn req_bool(&self, key: &str) -> Result<bool> {
        self.get(key)
            .as_bool()
            .ok_or_else(|| err!("field {key:?} missing or not a boolean"))
    }
}

impl fmt::Display for Json {
    /// Compact serialisation (used by metrics dumps and tests).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if !n.is_finite() {
                    // RFC 8259 has no NaN/Infinity token; emitting the
                    // Rust Display forms ("NaN", "inf") would produce
                    // output this parser itself rejects
                    write!(f, "null")
                } else if n.fract() == 0.0
                    && n.abs() < 1e15
                    && !(*n == 0.0 && n.is_sign_negative())
                {
                    // -0.0 must skip this fast path: `-0.0 as i64` is 0,
                    // which parses back as +0.0 and breaks the bit-exact
                    // round-trip the cost-model artifacts rely on. Rust's
                    // f64 Display prints "-0", which `parse::<f64>()`
                    // restores with the sign bit intact.
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", c as char, self.i)
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("unexpected byte {:?} at {}", c as char, self.i),
            None => bail!("unexpected end of input"),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            // Surrogate pairs are not needed by the
                            // manifest; reject rather than mis-decode.
                            let c = char::from_u32(cp)
                                .ok_or_else(|| err!("invalid \\u{hex}"))?;
                            s.push(c);
                            self.i += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn nested_structure() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c"));
        assert_eq!(v.get("d"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → ∑\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → ∑"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"arr":[1,2.5,"x"],"n":null,"t":true}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // the parser rejects "NaN"/"inf"; the serializer must never
        // produce them (empty SampleSet summaries used to)
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let out = Json::Arr(vec![Json::Num(v)]).to_string();
            assert_eq!(out, "[null]");
            assert!(Json::parse(&out).is_ok());
        }
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 7, "s": "x", "f": 1.5, "b": true}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 7);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert!((v.req_f64("f").unwrap() - 1.5).abs() < 1e-12);
        assert!(v.req_bool("b").unwrap());
        assert_eq!(v.get("b").as_bool(), Some(true));
        assert!(v.req_usize("f").is_err()); // fractional
        assert!(v.req_str("n").is_err()); // wrong type
        assert!(v.req_arr("missing").is_err());
        assert!(v.req_bool("n").is_err()); // wrong type
    }

    /// Bitwise emit→parse round-trip of one finite f64.
    fn roundtrip_bits(v: f64) {
        let text = Json::Num(v).to_string();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("reparse of {text:?} (from {v:e}): {e}"))
            .as_f64()
            .unwrap();
        assert_eq!(
            back.to_bits(),
            v.to_bits(),
            "round-trip changed {v:e} (emitted {text:?}) to {back:e}"
        );
    }

    #[test]
    fn negative_zero_roundtrips_bitwise() {
        // regression: the integer fast path printed "-0.0 as i64" = "0",
        // silently flipping the sign bit on reload
        assert_eq!(Json::Num(-0.0).to_string(), "-0");
        roundtrip_bits(-0.0);
        roundtrip_bits(0.0);
    }

    #[test]
    fn special_values_roundtrip_bitwise() {
        for v in [
            f64::MIN,
            f64::MAX,
            f64::MIN_POSITIVE,
            5e-324,             // smallest subnormal
            1e15,               // integer fast-path boundary
            1e15 - 1.0,         // last value inside the fast path
            9007199254740992.0, // 2^53
            0.1,
            1.0 / 3.0,
            -2.5e-6,
            123456789.123456,
        ] {
            roundtrip_bits(v);
            roundtrip_bits(-v);
        }
    }

    #[test]
    fn random_finite_f64_roundtrip_property() {
        // Rust's f64 Display is shortest-round-trip, so every finite
        // value the serializer emits must reparse to identical bits —
        // the cost model's save→load bitwise-prediction guarantee rests
        // on this. Drive it with PRNG bit patterns across the full
        // exponent range.
        let mut prng = crate::util::prng::Prng::new(0x5eed_c0de);
        let mut checked = 0usize;
        while checked < 4000 {
            let v = f64::from_bits(prng.next_u64());
            if !v.is_finite() {
                continue; // non-finite serializes as null by design
            }
            roundtrip_bits(v);
            checked += 1;
        }
    }
}
