//! In-tree utility substrates.
//!
//! The build environment is fully offline (only the `xla` crate's vendored
//! closure is available), so the small infrastructure crates a project
//! would normally pull from crates.io are implemented here from scratch:
//!
//! * [`error`] — context-chaining error type + `Result` alias + the
//!   `err!`/`bail!`/`ensure!` macros (replaces `anyhow`).
//! * [`json`]  — a strict recursive-descent JSON parser + value model
//!   (replaces `serde_json`; parses the AOT manifest).
//! * [`toml`]  — a pragmatic TOML-subset parser (replaces `toml`; parses
//!   run configuration files).
//! * [`cli`]   — declarative-ish argument parsing (replaces `clap`).
//! * [`prng`]  — a splitmix64/xoshiro256** PRNG (replaces `rand`; drives
//!   synthetic images and the property-test generators).

// `error` must be first and `#[macro_use]`: its `macro_rules!`
// definitions are textually scoped, and every later module uses
// `bail!`/`ensure!` unqualified.
#[macro_use]
pub mod error;

pub mod cli;
pub mod json;
pub mod prng;
pub mod toml;
