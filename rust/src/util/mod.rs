//! In-tree utility substrates.
//!
//! The build environment is fully offline (only the `xla` crate's vendored
//! closure is available), so the small infrastructure crates a project
//! would normally pull from crates.io are implemented here from scratch:
//!
//! * [`json`]  — a strict recursive-descent JSON parser + value model
//!   (replaces `serde_json`; parses the AOT manifest).
//! * [`toml`]  — a pragmatic TOML-subset parser (replaces `toml`; parses
//!   run configuration files).
//! * [`cli`]   — declarative-ish argument parsing (replaces `clap`).
//! * [`prng`]  — a splitmix64/xoshiro256** PRNG (replaces `rand`; drives
//!   synthetic images and the property-test generators).

pub mod cli;
pub mod json;
pub mod prng;
pub mod toml;
